//! Drive a full route under every scheduler and report the driving-safety
//! metrics of §8.4: per-scheduler STMRate and the Fig. 14 braking probe
//! (the vehicle brakes for an obstacle seen 250 m ahead after `--brake-at`
//! meters; the braking distance follows from the probe task's wait +
//! compute + scheduler latency + CAN + mechanical lag).
//!
//!     cargo run --release --example drive_route -- --dist 400 \
//!         [--ckpt checkpoints/flexai_ub.json] [--area ub] [--seed 42]

use hmai::config::ExperimentConfig;
use hmai::harness;
use hmai::safety::braking::{braking_distance_m, stops_within, BrakingBreakdown};
use hmai::sim::{SimOptions, SimResult};
use hmai::util::cli::Args;
use hmai::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = ExperimentConfig::default();
    cfg.env.distances_m = vec![400.0];
    cfg.apply_args(&args)?;
    cfg.env.distances_m.truncate(1);
    let brake_at = args.get_f64("brake-at", cfg.env.distances_m[0] * 0.5)?;
    let sensing_m = 250.0; // forward camera max distance (§6.1)

    let platform = cfg.platform()?;
    let queues = harness::make_queues(&cfg.env);
    let v = cfg.env.area.max_velocity_ms();
    println!(
        "route: {:.0} m ({}), {} tasks; brake event at {brake_at:.0} m, v = {v:.1} m/s",
        cfg.env.distances_m[0],
        cfg.env.area.name(),
        queues[0].len()
    );

    let mut table = Table::new([
        "Scheduler", "STMRate", "T_wait (ms)", "T_sched (ms)", "T_compute (ms)",
        "Braking dist (m)", "Safe (<250 m)",
    ]);

    let mut probe = |name: &str, r: &SimResult| {
        let t_probe = brake_at / v;
        let rec = r
            .records
            .iter()
            .filter(|t| t.release_s >= t_probe && !t.model.is_tracker())
            .min_by(|a, b| a.release_s.total_cmp(&b.release_s))
            .expect("route long enough for probe");
        let bd = BrakingBreakdown::new(rec.wait_s, r.sched_per_task_s(), rec.compute_s);
        let dist = braking_distance_m(v, &bd);
        table.row([
            name.to_string(),
            pct(r.summary.stm_rate()),
            f2(bd.t_wait * 1e3),
            f2(bd.t_schedule * 1e3),
            f2(bd.t_compute * 1e3),
            f2(dist),
            if stops_within(v, &bd, sensing_m) { "yes".into() } else { "NO".into() },
        ]);
    };

    // FlexAI (checkpoint if given, fresh otherwise) ...
    {
        let mut cfg_f = cfg.clone();
        cfg_f.scheduler = "flexai".into();
        let mut s = harness::make_scheduler(&cfg_f)?;
        let r = harness::run_queues(&queues, &platform, s.as_mut(), SimOptions {
            record_tasks: true,
        })
        .remove(0);
        probe("FlexAI", &r);
    }
    // ... vs every baseline.
    for name in hmai::sched::BASELINES {
        let mut s = hmai::sched::by_name(name, cfg.env.seed).expect("baseline");
        let r = harness::run_queues(&queues, &platform, s.as_mut(), SimOptions {
            record_tasks: true,
        })
        .remove(0);
        probe(&s.name(), &r);
    }
    table.print();
    Ok(())
}
