//! Drive a full route under every scheduler and report the driving-safety
//! metrics of §8.4: per-scheduler STMRate and the Fig. 14 braking probe
//! (the vehicle brakes for an obstacle seen 250 m ahead after `--brake-at`
//! meters; the braking distance follows from the probe task's wait +
//! compute + scheduler latency + CAN + mechanical lag).
//!
//! The whole comparison is one `ExperimentPlan` (FlexAI + the Fig. 12
//! baselines) executed by the `Engine` — pass `--jobs N` to run the
//! schedulers' probe trials in parallel.  `--scenario <name>` drives the
//! route through a scenario-library archetype (`env::scenario`: e.g.
//! night-rain's degraded camera rates or sensor-dropout's mid-route
//! camera blackout) instead of the plain `--area` route, and `--events`
//! applies the archetype's platform events (try
//! `--scenario accel-failure --events` to watch braking distances move
//! when an accelerator dies mid-route).
//!
//!     cargo run --release --example drive_route -- --dist 400 \
//!         [--ckpt checkpoints/flexai_ub.json] [--area ub | --scenario night-rain] \
//!         [--events] [--seed 42] [--jobs 4]

// Examples narrate on stderr when artifacts are missing (deny carve-out).
#![allow(clippy::print_stderr)]

use hmai::config::ExperimentConfig;
use hmai::engine::{Engine, TrialResult};
use hmai::harness;
use hmai::safety::braking::{braking_distance_m, stops_within, BrakingBreakdown};
use hmai::sched::{baseline_specs, SchedulerSpec};
use hmai::sim::SimOptions;
use hmai::util::cli::Args;
use hmai::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = ExperimentConfig::default();
    cfg.env.distances_m = vec![400.0];
    cfg.apply_args(&args)?;
    cfg.env.distances_m.truncate(1);
    let brake_at = args.get_f64("brake-at", cfg.env.distances_m[0] * 0.5)?;
    let sensing_m = 250.0; // forward camera max distance (§6.1)

    let mut schedulers = Vec::new();
    match harness::load_runtime() {
        Ok(_) => schedulers.push(SchedulerSpec::FlexAI {
            checkpoint: (!cfg.checkpoint.is_empty()).then(|| cfg.checkpoint.clone()),
        }),
        Err(e) => eprintln!("note: FlexAI skipped ({e:#})"),
    }
    schedulers.extend(baseline_specs());

    let plan = cfg.plan()?.schedulers(schedulers);
    let registry = harness::registry(&cfg);
    let results = Engine::new(&registry)
        .jobs(cfg.jobs)
        .events(cfg.events)
        .sim_options(SimOptions { record_tasks: true })
        .run(&plan)?;

    println!(
        "route: {:.0} m, {} tasks; brake event at {brake_at:.0} m",
        cfg.env.distances_m[0],
        results[0].summary.tasks
    );

    let mut table = Table::new([
        "Scheduler", "Scenario", "STMRate", "T_wait (ms)", "T_sched (ms)", "T_compute (ms)",
        "Braking dist (m)", "Safe (<250 m)",
    ]);
    for r in &results {
        // Map the brake point to the trial's own clock: a library
        // archetype walks its legs at their own speeds, so the probe
        // lands in the correct leg of a composite route.
        let (t_probe, area) = match &r.trial.scenario.archetype {
            Some(arch) => arch.at_distance(r.trial.scenario.distance_m, brake_at),
            None => {
                let area = r.trial.scenario.area;
                (brake_at / area.max_velocity_ms(), area)
            }
        };
        let v = area.max_velocity_ms();
        let rec = probe(r, t_probe);
        let bd = BrakingBreakdown::new(rec.wait_s, r.sched_per_task_s(), rec.compute_s);
        let dist = braking_distance_m(v, &bd);
        table.row([
            r.summary.scheduler.clone(),
            r.trial.scenario.scenario_name(),
            pct(r.summary.stm_rate()),
            f2(bd.t_wait * 1e3),
            f2(bd.t_schedule * 1e3),
            f2(bd.t_compute * 1e3),
            f2(dist),
            if stops_within(v, &bd, sensing_m) { "yes".into() } else { "NO".into() },
        ]);
    }
    table.print();
    Ok(())
}

/// First forward-camera detection task released at or after `t_probe`.
fn probe(r: &TrialResult, t_probe: f64) -> &hmai::sim::TaskRecord {
    hmai::sim::first_detection_after(&r.records, t_probe).expect("route long enough for probe")
}
