//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds the paper's HMAI platform (4 SconvOD, 4 SconvIC, 3 MconvMC),
//! generates a short urban driving route's task queue, schedules it with a
//! heuristic baseline and with FlexAI (fresh DQN parameters through the
//! AOT-compiled PJRT path), and prints the §6 metrics side by side.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use hmai::config::EnvConfig;
use hmai::env::Area;
use hmai::harness;
use hmai::platform::Platform;
use hmai::runtime::Runtime;
use hmai::sched::flexai::{FlexAI, FlexAIConfig};
use hmai::sched::minmin::MinMin;
use hmai::sched::Scheduler;
use hmai::sim::{simulate, SimOptions};
use hmai::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    // 1. The platform: the paper's HMAI configuration (§8.2).
    let platform = Platform::hmai();
    println!(
        "platform: {} ({} sub-accelerators, {:.1} peak TOPS)",
        platform.name,
        platform.len(),
        platform.peak_tops()
    );

    // 2. The environment: a 150 m urban route → one task queue (Fig. 9).
    let env = EnvConfig { area: Area::Urban, distances_m: vec![150.0], seed: 7 };
    let queue = harness::make_queues(&env).remove(0);
    println!(
        "queue: {} tasks over {:.1} s ({:.0} tasks/s)",
        queue.len(),
        queue.route_duration_s,
        queue.len() as f64 / queue.route_duration_s
    );

    // 3. Schedulers: Min-Min heuristic vs FlexAI (untrained Q-network —
    //    run `cargo run --release --example train_flexai` for the real
    //    agent; the deadline shield already makes the fresh agent safe).
    let rt = Arc::new(Runtime::load_default()?);
    let mut flexai = FlexAI::new(rt, FlexAIConfig { seed: 7, ..Default::default() })?;
    flexai.set_training(false);
    let mut minmin = MinMin::new();

    let mut table = Table::new([
        "Scheduler", "STMRate", "Wait (s)", "Energy (J)", "R_Balance", "MS/task", "Gvalue",
    ]);
    for sched in [&mut minmin as &mut dyn Scheduler, &mut flexai] {
        let r = simulate(&queue, &platform, sched, SimOptions::default());
        let s = &r.summary;
        table.row([
            s.scheduler.clone(),
            pct(s.stm_rate()),
            f2(s.wait_s),
            f2(s.energy_j),
            f2(s.r_balance),
            f2(s.ms_per_task()),
            f2(s.gvalue),
        ]);
    }
    table.print();
    Ok(())
}
