//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds an `ExperimentPlan` — the paper's HMAI platform (4 SconvOD,
//! 4 SconvIC, 3 MconvMC), a short urban route's task queue, and two
//! schedulers (Min-Min heuristic vs FlexAI through the AOT-compiled PJRT
//! path) — and executes it on the `Engine`, printing the §6 metrics side
//! by side.  Without `make artifacts` the FlexAI rows are skipped.
//!
//! Beyond the single urban route shown here, `plan.scenarios([...])`
//! sweeps the scenario-variability library (`env::scenario`) — see
//! `--example scenario_tour` for the full archetype catalogue.
//!
//!     make artifacts && cargo run --release --example quickstart

// Examples narrate on stderr when artifacts are missing (deny carve-out).
#![allow(clippy::print_stderr)]

use hmai::config::ExperimentConfig;
use hmai::engine::Engine;
use hmai::env::Area;
use hmai::harness;
use hmai::plan::ExperimentPlan;
use hmai::platform::Platform;
use hmai::sched::SchedulerSpec;
use hmai::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    // 1. The platform: the paper's HMAI configuration (§8.2).
    let platform = Platform::hmai();
    println!(
        "platform: {} ({} sub-accelerators, {:.1} peak TOPS)",
        platform.name,
        platform.len(),
        platform.peak_tops()
    );

    // 2. The plan: a 150 m urban route → one task queue (Fig. 9), swept by
    //    Min-Min and — when the PJRT artifacts are present — FlexAI with
    //    fresh Q-network parameters (run `--example train_flexai` for the
    //    real agent; the deadline shield already makes the fresh agent safe).
    let mut schedulers = vec![SchedulerSpec::MinMin];
    match harness::load_runtime() {
        Ok(_) => schedulers.push(SchedulerSpec::FlexAI { checkpoint: None }),
        Err(e) => eprintln!("note: FlexAI skipped ({e:#})"),
    }
    let plan = ExperimentPlan::new()
        .area(Area::Urban)
        .distances([150.0])
        .schedulers(schedulers)
        .seed(7);

    // 3. The engine: registry = baselines + FlexAI factory; one worker per
    //    scheduler is plenty here.
    let registry = harness::registry(&ExperimentConfig::default());
    let results = Engine::new(&registry).jobs(2).run(&plan)?;

    let q = plan.trials()?[0].queue();
    println!(
        "queue: {} tasks over {:.1} s ({:.0} tasks/s)",
        q.len(),
        q.route_duration_s,
        q.len() as f64 / q.route_duration_s
    );

    let mut table = Table::new([
        "Scheduler", "STMRate", "Wait (s)", "Energy (J)", "R_Balance", "MS/task", "Gvalue",
    ]);
    for r in &results {
        let s = &r.summary;
        table.row([
            s.scheduler.clone(),
            pct(s.stm_rate()),
            f2(s.wait_s),
            f2(s.energy_j),
            f2(s.r_balance),
            f2(s.ms_per_task()),
            f2(s.gvalue),
        ]);
    }
    table.print();
    Ok(())
}
