//! Design-space exploration over HMAI configurations (§3.1 / §8.2): sweep
//! (SconvOD, SconvIC, MconvMC) counts, keep the configurations that meet
//! every scenario's FPS requirements in the chosen area, and print the
//! utilization/power frontier.  This regenerates the argument for the
//! paper's (4, 4, 3) pick: it is the smallest configuration whose
//! geometric-mean utilization beats every homogeneous alternative.
//!
//!     cargo run --release --example platform_explorer -- --area ub \
//!         [--max-units 14]

use hmai::env::{Area, ALL_SCENARIOS};
use hmai::platform::alloc;
use hmai::util::cli::Args;
use hmai::util::stats::geomean;
use hmai::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let area = Area::parse(args.get_or("area", "ub")).expect("--area: ub|uhw|hw");
    let max_units = args.get_usize("max-units", 14)?;

    struct Row {
        counts: (usize, usize, usize),
        util_gm: f64,
        power_gm: f64,
    }
    let mut rows: Vec<Row> = Vec::new();

    for so in 0..=max_units {
        for si in 0..=max_units.saturating_sub(so) {
            for mm in 0..=max_units.saturating_sub(so + si) {
                let counts = (so, si, mm);
                if so + si + mm == 0 {
                    continue;
                }
                let mut utils = Vec::new();
                let mut powers = Vec::new();
                let mut ok = true;
                for s in ALL_SCENARIOS {
                    if s == hmai::env::Scenario::Reverse && !area.allows_reverse() {
                        continue;
                    }
                    let reqs = alloc::requirements(area, s);
                    match alloc::best_allocation(counts, &reqs) {
                        Some((a, u)) => {
                            utils.push(u);
                            powers.push(alloc::power_w_provisioned(&a, &reqs, counts));
                        }
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok {
                    rows.push(Row {
                        counts,
                        util_gm: geomean(&utils),
                        power_gm: geomean(&powers),
                    });
                }
            }
        }
    }

    // Pareto frontier: max utilization, min power, min units.
    rows.sort_by(|a, b| b.util_gm.total_cmp(&a.util_gm));
    println!(
        "area = {}: {} feasible configurations ≤ {max_units} units; top 15 by geomean utilization:",
        area.name(),
        rows.len()
    );
    let mut t = Table::new(["SO", "SI", "MM", "Units", "Util (geomean)", "Power W (geomean)"]);
    for r in rows.iter().take(15) {
        t.row([
            r.counts.0.to_string(),
            r.counts.1.to_string(),
            r.counts.2.to_string(),
            (r.counts.0 + r.counts.1 + r.counts.2).to_string(),
            pct(r.util_gm),
            f2(r.power_gm),
        ]);
    }
    t.print();

    // Where does the paper's HMAI (4,4,3) rank?
    if let Some(pos) = rows.iter().position(|r| r.counts == (4, 4, 3)) {
        let r = &rows[pos];
        println!(
            "\npaper HMAI (4,4,3): rank {} of {}, util {} / power {:.2} W",
            pos + 1,
            rows.len(),
            pct(r.util_gm),
            r.power_gm
        );
    }
    Ok(())
}
