//! Tour of the scenario-variability library: every archetype — named route
//! archetypes (urban-rush, highway-cruise, night-rain at degraded camera
//! rates, mid-route sensor-dropout, multi-area composites) and the §7
//! camera-rig variants (12/20/30 cameras) — compiled down to the concrete
//! `RouteParams`/`Segment` timeline, then swept by every registered
//! scheduler through the typed `ExperimentPlan`/`Engine` API.
//!
//! The same library drives `hmai schedule --scenario <name|all>`,
//! `hmai env --scenario all`, `hmai braking --scenario all` and
//! `cargo bench --bench bench_scenarios`.
//!
//!     cargo run --release --example scenario_tour -- \
//!         [--dist 300] [--seed 42] [--jobs 4] [--scenario urban-rush,night-rain]
//!
//! Without `make artifacts`, FlexAI is skipped and the tour covers the
//! remaining registered schedulers.

// Examples narrate on stderr when artifacts are missing (deny carve-out).
#![allow(clippy::print_stderr)]

use hmai::config::ExperimentConfig;
use hmai::engine::Engine;
use hmai::env::scenario;
use hmai::env::taskgen::DeadlineMode;
use hmai::plan::ExperimentPlan;
use hmai::sched::SchedulerSpec;
use hmai::util::cli::Args;
use hmai::util::table::{f1, f2, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dist = args.get_f64("dist", 300.0)?;
    let seed = args.get_u64("seed", 42)?;
    let jobs = args.get_usize("jobs", 0)?;
    let names: Vec<String> = match args.get("scenario") {
        None => scenario::names(),
        Some(s) if s.eq_ignore_ascii_case("all") => scenario::names(),
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
    };

    // 1. Compile each archetype and show what it turned into: legs,
    //    camera rig, rate scale, dropout windows, and the resulting
    //    task-queue shape (the archetype → RouteParams/Segment pipeline).
    println!("scenario library ({} archetypes selected):\n", names.len());
    let mut t = Table::new([
        "Scenario", "Description", "Legs", "Cameras", "Hz x", "Tasks", "Tasks/s",
    ]);
    for name in &names {
        let arch = scenario::find(name)?;
        let q = arch.queue_for(dist, 0, DeadlineMode::Rss, seed);
        let legs: Vec<String> =
            arch.legs.iter().map(|l| l.area.name().to_string()).collect();
        t.row([
            arch.name.clone(),
            arch.help.to_string(),
            legs.join("+"),
            arch.rig.total().to_string(),
            f2(arch.hz_scale),
            q.len().to_string(),
            f1(q.len() as f64 / q.route_duration_s),
        ]);
    }
    t.print();

    // 2. Sweep the selected archetypes with every registered scheduler
    //    (FlexAI rides along when the PJRT runtime is available).
    let registry = hmai::harness::registry(&ExperimentConfig::default());
    let mut schedulers: Vec<SchedulerSpec> = Vec::new();
    match hmai::harness::load_runtime() {
        Ok(_) => schedulers.push(SchedulerSpec::FlexAI { checkpoint: None }),
        Err(e) => eprintln!("note: FlexAI skipped ({e:#})"),
    }
    schedulers.extend(hmai::harness::registered_non_flexai_specs(&registry));

    let plan = ExperimentPlan::new()
        .scenarios(names)
        .distances([dist])
        .schedulers(schedulers)
        .seed(seed);
    println!("\nsweeping {} trials (jobs = {jobs})...", plan.len());
    let (_, sweep) = Engine::new(&registry).jobs(jobs).sweep(&plan)?;
    println!("\nper-scenario breakdown:");
    hmai::reports::sweep_table(&sweep).print();
    Ok(())
}
