//! End-to-end driver: train the FlexAI DQN on real task queues and prove
//! every layer composes — the L1 Pallas kernels and L2 JAX train step run
//! as AOT-compiled HLO under the rust RL loop (L3), on the same artifacts
//! the serving path uses.  Python never runs here.
//!
//! Reproduces the Fig. 11 experiment end to end:
//!   * N episodes, one task queue per episode (§8.3);
//!   * logs the TD loss curve (written to `flexai_loss.csv`);
//!   * saves a checkpoint;
//!   * evaluates the trained agent vs Min-Min / ATA / SA / worst-case on a
//!     held-out route and prints the Fig. 12-style comparison.
//!
//!     make artifacts && cargo run --release --example train_flexai
//!
//! Flags: --episodes N (default 4)  --episode-dist M (default 150)
//!        --eval-dist M (default 250)  --seed S  --out FILE

use hmai::config::{EnvConfig, ExperimentConfig, TrainConfig};
use hmai::engine::Engine;
use hmai::env::Area;
use hmai::harness;
use hmai::sched::{baseline_specs, SchedulerSpec};
use hmai::util::cli::Args;
use hmai::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let episodes = args.get_usize("episodes", 4)?;
    let episode_dist = args.get_f64("episode-dist", 150.0)?;
    let eval_dist = args.get_f64("eval-dist", 250.0)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args.get_or("out", "flexai_ckpt.json").to_string();

    let cfg = ExperimentConfig {
        env: EnvConfig { area: Area::Urban, distances_m: vec![eval_dist], seed },
        train: TrainConfig {
            episodes,
            episode_distance_m: episode_dist,
            checkpoint: out.clone(),
        },
        ..Default::default()
    };

    // --- Train (Fig. 11) ---
    println!("training FlexAI: {episodes} episodes x {episode_dist} m (UB)");
    let t0 = std::time::Instant::now();
    let outcome = harness::train_flexai(&cfg)?;
    println!(
        "trained in {:.1} s: {} decisions, {} SGD steps, {} target syncs",
        t0.elapsed().as_secs_f64(),
        outcome.agent.steps,
        outcome.agent.train_steps,
        outcome.agent.target_syncs
    );

    // Loss curve: console summary (per-decile means) + CSV.
    let losses = &outcome.losses;
    if !losses.is_empty() {
        let mut csv = String::from("step,loss\n");
        for (i, l) in losses.iter().enumerate() {
            csv.push_str(&format!("{i},{l}\n"));
        }
        std::fs::write("flexai_loss.csv", csv)?;
        println!("loss curve -> flexai_loss.csv ({} points)", losses.len());
        let dec = losses.len().max(10) / 10;
        let mut t = Table::new(["Decile", "Mean TD loss"]);
        for d in 0..10 {
            let lo = d * dec;
            let hi = ((d + 1) * dec).min(losses.len());
            if lo >= hi {
                break;
            }
            let mean: f32 = losses[lo..hi].iter().sum::<f32>() / (hi - lo) as f32;
            t.row([format!("{}%", (d + 1) * 10), format!("{mean:.4}")]);
        }
        t.print();
    }

    let mut per_ep = Table::new(["Episode", "Tasks", "STMRate", "Wait (s)", "MS/task"]);
    for (i, s) in outcome.episode_summaries.iter().enumerate() {
        per_ep.row([
            (i + 1).to_string(),
            s.tasks.to_string(),
            pct(s.stm_rate()),
            f2(s.wait_s),
            f2(s.ms_per_task()),
        ]);
    }
    per_ep.print();

    hmai::sched::flexai::checkpoint::save(&outcome.agent, std::path::Path::new(&out))?;
    println!("checkpoint -> {out}");

    // --- Evaluate on a held-out route (Fig. 12-style), through the
    //     plan/engine API: FlexAI restores the checkpoint just saved, the
    //     baselines come from the canonical table, and `--jobs` runs the
    //     comparison trials in parallel. ---
    println!("\nheld-out evaluation: {} m route (UB)", eval_dist);
    let mut schedulers = vec![SchedulerSpec::FlexAI { checkpoint: Some(out.clone()) }];
    schedulers.extend(baseline_specs());
    let plan = cfg.plan()?.schedulers(schedulers);
    let registry = harness::registry(&cfg);
    let results = Engine::new(&registry)
        .jobs(args.get_usize("jobs", cfg.jobs)?)
        .run(&plan)?;

    let mut table = Table::new([
        "Scheduler", "STMRate", "Time (s)", "Wait (s)", "Energy (J)", "R_Balance", "MS/task",
    ]);
    for r in &results {
        let s = &r.summary;
        table.row([
            s.scheduler.clone(),
            pct(s.stm_rate()),
            f2(s.total_time_s),
            f2(s.wait_s),
            f2(s.energy_j),
            f2(s.r_balance),
            f2(s.ms_per_task()),
        ]);
    }
    table.print();
    Ok(())
}
