//! Ablation study over FlexAI's design choices (DESIGN.md deviations):
//!   A. deadline shield on/off (inference-time action filter)
//!   B. guided exploration on/off (training-time)
//! Each variant trains a fresh agent (same seed, 3 episodes × 100 m) and
//! evaluates greedily on a held-out 200 m UB queue.
//!
//! Expected: guided exploration is the load-bearing piece (uniform
//! exploration collapses queues and the policy never sees good states);
//! the shield mainly protects the *undertrained* agent — a converged
//! policy rarely needs the fallback.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::config::{EnvConfig, ExperimentConfig, TrainConfig};
use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::harness;
use hmai::plan::queue_for;
use hmai::platform::Platform;
use hmai::sim::{simulate, SimOptions};
use hmai::util::bench::section;
use hmai::util::table::{f2, pct, Table};

fn main() {
    if let Err(e) = harness::load_runtime() {
        eprintln!("[bench] skipping ablation: {e:#}");
        return;
    }
    let scale = common::scale() / 0.2;
    let train_dist = 100.0 * scale.max(0.5);
    let eval_dist = 200.0 * scale.max(0.5);
    let queue = queue_for(Area::Urban, eval_dist, 0, DeadlineMode::Rss, 42);
    let platform = Platform::hmai();

    section(&format!(
        "FlexAI ablations — train 3 x {train_dist:.0} m, eval {eval_dist:.0} m ({} tasks)",
        queue.len()
    ));

    let mut t = Table::new([
        "Variant", "STMRate", "Wait (s)", "Energy (J)", "R_Balance", "MS/task",
    ]);
    let mut rows: Vec<(String, f64, f64)> = Vec::new(); // (name, stm, wait)

    for (name, shield, guided) in [
        ("full (shield + guided)", true, true),
        ("no shield", false, true),
        ("no guided exploration", true, false),
        ("neither (paper-pure DQN)", false, false),
    ] {
        let cfg = ExperimentConfig {
            env: EnvConfig { area: Area::Urban, distances_m: vec![train_dist], seed: 42 },
            train: TrainConfig {
                episodes: 3,
                episode_distance_m: train_dist,
                checkpoint: String::new(),
            },
            flexai: hmai::sched::flexai::FlexAIConfig {
                safety_shield: shield,
                guided_explore: guided,
                seed: 42,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut out = harness::train_flexai(&cfg).expect("artifacts present");
        out.agent.set_training(false);
        let r = simulate(&queue, &platform, &mut out.agent, SimOptions::default());
        let s = &r.summary;
        t.row([
            name.to_string(),
            pct(s.stm_rate()),
            f2(s.wait_s),
            f2(s.energy_j),
            f2(s.r_balance),
            f2(s.ms_per_task()),
        ]);
        rows.push((name.to_string(), s.stm_rate(), s.wait_s));
    }
    t.print();

    // The full variant must be the safest, and guided exploration must
    // matter more than the shield for queue health.
    let get = |n: &str| rows.iter().find(|(x, _, _)| x.starts_with(n)).unwrap();
    let full = get("full");
    let pure = get("neither");
    assert!(full.1 >= pure.1 - 1e-9, "full stm {} < pure {}", full.1, pure.1);
    let no_guided = get("no guided");
    assert!(
        full.2 <= no_guided.2,
        "guided exploration should reduce waiting: {} vs {}",
        full.2,
        no_guided.2
    );
    println!("\nablation OK: full variant safest; guided exploration carries queue health");
}
