//! Fig. 13: safety-time meet rate (STMRate) per task queue per scheduler.
//! Shape target: FlexAI ≈ 100% on every queue; ATA also high (optimized
//! toward MS); Min-Min / GA / SA / worst-case well below (paper averages
//! 21% / 34% / 51% for heuristics / GA / SA across areas).
//!
//! Both deadline regimes run as one `ExperimentPlan` deadline sweep
//! through the `Engine` worker pool.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::engine::{Engine, TrialResult};
use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::util::bench::section;
use hmai::util::table::{pct, Table};

/// Per-queue STM rates of one scheduler under one regime, queue order.
fn rates(results: &[TrialResult], sched: &str, mode: DeadlineMode) -> Vec<f64> {
    let mut rows: Vec<(usize, f64)> = results
        .iter()
        .filter(|r| r.summary.scheduler == sched && r.trial.scenario.deadline == mode)
        .map(|r| (r.trial.queue_index, r.summary.stm_rate()))
        .collect();
    rows.sort_by_key(|(qi, _)| *qi);
    rows.into_iter().map(|(_, s)| s).collect()
}

fn print_table(rows: &[(String, Vec<f64>)]) {
    let mut t = Table::new(["Scheduler", "Q1", "Q2", "Q3", "Q4", "Q5", "Mean"]);
    for (name, rates) in rows {
        let mut row = vec![name.clone()];
        row.extend(rates.iter().map(|&r| pct(r)));
        row.push(pct(rates.iter().sum::<f64>() / rates.len() as f64));
        t.row(row);
    }
    t.print();
}

fn main() {
    let area = Area::Urban;
    let reg = common::registry();

    let mut schedulers = Vec::new();
    let flexai_on = match common::flexai_spec(area) {
        Ok(spec) => {
            schedulers.push(spec);
            true
        }
        Err(e) => {
            eprintln!("[bench] FlexAI unavailable, baselines only: {e:#}");
            false
        }
    };
    schedulers.extend(common::baselines());
    // Row labels come from the specs themselves (the canonical table's
    // display names), so they can never drift from what the engine ran.
    let sched_names: Vec<String> =
        schedulers.iter().map(|s| s.display().to_string()).collect();

    // One plan sweeps both deadline regimes; the engine runs the full
    // scheduler × regime × queue matrix on the worker pool.
    let plan = common::plan(area)
        .deadlines([DeadlineMode::Rss, DeadlineMode::FrameBudget])
        .schedulers(schedulers);
    let results = Engine::new(&reg).jobs(common::jobs()).run(&plan).expect("sweep runs");

    for (mode, title) in [
        (DeadlineMode::Rss, "Fig. 13 — STMRate per queue (UB, RSS deadlines — §6.1)"),
        (DeadlineMode::FrameBudget, "Fig. 13 — STMRate per queue (UB, frame-budget deadlines)"),
    ] {
        section(title);
        let rows: Vec<(String, Vec<f64>)> = sched_names
            .iter()
            .map(|n| (n.clone(), rates(&results, n, mode)))
            .collect();
        print_table(&rows);
    }

    if !flexai_on {
        println!("\nfig13 OK (baselines only; FlexAI skipped)");
        return;
    }

    // Paper shape: FlexAI basically 100% on every queue, in both regimes;
    // under frame-budget deadlines the baseline spread opens up (paper:
    // heuristics 21% / GA 34% / SA 51% on average).
    let flex_rss = rates(&results, "FlexAI", DeadlineMode::Rss);
    for (i, r) in flex_rss.iter().enumerate() {
        assert!(*r > 0.99, "FlexAI queue {} RSS STMRate {}", i + 1, r);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let flex_fb = mean(&rates(&results, "FlexAI", DeadlineMode::FrameBudget));
    // FlexAI must stay far above the load-blind baselines under the tight
    // regime (the paper's 21-53% band); ATA/SA parity is acceptable.
    for name in ["Min-Min", "GA", "WorstCase"] {
        let m = mean(&rates(&results, name, DeadlineMode::FrameBudget));
        assert!(
            flex_fb > m + 0.2,
            "FlexAI frame-budget STMRate {flex_fb} not >> {name} {m}"
        );
    }
    println!(
        "\nfig13 OK: FlexAI {:.1}% frame-budget mean vs Min-Min/GA in the paper's 21-53% band",
        flex_fb * 100.0
    );
}
