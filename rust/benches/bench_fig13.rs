//! Fig. 13: safety-time meet rate (STMRate) per task queue per scheduler.
//! Shape target: FlexAI ≈ 100% on every queue; ATA also high (optimized
//! toward MS); Min-Min / GA / SA / worst-case well below (paper averages
//! 21% / 34% / 51% for heuristics / GA / SA across areas).

#[path = "common.rs"]
mod common;

use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::harness;
use hmai::platform::Platform;
use hmai::sim::SimOptions;
use hmai::util::bench::section;
use hmai::util::table::{pct, Table};

fn run_regime(area: Area, mode: DeadlineMode) -> Vec<(String, Vec<f64>)> {
    let env = common::env(area);
    let queues = harness::make_queues_with_deadline(&env, mode);
    let platform = Platform::hmai();
    let mut out = Vec::new();
    {
        let mut agent = common::flexai(area).expect("flexai constructible");
        let rs = harness::run_queues(&queues, &platform, &mut agent, SimOptions::default());
        out.push(("FlexAI".to_string(), rs.iter().map(|r| r.summary.stm_rate()).collect()));
    }
    for mut b in common::baselines(42) {
        let rs = harness::run_queues(&queues, &platform, b.as_mut(), SimOptions::default());
        out.push((b.name(), rs.iter().map(|r| r.summary.stm_rate()).collect()));
    }
    out
}

fn print_table(rows: &[(String, Vec<f64>)]) {
    let mut t = Table::new(["Scheduler", "Q1", "Q2", "Q3", "Q4", "Q5", "Mean"]);
    for (name, rates) in rows {
        let mut row = vec![name.clone()];
        row.extend(rates.iter().map(|&r| pct(r)));
        row.push(pct(rates.iter().sum::<f64>() / rates.len() as f64));
        t.row(row);
    }
    t.print();
}

fn main() {
    let area = Area::Urban;

    section("Fig. 13 — STMRate per queue (UB, RSS deadlines — §6.1)");
    let rss = run_regime(area, DeadlineMode::Rss);
    print_table(&rss);

    section("Fig. 13 — STMRate per queue (UB, frame-budget deadlines)");
    let fb = run_regime(area, DeadlineMode::FrameBudget);
    print_table(&fb);

    // Paper shape: FlexAI basically 100% on every queue, in both regimes;
    // under frame-budget deadlines the baseline spread opens up (paper:
    // heuristics 21% / GA 34% / SA 51% on average).
    let flex_rss = &rss.iter().find(|(n, _)| n == "FlexAI").unwrap().1;
    for (i, r) in flex_rss.iter().enumerate() {
        assert!(*r > 0.99, "FlexAI queue {} RSS STMRate {}", i + 1, r);
    }
    let flex_fb: f64 = {
        let v = &fb.iter().find(|(n, _)| n == "FlexAI").unwrap().1;
        v.iter().sum::<f64>() / v.len() as f64
    };
    // FlexAI must stay far above the load-blind baselines under the tight
    // regime (the paper's 21-53% band); ATA/SA parity is acceptable.
    for name in ["Min-Min", "GA", "WorstCase"] {
        let rates = &fb.iter().find(|(n, _)| n == name).unwrap().1;
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        assert!(
            flex_fb > mean + 0.2,
            "FlexAI frame-budget STMRate {flex_fb} not >> {name} {mean}"
        );
    }
    println!("\nfig13 OK: FlexAI {:.1}% frame-budget mean vs Min-Min/GA in the paper's 21-53% band", flex_fb * 100.0);
}
