//! Table 8: FPS of the three sub-accelerator cycle models on the three
//! CNNs, plus the model-evaluation microbenchmark (the scheduler hot path
//! reads the cached matrix).

#[path = "common.rs"]
mod common;

use hmai::accel::{cost, task_cost, AccelKind, ALL_ACCELS};
use hmai::util::bench::{section, Bencher};
use hmai::workload::{ALL_MODELS, ModelKind};

fn main() {
    section("Table 8 — sub-accelerator FPS");
    println!("{}", hmai::reports::render("table8").unwrap());

    section("energy / power per (accelerator, model)");
    for m in ALL_MODELS {
        for a in ALL_ACCELS {
            let c = cost(a, m);
            println!(
                "{:8} {:8}  {:8.2} FPS  {:7.2} mJ/inf  {:6.2} W busy  util {:4.1}%",
                m.name(),
                a.name(),
                c.fps(),
                c.energy_j * 1e3,
                c.power_w(),
                c.utilization * 100.0
            );
        }
    }

    // Paper values within rounding.
    for (a, m, fps) in [
        (AccelKind::SconvOD, ModelKind::Yolo, 170.37),
        (AccelKind::SconvIC, ModelKind::Ssd, 82.94),
        (AccelKind::MconvMC, ModelKind::Goturn, 500.54),
    ] {
        let ours = cost(a, m).fps();
        assert!((ours / fps - 1.0).abs() < 1e-3, "{a:?} {m:?} {ours} != {fps}");
    }

    section("microbench");
    let mut b = Bencher::new();
    b.bench("cost() cached lookup", || {
        for a in ALL_ACCELS {
            for m in ALL_MODELS {
                std::hint::black_box(cost(a, m));
            }
        }
    });
    b.bench("task_cost() full cycle model (9 pairs)", || {
        for a in ALL_ACCELS {
            for m in ALL_MODELS {
                std::hint::black_box(task_cost(a, m));
            }
        }
    });
    println!("\ntable8 OK");
}
