//! Fig. 12: FlexAI vs ATA / GA / Min-Min / SA / worst-case on (a) time,
//! (b) R_Balance, (c) MS, (d) energy — five task queues per area, three
//! areas (UB / UHW / HW), geometric means in the M column.
//!
//! Shape targets (paper): FlexAI minimal time in every area; FlexAI best
//! R_Balance; ATA the only baseline beating FlexAI on MS; worst-case and
//! GA far behind on time/balance.
//!
//! Runs entirely through `ExperimentPlan`/`Engine` (trials execute on the
//! worker pool; FlexAI trials restore one shared trained checkpoint).
//! Set HMAI_BENCH_AREAS to restrict areas, HMAI_BENCH_SCALE to resize,
//! HMAI_BENCH_JOBS to pin the worker count.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::engine::Engine;
use hmai::env::Area;
use hmai::metrics::summary::SweepGroup;
use hmai::util::bench::section;

fn areas() -> Vec<Area> {
    let spec = std::env::var("HMAI_BENCH_AREAS").unwrap_or_else(|_| "ub,uhw,hw".into());
    spec.split(',').filter_map(Area::parse).collect()
}

fn main() {
    let reg = common::registry();
    for area in areas() {
        let mut schedulers = Vec::new();
        let flexai_on = match common::flexai_spec(area) {
            Ok(spec) => {
                schedulers.push(spec);
                true
            }
            Err(e) => {
                eprintln!("[bench] FlexAI unavailable, baselines only: {e:#}");
                false
            }
        };
        schedulers.extend(common::baselines());

        let plan = common::plan(area).schedulers(schedulers);
        let trials = plan.len();
        let (_, sweep) = Engine::new(&reg)
            .jobs(common::jobs())
            .sweep(&plan)
            .expect("sweep runs");
        section(&format!(
            "Fig. 12 — {} ({} trials through Engine, {} queues/scheduler)",
            area.name(),
            trials,
            common::distances().len()
        ));
        hmai::reports::sweep_table(&sweep).print();

        // Shape assertions per area.
        let by = |name: &str| -> &SweepGroup {
            sweep.by_scheduler(name).unwrap_or_else(|| panic!("{name} missing"))
        };
        let worst = by("WorstCase").geomean_time_s();
        let ga = by("GA").geomean_time_s();
        if flexai_on {
            let flex = by("FlexAI");
            let flex_time = flex.geomean_time_s();
            assert!(flex_time < worst, "{}: FlexAI time !< worst", area.name());
            assert!(flex_time < ga, "{}: FlexAI time !< GA", area.name());
            let flex_stm = flex.mean_stm_rate();
            assert!(flex_stm > 0.99, "{}: FlexAI STMRate {flex_stm}", area.name());
        } else {
            // Baseline-only shape: the unscheduled floor is still the floor.
            assert!(by("Min-Min").geomean_time_s() < worst, "{}", area.name());
        }
    }
    println!("\nfig12 OK");
}
