//! Fig. 12: FlexAI vs ATA / GA / Min-Min / SA / worst-case on (a) time,
//! (b) R_Balance, (c) MS, (d) energy — five task queues per area, three
//! areas (UB / UHW / HW), geometric means in the M column.
//!
//! Shape targets (paper): FlexAI minimal time in every area; FlexAI best
//! R_Balance; ATA the only baseline beating FlexAI on MS; worst-case and
//! GA far behind on time/balance.
//!
//! Set HMAI_BENCH_AREAS=ub to restrict areas, HMAI_BENCH_SCALE to resize.

#[path = "common.rs"]
mod common;

use hmai::env::Area;
use hmai::harness;
use hmai::metrics::summary::RunSummary;
use hmai::sim::SimOptions;
use hmai::util::bench::section;
use hmai::util::stats::geomean;
use hmai::util::table::{f2, pct, Table};

fn areas() -> Vec<Area> {
    let spec = std::env::var("HMAI_BENCH_AREAS").unwrap_or_else(|_| "ub,uhw,hw".into());
    spec.split(',').filter_map(Area::parse).collect()
}

fn main() {
    for area in areas() {
        let env = common::env(area);
        let queues = harness::make_queues(&env);
        section(&format!(
            "Fig. 12 — {} ({} queues, {} tasks total)",
            area.name(),
            queues.len(),
            queues.iter().map(|q| q.len()).sum::<usize>()
        ));

        let platform = hmai::platform::Platform::hmai();
        let mut results: Vec<(String, Vec<RunSummary>)> = Vec::new();
        {
            let mut agent = common::flexai(area).expect("flexai constructible");
            let rs =
                harness::run_queues(&queues, &platform, &mut agent, SimOptions::default());
            results.push(("FlexAI".into(), rs.into_iter().map(|r| r.summary).collect()));
        }
        for mut b in common::baselines(42) {
            let rs =
                harness::run_queues(&queues, &platform, b.as_mut(), SimOptions::default());
            results.push((b.name(), rs.into_iter().map(|r| r.summary).collect()));
        }

        let mut t = Table::new([
            "Scheduler", "Time M (s)", "R_Balance M", "MS/task M", "Energy M (J)", "STMRate M",
        ]);
        let geo = |f: &dyn Fn(&RunSummary) -> f64, rs: &[RunSummary]| {
            geomean(&rs.iter().map(|s| f(s).max(1e-12)).collect::<Vec<_>>())
        };
        for (name, rs) in &results {
            t.row([
                name.clone(),
                f2(geo(&|s| s.total_time_s, rs)),
                f2(rs.iter().map(|s| s.r_balance).sum::<f64>() / rs.len() as f64),
                f2(rs.iter().map(|s| s.ms_per_task()).sum::<f64>() / rs.len() as f64),
                f2(geo(&|s| s.energy_j, rs)),
                pct(rs.iter().map(|s| s.stm_rate()).sum::<f64>() / rs.len() as f64),
            ]);
        }
        t.print();

        // Shape assertions per area.
        let by = |name: &str| results.iter().find(|(n, _)| n == name).map(|(_, r)| r).unwrap();
        let flex = by("FlexAI");
        let worst = by("WorstCase");
        let ga = by("GA");
        let flex_time = geo(&|s| s.total_time_s, flex);
        assert!(
            flex_time < geo(&|s| s.total_time_s, worst),
            "{}: FlexAI time !< worst",
            area.name()
        );
        assert!(
            flex_time < geo(&|s| s.total_time_s, ga),
            "{}: FlexAI time !< GA",
            area.name()
        );
        let flex_stm = flex.iter().map(|s| s.stm_rate()).sum::<f64>() / flex.len() as f64;
        assert!(flex_stm > 0.99, "{}: FlexAI STMRate {flex_stm}", area.name());
    }
    println!("\nfig12 OK");
}
