//! Shared plumbing for the per-figure benches.
//!
//! Scale: the paper evaluates five 1-2 km routes per area (up to ~200k
//! tasks each).  `HMAI_BENCH_SCALE` (default 0.2) scales the route
//! distances so `cargo bench` completes in minutes; set it to 1.0 to
//! regenerate the figures at full paper scale.

#![allow(dead_code)] // each bench uses a subset of these helpers

use std::sync::Arc;

use hmai::config::{EnvConfig, ExperimentConfig};
use hmai::env::Area;
use hmai::harness;
use hmai::sched::flexai::{checkpoint, FlexAI, FlexAIConfig};
use hmai::sched::Scheduler;

/// Route-distance scale factor.
pub fn scale() -> f64 {
    std::env::var("HMAI_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2)
}

/// The paper's five route distances (m), scaled.
pub fn distances() -> Vec<f64> {
    let s = scale();
    vec![1000.0, 1250.0, 1500.0, 1750.0, 2000.0]
        .into_iter()
        .map(|d| d * s)
        .collect()
}

/// Evaluation environment for one area.
pub fn env(area: Area) -> EnvConfig {
    EnvConfig { area, distances_m: distances(), seed: 42 }
}

/// FlexAI for benching: loads `checkpoints/flexai_<area>.json` (or
/// `$HMAI_CKPT`) when present; otherwise trains a quick agent in-process
/// so the bench is self-contained.
pub fn flexai(area: Area) -> anyhow::Result<FlexAI> {
    let rt = harness::load_runtime()?;
    let cfg = FlexAIConfig { seed: 42, ..Default::default() };
    let path = std::env::var("HMAI_CKPT").unwrap_or_else(|_| {
        format!("checkpoints/flexai_{}.json", area.name().to_lowercase())
    });
    if std::path::Path::new(&path).exists() {
        eprintln!("[bench] loading FlexAI checkpoint {path}");
        return checkpoint::load(rt, std::path::Path::new(&path), cfg);
    }
    eprintln!("[bench] no checkpoint at {path}; training a quick agent (2 eps x 100 m)");
    let tcfg = ExperimentConfig {
        env: EnvConfig { area, distances_m: vec![100.0], seed: 42 },
        train: hmai::config::TrainConfig {
            episodes: 2,
            episode_distance_m: 100.0,
            checkpoint: String::new(),
        },
        ..Default::default()
    };
    let mut out = harness::train_flexai(&tcfg)?;
    out.agent.set_training(false);
    Ok(out.agent)
}

/// All Fig. 12 baselines, constructed fresh.
pub fn baselines(seed: u64) -> Vec<Box<dyn Scheduler>> {
    hmai::sched::BASELINES
        .iter()
        .map(|n| hmai::sched::by_name(n, seed).expect("baseline"))
        .collect()
}

/// Arc'd runtime for perf benches.
pub fn runtime() -> anyhow::Result<Arc<hmai::runtime::Runtime>> {
    harness::load_runtime()
}
