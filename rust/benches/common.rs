//! Shared plumbing for the per-figure benches.
//!
//! Scale: the paper evaluates five 1-2 km routes per area (up to ~200k
//! tasks each).  `HMAI_BENCH_SCALE` (default 0.2) scales the route
//! distances so `cargo bench` completes in minutes; set it to 1.0 to
//! regenerate the figures at full paper scale.  `HMAI_BENCH_JOBS` sets the
//! engine worker count (default: all cores).

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#![allow(dead_code)] // each bench uses a subset of these helpers

use std::sync::Arc;

use hmai::config::{EnvConfig, ExperimentConfig};
use hmai::env::Area;
use hmai::harness;
use hmai::plan::ExperimentPlan;
use hmai::sched::flexai::{checkpoint, FlexAI, FlexAIConfig};
use hmai::sched::{Registry, SchedulerSpec};

/// Route-distance scale factor.
pub fn scale() -> f64 {
    std::env::var("HMAI_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2)
}

/// Engine worker threads (0 = all cores).
pub fn jobs() -> usize {
    std::env::var("HMAI_BENCH_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// The paper's five route distances (m), scaled.
pub fn distances() -> Vec<f64> {
    let s = scale();
    vec![1000.0, 1250.0, 1500.0, 1750.0, 2000.0]
        .into_iter()
        .map(|d| d * s)
        .collect()
}

/// Evaluation environment for one area.
pub fn env(area: Area) -> EnvConfig {
    EnvConfig { area, distances_m: distances(), seed: 42 }
}

/// The standard per-area evaluation sweep (no schedulers yet).
pub fn plan(area: Area) -> ExperimentPlan {
    ExperimentPlan::new().area(area).distances(distances()).seed(42)
}

/// Registry with every baseline plus the FlexAI factory (greedy inference).
pub fn registry() -> Registry {
    harness::registry(&ExperimentConfig::default())
}

/// FlexAI for benching: loads `checkpoints/flexai_<area>.json` (or
/// `$HMAI_CKPT`) when present; otherwise trains a quick agent in-process
/// so the bench is self-contained.
pub fn flexai(area: Area) -> anyhow::Result<FlexAI> {
    let rt = harness::load_runtime()?;
    let cfg = FlexAIConfig { seed: 42, ..Default::default() };
    let path = ckpt_path(area);
    if std::path::Path::new(&path).exists() {
        eprintln!("[bench] loading FlexAI checkpoint {path}");
        return checkpoint::load(rt, std::path::Path::new(&path), cfg);
    }
    eprintln!("[bench] no checkpoint at {path}; training a quick agent (2 eps x 100 m)");
    let tcfg = ExperimentConfig {
        env: EnvConfig { area, distances_m: vec![100.0], seed: 42 },
        train: hmai::config::TrainConfig {
            episodes: 2,
            episode_distance_m: 100.0,
            checkpoint: String::new(),
        },
        ..Default::default()
    };
    let mut out = harness::train_flexai(&tcfg)?;
    out.agent.set_training(false);
    Ok(out.agent)
}

fn ckpt_path(area: Area) -> String {
    std::env::var("HMAI_CKPT").unwrap_or_else(|_| {
        format!("checkpoints/flexai_{}.json", area.name().to_lowercase())
    })
}

/// A FlexAI scheduler spec usable in an `ExperimentPlan`: resolves (or
/// trains + saves) a checkpoint and returns `FlexAI { checkpoint }`, so
/// every engine trial restores the *same* trained agent.  Errs when the
/// PJRT runtime/artifacts are unavailable — benches then skip FlexAI rows.
pub fn flexai_spec(area: Area) -> anyhow::Result<SchedulerSpec> {
    let path = ckpt_path(area);
    if !std::path::Path::new(&path).exists() {
        let agent = flexai(area)?; // trains the quick agent
        let tmp = std::env::temp_dir().join(format!(
            "hmai_bench_flexai_{}.json",
            area.name().to_lowercase()
        ));
        checkpoint::save(&agent, &tmp)?;
        return Ok(SchedulerSpec::FlexAI {
            checkpoint: Some(tmp.to_string_lossy().into_owned()),
        });
    }
    Ok(SchedulerSpec::FlexAI { checkpoint: Some(path) })
}

/// All Fig. 12 baseline specs, from the canonical table.
pub fn baselines() -> Vec<SchedulerSpec> {
    hmai::sched::baseline_specs()
}

/// Arc'd runtime for perf benches.
pub fn runtime() -> anyhow::Result<Arc<hmai::runtime::Runtime>> {
    harness::load_runtime()
}
