//! Fig. 1 + Table 5: frame-rate requirements per (area, scenario, camera
//! group) and the per-model FPS requirements they induce.  Asserts the
//! paper's headline totals (UB: DET 870 / TRA 840 / reverse 740) hold.

#[path = "common.rs"]
mod common;

use hmai::env::camera_hz::{aggregate_fps, model_fps_requirement};
use hmai::env::{Area, Scenario, ALL_AREAS, ALL_SCENARIOS};
use hmai::util::bench::section;
use hmai::workload::ModelKind;

fn main() {
    section("Fig. 1 — Camera_HZ(area, scenario, group)");
    println!("{}", hmai::reports::render("fig1").unwrap());

    section("Table 5 — per-model FPS requirements (urban)");
    println!("{}", hmai::reports::render("table5").unwrap());

    section("requirement matrix across areas");
    for a in ALL_AREAS {
        for s in ALL_SCENARIOS {
            if s == Scenario::Reverse && !a.allows_reverse() {
                continue;
            }
            println!(
                "{:4} {:3}  DET {:6.0}  TRA {:6.0}  YOLO {:5.0}  SSD {:5.0}  GOTURN {:5.0}",
                a.name(),
                s.name(),
                aggregate_fps(a, s, false),
                aggregate_fps(a, s, true),
                model_fps_requirement(a, s, ModelKind::Yolo),
                model_fps_requirement(a, s, ModelKind::Ssd),
                model_fps_requirement(a, s, ModelKind::Goturn),
            );
        }
    }

    // Paper checks (Table 5).
    let ub = Area::Urban;
    assert!((aggregate_fps(ub, Scenario::GoStraight, false) - 870.0).abs() < 1.0);
    assert!((aggregate_fps(ub, Scenario::GoStraight, true) - 840.0).abs() < 1.0);
    assert!((aggregate_fps(ub, Scenario::Turn, false) - 950.0).abs() < 1.0);
    assert!((aggregate_fps(ub, Scenario::Reverse, false) - 740.0).abs() < 1.0);
    println!("\nfig1/table5 OK: paper totals reproduced");
}
