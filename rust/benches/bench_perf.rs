//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md): the scheduler
//! decision pipeline (featurize → PJRT Q-inference → pick), the DQN train
//! step, the discrete-event engine, and the baseline schedulers'
//! per-decision costs — now with *before/after* sections that time the
//! pre-overhaul reference algorithms (`sched::reference`) against the
//! optimized hot paths in the same build, and report the speedups.
//!
//! The engine-primitive and baseline-scheduler sections run with or
//! without the PJRT runtime; the compiled-executable sections join when
//! the artifacts are available.  Results are also written to
//! `BENCH_PERF.json` (via `util::json`) so CI can track a machine-readable
//! perf trajectory: `benches/compare_bench.py` diffs it against the
//! committed `benches/perf_baseline.json` and warns (fail-soft) on >25%
//! regressions.  Refresh the baseline by copying a CI `BENCH_PERF.json`
//! artifact over `benches/perf_baseline.json`.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::metrics::NormScales;
use hmai::plan::queue_for;
use hmai::platform::Platform;
use hmai::runtime::TrainBatch;
use hmai::sched::flexai::featurize::featurize;
use hmai::sched::reference::{self, reference_registry};
use hmai::sched::{Registry, RolloutCtx, Scheduler};
use hmai::sim::{simulate, ShadowState, SimOptions};
use hmai::util::bench::{section, Bencher};
use hmai::util::json::Json;

const JSON_PATH: &str = "BENCH_PERF.json";

fn main() -> anyhow::Result<()> {
    let platform = Platform::hmai();
    let queue = queue_for(Area::Urban, 60.0, 0, DeadlineMode::Rss, 1);
    let scales = NormScales::for_queue(&queue, &platform);
    let state = ShadowState::new(&platform, scales);
    let task = queue.tasks[0].clone();
    // The 30-camera burst every scheduling section shares (§7: one frame
    // from each of the 30 cameras per burst).
    let burst: Vec<_> = queue.tasks.iter().take(30).cloned().collect();
    let mut b = Bencher::new();

    section("L3 engine primitives");
    b.bench("ShadowState::clone (11 accels)", || {
        std::hint::black_box(state.clone());
    });
    b.bench("ShadowState::apply", || {
        let mut s = state.clone();
        std::hint::black_box(s.apply(&task, 3));
    });
    // The r_j micro-decision: O(N) scan vs the cached running count
    // (`busy_count`).  These two rows are the number the cache is
    // justified by — if they ever converge, drop the cache.
    b.bench("busy_fraction_at (O(N) scan)", || {
        std::hint::black_box(state.busy_fraction_at(0.0));
    });
    b.bench("busy count (cached)", || {
        std::hint::black_box(state.busy_count());
    });

    section("chiplet comm model (hmai+mesh2x2), compute-only vs comm-aware");
    let mesh = Platform::try_parse("hmai+mesh2x2").map_err(anyhow::Error::msg)?;
    let mesh_state = ShadowState::new(&mesh, NormScales::for_queue(&queue, &mesh));
    // Slot 3 sits on the diagonal chiplet — the longest (two-hop) ingress
    // route mesh2x2 has, so its pricing walks the full per-hop timeline.
    let mono_est = b
        .bench("est_response: mono (compute-only)", || {
            std::hint::black_box(state.est_response(&task, 3));
        })
        .mean();
    let mesh_est = b
        .bench("est_response: mesh2x2 (comm-aware)", || {
            std::hint::black_box(mesh_state.est_response(&task, 3));
        })
        .mean();
    // Link contention: 30 commits through one ingress route reserve the
    // same links back-to-back, the worst case for the busy-window walk.
    b.bench("apply x30, one far slot (link contention)", || {
        let mut s = mesh_state.clone();
        for t in &burst {
            std::hint::black_box(s.apply(t, 3));
        }
    });
    let mut mm_mono = hmai::sched::minmin::MinMin::new();
    let mut mm_mesh = hmai::sched::minmin::MinMin::new();
    let mono_burst = b
        .bench("minmin 30-task burst: mono", || {
            std::hint::black_box(mm_mono.schedule_batch(&burst, &state));
        })
        .mean();
    let mesh_burst = b
        .bench("minmin 30-task burst: mesh2x2", || {
            std::hint::black_box(mm_mesh.schedule_batch(&burst, &mesh_state));
        })
        .mean();
    let ratio = |a: f64, m: f64| if m > 0.0 { a / m } else { 0.0 };
    let comm_overhead = vec![
        ("est_response", ratio(mesh_est, mono_est)),
        ("minmin_burst", ratio(mesh_burst, mono_burst)),
    ];
    for (key, r) in &comm_overhead {
        println!("    -> comm-aware {key}: {r:.2}x the compute-only cost");
    }

    section("rollout fitness (30-task genome), before/after");
    let genome: Vec<usize> = (0..burst.len()).map(|i| i % platform.len()).collect();
    b.bench("rollout_cost: full-clone reference", || {
        std::hint::black_box(reference::ref_rollout_cost(&burst, &genome, &state));
    });
    let mut ctx = RolloutCtx::for_burst(&burst, &state);
    b.bench("rollout_cost: RolloutCtx (reused)", || {
        std::hint::black_box(ctx.rollout_cost(&burst, &genome));
    });

    // The compiled-executable sections need the PJRT runtime; without it
    // the bench still measures (and reports) everything runtime-free.
    let rt = match common::runtime() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("[bench] PJRT sections skipped: {e:#}");
            None
        }
    };
    if let Some(rt) = &rt {
        let mut feat = vec![0.0f32; rt.meta.in_dim];
        // Label by the artifact's own layout: 134-dim for the 8-slot-feat
        // v1 layout, 150-dim once the locality feature (v2) is compiled.
        b.bench(&format!("featurize ({}-dim state)", rt.meta.in_dim), || {
            std::hint::black_box(featurize(&task, &state, &rt.meta, &mut feat));
        });

        section("L2/L1 compiled executables (PJRT CPU)");
        let params = rt.init_params(1)?;
        featurize(&task, &state, &rt.meta, &mut feat);
        b.bench(&format!("qnet_infer (1x{} -> 16 Q)", rt.meta.in_dim), || {
            std::hint::black_box(rt.infer(&params, &feat).unwrap());
        });
        let mut states = Vec::new();
        for _ in 0..rt.meta.infer_batch {
            states.extend_from_slice(&feat);
        }
        b.bench(&format!("qnet_infer_batch ({}x{})", rt.meta.infer_batch, rt.meta.in_dim), || {
            std::hint::black_box(rt.infer_batch(&params, &states).unwrap());
        });
        let mut batch = TrainBatch::zeros(&rt.meta);
        for (i, v) in batch.s.iter_mut().enumerate() {
            *v = (i % 13) as f32 / 13.0;
        }
        batch.s2.copy_from_slice(&batch.s);
        let targ = params.clone();
        b.bench("qnet_train (batch 64, SGD step)", || {
            std::hint::black_box(rt.train_step(&params, &targ, &batch).unwrap());
        });
    }

    section("end-to-end scheduling throughput (tasks/s), before/after");
    let reg = Registry::new();
    let ref_reg = reference_registry();
    // (canonical name, BENCH_PERF.json speedup key); rr has no reference
    // twin (it was not part of the overhaul).
    let speedup_keys = [
        ("minmin", Some("minmin_burst")),
        ("ata", Some("ata_burst")),
        ("edp", Some("edp_burst")),
        ("sa", Some("sa_anneal")),
        ("ga", Some("ga_generation")),
        ("rr", None),
    ];
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    for (name, key) in speedup_keys {
        let mut s = reg.build_by_name(name, 1).unwrap();
        let after = {
            let r = b.bench(&format!("{name}: 30-task burst"), || {
                std::hint::black_box(s.schedule_batch(&burst, &state));
            });
            println!("    -> {:.0} decisions/s", 30.0 / r.mean());
            r.mean()
        };
        let Some(key) = key else { continue };
        let mut rs = ref_reg.build_by_name(name, 1).unwrap();
        let before = {
            let r = b.bench(&format!("{name}: 30-task burst (reference)"), || {
                std::hint::black_box(rs.schedule_batch(&burst, &state));
            });
            r.mean()
        };
        let ratio = if after > 0.0 { before / after } else { 0.0 };
        println!("    -> {ratio:.2}x vs reference");
        speedups.push((key, ratio));
    }
    if let Some(rt) = &rt {
        let mut agent = hmai::sched::flexai::FlexAI::new(
            rt.clone(),
            hmai::sched::flexai::FlexAIConfig { seed: 1, ..Default::default() },
        )?;
        agent.set_training(false);
        let r = b.bench("flexai: 30-task burst (greedy)", || {
            std::hint::black_box(agent.schedule_batch(&burst, &state));
        });
        println!("    -> {:.0} decisions/s", 30.0 / r.mean());
    }

    section("whole-queue simulation (Min-Min, 60 m route)");
    let mut minmin = hmai::sched::minmin::MinMin::new();
    b.bench("simulate 60 m UB queue", || {
        minmin.reset();
        std::hint::black_box(simulate(&queue, &platform, &mut minmin, SimOptions::default()));
    });

    section("DSE frontier (greedy, budget 3, 50 m urban-rush)");
    let mut heavy = Bencher::heavy();
    let dse_cfg = hmai::dse::DseConfig {
        budget_area: 3.0,
        distances_m: vec![50.0],
        max_evals: 32,
        beam: 1,
        search: hmai::dse::SearchMode::Greedy,
        seed: 1,
        ..Default::default()
    };
    let frontier_size = std::cell::Cell::new(0usize);
    heavy.bench("dse greedy search + Pareto frontier", || {
        let report = hmai::dse::run(&dse_cfg, &reg).unwrap();
        frontier_size.set(report.frontier);
        std::hint::black_box(report);
    });
    println!("    -> frontier of {} non-dominated mixes", frontier_size.get());

    section("DSE fidelity pipeline, full-fidelity vs multi-fidelity");
    // Same exploration twice: `exact` evaluates every candidate at full
    // fidelity (the pre-pipeline evaluator), `multi` prunes by analytic
    // bounds and screens on truncated routes first.  The ratio is the
    // pipeline's wall-clock win on this slice.
    let exact_cfg = hmai::dse::DseConfig {
        fidelity: hmai::dse::FidelityMode::Exact,
        ..dse_cfg.clone()
    };
    let exact_mean = heavy
        .bench("dse::run --fidelity exact", || {
            std::hint::black_box(hmai::dse::run(&exact_cfg, &reg).unwrap());
        })
        .mean();
    let multi_cfg = hmai::dse::DseConfig {
        fidelity: hmai::dse::FidelityMode::Multi,
        ..dse_cfg.clone()
    };
    let multi_mean = heavy
        .bench("dse::run --fidelity multi", || {
            std::hint::black_box(hmai::dse::run(&multi_cfg, &reg).unwrap());
        })
        .mean();
    let mf_ratio = if multi_mean > 0.0 { exact_mean / multi_mean } else { 0.0 };
    println!("    -> multi-fidelity pipeline: {mf_ratio:.2}x vs exact");
    speedups.push(("dse_multifidelity", mf_ratio));

    for (key, ratio) in &speedups {
        println!("speedup {key}: {ratio:.2}x");
    }

    // Machine-readable perf trajectory: one row per benchmark, plus the
    // before/after speedup ratios measured in this very run.
    let rows: Vec<Json> = b
        .results()
        .iter()
        .chain(heavy.results().iter())
        .map(|r| {
            Json::from_pairs(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_s", Json::Num(r.mean())),
                ("p50_s", Json::Num(r.p50())),
                ("p95_s", Json::Num(r.p95())),
                ("iters", Json::Num(r.samples.len() as f64)),
            ])
        })
        .collect();
    let speedup_json =
        Json::from_pairs(speedups.iter().map(|(k, v)| (*k, Json::Num(*v))).collect());
    let comm_json =
        Json::from_pairs(comm_overhead.iter().map(|(k, v)| (*k, Json::Num(*v))).collect());
    let report = Json::from_pairs(vec![
        ("bench", Json::Str("bench_perf".to_string())),
        ("pjrt_runtime", Json::Bool(rt.is_some())),
        ("dse_frontier_size", Json::Num(frontier_size.get() as f64)),
        ("speedup", speedup_json),
        ("comm_overhead", comm_json),
        ("results", Json::Arr(rows)),
    ]);
    report.write_to(std::path::Path::new(JSON_PATH))?;
    println!("\njson -> {JSON_PATH}");
    Ok(())
}
