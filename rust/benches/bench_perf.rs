//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md): the scheduler
//! decision pipeline (featurize → PJRT Q-inference → pick), the DQN train
//! step, the discrete-event engine, and the baseline schedulers'
//! per-decision costs.
//!
//! The engine-primitive and baseline-scheduler sections run with or
//! without the PJRT runtime; the compiled-executable sections join when
//! the artifacts are available.  Results are also written to
//! `BENCH_PERF.json` (via `util::json`) so CI can track a machine-readable
//! perf trajectory.

#[path = "common.rs"]
mod common;

use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::metrics::NormScales;
use hmai::plan::queue_for;
use hmai::platform::Platform;
use hmai::runtime::TrainBatch;
use hmai::sched::flexai::featurize::featurize;
use hmai::sched::{Registry, Scheduler};
use hmai::sim::{simulate, ShadowState, SimOptions};
use hmai::util::bench::{section, Bencher};
use hmai::util::json::Json;

const JSON_PATH: &str = "BENCH_PERF.json";

fn main() -> anyhow::Result<()> {
    let platform = Platform::hmai();
    let queue = queue_for(Area::Urban, 60.0, 0, DeadlineMode::Rss, 1);
    let scales = NormScales::for_queue(&queue, &platform);
    let state = ShadowState::new(&platform, scales);
    let task = queue.tasks[0].clone();
    let mut b = Bencher::new();

    section("L3 engine primitives");
    b.bench("ShadowState::clone (11 accels)", || {
        std::hint::black_box(state.clone());
    });
    b.bench("ShadowState::apply", || {
        let mut s = state.clone();
        std::hint::black_box(s.apply(&task, 3));
    });

    // The compiled-executable sections need the PJRT runtime; without it
    // the bench still measures (and reports) everything runtime-free.
    let rt = match common::runtime() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("[bench] PJRT sections skipped: {e:#}");
            None
        }
    };
    if let Some(rt) = &rt {
        let mut feat = vec![0.0f32; rt.meta.in_dim];
        b.bench("featurize (134-dim state)", || {
            std::hint::black_box(featurize(&task, &state, &rt.meta, &mut feat));
        });

        section("L2/L1 compiled executables (PJRT CPU)");
        let params = rt.init_params(1)?;
        featurize(&task, &state, &rt.meta, &mut feat);
        b.bench("qnet_infer (1x134 -> 16 Q)", || {
            std::hint::black_box(rt.infer(&params, &feat).unwrap());
        });
        let mut states = Vec::new();
        for _ in 0..rt.meta.infer_batch {
            states.extend_from_slice(&feat);
        }
        b.bench("qnet_infer_batch (30x134)", || {
            std::hint::black_box(rt.infer_batch(&params, &states).unwrap());
        });
        let mut batch = TrainBatch::zeros(&rt.meta);
        for (i, v) in batch.s.iter_mut().enumerate() {
            *v = (i % 13) as f32 / 13.0;
        }
        batch.s2.copy_from_slice(&batch.s);
        let targ = params.clone();
        b.bench("qnet_train (batch 64, SGD step)", || {
            std::hint::black_box(rt.train_step(&params, &targ, &batch).unwrap());
        });
    }

    section("end-to-end scheduling throughput (tasks/s)");
    let reg = Registry::new();
    let burst: Vec<_> = queue.tasks.iter().take(30).cloned().collect();
    for name in ["minmin", "ata", "edp", "sa", "ga", "rr"] {
        let mut s = reg.build_by_name(name, 1).unwrap();
        let r = b.bench(&format!("{name}: 30-task burst"), || {
            std::hint::black_box(s.schedule_batch(&burst, &state));
        });
        println!("    -> {:.0} decisions/s", 30.0 / r.mean());
    }
    if let Some(rt) = &rt {
        let mut agent = hmai::sched::flexai::FlexAI::new(
            rt.clone(),
            hmai::sched::flexai::FlexAIConfig { seed: 1, ..Default::default() },
        )?;
        agent.set_training(false);
        let r = b.bench("flexai: 30-task burst (greedy)", || {
            std::hint::black_box(agent.schedule_batch(&burst, &state));
        });
        println!("    -> {:.0} decisions/s", 30.0 / r.mean());
    }

    section("whole-queue simulation (Min-Min, 60 m route)");
    let mut minmin = hmai::sched::minmin::MinMin::new();
    b.bench("simulate 60 m UB queue", || {
        minmin.reset();
        std::hint::black_box(simulate(&queue, &platform, &mut minmin, SimOptions::default()));
    });

    section("DSE frontier (greedy, budget 3, 50 m urban-rush)");
    let mut heavy = Bencher::heavy();
    let dse_cfg = hmai::dse::DseConfig {
        budget_area: 3.0,
        distances_m: vec![50.0],
        max_evals: 32,
        beam: 1,
        search: hmai::dse::SearchMode::Greedy,
        seed: 1,
        ..Default::default()
    };
    let frontier_size = std::cell::Cell::new(0usize);
    heavy.bench("dse greedy search + Pareto frontier", || {
        let report = hmai::dse::run(&dse_cfg, &reg).unwrap();
        frontier_size.set(report.frontier);
        std::hint::black_box(report);
    });
    println!("    -> frontier of {} non-dominated mixes", frontier_size.get());

    // Machine-readable perf trajectory: one row per benchmark.
    let rows: Vec<Json> = b
        .results()
        .iter()
        .chain(heavy.results().iter())
        .map(|r| {
            Json::from_pairs(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_s", Json::Num(r.mean())),
                ("p50_s", Json::Num(r.p50())),
                ("p95_s", Json::Num(r.p95())),
                ("iters", Json::Num(r.samples.len() as f64)),
            ])
        })
        .collect();
    let report = Json::from_pairs(vec![
        ("bench", Json::Str("bench_perf".to_string())),
        ("pjrt_runtime", Json::Bool(rt.is_some())),
        ("dse_frontier_size", Json::Num(frontier_size.get() as f64)),
        ("results", Json::Arr(rows)),
    ]);
    report.write_to(std::path::Path::new(JSON_PATH))?;
    println!("\njson -> {JSON_PATH}");
    Ok(())
}
