//! Scenario-variability sweep: every library archetype × every registered
//! scheduler through the `Engine`, printing per-archetype queue statistics
//! and the per-scenario breakdown table.  FlexAI joins the sweep when the
//! PJRT artifacts are available (same checkpoint resolution as fig12);
//! otherwise the sweep covers the remaining registered schedulers.
//!
//! Set HMAI_BENCH_SCALE to resize routes, HMAI_BENCH_JOBS to pin workers.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::engine::Engine;
use hmai::env::scenario;
use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::plan::ExperimentPlan;
use hmai::sched::SchedulerSpec;
use hmai::util::bench::section;
use hmai::util::json::Json;
use hmai::util::table::{f1, f2, Table};

const JSON_PATH: &str = "BENCH_SCENARIOS.json";

fn main() {
    let dist = 300.0 * (common::scale() / 0.2).max(0.2);

    section(&format!("scenario library — queue statistics at {dist:.0} m"));
    let mut t = Table::new([
        "Scenario", "Legs", "Cameras", "Hz x", "Dropouts", "Events", "Tasks", "Tasks/s",
    ]);
    for arch in scenario::library() {
        let q = arch.queue_for(dist, 0, DeadlineMode::Rss, 42);
        t.row([
            arch.name.clone(),
            arch.legs.len().to_string(),
            arch.rig.total().to_string(),
            f2(arch.hz_scale),
            arch.dropouts.len().to_string(),
            arch.events.len().to_string(),
            q.len().to_string(),
            f1(q.len() as f64 / q.route_duration_s),
        ]);
    }
    t.print();

    // Every registered scheduler sweeps the whole library.  FlexAI's
    // factory is registered but needs artifacts: include it only when a
    // runtime resolves, like the fig12/fig14 benches.
    let reg = common::registry();
    let mut schedulers: Vec<SchedulerSpec> = Vec::new();
    match common::flexai_spec(Area::Urban) {
        Ok(spec) => schedulers.push(spec),
        Err(e) => eprintln!("[bench] FlexAI unavailable, remaining schedulers only: {e:#}"),
    }
    schedulers.extend(hmai::harness::registered_non_flexai_specs(&reg));

    let plan = ExperimentPlan::new()
        .all_scenarios()
        .distances([dist])
        .schedulers(schedulers)
        .seed(42);
    section(&format!(
        "scenario × scheduler sweep ({} archetypes × {} schedulers = {} trials, events on)",
        scenario::names().len(),
        plan.len() / scenario::names().len(),
        plan.len()
    ));
    let t0 = std::time::Instant::now();
    // Streaming sweep: trials fold into the summary and drop immediately
    // (no retained SimResults), with platform events live so the fault
    // archetypes (accel-failure, thermal-throttle) actually fail hardware.
    let sweep = Engine::new(&reg)
        .jobs(common::jobs())
        .events(true)
        .sweep_streaming(&plan)
        .expect("sweep runs");
    let elapsed_s = t0.elapsed().as_secs_f64();
    println!("{} trials in {elapsed_s:.1} s", sweep.total_runs());
    hmai::reports::sweep_table(&sweep).print();

    // Shape: one sweep row per (scheduler, archetype) and a stable,
    // jobs-invariant fingerprint (the tests pin jobs-invariance; here we
    // print it so regressions are visible in bench logs).
    assert_eq!(sweep.total_runs(), plan.len());
    println!("\nsweep fingerprint: {:016x}", sweep.fingerprint());

    // Machine-readable trajectory, through the shared util::json writer.
    let report = Json::from_pairs(vec![
        ("bench", Json::Str("bench_scenarios".to_string())),
        ("distance_m", Json::Num(dist)),
        ("events", Json::Bool(true)),
        ("trials", Json::Num(sweep.total_runs() as f64)),
        ("elapsed_s", Json::Num(elapsed_s)),
        ("fingerprint", Json::Str(format!("{:016x}", sweep.fingerprint()))),
        ("sweep", sweep.to_json()),
    ]);
    report.write_to(std::path::Path::new(JSON_PATH)).expect("write bench json");
    println!("json -> {JSON_PATH}");
    println!("bench_scenarios OK");
}
