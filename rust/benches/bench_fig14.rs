//! Fig. 14: the braking experiment — after 1 km (scaled) the forward
//! camera sees an obstacle 250 m ahead at 60 km/h; the braking distance
//! decomposes into T_wait + T_schedule + T_compute + T_data + T_mech plus
//! the kinematic stopping distance (Eq. 1 family, §8.4).
//!
//! Shape targets: FlexAI has the smallest braking distance, driven by
//! T_wait ≈ 0; the worst case (and typically GA) exceeds the 250 m sensing
//! range (collision); braking-distance reduction vs the worst baseline is
//! the paper's headline "up to 96%".
//!
//! Every scheduler's probe run executes as one `Engine` trial (with task
//! records on), so the whole figure is a single parallel sweep.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::engine::{Engine, TrialResult};
use hmai::env::Area;
use hmai::safety::braking::{braking_distance_m, stops_within, BrakingBreakdown};
use hmai::sim::SimOptions;
use hmai::util::bench::section;
use hmai::util::table::{f2, pct, Table};

fn main() {
    let area = Area::Urban;
    let dist = common::distances()[0]; // one route
    let brake_at = dist * 0.5;
    let v = area.max_velocity_ms();
    section(&format!(
        "Fig. 14 — braking probe at {brake_at:.0} m of a {dist:.0} m route, v = {v:.1} m/s"
    ));

    let reg = common::registry();
    let mut schedulers = Vec::new();
    let flexai_on = match common::flexai_spec(area) {
        Ok(spec) => {
            schedulers.push(spec);
            true
        }
        Err(e) => {
            eprintln!("[bench] FlexAI unavailable, baselines only: {e:#}");
            false
        }
    };
    schedulers.extend(common::baselines());

    let plan = common::plan(area).distances([dist]).schedulers(schedulers);
    let results = Engine::new(&reg)
        .jobs(common::jobs())
        .sim_options(SimOptions { record_tasks: true })
        .run(&plan)
        .expect("sweep runs");

    let mut t = Table::new([
        "Scheduler", "T_wait (ms)", "T_sched (ms)", "T_compute (ms)", "Total (ms)",
        "Braking dist (m)", "Safe", "STMRate",
    ]);
    let mut dists: Vec<(String, f64)> = Vec::new();

    for r in &results {
        let rec = probe(r, brake_at / v);
        let bd = BrakingBreakdown::new(rec.wait_s, r.sched_per_task_s(), rec.compute_s);
        let d = braking_distance_m(v, &bd);
        t.row([
            r.summary.scheduler.clone(),
            f2(bd.t_wait * 1e3),
            f2(bd.t_schedule * 1e3),
            f2(bd.t_compute * 1e3),
            f2(bd.total() * 1e3),
            f2(d),
            if stops_within(v, &bd, 250.0) { "yes".into() } else { "NO".into() },
            pct(r.summary.stm_rate()),
        ]);
        dists.push((r.summary.scheduler.clone(), d));
    }
    t.print();

    let worst_d = dists.iter().map(|(_, d)| *d).fold(0.0, f64::max);
    if flexai_on {
        let flex = dists.iter().find(|(n, _)| n == "FlexAI").unwrap().1;
        for (name, d) in &dists {
            // Within half a percent counts as a tie (SA lands within ~5 mm).
            assert!(flex <= *d * 1.005, "FlexAI braking {flex} m !<= {name} {d} m");
        }
        println!(
            "\nfig14 OK: FlexAI {flex:.2} m; max reduction vs worst baseline = {}",
            pct(1.0 - flex / worst_d)
        );
    } else {
        println!("\nfig14 OK (baselines only; FlexAI skipped); worst {worst_d:.2} m");
    }
}

/// First forward-camera detection task released at or after `t_probe`.
fn probe(r: &TrialResult, t_probe: f64) -> &hmai::sim::TaskRecord {
    hmai::sim::first_detection_after(&r.records, t_probe).expect("probe task exists")
}
