//! Fig. 14: the braking experiment — after 1 km (scaled) the forward
//! camera sees an obstacle 250 m ahead at 60 km/h; the braking distance
//! decomposes into T_wait + T_schedule + T_compute + T_data + T_mech plus
//! the kinematic stopping distance (Eq. 1 family, §8.4).
//!
//! Shape targets: FlexAI has the smallest braking distance, driven by
//! T_wait ≈ 0; the worst case (and typically GA) exceeds the 250 m sensing
//! range (collision); braking-distance reduction vs the worst baseline is
//! the paper's headline "up to 96%".

#[path = "common.rs"]
mod common;

use hmai::env::Area;
use hmai::harness;
use hmai::platform::Platform;
use hmai::safety::braking::{braking_distance_m, stops_within, BrakingBreakdown};
use hmai::sim::{SimOptions, SimResult};
use hmai::util::bench::section;
use hmai::util::table::{f2, pct, Table};

fn main() {
    let area = Area::Urban;
    let mut env = common::env(area);
    env.distances_m = vec![env.distances_m[0]]; // one route
    let brake_at = env.distances_m[0] * 0.5;
    let queues = harness::make_queues(&env);
    let platform = Platform::hmai();
    let v = area.max_velocity_ms();
    section(&format!(
        "Fig. 14 — braking probe at {brake_at:.0} m of a {:.0} m route, v = {v:.1} m/s",
        env.distances_m[0]
    ));

    let mut t = Table::new([
        "Scheduler", "T_wait (ms)", "T_sched (ms)", "T_compute (ms)", "Total (ms)",
        "Braking dist (m)", "Safe", "STMRate",
    ]);
    let mut dists: Vec<(String, f64)> = Vec::new();

    let mut probe = |name: String, r: &SimResult| {
        let t_probe = brake_at / v;
        let rec = r
            .records
            .iter()
            .filter(|x| x.release_s >= t_probe && !x.model.is_tracker())
            .min_by(|a, b| a.release_s.total_cmp(&b.release_s))
            .expect("probe task exists");
        let bd = BrakingBreakdown::new(rec.wait_s, r.sched_per_task_s(), rec.compute_s);
        let d = braking_distance_m(v, &bd);
        t.row([
            name.clone(),
            f2(bd.t_wait * 1e3),
            f2(bd.t_schedule * 1e3),
            f2(bd.t_compute * 1e3),
            f2(bd.total() * 1e3),
            f2(d),
            if stops_within(v, &bd, 250.0) { "yes".into() } else { "NO".into() },
            pct(r.summary.stm_rate()),
        ]);
        dists.push((name, d));
    };

    {
        let mut agent = common::flexai(area).expect("flexai constructible");
        let r = harness::run_queues(&queues, &platform, &mut agent, SimOptions {
            record_tasks: true,
        })
        .remove(0);
        probe("FlexAI".into(), &r);
    }
    for mut b in common::baselines(42) {
        let r = harness::run_queues(&queues, &platform, b.as_mut(), SimOptions {
            record_tasks: true,
        })
        .remove(0);
        probe(b.name(), &r);
    }
    t.print();

    let flex = dists.iter().find(|(n, _)| n == "FlexAI").unwrap().1;
    let worst_d = dists.iter().map(|(_, d)| *d).fold(0.0, f64::max);
    for (name, d) in &dists {
        // Within half a percent counts as a tie (SA lands within ~5 mm).
        assert!(flex <= *d * 1.005, "FlexAI braking {flex} m !<= {name} {d} m");
    }
    println!(
        "\nfig14 OK: FlexAI {flex:.2} m; max reduction vs worst baseline = {}",
        pct(1.0 - flex / worst_d)
    );
}
