//! Fig. 10: HMAI vs NVIDIA Tesla T4 vs homogeneous platforms — speedup,
//! power and TOPS/W over five urban task queues (scheduler held constant:
//! SA on every multi-accelerator platform, so the comparison isolates the
//! *hardware*; FlexAI-vs-baseline scheduling is Fig. 12's axis).
//!
//! Shape targets (paper): HMAI ~5x speedup over T4 with ~2x its power but
//! higher TOPS/W (~2.5x); homogeneous platforms are faster than HMAI (more
//! units provisioned) but less efficient (TOPS/W below HMAI).

#[path = "common.rs"]
mod common;

use hmai::accel::{energy::idle_power_w, t4};
use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::plan::queue_for;
use hmai::platform::Platform;
use hmai::sched::sa::Sa;
use hmai::sim::{simulate, SimOptions};
use hmai::util::bench::section;
use hmai::util::stats::geomean;
use hmai::util::table::{f2, times, Table};
use hmai::workload::model;

struct PlatformRow {
    speedups: Vec<f64>,
    powers: Vec<f64>,
    tops_w: Vec<f64>,
}

fn main() {
    let env = common::env(Area::Urban);
    let queues: Vec<_> = env
        .distances_m
        .iter()
        .enumerate()
        .map(|(i, &d)| queue_for(env.area, d, i, DeadlineMode::Rss, env.seed))
        .collect();
    println!(
        "5 urban queues, {} tasks total (HMAI_BENCH_SCALE={})",
        queues.iter().map(|q| q.len()).sum::<usize>(),
        common::scale()
    );

    // T4 baseline: sequential inference at the roofline model's latency.
    let t4_time: Vec<f64> = queues
        .iter()
        .map(|q| q.tasks.iter().map(|t| t4::latency_s(t.model)).sum())
        .collect();
    let total_tops: Vec<f64> = queues
        .iter()
        .map(|q| {
            q.tasks.iter().map(|t| 2.0 * model(t.model).total_macs as f64).sum::<f64>() / 1e12
        })
        .collect();

    let platforms = [
        Platform::hmai(),
        Platform::homogeneous(hmai::accel::AccelKind::SconvOD),
        Platform::homogeneous(hmai::accel::AccelKind::SconvIC),
        Platform::homogeneous(hmai::accel::AccelKind::MconvMC),
    ];

    let mut rows: Vec<(String, PlatformRow)> = Vec::new();
    for p in &platforms {
        let mut r = PlatformRow { speedups: vec![], powers: vec![], tops_w: vec![] };
        for (i, q) in queues.iter().enumerate() {
            let mut sa = Sa::new(42);
            let res = simulate(q, p, &mut sa, SimOptions::default());
            // Fig. 10(a) speedup is a *throughput* claim: time to process
            // the queue (busiest accelerator's busy time) — this is where
            // the over-provisioned homogeneous platforms beat HMAI.
            let makespan = res.summary.makespan_s.max(1e-9);
            // Fig. 10(b/c) power and TOPS/W are *deployment* claims: the
            // platform runs for the route duration and provisioned-but-
            // idle units burn idle power — this is where HMAI's higher
            // utilization wins (the paper's own §8.2 argument).
            let wall = makespan.max(q.route_duration_s);
            let t4_wall = t4_time[i];
            let mut power = 0.0;
            for (ai, am) in res.final_state.metrics.per_accel.iter().enumerate() {
                let busy_frac = (am.busy_s / wall).min(1.0);
                power += am.energy_j / wall
                    + idle_power_w(p.accels[ai].kind) * (1.0 - busy_frac);
            }
            r.speedups.push(t4_wall / makespan);
            r.powers.push(power);
            r.tops_w.push(total_tops[i] / wall / power);
        }
        rows.push((p.name.clone(), r));
    }

    let t4_tops_w: Vec<f64> = (0..queues.len())
        .map(|i| total_tops[i] / t4_time[i] / t4::TDP_W)
        .collect();

    section("Fig. 10(a) — speedup over Tesla T4 (geomean over 5 queues)");
    let mut t = Table::new(["Platform", "Speedup", "Power (W)", "Power vs T4", "TOPS/W", "TOPS/W vs T4"]);
    t.row([
        "Tesla T4".into(),
        times(1.0),
        f2(t4::TDP_W),
        times(1.0),
        format!("{:.4}", geomean(&t4_tops_w)),
        times(1.0),
    ]);
    for (name, r) in &rows {
        t.row([
            name.clone(),
            times(geomean(&r.speedups)),
            f2(geomean(&r.powers)),
            times(geomean(&r.powers) / t4::TDP_W),
            format!("{:.4}", geomean(&r.tops_w)),
            times(geomean(&r.tops_w) / geomean(&t4_tops_w)),
        ]);
    }
    t.print();

    // Shape assertions.
    let hmai_row = &rows[0].1;
    let hmai_speed = geomean(&hmai_row.speedups);
    let hmai_tw = geomean(&hmai_row.tops_w);
    assert!(hmai_speed > 2.0, "HMAI speedup over T4 = {hmai_speed}");
    assert!(
        hmai_tw > geomean(&t4_tops_w),
        "HMAI TOPS/W {hmai_tw} !> T4 {}",
        geomean(&t4_tops_w)
    );
    for (name, r) in &rows[1..] {
        assert!(
            hmai_tw > geomean(&r.tops_w),
            "HMAI TOPS/W !> {name} ({} vs {})",
            hmai_tw,
            geomean(&r.tops_w)
        );
    }
    println!("\nfig10 OK: HMAI {:.1}x T4 speedup, best TOPS/W of all platforms", hmai_speed);
}
