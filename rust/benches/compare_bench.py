#!/usr/bin/env python3
"""Fail-soft perf-regression compare for BENCH_PERF.json trajectories.

Usage:
    python3 benches/compare_bench.py <baseline.json> <current.json> [--warn-pct 25]

Matches benchmark rows by name and compares mean_s.  Rows slower than the
baseline by more than --warn-pct emit a GitHub Actions `::warning::`
annotation; everything else is informational.  The script NEVER fails the
build (exit code is always 0): micro-benchmarks on shared CI runners are
noisy, so regressions warn humans instead of blocking merges.

The committed baseline lives at benches/perf_baseline.json.  A baseline
with `"bootstrap": true` (or no rows) skips the comparison and prints
refresh instructions — copy a CI BENCH_PERF.json artifact over it to arm
the gate.
"""

import argparse
import json
import sys


def annotate(line):
    """GitHub workflow commands (::warning::/::notice::) go to stderr so the
    runner still parses them but a `| tee -a $GITHUB_STEP_SUMMARY` on stdout
    does not splice them into the markdown tables."""
    print(line, file=sys.stderr)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        annotate(f"::notice::perf compare skipped: cannot read {path}: {e}")
        return None


def rows_by_name(doc):
    out = {}
    for row in doc.get("results", []):
        name, mean = row.get("name"), row.get("mean_s")
        if isinstance(name, str) and isinstance(mean, (int, float)) and mean > 0:
            out[name] = float(mean)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--warn-pct", type=float, default=25.0)
    args = ap.parse_args()

    current = load(args.current)
    if current is None:
        return 0
    speedup = current.get("speedup", {})
    if speedup:
        print("### Measured speedups (reference vs optimized, this run)")
        for key, ratio in sorted(speedup.items()):
            print(f"- `{key}`: **{ratio:.2f}x**")

    baseline = load(args.baseline)
    if baseline is None:
        return 0
    if baseline.get("bootstrap") or not baseline.get("results"):
        annotate(
            "::notice::perf baseline is a bootstrap placeholder (no committed "
            "measurements). Refresh: download the BENCH_PERF.json artifact from "
            "a CI run on this machine class and commit it as "
            "benches/perf_baseline.json"
        )
        return 0

    base, cur = rows_by_name(baseline), rows_by_name(current)
    shared = [n for n in cur if n in base]
    if not shared:
        annotate("::notice::perf compare: no benchmark names shared with the baseline")
        return 0

    print(f"\n### Perf vs committed baseline (warn at >{args.warn_pct:.0f}% slower)")
    print("| benchmark | baseline mean | current mean | delta |")
    print("|---|---|---|---|")
    regressions = 0
    for name in shared:
        pct = (cur[name] - base[name]) / base[name] * 100.0
        flag = ""
        if pct > args.warn_pct:
            regressions += 1
            flag = " ⚠️"
            annotate(
                f"::warning::perf regression: '{name}' is {pct:.0f}% slower "
                f"than the committed baseline ({base[name]:.3e}s -> {cur[name]:.3e}s)"
            )
        print(f"| {name} | {base[name]:.3e} s | {cur[name]:.3e} s | {pct:+.1f}%{flag} |")
    dropped = sorted(set(base) - set(cur))
    if dropped:
        print(f"\n(baseline rows with no current match: {', '.join(dropped)})")
    print(
        f"\n{regressions} regression(s) over threshold out of {len(shared)} "
        "compared rows (fail-soft: warnings only)."
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
