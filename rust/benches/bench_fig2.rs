//! Fig. 2 (+ Table 9): energy and resource utilization of homogeneous
//! platforms vs the heterogeneous HMAI across the three urban scenarios,
//! via the exhaustive allocation search.  Asserts the paper's shape: HMAI
//! has the lowest power and the highest utilization in every scenario.

#[path = "common.rs"]
mod common;

use hmai::env::{Area, ALL_SCENARIOS};
use hmai::platform::alloc;
use hmai::util::bench::{section, Bencher};
use hmai::util::table::{f2, pct, Table};

fn main() {
    let area = Area::Urban;
    let platforms: [(&str, (usize, usize, usize)); 4] = [
        ("13xSconvOD", (13, 0, 0)),
        ("13xSconvIC", (0, 13, 0)),
        ("12xMconvMC", (0, 0, 12)),
        ("HMAI(4,4,3)", (4, 4, 3)),
    ];

    section("Fig. 2 — power + utilization, homogeneous vs HMAI (urban)");
    let mut t = Table::new(["Platform", "Scenario", "Power (W)", "Utilization"]);
    let mut hmai_vals = Vec::new();
    let mut homo_vals: Vec<(String, hmai::env::Scenario, f64, f64)> = Vec::new();
    for (name, counts) in platforms {
        for s in ALL_SCENARIOS {
            let reqs = alloc::requirements(area, s);
            let (a, u) = alloc::best_allocation(counts, &reqs)
                .unwrap_or_else(|| panic!("{name} infeasible in {s:?}"));
            let p = alloc::power_w_provisioned(&a, &reqs, counts);
            t.row([name.to_string(), s.name().to_string(), f2(p), pct(u)]);
            if name.starts_with("HMAI") {
                hmai_vals.push((s, p, u));
            } else {
                homo_vals.push((name.to_string(), s, p, u));
            }
        }
    }
    t.print();

    section("Table 9 — best allocation on (4, 4, 3)");
    println!("{}", hmai::reports::render("table9").unwrap());

    // Paper shape: HMAI strictly better on both axes, every scenario.
    for (s, hp, hu) in &hmai_vals {
        for (name, hs, p, u) in &homo_vals {
            if hs == s {
                assert!(hp < p, "{name} {s:?}: HMAI power {hp} !< {p}");
                assert!(hu > u, "{name} {s:?}: HMAI util {hu} !> {u}");
            }
        }
    }

    section("microbench — allocation search");
    let mut b = Bencher::new();
    let reqs = alloc::requirements(area, hmai::env::Scenario::GoStraight);
    b.bench("best_allocation (4,4,3) exhaustive", || {
        std::hint::black_box(alloc::best_allocation((4, 4, 3), &reqs));
    });
    println!("\nfig2/table9 OK: HMAI dominates homogeneous on power and utilization");
}
