//! Fig. 11: FlexAI RL training loss curve — the TD loss falls steeply in
//! the first episode and stabilizes near zero in later episodes because
//! queue compositions are similar across episodes (§8.3).
//!
//! Full-scale training lives in `examples/train_flexai.rs`; this bench
//! runs a short in-process training and checks the convergence shape.

// Bench drivers report progress on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

#[path = "common.rs"]
mod common;

use hmai::config::{EnvConfig, ExperimentConfig, TrainConfig};
use hmai::env::Area;
use hmai::harness;
use hmai::util::bench::section;
use hmai::util::stats::mean;

fn main() {
    if let Err(e) = harness::load_runtime() {
        eprintln!("[bench] skipping fig11: {e:#}");
        return;
    }
    let dist = 100.0 * (common::scale() / 0.2).max(0.5);
    let cfg = ExperimentConfig {
        env: EnvConfig { area: Area::Urban, distances_m: vec![dist], seed: 42 },
        train: TrainConfig {
            episodes: 3,
            episode_distance_m: dist,
            checkpoint: String::new(),
        },
        ..Default::default()
    };
    section(&format!("Fig. 11 — TD loss curve (3 episodes x {dist:.0} m)"));
    let t0 = std::time::Instant::now();
    let out = harness::train_flexai(&cfg).expect("artifacts present (make artifacts)");
    let losses = &out.losses;
    println!(
        "{} decisions, {} SGD steps in {:.1} s",
        out.agent.steps,
        losses.len(),
        t0.elapsed().as_secs_f64()
    );

    // Print the curve in 20 buckets (the Fig. 11 series).
    let buckets = 20.min(losses.len());
    let per = (losses.len() / buckets).max(1);
    println!("\n  step      mean TD loss");
    for b in 0..buckets {
        let lo = b * per;
        let hi = ((b + 1) * per).min(losses.len());
        if lo >= hi {
            break;
        }
        let m = losses[lo..hi].iter().map(|&x| x as f64).sum::<f64>() / (hi - lo) as f64;
        let bar = "#".repeat(((m * 40.0).min(60.0)) as usize);
        println!("  {:6}  {:10.4}  {}", lo, m, bar);
    }

    // Shape: the steep initial collapse of Fig. 11 — the first ~50 SGD
    // steps sit far above the converged plateau (the paper's curve drops
    // from ~1e3 to ~0 within the first episode; ours from ~8 to ~0.75).
    let k = 50.min(losses.len() / 2);
    let head: Vec<f64> = losses[..k].iter().map(|&x| x as f64).collect();
    let d = losses.len() / 10;
    let tail: Vec<f64> = losses[losses.len() - d..].iter().map(|&x| x as f64).collect();
    assert!(
        mean(&head) > 2.0 * mean(&tail),
        "loss did not collapse: head {} vs tail {}",
        mean(&head),
        mean(&tail)
    );
    println!(
        "\nfig11 OK: loss collapsed {:.1}x (first {k} steps vs last decile)",
        mean(&head) / mean(&tail)
    );
}
