//! Regenerators for the paper's static tables (1-4, 6-9, 11).  Each
//! function returns a rendered `Table` whose rows come from the library's
//! models, not hard-coded copies — `hmai report <name>` prints them, the
//! test suite asserts the headline cells.

// Report rendering may narrate on stderr (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

use anyhow::{bail, Result};

use crate::accel::{cost, AccelKind, ALL_ACCELS};
use crate::env::camera_hz::{camera_hz, model_fps_requirement};
use crate::env::objects::table2_rows;
use crate::env::{Area, Scenario, ALL_GROUPS, ALL_SCENARIOS};
use crate::metrics::summary::SweepSummary;
use crate::platform::alloc;
use crate::util::table::{f1, f2, pct, Table};
use crate::workload::accuracy::TABLE3;
use crate::workload::{model, ALL_MODELS};

/// Render a sweep (`Engine` output) as the Fig. 12-style comparison table:
/// one row per scheduler × platform × scenario × area × deadline group,
/// aggregate columns over that group's queues/seeds.  The Scenario column
/// is the per-archetype breakdown of the scenario-variability library
/// ("-" for plain area/distance sweeps).  The three survival columns
/// (safety-tier STM, lost tasks, panicked trials) only move under fault
/// campaigns — an event-free sweep shows 100% / 0 / 0.
pub fn sweep_table(s: &SweepSummary) -> Table {
    let mut t = Table::new([
        "Scheduler", "Platform", "Scenario", "Area", "DL", "Queues", "Time M (s)",
        "Energy M (J)", "R_Balance", "MS/task", "STMRate", "Safety STM", "Lost", "Panicked",
        "Rsp P50 (ms)", "Rsp P99 (ms)", "Rsp P99.9 (ms)", "Brk P50 (m)", "Brk P99 (m)",
        "Brk P99.9 (m)",
    ]);
    for g in &s.groups {
        t.row([
            g.key.scheduler.clone(),
            g.key.platform.clone(),
            g.key.scenario.clone(),
            g.key.area.clone(),
            g.key.deadline.clone(),
            g.trials().to_string(),
            f2(g.geomean_time_s()),
            f1(g.geomean_energy_j()),
            f2(g.mean_r_balance()),
            f2(g.mean_ms_per_task()),
            pct(g.mean_stm_rate()),
            pct(g.safety_stm_rate()),
            g.stats.sum_lost_tasks.to_string(),
            g.failed_trials().to_string(),
            f2(g.response_quantile_s(0.50) * 1e3),
            f2(g.response_quantile_s(0.99) * 1e3),
            f2(g.response_quantile_s(0.999) * 1e3),
            f2(g.braking_quantile_m(0.50)),
            f2(g.braking_quantile_m(0.99)),
            f2(g.braking_quantile_m(0.999)),
        ]);
    }
    t
}

/// Render a DSE run (`hmai dse`) as a table: the Pareto frontier of
/// (deadline-met %, energy, area) first (★), then every other evaluated
/// mix in report order.
pub fn dse_table(report: &crate::dse::DseReport) -> Table {
    let mut t = Table::new([
        "", "Mix", "Topology", "Dies", "Cores", "Area", "Peak W", "STMRate", "STM UB",
        "Energy M (J)", "E LB (J)", "Time M (s)", "R_Balance", "Comm ms/task",
    ]);
    for r in &report.rows {
        t.row([
            if r.on_frontier { "★".to_string() } else { String::new() },
            r.spec.clone(),
            r.topology.clone(),
            r.chiplets.to_string(),
            r.cores.to_string(),
            f2(r.area),
            f1(r.peak_power_w),
            pct(r.stm_rate),
            pct(r.stm_bound),
            f1(r.energy_j),
            f1(r.energy_bound_j),
            f2(r.time_s),
            f2(r.r_balance),
            f2(r.comm_delay_ms_per_task),
        ]);
    }
    t
}

/// Render the multi-fidelity pipeline's accounting (`hmai dse` under the
/// default `--fidelity multi`): how the candidate pool shrank through
/// analytic pruning and each successive-halving rung before full-fidelity
/// evaluation.  `pool == pruned + screened out + promoted` by
/// construction — nothing leaves the pipeline uncounted.
pub fn dse_pipeline_table(report: &crate::dse::DseReport) -> Table {
    let mut t = Table::new(["Stage", "In", "Out", "Note"]);
    let pruned = report.pruned();
    t.row([
        "bound prune".to_string(),
        report.pool.to_string(),
        (report.pool - pruned).to_string(),
        format!("{pruned} dominated analytically"),
    ]);
    for (i, r) in report.rung_log.iter().enumerate() {
        t.row([
            format!("rung {}/{}", i + 1, report.rung_log.len()),
            r.entered.to_string(),
            r.promoted.to_string(),
            format!("screened at {:.3} of the route", r.route_frac),
        ]);
    }
    t.row([
        "full fidelity".to_string(),
        report.promoted.to_string(),
        report.evaluated.to_string(),
        format!(
            "{} low-fidelity eval(s), {} full row(s)",
            report.low_fidelity_evals, report.evaluated
        ),
    ]);
    t
}

/// Table 1: MACs, weights+neurons, layer counts of the three CNNs.
pub fn table1() -> Table {
    let mut t = Table::new(["CNN", "#MACs (G)", "#weights+neurons (M)", "Layers"]);
    for kind in ALL_MODELS {
        let m = model(kind);
        t.row([
            kind.name().to_string(),
            f1(m.gmacs()),
            f1(m.mweights_neurons()),
            m.num_layers().to_string(),
        ]);
    }
    t
}

/// Table 2: object area / image proportion at representative distances.
pub fn table2() -> Table {
    let mut t = Table::new(["Object", "Distance (m)", "Area (px)", "Proportion"]);
    for row in table2_rows() {
        t.row([
            row.class.name().to_string(),
            f2(row.distance_m),
            format!("{:.0}", row.model_area_px),
            format!("{:.2}%", row.model_area_px / (640.0 * 480.0) * 100.0),
        ]);
    }
    t
}

/// Table 3: YOLO/SSD AP by object size (constants from the cited papers).
pub fn table3() -> Table {
    let mut t = Table::new(["Method", "Backbone", "AP_S", "AP_M", "AP_L"]);
    for row in TABLE3 {
        t.row([
            row.method.to_string(),
            row.backbone.to_string(),
            f1(row.ap_s),
            f1(row.ap_m),
            f1(row.ap_l),
        ]);
    }
    t
}

/// Table 4: camera counts per function group.
pub fn table4() -> Table {
    let mut t = Table::new(["Function", "Cameras"]);
    for g in ALL_GROUPS {
        t.row([g.name().to_string(), g.count().to_string()]);
    }
    t.row(["Total".to_string(), crate::env::total_cameras().to_string()]);
    t
}

/// Table 5: per-model FPS requirements in urban area (derived from the
/// Fig. 1 Camera_HZ tables, not hard-coded).
pub fn table5() -> Table {
    let mut t = Table::new(["Scenario", "DET", "TRA", "YOLO", "SSD", "GOTURN"]);
    for s in ALL_SCENARIOS {
        let det = crate::env::camera_hz::aggregate_fps(Area::Urban, s, false);
        let tra = crate::env::camera_hz::aggregate_fps(Area::Urban, s, true);
        t.row([
            s.name().to_string(),
            format!("{det:.0}"),
            format!("{tra:.0}"),
            format!("{:.0}", model_fps_requirement(Area::Urban, s, crate::workload::ModelKind::Yolo)),
            format!("{:.0}", model_fps_requirement(Area::Urban, s, crate::workload::ModelKind::Ssd)),
            format!("{:.0}", model_fps_requirement(Area::Urban, s, crate::workload::ModelKind::Goturn)),
        ]);
    }
    t
}

/// Table 6: camera frame rates across driving datasets (literature
/// constants motivating ≥40 FPS cameras).
pub fn table6() -> Table {
    let mut t = Table::new(["Source", "Max velocity (km/h)", "Frame rate (FPS)"]);
    for (src, v, f) in [
        ("KITTI", "90", "10-100"),
        ("ApolloScape", "30", "30"),
        ("Princeton", "80", "10"),
        ("VisLab", "70.9", ">25"),
        ("Oxford RobotCar", "n/a", "11.1-16"),
        ("Comma.ai", "n/a", "20"),
    ] {
        t.row([src, v, f]);
    }
    t
}

/// Table 7: peak FPS of single accelerators from the literature — the
/// motivation that no single accelerator reaches the 1200 FPS a 30-camera
/// car needs.
pub fn table7() -> Table {
    let mut t = Table::new(["Device", "YOLO variant", "Peak FPS"]);
    for (d, y, f) in [
        ("GTX TitanX", "Sim-YOLO-v2", 88.0),
        ("GTX TitanX", "FAST YOLO", 155.0),
        ("Zynq UltraScale+", "Tincy YOLO", 30.0),
        ("Zynq UltraScale+", "Lightweight YOLO-v2", 40.81),
        ("Virtex-7 VC707", "Tiny YOLO-v2", 66.56),
        ("Virtex-7 VC707", "Sim-YOLO-v2", 109.3),
        ("ADM-7V3 FPGA(1)", "Tiny YOLO", 208.2),
        ("ADM-7V3 FPGA(2)", "Tiny YOLO", 314.2),
    ] {
        t.row([d.to_string(), y.to_string(), f1(f)]);
    }
    t
}

/// Table 8: FPS of the three sub-accelerators on the three CNNs (the
/// calibrated cycle model).
pub fn table8() -> Table {
    let mut t = Table::new(["Model", "SconvOD (FPS)", "SconvIC (FPS)", "MconvMC (FPS)"]);
    for m in ALL_MODELS {
        t.row([
            m.name().to_string(),
            f2(cost(AccelKind::SconvOD, m).fps()),
            f2(cost(AccelKind::SconvIC, m).fps()),
            f2(cost(AccelKind::MconvMC, m).fps()),
        ]);
    }
    t
}

/// Table 9: best task allocation on (4 SO, 4 SI, 3 MM) per UB scenario.
pub fn table9() -> Table {
    let mut t = Table::new(["Scenario", "YOLO", "SSD", "GOTURN", "Utilization"]);
    for s in ALL_SCENARIOS {
        let reqs = alloc::requirements(Area::Urban, s);
        let (a, u) = alloc::best_allocation((4, 4, 3), &reqs).expect("HMAI is feasible in UB");
        let cell = |mi: usize| {
            let mut parts = Vec::new();
            for k in ALL_ACCELS {
                let n = a[k.index()][mi];
                if n > 0 {
                    parts.push(format!("{} {}", n, k.short()));
                }
            }
            if parts.is_empty() { "-".into() } else { parts.join(", ") }
        };
        t.row([
            s.name().to_string(),
            cell(0),
            cell(1),
            cell(2),
            format!("{:.2}%", u * 100.0),
        ]);
    }
    t
}

/// Table 11: which metrics each algorithm covers.
pub fn table11() -> Table {
    let mut t = Table::new(["Metric", "EDP", "Min-Min", "ATA", "Rand", "GA", "SA", "FlexAI"]);
    let y = "yes";
    let n = "-";
    t.row(["Time", y, y, n, y, y, y, y]);
    t.row(["Energy", y, y, y, y, y, y, y]);
    t.row(["Resource", n, n, n, n, n, n, y]);
    t.row(["MS", n, n, y, n, n, n, y]);
    t
}

/// HMAI peak summary (supporting §3.1 numbers).
pub fn platform_summary() -> Table {
    let mut t = Table::new(["Platform", "Accels", "Peak TOPS"]);
    for (name, p) in [
        ("HMAI", crate::platform::Platform::hmai()),
        ("13xSconvOD", crate::platform::Platform::homogeneous(AccelKind::SconvOD)),
        ("13xSconvIC", crate::platform::Platform::homogeneous(AccelKind::SconvIC)),
        ("12xMconvMC", crate::platform::Platform::homogeneous(AccelKind::MconvMC)),
    ] {
        t.row([name.to_string(), p.len().to_string(), f2(p.peak_tops())]);
    }
    t
}

/// Fig. 1 frame-rate requirement matrix (per area × scenario × group).
pub fn fig1() -> Table {
    let mut t = Table::new(["Area", "Scenario", "FC", "FLSC", "RLSC", "FRSC", "RRSC", "RC"]);
    for a in crate::env::ALL_AREAS {
        for s in ALL_SCENARIOS {
            if s == Scenario::Reverse && !a.allows_reverse() {
                continue;
            }
            let mut row = vec![a.name().to_string(), s.name().to_string()];
            for g in ALL_GROUPS {
                row.push(format!("{:.0}", camera_hz(a, s, g)));
            }
            t.row(row);
        }
    }
    t
}

/// Render a report by name.
pub fn render(name: &str) -> Result<String> {
    let t = match name {
        "table1" => table1(),
        "table2" => table2(),
        "table3" => table3(),
        "table4" => table4(),
        "table5" => table5(),
        "table6" => table6(),
        "table7" => table7(),
        "table8" => table8(),
        "table9" => table9(),
        "table11" => table11(),
        "fig1" => fig1(),
        "platforms" => platform_summary(),
        _ => bail!(
            "unknown report '{name}' (try table1-9, table11, fig1, platforms)"
        ),
    };
    Ok(t.render())
}

/// All report names, for `hmai report all`.
pub const ALL_REPORTS: [&str; 12] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "table9",
    "table11", "fig1", "platforms",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render() {
        for name in ALL_REPORTS {
            let s = render(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(s.lines().count() >= 3, "{name} too short:\n{s}");
        }
        assert!(render("nope").is_err());
    }

    #[test]
    fn table1_headline_cells() {
        let s = table1().render();
        assert!(s.contains("SSD"));
        assert!(s.contains("101")); // YOLO layers
        assert!(s.contains("11")); // GOTURN layers
    }

    #[test]
    fn table5_matches_paper_totals() {
        let s = table5().render();
        assert!(s.contains("870"), "{s}");
        assert!(s.contains("840"), "{s}");
        assert!(s.contains("740"), "{s}");
    }

    #[test]
    fn table8_matches_calibration() {
        let s = table8().render();
        assert!(s.contains("170.37"), "{s}");
        assert!(s.contains("500.54"), "{s}");
    }

    #[test]
    fn table9_is_feasible_allocation_text() {
        let s = table9().render();
        assert!(s.contains('%'));
        assert!(s.contains("SO") || s.contains("SI") || s.contains("MM"));
    }

    #[test]
    fn sweep_table_renders_group_rows() {
        use crate::metrics::summary::{RunSummary, SweepKey};
        use crate::metrics::{NormScales, PlatformMetrics};
        let m = PlatformMetrics::new(2, NormScales::unit());
        let run = RunSummary::from_metrics("Min-Min", "HMAI", &m, 0, 0.0, 0.0, 0.0, 0.0);
        let mut sw = SweepSummary::new();
        sw.push(
            SweepKey {
                scheduler: "Min-Min".into(),
                platform: "HMAI".into(),
                scenario: "night-rain".into(),
                area: "UB".into(),
                deadline: "rss".into(),
            },
            run,
        );
        let s = sweep_table(&sw).render();
        assert!(s.contains("Min-Min"), "{s}");
        assert!(s.contains("STMRate"), "{s}");
        assert!(s.contains("Scenario"), "{s}");
        assert!(s.contains("night-rain"), "{s}");
        assert!(s.contains("Rsp P99 (ms)"), "{s}");
        assert!(s.contains("Brk P99.9 (m)"), "{s}");
        // Survival columns: an event-free run shows the benign values.
        assert!(s.contains("Safety STM"), "{s}");
        assert!(s.contains("Lost"), "{s}");
        assert!(s.contains("Panicked"), "{s}");
    }

    #[test]
    fn dse_table_marks_frontier_rows() {
        use crate::dse::{DseReport, EvalRow, Mix};
        let row = |spec: &str, frontier: bool| EvalRow {
            mix: Mix::hmai_std(),
            spec: spec.to_string(),
            topology: "mesh2x2".to_string(),
            chiplets: 4,
            cores: 11,
            area: 11.0,
            peak_power_w: 150.0,
            stm_rate: 0.95,
            energy_j: 1234.5,
            time_s: 10.0,
            r_balance: 0.8,
            comm_delay_ms_per_task: 1.25,
            comm_gb: 0.5,
            stm_bound: 0.99,
            energy_bound_j: 1000.0,
            on_frontier: frontier,
        };
        let report = DseReport {
            rows: vec![row("so:4,si:4,mm:3+mesh2x2", true), row("so:1@2x", false)],
            frontier: 1,
            evaluated: 2,
            search: "greedy",
            fidelity: "multi",
            rungs: 1,
            keep_frac: 0.5,
            budget_area: 12.0,
            power_cap_w: None,
            truncated: 0,
            topologies: vec!["mono".to_string(), "mesh2x2".to_string()],
            pool: 5,
            pruned_rows: vec![crate::dse::PrunedRow {
                spec: "so:9".to_string(),
                topology: "mono".to_string(),
                area: 9.0,
                stm_bound: 0.4,
                energy_bound_j: 2000.0,
            }],
            screened_out: 2,
            promoted: 2,
            low_fidelity_evals: 4,
            rung_log: vec![crate::dse::RungLog { route_frac: 0.5, entered: 4, promoted: 2 }],
        };
        let s = dse_table(&report).render();
        assert!(s.contains("so:4,si:4,mm:3+mesh2x2"), "{s}");
        assert!(s.contains('★'), "{s}");
        assert!(s.contains("95.0%"), "{s}");
        assert!(s.contains("99.0%"), "{s}"); // STM upper bound column
        assert!(s.contains("E LB (J)"), "{s}");
        assert!(s.contains("1000.0"), "{s}");
        assert!(s.contains("Topology"), "{s}");
        assert!(s.contains("mesh2x2"), "{s}");
        assert!(s.contains("Comm ms/task"), "{s}");
        assert!(s.contains("1.25"), "{s}");

        let p = dse_pipeline_table(&report).render();
        assert!(p.contains("bound prune"), "{p}");
        assert!(p.contains("1 dominated analytically"), "{p}");
        assert!(p.contains("rung 1/1"), "{p}");
        assert!(p.contains("screened at 0.500 of the route"), "{p}");
        assert!(p.contains("full fidelity"), "{p}");
        assert!(p.contains("4 low-fidelity eval(s)"), "{p}");
    }

    #[test]
    fn peak_tops_consistent() {
        let s = platform_summary().render();
        assert!(s.contains(&format!("{:.2}", 11.0 * crate::accel::peak_tops())));
    }
}
