//! Analytic candidate bounds for multi-fidelity DSE: a best-case
//! deadline-met rate and a lowest-possible energy per candidate mix,
//! derived from the *demand* of the evaluation slice (task counts per
//! model, route horizons) and the mix's static capacity
//! ([`Mix::capacity_fps`]) — no simulation involved.
//!
//! Soundness is the whole point: a candidate is pruned only when an
//! already-evaluated full-fidelity row dominates its *best case*, so
//! pruning can never remove a Pareto-frontier member (domination is
//! transitive, see DESIGN.md "DSE evaluation pipeline").
//!
//! Why the bounds hold against the simulator:
//!   * STM upper bound — in one evaluation cell every model-`m` task is
//!     released inside `[0, T)` (`T` = route duration) and meets its
//!     deadline only if it completes within its RSS slack, i.e. inside
//!     `[0, T + S_m)` with `S_m` the cell's largest model-`m` safety
//!     time.  Each core completes model-`m` work at most at its
//!     `cost_sized(...).fps()` rate (events are off in DSE and
//!     interconnect delay only *adds* latency), so the met count is at
//!     most `min(N_m, capacity_fps(m) · (T + S_m))` even with cores
//!     shared across models.
//!   * Energy lower bound — the simulator charges every executed task
//!     exactly its cost-table `energy_j` (energy is work, not duration,
//!     and communication adds none), and with events off no task is
//!     lost, so a cell's run energy is at least
//!     `Σ_m N_m · min_core_energy(m)`.  The per-run geometric mean the
//!     report uses is monotone in each run, so the geomean of the cell
//!     floors bounds the reported energy from below.

use anyhow::Result;

use crate::accel;
use crate::engine::QueueCache;
use crate::env::scenario;
use crate::plan::{replicate_seeds, Fidelity, Scenario, Trial};
use crate::workload::ALL_MODELS;

use super::{DseConfig, EvalRow, Mix};

/// One evaluation cell — one (scenario, distance, seed replicate) queue.
#[derive(Debug, Clone)]
pub(super) struct DemandCell {
    /// Task count per model kind (`ModelKind::index` order).
    pub n: [u64; 3],
    /// Largest RSS safety slack per model kind (s); 0 when absent.
    pub slack_s: [f64; 3],
    /// Route duration (s).
    pub route_s: f64,
    /// Total tasks in the cell.
    pub total: u64,
}

/// The evaluation slice's demand: one cell per (scenario, distance,
/// seed replicate), in plan-expansion order.  Candidate-independent, so
/// it is built once per DSE run.
#[derive(Debug, Clone)]
pub(super) struct Demand {
    pub cells: Vec<DemandCell>,
}

/// Best-case metrics for one candidate mix against a [`Demand`].
#[derive(Debug, Clone, Copy)]
pub struct CandidateBound {
    /// Upper bound on the deadline-met rate (Σmet / Σtasks).
    pub stm_ub: f64,
    /// Lower bound on the geometric-mean per-queue energy (J).
    pub energy_lb_j: f64,
}

/// Build the demand of `cfg`'s evaluation slice.  Queues are fetched
/// through the shared engine `cache` at full fidelity, so the candidate
/// evaluations that follow reuse the exact same `Arc`ed queues instead of
/// re-synthesizing routes.
pub(super) fn build_demand(cfg: &DseConfig, cache: &QueueCache) -> Result<Demand> {
    let seeds = replicate_seeds(cfg.seed, cfg.replicates.max(1));
    let mut cells = Vec::new();
    for seed in seeds {
        for name in &cfg.scenarios {
            let arch = scenario::find(name)?;
            let area = arch.primary_area();
            for (qi, &distance_m) in cfg.distances_m.iter().enumerate() {
                let trial = Trial {
                    id: 0,
                    scenario: Scenario {
                        archetype: Some(arch.clone()),
                        area,
                        distance_m,
                        deadline: cfg.deadline,
                    },
                    queue_index: qi,
                    platform: "hmai".to_string(),
                    scheduler: cfg.scheduler.clone(),
                    seed,
                    sched_seed: seed,
                    fidelity: Fidelity::full(),
                };
                let queue = cache.get(&trial);
                let mut n = [0u64; 3];
                let mut slack_s = [0.0f64; 3];
                for t in &queue.tasks {
                    let mi = t.model.index();
                    n[mi] += 1;
                    slack_s[mi] = slack_s[mi].max(t.safety_time_s);
                }
                cells.push(DemandCell {
                    n,
                    slack_s,
                    route_s: queue.route_duration_s,
                    total: queue.tasks.len() as u64,
                });
            }
        }
    }
    Ok(Demand { cells })
}

/// Compute `mix`'s best-case bound against `demand`.
pub(super) fn candidate_bound(mix: &Mix, demand: &Demand) -> CandidateBound {
    // Static per-model capacity and cheapest per-task energy of the mix.
    let mut cap_fps = [0.0f64; 3];
    let mut min_e = [f64::INFINITY; 3];
    for (mi, &model) in ALL_MODELS.iter().enumerate() {
        cap_fps[mi] = mix.capacity_fps(model);
        for (k, s, _) in mix.cells() {
            min_e[mi] = min_e[mi].min(accel::cost_sized(k, model, s).energy_j);
        }
    }
    let mut met_ub = 0.0f64;
    let mut tasks = 0.0f64;
    let mut sum_ln_floor = 0.0f64;
    for cell in &demand.cells {
        let mut cell_floor = 0.0f64;
        for mi in 0..ALL_MODELS.len() {
            let n = cell.n[mi] as f64;
            if n == 0.0 {
                continue;
            }
            let window_s = cell.route_s + cell.slack_s[mi];
            met_ub += n.min(cap_fps[mi] * window_s);
            if min_e[mi].is_finite() {
                cell_floor += n * min_e[mi];
            }
        }
        tasks += cell.total as f64;
        sum_ln_floor += cell_floor.max(1e-300).ln();
    }
    let stm_ub = if tasks == 0.0 { 1.0 } else { (met_ub / tasks).min(1.0) };
    // Small relative margin so float fold-order noise can never make an
    // exact-arithmetic-sound bound unsound in practice.
    let n_cells = demand.cells.len().max(1) as f64;
    let energy_lb_j = (sum_ln_floor / n_cells).exp() * (1.0 - 1e-9);
    CandidateBound { stm_ub, energy_lb_j }
}

/// Is a candidate with this `area` and best-case `bound` dominated by an
/// already-evaluated full-fidelity row?  Uses the same (stm ↑, energy ↓,
/// area ↓, at least one strict) domination as [`super::mark_frontier`],
/// applied to the candidate's *best case* — so a `true` here proves the
/// candidate's eventual row could never sit on the frontier.
pub(super) fn bound_dominated(rows: &[EvalRow], area: f64, bound: &CandidateBound) -> bool {
    rows.iter().any(|r| {
        r.stm_rate >= bound.stm_ub
            && r.energy_j <= bound.energy_lb_j
            && r.area <= area
            && (r.stm_rate > bound.stm_ub || r.energy_j < bound.energy_lb_j || r.area < area)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{AccelKind, CoreSize};

    fn tiny_cfg() -> DseConfig {
        DseConfig {
            scenarios: vec!["urban-rush".to_string()],
            distances_m: vec![40.0, 60.0],
            ..Default::default()
        }
    }

    #[test]
    fn demand_covers_every_cell_of_the_slice() {
        let cache = QueueCache::default();
        let d = build_demand(&tiny_cfg(), &cache).unwrap();
        assert_eq!(d.cells.len(), 2, "1 scenario x 2 distances x 1 replicate");
        for cell in &d.cells {
            assert!(cell.total > 0);
            assert_eq!(cell.n.iter().sum::<u64>(), cell.total);
            assert!(cell.route_s > 0.0);
            for mi in 0..3 {
                assert!((cell.n[mi] > 0) == (cell.slack_s[mi] > 0.0), "model {mi}");
            }
        }
        // Replicates multiply the cells.
        let cfg = DseConfig { replicates: 3, ..tiny_cfg() };
        let d3 = build_demand(&cfg, &cache).unwrap();
        assert_eq!(d3.cells.len(), 6);
        // Replicate 0 is the base seed: its cells match the single-replicate run.
        assert_eq!(d3.cells[0].total, d.cells[0].total);
    }

    #[test]
    fn bounds_grow_with_capacity_and_shrink_with_cheap_cores() {
        let cache = QueueCache::default();
        let d = build_demand(&tiny_cfg(), &cache).unwrap();
        let hmai = Mix::hmai_std();
        let b = candidate_bound(&hmai, &d);
        assert!(b.stm_ub > 0.0 && b.stm_ub <= 1.0);
        assert!(b.energy_lb_j > 0.0);
        // More cores: never a lower STM ceiling, never a higher energy floor.
        let bigger = hmai.with_added(AccelKind::SconvOD, CoreSize::Double);
        let bb = candidate_bound(&bigger, &d);
        assert!(bb.stm_ub >= b.stm_ub);
        assert!(bb.energy_lb_j <= b.energy_lb_j + 1e-12);
        // A single half core is capacity-starved well below a full rate.
        let one = Mix::default().with_added(AccelKind::SconvOD, CoreSize::Half);
        let ob = candidate_bound(&one, &d);
        assert!(ob.stm_ub < 1.0, "{}", ob.stm_ub);
    }

    #[test]
    fn bound_domination_needs_all_axes_and_one_strict() {
        let row = |stm: f64, e: f64, a: f64| EvalRow {
            mix: Mix::default(),
            spec: "r".to_string(),
            topology: "mono".to_string(),
            chiplets: 1,
            cores: 1,
            area: a,
            peak_power_w: 1.0,
            stm_rate: stm,
            energy_j: e,
            time_s: 1.0,
            r_balance: 0.5,
            comm_delay_ms_per_task: 0.0,
            comm_gb: 0.0,
            stm_bound: 1.0,
            energy_bound_j: 0.0,
            on_frontier: false,
        };
        let rows = vec![row(0.8, 10.0, 4.0)];
        let b = |stm_ub: f64, energy_lb_j: f64| CandidateBound { stm_ub, energy_lb_j };
        // Strictly worse best case on every axis: pruned.
        assert!(bound_dominated(&rows, 5.0, &b(0.7, 11.0)));
        // Equal on all axes, nothing strict: kept.
        assert!(!bound_dominated(&rows, 4.0, &b(0.8, 10.0)));
        // Equal bound, strictly larger area: pruned.
        assert!(bound_dominated(&rows, 4.5, &b(0.8, 10.0)));
        // A better best-case STM survives any row.
        assert!(!bound_dominated(&rows, 9.0, &b(0.9, 12.0)));
        // A cheaper best-case energy survives too.
        assert!(!bound_dominated(&rows, 9.0, &b(0.5, 9.0)));
    }
}
