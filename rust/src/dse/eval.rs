//! The DSE's batched candidate evaluator.
//!
//! Every unseen candidate of a batch — across *all* topology entries —
//! goes through **one** [`ExperimentPlan`] whose platform axis is the
//! candidate list, so trials from independent candidates run concurrently
//! on the engine's worker pool instead of serially per entry.  Three
//! caches make repeated evaluation cheap:
//!
//!   * a shared [`QueueCache`] handed to every engine run, so routes are
//!     synthesized once per (scenario, distance, seed, fidelity) for the
//!     whole exploration;
//!   * a per-(candidate, fidelity) result cache (`index` for full
//!     fidelity, `lf` for screening fractions), so rungs re-promoting a
//!     candidate never re-simulate it;
//!   * a compute memo keyed on the *canonical platform name* × fidelity,
//!     so spec spellings the platform parser folds together (e.g. a
//!     `+mono`-equivalent topology suffix) are simulated and folded once.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::engine::{Engine, QueueCache};
use crate::metrics::summary::SweepSummary;
use crate::plan::{ExperimentPlan, Fidelity};
use crate::platform::Platform;
use crate::sched::Registry;

use super::bounds::{self, Demand};
use super::{DseConfig, EvalRow, Mix, TopoEntry};

/// Folded simulation metrics for one candidate at one fidelity.
#[derive(Debug, Clone, Copy)]
pub(super) struct Metrics {
    pub stm_rate: f64,
    pub energy_j: f64,
    pub time_s: f64,
    pub r_balance: f64,
    pub comm_delay_ms_per_task: f64,
    pub comm_gb: f64,
}

pub(super) struct Evaluator<'a> {
    pub cfg: &'a DseConfig,
    registry: &'a Registry,
    /// Resolved topology axis (`[mono]` when the axis is off).
    pub topos: &'a [TopoEntry],
    /// The slice's demand (for analytic bounds on every row).
    pub demand: Demand,
    cache: Arc<QueueCache>,
    /// Full-fidelity rows, in first-evaluation order (deterministic).
    pub rows: Vec<EvalRow>,
    /// (mix, topology-axis index) → full-fidelity row index.
    index: BTreeMap<(Mix, usize), usize>,
    /// (mix, topology-axis index, route-frac bits) → screening metrics.
    lf: BTreeMap<(Mix, usize, u64), Metrics>,
    /// Canonical platform name × route-frac bits → folded metrics.
    memo: BTreeMap<(String, u64), Metrics>,
    /// Low-fidelity pairs in first-evaluation order (the greedy search's
    /// candidate pool under multi fidelity).
    pub lf_order: Vec<(Mix, usize)>,
    /// Candidates actually simulated at full / screening fidelity.
    pub full_sims: usize,
    pub lf_sims: usize,
    /// Candidates served from the canonical-name memo without a sweep.
    pub memo_hits: usize,
}

impl<'a> Evaluator<'a> {
    pub fn new(
        cfg: &'a DseConfig,
        registry: &'a Registry,
        topos: &'a [TopoEntry],
    ) -> Result<Evaluator<'a>> {
        let cache = Arc::new(QueueCache::default());
        let demand = bounds::build_demand(cfg, &cache)?;
        Ok(Evaluator {
            cfg,
            registry,
            topos,
            demand,
            cache,
            rows: Vec::new(),
            index: BTreeMap::new(),
            lf: BTreeMap::new(),
            memo: BTreeMap::new(),
            lf_order: Vec::new(),
            full_sims: 0,
            lf_sims: 0,
            memo_hits: 0,
        })
    }

    /// The full-fidelity axis of this run: whole routes,
    /// `cfg.replicates` seed replicates.
    pub fn full_fidelity(&self) -> Fidelity {
        Fidelity { route_frac: 1.0, replicates: self.cfg.replicates.max(1) }
    }

    pub fn evaluated(&self) -> usize {
        self.rows.len()
    }

    pub fn has_row(&self, mix: &Mix, ti: usize) -> bool {
        self.index.contains_key(&(*mix, ti))
    }

    pub fn row(&self, mix: &Mix, ti: usize) -> &EvalRow {
        &self.rows[self.index[&(*mix, ti)]]
    }

    /// Candidates evaluated so far at `fid` (the search-budget counter).
    pub fn searched(&self, fid: Fidelity) -> usize {
        if fid.is_full() {
            self.rows.len()
        } else {
            self.lf_order.len()
        }
    }

    /// Folded metrics of an already-evaluated candidate at `fid`.
    pub fn metric(&self, mix: &Mix, ti: usize, fid: Fidelity) -> Metrics {
        if fid.is_full() {
            let r = self.row(mix, ti);
            Metrics {
                stm_rate: r.stm_rate,
                energy_j: r.energy_j,
                time_s: r.time_s,
                r_balance: r.r_balance,
                comm_delay_ms_per_task: r.comm_delay_ms_per_task,
                comm_gb: r.comm_gb,
            }
        } else {
            self.lf[&(*mix, ti, fid.frac_bits())]
        }
    }

    /// Evaluate every not-yet-seen mix of `mixes` on topology entry `ti`.
    pub fn eval_all(&mut self, mixes: &[Mix], ti: usize, fid: Fidelity) -> Result<()> {
        let pairs: Vec<(Mix, usize)> = mixes.iter().map(|&m| (m, ti)).collect();
        self.eval_pairs(&pairs, fid)
    }

    /// Evaluate every not-yet-seen (mix, topology entry) pair of `pairs`
    /// at fidelity `fid` in one engine sweep.
    pub fn eval_pairs(&mut self, pairs: &[(Mix, usize)], fid: Fidelity) -> Result<()> {
        let frac = fid.frac_bits();
        let mut fresh: Vec<(Mix, usize)> = Vec::new();
        for &(m, ti) in pairs {
            let seen = if fid.is_full() {
                self.index.contains_key(&(m, ti))
            } else {
                self.lf.contains_key(&(m, ti, frac))
            };
            if !seen && !fresh.contains(&(m, ti)) {
                fresh.push((m, ti));
            }
        }
        if fresh.is_empty() {
            return Ok(());
        }
        // Resolve each candidate's canonical platform name; only names the
        // compute memo has never seen enter the plan's platform axis.
        let mut named: Vec<(Mix, usize, String, String)> = Vec::new();
        let mut queued: BTreeSet<String> = BTreeSet::new();
        let mut specs: Vec<String> = Vec::new();
        for &(m, ti) in &fresh {
            let entry = &self.topos[ti];
            let spec = entry.spec_for(&m);
            // Sweep groups key on the *platform name*: the bare mix name
            // for mono, the `+topology`-suffixed name otherwise.
            let name = match &entry.topo {
                None => m.platform().name,
                Some(_) => Platform::try_parse(&spec)
                    .map_err(anyhow::Error::msg)
                    .context("dse spec")?
                    .name,
            };
            if self.memo.contains_key(&(name.clone(), frac)) {
                self.memo_hits += 1;
            } else if queued.insert(name.clone()) {
                specs.push(spec.clone());
            } else {
                self.memo_hits += 1; // name-equivalent spelling in this batch
            }
            named.push((m, ti, spec, name));
        }
        if !specs.is_empty() {
            let plan = ExperimentPlan::new()
                .scenarios(self.cfg.scenarios.iter().cloned())
                .distances(self.cfg.distances_m.iter().copied())
                .deadline(self.cfg.deadline)
                .platforms(specs.iter().cloned())
                .scheduler(self.cfg.scheduler.clone())
                .seed(self.cfg.seed)
                .fidelity(fid);
            let sweep = Engine::new(self.registry)
                .jobs(self.cfg.jobs)
                .queue_cache(Arc::clone(&self.cache))
                .sweep_streaming(&plan)
                .context("dse candidate sweep")?;
            if fid.is_full() {
                self.full_sims += specs.len();
            } else {
                self.lf_sims += specs.len();
            }
            for (_, _, _, name) in &named {
                if queued.remove(name) {
                    let folded = fold_metrics(&sweep, name)?;
                    self.memo.insert((name.clone(), frac), folded);
                }
            }
        }
        for (m, ti, spec, name) in named {
            let met = *self
                .memo
                .get(&(name.clone(), frac))
                .ok_or_else(|| anyhow::anyhow!("dse: no folded metrics for '{name}'"))?;
            if fid.is_full() {
                let row = self.make_row(m, ti, spec, met);
                self.index.insert((m, ti), self.rows.len());
                self.rows.push(row);
            } else {
                self.lf.insert((m, ti, frac), met);
                self.lf_order.push((m, ti));
            }
        }
        Ok(())
    }

    fn make_row(&self, mix: Mix, ti: usize, spec: String, m: Metrics) -> EvalRow {
        let entry = &self.topos[ti];
        let b = bounds::candidate_bound(&mix, &self.demand);
        EvalRow {
            mix,
            spec,
            topology: entry.label.clone(),
            chiplets: entry.chiplets(),
            cores: mix.cores(),
            area: mix.area_units(),
            peak_power_w: mix.peak_power_w(),
            stm_rate: m.stm_rate,
            energy_j: m.energy_j,
            time_s: m.time_s,
            r_balance: m.r_balance,
            comm_delay_ms_per_task: m.comm_delay_ms_per_task,
            comm_gb: m.comm_gb,
            stm_bound: b.stm_ub,
            energy_bound_j: b.energy_lb_j,
            on_frontier: false,
        }
    }
}

/// Fold a candidate's sweep rows (one group per scenario) into metrics.
fn fold_metrics(sweep: &SweepSummary, name: &str) -> Result<Metrics> {
    let mut met = 0u64;
    let mut tasks = 0u64;
    let mut n = 0u64;
    let mut sum_ln_e = 0.0;
    let mut sum_ln_t = 0.0;
    let mut sum_rb = 0.0;
    let mut sum_comm_delay = 0.0;
    let mut sum_comm_gb = 0.0;
    for g in sweep.groups.iter().filter(|g| g.key.platform == name) {
        met += g.stats.sum_tasks_met;
        tasks += g.stats.sum_tasks;
        n += g.stats.trials;
        sum_ln_e += g.stats.sum_ln_energy;
        sum_ln_t += g.stats.sum_ln_time;
        sum_rb += g.stats.sum_r_balance;
        sum_comm_delay += g.stats.sum_comm_delay;
        sum_comm_gb += g.stats.sum_comm_gb;
    }
    anyhow::ensure!(n > 0, "no sweep rows for candidate '{name}'");
    Ok(Metrics {
        stm_rate: if tasks == 0 { 1.0 } else { met as f64 / tasks as f64 },
        energy_j: (sum_ln_e / n as f64).exp(),
        time_s: (sum_ln_t / n as f64).exp(),
        r_balance: sum_rb / n as f64,
        comm_delay_ms_per_task: if tasks == 0 { 0.0 } else { sum_comm_delay / tasks as f64 * 1e3 },
        comm_gb: sum_comm_gb / n as f64,
    })
}
