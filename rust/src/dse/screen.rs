//! The multi-fidelity screening pipeline: analytic bound pruning, then
//! successive-halving rungs at truncated route fractions, then one
//! full-fidelity pass over the promoted set.  Frontier rows only ever
//! come from that last pass — screening decides *which* candidates pay
//! for full evaluation, never what their reported numbers are.
//!
//! Promotion per rung is the union of
//!   * the top `ceil(keep_frac · n)` candidates by the screening
//!     metrics (stm ↓desc, energy ↑asc, area ↑asc, spec), and
//!   * every candidate non-dominated *at this fidelity* —
//! because a pure top-K by STM would demote small-area frontier members
//! (they rank last on throughput by construction).  Promoting the
//! screening frontier wholesale is what lets the default mode reproduce
//! the exact frontier set on the deterministic test slices.

use anyhow::Result;

use crate::plan::Fidelity;

use super::bounds;
use super::eval::Evaluator;
use super::{DseConfig, Mix, PrunedRow, RungLog};

/// Accounting of one pipeline run.  `pool == pruned_rows.len() +
/// screened_out + promoted` — every pool candidate is pruned, screened
/// out at some rung, or promoted to full fidelity; nothing is dropped
/// silently.
pub(super) struct PipelineOutcome {
    pub pool: usize,
    pub pruned_rows: Vec<PrunedRow>,
    pub screened_out: usize,
    pub promoted: usize,
    pub rung_log: Vec<RungLog>,
}

/// Route fraction of rung `i` of `rungs`: the last rung screens at half
/// the route, each earlier rung at half the next (`0.5^(rungs - i)`).
pub(super) fn rung_frac(rungs: usize, i: usize) -> f64 {
    0.5f64.powi((rungs - i) as i32)
}

/// Run `pool` through the pipeline.  `ev` must already hold every
/// full-fidelity reference row the bound pruner may compare against
/// (the HMAI anchor); pool members already evaluated at full fidelity
/// count as promoted without re-entering the rungs.
pub(super) fn run_pipeline(
    cfg: &DseConfig,
    ev: &mut Evaluator,
    pool: Vec<(Mix, usize)>,
) -> Result<PipelineOutcome> {
    let pool_n = pool.len();
    // Stage 1: analytic capacity/energy bounds against evaluated rows.
    let mut pruned_rows: Vec<PrunedRow> = Vec::new();
    let mut survivors: Vec<(Mix, usize)> = Vec::new();
    let mut already_full = 0usize;
    for (m, ti) in pool {
        if ev.has_row(&m, ti) {
            already_full += 1;
            continue;
        }
        let area = m.area_units();
        let b = bounds::candidate_bound(&m, &ev.demand);
        if bounds::bound_dominated(&ev.rows, area, &b) {
            pruned_rows.push(PrunedRow {
                spec: ev.topos[ti].spec_for(&m),
                topology: ev.topos[ti].label.clone(),
                area,
                stm_bound: b.stm_ub,
                energy_bound_j: b.energy_lb_j,
            });
        } else {
            survivors.push((m, ti));
        }
    }
    if !pruned_rows.is_empty() {
        crate::log_info!(
            "dse",
            "analytic bounds pruned {} of {pool_n} candidate(s) before any simulation \
             (best-case STM/energy dominated by an evaluated row)",
            pruned_rows.len(),
        );
    }
    // Stage 2: successive-halving rungs on truncated routes.
    let mut rung_log: Vec<RungLog> = Vec::new();
    let mut screened_out = 0usize;
    for i in 0..cfg.rungs {
        let frac = rung_frac(cfg.rungs, i);
        let fid = Fidelity { route_frac: frac, replicates: 1 };
        ev.eval_pairs(&survivors, fid)?;
        let entered = survivors.len();
        survivors = promote(cfg, ev, survivors, fid);
        screened_out += entered - survivors.len();
        rung_log.push(RungLog { route_frac: frac, entered, promoted: survivors.len() });
    }
    // Stage 3: full fidelity for the promoted set.
    let full = ev.full_fidelity();
    ev.eval_pairs(&survivors, full)?;
    Ok(PipelineOutcome {
        pool: pool_n,
        pruned_rows,
        screened_out,
        promoted: survivors.len() + already_full,
        rung_log,
    })
}

/// One rung's promotion: top `keep_frac` by screening rank, unioned with
/// the screening-fidelity Pareto frontier.  Pool order is preserved.
fn promote(
    cfg: &DseConfig,
    ev: &Evaluator,
    pairs: Vec<(Mix, usize)>,
    fid: Fidelity,
) -> Vec<(Mix, usize)> {
    let n = pairs.len();
    if n <= 1 {
        return pairs;
    }
    // (stm, energy, area, spec) per candidate at this fidelity.
    let stats: Vec<(f64, f64, f64, String)> = pairs
        .iter()
        .map(|&(m, ti)| {
            let met = ev.metric(&m, ti, fid);
            (met.stm_rate, met.energy_j, m.area_units(), ev.topos[ti].spec_for(&m))
        })
        .collect();
    let keep = ((cfg.keep_frac * n as f64).ceil() as usize).clamp(1, n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let (sa, sb) = (&stats[a], &stats[b]);
        sb.0.total_cmp(&sa.0)
            .then(sa.1.total_cmp(&sb.1))
            .then(sa.2.total_cmp(&sb.2))
            .then(sa.3.cmp(&sb.3))
    });
    let mut selected = vec![false; n];
    for &i in order.iter().take(keep) {
        selected[i] = true;
    }
    for i in 0..n {
        let dominated = (0..n).any(|j| {
            j != i
                && stats[j].0 >= stats[i].0
                && stats[j].1 <= stats[i].1
                && stats[j].2 <= stats[i].2
                && (stats[j].0 > stats[i].0
                    || stats[j].1 < stats[i].1
                    || stats[j].2 < stats[i].2)
        });
        if !dominated {
            selected[i] = true;
        }
    }
    pairs.into_iter().enumerate().filter(|(i, _)| selected[*i]).map(|(_, p)| p).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_schedule_halves_toward_full() {
        assert_eq!(rung_frac(1, 0), 0.5);
        assert_eq!(rung_frac(2, 0), 0.25);
        assert_eq!(rung_frac(2, 1), 0.5);
        assert_eq!(rung_frac(3, 0), 0.125);
        for rungs in 1..=6 {
            for i in 1..rungs {
                assert_eq!(rung_frac(rungs, i), 2.0 * rung_frac(rungs, i - 1));
            }
            assert!(rung_frac(rungs, rungs - 1) < 1.0);
        }
    }
}
