//! Design-space exploration over heterogeneous platform mixes (§5, §8):
//! the paper argues the AV substrate "requires a design space exploration
//! for a new form of parallelism" — this module searches the
//! (kind × [`CoreSize`] × count) mix space under an area (and optional
//! peak-power) budget, evaluates each candidate platform on the real
//! [`Engine`](crate::engine::Engine) across a scenario-library slice, and
//! reports the Pareto frontier of deadline-met rate vs energy vs area.
//!
//! Three orthogonal axes shape a run:
//!
//!   * **Search** (`--search`): *full* enumerates every
//!     per-kind-uniform-size mix within the budget (shortlisted by static
//!     capacity when it explodes, logged never silent); *greedy* beam
//!     search grows mixes one core at a time; *auto* picks.
//!   * **Topology** (`--topology`): adds chiplet packages
//!     ([`Topology`] presets) as a second candidate axis — every mix is
//!     evaluated monolithically *and* on each listed topology (spec
//!     `"{mix}+{topo}"`), paying communication through the
//!     [`crate::interconnect`] model, with the reticle constraint
//!     ([`MONO_DIE_AREA_UNITS`]) capping a monolithic die while a
//!     C-chiplet package may spend up to C reticles.
//!   * **Fidelity** (`--fidelity`): *multi* (the default) runs the
//!     multi-fidelity pipeline — analytic capacity/energy bounds prune
//!     candidates whose best case is already dominated, successive-
//!     halving rungs screen the rest on truncated routes
//!     (`--rungs`, `--keep-frac`), and only the promoted set pays for
//!     full-fidelity evaluation; *exact* disables pruning and screening
//!     entirely and reproduces the pre-fidelity evaluator bit-for-bit.
//!
//! Whatever the axes, **frontier rows only ever come from full-fidelity
//! evaluations** (`tests/dse_fidelity.rs` pins both the exact-mode
//! bit-identity and the multi-mode frontier-set equality), and evaluation
//! batches every unseen candidate — across all topology entries — into
//! *one* [`ExperimentPlan`](crate::plan::ExperimentPlan) so trials
//! parallelize across `--jobs`, queues are shared through one
//! [`QueueCache`](crate::engine::QueueCache) for the whole run, and
//! name-equivalent spec spellings are simulated once (see `eval.rs`).

mod bounds;
mod eval;
mod screen;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::accel::{self, AccelKind, CoreSize, ALL_ACCELS, ALL_SIZES};
use crate::env::taskgen::DeadlineMode;
use crate::interconnect::{Topology, MONO_DIE_AREA_UNITS};
use crate::plan::Fidelity;
use crate::platform::Platform;
use crate::sched::{Registry, SchedulerSpec};
use crate::util::json::Json;
use crate::workload::{ModelKind, ALL_MODELS};

pub use bounds::CandidateBound;
use eval::Evaluator;

/// How `run` explores the mix space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Full enumeration when it fits `max_evals`, greedy otherwise.
    Auto,
    /// Force full enumeration (shortlisted to `max_evals` by static
    /// capacity when the space is larger — logged, never silent).
    Full,
    /// Force the greedy beam search.
    Greedy,
}

impl SearchMode {
    pub fn parse(s: &str) -> Result<SearchMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(SearchMode::Auto),
            "full" => Ok(SearchMode::Full),
            "greedy" | "beam" => Ok(SearchMode::Greedy),
            other => anyhow::bail!("--search: expected auto|full|greedy, got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SearchMode::Auto => "auto",
            SearchMode::Full => "full",
            SearchMode::Greedy => "greedy",
        }
    }
}

/// How `run` spends simulation effort per candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FidelityMode {
    /// Bound pruning + successive-halving screening; only promoted
    /// candidates are evaluated at full fidelity (the default).
    Multi,
    /// Every candidate evaluated at full fidelity, no pruning or
    /// screening — bit-identical to the pre-fidelity evaluator.
    Exact,
}

impl FidelityMode {
    pub fn parse(s: &str) -> Result<FidelityMode> {
        match s.to_ascii_lowercase().as_str() {
            "multi" | "mf" => Ok(FidelityMode::Multi),
            "exact" => Ok(FidelityMode::Exact),
            other => anyhow::bail!("--fidelity: expected multi|exact, got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FidelityMode::Multi => "multi",
            FidelityMode::Exact => "exact",
        }
    }
}

/// DSE run parameters.
#[derive(Debug, Clone)]
pub struct DseConfig {
    /// Area budget in standard-core equivalents ([`CoreSize::area_units`]).
    pub budget_area: f64,
    /// Optional peak-power cap (W, [`Platform::peak_power_w`]).
    pub power_cap_w: Option<f64>,
    /// Scenario-library slice each candidate is evaluated on.
    pub scenarios: Vec<String>,
    pub distances_m: Vec<f64>,
    pub deadline: DeadlineMode,
    pub scheduler: SchedulerSpec,
    pub seed: u64,
    pub jobs: usize,
    /// Hard cap on searched candidates (truncation is logged).
    pub max_evals: usize,
    /// Beam width of the greedy search.
    pub beam: usize,
    pub search: SearchMode,
    /// Chiplet topologies to search alongside the implicit monolithic
    /// candidate ([`Topology::try_parse`] grammar, placement-free).  Empty
    /// disables the topology axis entirely (legacy behavior).
    pub topologies: Vec<String>,
    pub fidelity: FidelityMode,
    /// Successive-halving rungs of the multi-fidelity pipeline (1..=6;
    /// rung `i` of `n` screens at `0.5^(n-i)` of the route).
    pub rungs: usize,
    /// Fraction of candidates promoted per rung, in (0, 1] — the
    /// screening-fidelity Pareto frontier is always promoted on top.
    pub keep_frac: f64,
    /// Seed replicates of every full-fidelity evaluation
    /// ([`crate::plan::replicate_seeds`]; screening rungs always use 1).
    pub replicates: usize,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            budget_area: 12.0,
            power_cap_w: None,
            scenarios: vec!["urban-rush".to_string()],
            distances_m: vec![150.0],
            deadline: DeadlineMode::Rss,
            scheduler: SchedulerSpec::MinMin,
            seed: 42,
            jobs: 1,
            max_evals: 256,
            beam: 2,
            search: SearchMode::Auto,
            topologies: Vec::new(),
            fidelity: FidelityMode::Multi,
            rungs: 1,
            keep_frac: 0.5,
            replicates: 1,
        }
    }
}

/// One entry on the topology axis: `label` is the canonical topology name
/// (`"mono"` for the implicit monolithic candidate, `topo == None`).
#[derive(Debug, Clone)]
struct TopoEntry {
    label: String,
    topo: Option<Arc<Topology>>,
}

impl TopoEntry {
    /// Platform spec for `mix` on this entry (`Platform::try_parse`
    /// grammar) — monolithic candidates keep the bare mix spec.
    fn spec_for(&self, mix: &Mix) -> String {
        match &self.topo {
            None => mix.spec(),
            Some(_) => format!("{}+{}", mix.spec(), self.label),
        }
    }

    fn chiplets(&self) -> usize {
        self.topo.as_ref().map_or(1, |t| t.chiplets)
    }
}

/// Build the topology axis: always the implicit monolithic entry first,
/// then each parsed `--topology` preset (deduplicated by canonical name,
/// explicit `mono` spellings folded into the implicit entry).
fn resolve_topologies(specs: &[String]) -> Result<Vec<TopoEntry>> {
    let mut out = vec![TopoEntry { label: "mono".to_string(), topo: None }];
    for s in specs {
        anyhow::ensure!(
            !s.contains('/'),
            "dse --topology '{s}': explicit placements cannot be searched (candidate mixes \
             vary their slot count) — use a placement-free preset like mesh2x2 or ring3"
        );
        let t = Topology::try_parse(s).map_err(|e| anyhow::anyhow!("dse --topology: {e}"))?;
        if t.is_mono() || out.iter().any(|e| e.label == t.name) {
            continue;
        }
        out.push(TopoEntry { label: t.name.clone(), topo: Some(Arc::new(t)) });
    }
    Ok(out)
}

/// Area budget a candidate of this topology entry may actually spend.
/// With the topology axis active every die must fit the reticle
/// ([`MONO_DIE_AREA_UNITS`]): a monolithic candidate is one die, a
/// C-chiplet candidate spreads its area over C dies ([`Topology::
/// max_die_area`]).  Without the axis (legacy `hmai dse`) the raw budget
/// passes through untouched.
fn effective_budget(budget_area: f64, entry: &TopoEntry, axis_active: bool) -> f64 {
    if !axis_active {
        return budget_area;
    }
    budget_area.min(MONO_DIE_AREA_UNITS * entry.chiplets() as f64)
}

/// One candidate platform mix: core count per (kind, size) cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Mix {
    /// `counts[kind.index()][size.index()]`.
    pub counts: [[usize; 3]; 3],
}

impl Mix {
    /// The paper's HMAI — (4 SO, 4 SI, 3 MM), all standard cores.
    pub fn hmai_std() -> Mix {
        let mut m = Mix::default();
        m.counts[AccelKind::SconvOD.index()][CoreSize::Std.index()] = 4;
        m.counts[AccelKind::SconvIC.index()][CoreSize::Std.index()] = 4;
        m.counts[AccelKind::MconvMC.index()][CoreSize::Std.index()] = 3;
        m
    }

    pub fn cores(&self) -> usize {
        self.counts.iter().flatten().sum()
    }

    pub fn area_units(&self) -> f64 {
        self.cells().map(|(_, s, n)| n as f64 * s.area_units()).sum()
    }

    pub fn peak_power_w(&self) -> f64 {
        self.cells().map(|(k, s, n)| n as f64 * accel::peak_power_w(k, s)).sum()
    }

    /// Aggregate best-case throughput for `model` (FPS) — the static
    /// capacity the full-mode shortlist ranks by and the analytic
    /// STM upper bound is derived from (`bounds.rs`).
    pub fn capacity_fps(&self, model: ModelKind) -> f64 {
        self.cells().map(|(k, s, n)| n as f64 * accel::cost_sized(k, model, s).fps()).sum()
    }

    /// Worst-model static capacity (FPS): the balanced-provisioning proxy.
    pub fn worst_capacity_fps(&self) -> f64 {
        ALL_MODELS.iter().map(|&m| self.capacity_fps(m)).fold(f64::INFINITY, f64::min)
    }

    /// This mix plus one more (kind, size) core.
    pub fn with_added(&self, kind: AccelKind, size: CoreSize) -> Mix {
        let mut m = *self;
        m.counts[kind.index()][size.index()] += 1;
        m
    }

    /// Non-empty (kind, size, count) cells, kind-major then size-major.
    fn cells(&self) -> impl Iterator<Item = (AccelKind, CoreSize, usize)> + '_ {
        ALL_ACCELS.iter().flat_map(move |&k| {
            ALL_SIZES
                .iter()
                .map(move |&s| (k, s, self.counts[k.index()][s.index()]))
                .filter(|(_, _, n)| *n > 0)
        })
    }

    /// Platform-spec string (`Platform::try_parse` grammar), e.g.
    /// `"so:4@2x,si:4,mm:3@0.5x"`.
    pub fn spec(&self) -> String {
        self.cells()
            .map(|(k, s, n)| format!("{}:{}{}", k.short().to_ascii_lowercase(), n, s.suffix()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Resolve to a concrete [`Platform`].
    pub fn platform(&self) -> Platform {
        let mix: Vec<(AccelKind, CoreSize, usize)> = self.cells().collect();
        Platform::from_mix(&format!("custom({})", self.spec()), &mix)
    }
}

/// One evaluated candidate: static characteristics + simulated outcome.
/// Every row in a report was evaluated at **full fidelity**.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub mix: Mix,
    /// Full candidate spec, topology suffix included (`"so:4,...+mesh2x2"`).
    pub spec: String,
    /// Topology label — `"mono"` for a monolithic candidate.
    pub topology: String,
    /// Die count of the package (1 for mono).
    pub chiplets: usize,
    pub cores: usize,
    pub area: f64,
    pub peak_power_w: f64,
    /// Deadline-met fraction over every run of the slice (Σmet / Σtasks).
    pub stm_rate: f64,
    /// Geometric-mean per-queue energy (J) over the slice.
    pub energy_j: f64,
    /// Geometric-mean wait+compute time (s) over the slice.
    pub time_s: f64,
    pub r_balance: f64,
    /// Mean interconnect delay per task (ms) — 0 on monolithic candidates.
    pub comm_delay_ms_per_task: f64,
    /// Mean bytes moved over the interconnect per trial (GB).
    pub comm_gb: f64,
    /// Analytic best-case deadline-met rate (`bounds.rs`); always ≥
    /// `stm_rate`.
    pub stm_bound: f64,
    /// Analytic lowest-possible energy (J); always ≤ `energy_j`.
    pub energy_bound_j: f64,
    /// Non-dominated on (stm_rate ↑, energy_j ↓, area ↓)?
    pub on_frontier: bool,
}

impl EvalRow {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("spec", Json::Str(self.spec.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("chiplets", Json::Num(self.chiplets as f64)),
            ("cores", Json::Num(self.cores as f64)),
            ("area_units", Json::Num(self.area)),
            ("peak_power_w", Json::Num(self.peak_power_w)),
            ("stm_rate", Json::Num(self.stm_rate)),
            ("energy_j", Json::Num(self.energy_j)),
            ("time_s", Json::Num(self.time_s)),
            ("r_balance", Json::Num(self.r_balance)),
            ("comm_delay_ms_per_task", Json::Num(self.comm_delay_ms_per_task)),
            ("comm_gb", Json::Num(self.comm_gb)),
            ("stm_bound", Json::Num(self.stm_bound)),
            ("energy_bound_j", Json::Num(self.energy_bound_j)),
            ("on_frontier", Json::Bool(self.on_frontier)),
        ])
    }
}

/// A candidate skipped by the analytic bound pruner: its best case was
/// already dominated by an evaluated full-fidelity row, so it could never
/// reach the frontier.  Reported, never silent.
#[derive(Debug, Clone)]
pub struct PrunedRow {
    pub spec: String,
    pub topology: String,
    pub area: f64,
    pub stm_bound: f64,
    pub energy_bound_j: f64,
}

impl PrunedRow {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("spec", Json::Str(self.spec.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("area_units", Json::Num(self.area)),
            ("stm_bound", Json::Num(self.stm_bound)),
            ("energy_bound_j", Json::Num(self.energy_bound_j)),
        ])
    }
}

/// One successive-halving rung's accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct RungLog {
    /// Route fraction this rung screened at.
    pub route_frac: f64,
    /// Candidates entering the rung.
    pub entered: usize,
    /// Candidates promoted out of it.
    pub promoted: usize,
}

impl RungLog {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("route_frac", Json::Num(self.route_frac)),
            ("entered", Json::Num(self.entered as f64)),
            ("promoted", Json::Num(self.promoted as f64)),
        ])
    }
}

/// Outcome of a DSE run: every evaluated mix (frontier rows first, then by
/// descending deadline-met rate) plus run and pipeline bookkeeping.
///
/// Multi-fidelity accounting invariant: `pool == pruned_rows.len() +
/// screened_out + promoted` — every candidate the search produced is
/// either pruned analytically, screened out at some rung, or promoted to
/// a full-fidelity row.  In exact mode the pipeline is inactive:
/// `pool == evaluated` and the other counts are 0.
#[derive(Debug)]
pub struct DseReport {
    pub rows: Vec<EvalRow>,
    pub frontier: usize,
    /// Full-fidelity-evaluated candidates (`rows.len()`).
    pub evaluated: usize,
    pub search: &'static str,
    pub fidelity: &'static str,
    pub rungs: usize,
    pub keep_frac: f64,
    pub budget_area: f64,
    pub power_cap_w: Option<f64>,
    /// Candidates dropped by `max_evals` (0 = exhaustive within mode).
    pub truncated: usize,
    /// Topology-axis labels, `"mono"` first (just `["mono"]` when the
    /// axis is off).
    pub topologies: Vec<String>,
    /// Candidates the search produced for the evaluation pipeline.
    pub pool: usize,
    /// Candidates skipped by analytic bounds (with their bounds).
    pub pruned_rows: Vec<PrunedRow>,
    /// Candidates dropped by successive-halving rungs.
    pub screened_out: usize,
    /// Candidates promoted to full fidelity (anchor overlaps included).
    pub promoted: usize,
    /// Candidate evaluations at screening fidelity (all rungs).
    pub low_fidelity_evals: usize,
    pub rung_log: Vec<RungLog>,
}

impl DseReport {
    /// Frontier rows, in report order.
    pub fn frontier_rows(&self) -> impl Iterator<Item = &EvalRow> {
        self.rows.iter().filter(|r| r.on_frontier)
    }

    pub fn find(&self, spec: &str) -> Option<&EvalRow> {
        self.rows.iter().find(|r| r.spec == spec)
    }

    /// Candidates skipped by the analytic bound pruner.
    pub fn pruned(&self) -> usize {
        self.pruned_rows.len()
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("budget_area", Json::Num(self.budget_area)),
            (
                "power_cap_w",
                self.power_cap_w.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("search", Json::Str(self.search.to_string())),
            ("fidelity", Json::Str(self.fidelity.to_string())),
            ("rungs", Json::Num(self.rungs as f64)),
            ("keep_frac", Json::Num(self.keep_frac)),
            ("evaluated", Json::Num(self.evaluated as f64)),
            ("truncated", Json::Num(self.truncated as f64)),
            ("pool", Json::Num(self.pool as f64)),
            ("pruned", Json::Num(self.pruned() as f64)),
            ("screened_out", Json::Num(self.screened_out as f64)),
            ("promoted", Json::Num(self.promoted as f64)),
            ("full_evals", Json::Num(self.evaluated as f64)),
            ("low_fidelity_evals", Json::Num(self.low_fidelity_evals as f64)),
            (
                "rung_log",
                Json::Arr(self.rung_log.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "pruned_rows",
                Json::Arr(self.pruned_rows.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "topologies",
                Json::Arr(self.topologies.iter().map(|t| Json::Str(t.clone())).collect()),
            ),
            ("frontier_size", Json::Num(self.frontier as f64)),
            (
                "frontier",
                Json::Arr(self.frontier_rows().map(|r| r.to_json()).collect()),
            ),
            ("rows", Json::Arr(self.rows.iter().map(|r| r.to_json()).collect())),
        ])
    }
}

/// Enumerate every mix with a *uniform size per kind* (the spec-syntax
/// shape) within the area/power budget, up to `limit` candidates.
/// Returns `(mixes, hit_limit)`.
pub fn enumerate(budget_area: f64, power_cap_w: Option<f64>, limit: usize) -> (Vec<Mix>, bool) {
    let mut out = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for so_size in ALL_SIZES {
        for si_size in ALL_SIZES {
            for mm_size in ALL_SIZES {
                let sizes = [so_size, si_size, mm_size];
                let max_n = |s: CoreSize| (budget_area / s.area_units()).floor() as usize;
                for so in 0..=max_n(so_size) {
                    for si in 0..=max_n(si_size) {
                        for mm in 0..=max_n(mm_size) {
                            if so + si + mm == 0 {
                                continue;
                            }
                            let mut mix = Mix::default();
                            for (k, (&n, s)) in
                                ALL_ACCELS.iter().zip([so, si, mm].iter().zip(sizes))
                            {
                                mix.counts[k.index()][s.index()] = n;
                            }
                            if mix.area_units() > budget_area + 1e-9 {
                                break; // mm grows area monotonically
                            }
                            if let Some(cap) = power_cap_w {
                                if mix.peak_power_w() > cap {
                                    break; // power also grows with mm
                                }
                            }
                            if seen.insert(mix) {
                                if out.len() >= limit {
                                    return (out, true);
                                }
                                out.push(mix);
                            }
                        }
                    }
                }
            }
        }
    }
    (out, false)
}

/// Mark the Pareto frontier on (stm_rate max, energy_j min, area min).
pub fn mark_frontier(rows: &mut [EvalRow]) -> usize {
    let n = rows.len();
    let mut frontier = 0;
    for i in 0..n {
        let dominated = (0..n).any(|j| {
            if i == j {
                return false;
            }
            let (a, b) = (&rows[i], &rows[j]);
            b.stm_rate >= a.stm_rate
                && b.energy_j <= a.energy_j
                && b.area <= a.area
                && (b.stm_rate > a.stm_rate || b.energy_j < a.energy_j || b.area < a.area)
        });
        rows[i].on_frontier = !dominated;
        if !dominated {
            frontier += 1;
        }
    }
    frontier
}

/// Shortlist an over-large enumeration to its `left` best candidates by
/// worst-model static capacity (balanced provisioning) — logged, never
/// silent.  Returns the dropped count.
fn shortlist_by_capacity(mixes: &mut Vec<Mix>, left: usize, label: &str) -> usize {
    let dropped = mixes.len().saturating_sub(left);
    crate::log_warn!(
        "dse",
        "full enumeration ({label}) has {} candidates; keeping the top {left} by \
         worst-model capacity ({dropped} dropped — use --search greedy or raise \
         --max-evals)",
        mixes.len(),
    );
    // One key build per mix (the list can be huge): positive finite f64s
    // order identically to their bit patterns, so `to_bits` keys give
    // capacity-desc / area-asc / spec-asc.
    mixes.sort_by_cached_key(|m| {
        (
            std::cmp::Reverse(m.worst_capacity_fps().to_bits()),
            m.area_units().to_bits(),
            m.spec(),
        )
    });
    mixes.truncate(left);
    dropped
}

/// Greedy beam search: grow mixes one (kind, size) core at a time, keeping
/// the `beam` best per step (deadline-met rate, then energy, then area),
/// until the budget admits no extension or `max_evals` is hit.  Every step
/// adds exactly one core, so area strictly grows and the loop terminates.
/// Searches one topology entry `ti` with its effective `budget_area`;
/// `evals_cap` is this entry's cumulative share of `max_evals` (equal to
/// `cfg.max_evals` when the topology axis is off).  The search evaluates
/// at `fid` — full fidelity in exact mode, the first screening rung in
/// multi mode (where its evaluations seed the pipeline's rung cache).
fn greedy_search(
    cfg: &DseConfig,
    ev: &mut Evaluator,
    ti: usize,
    budget_area: f64,
    evals_cap: usize,
    fid: Fidelity,
) -> Result<usize> {
    let within = |m: &Mix| {
        m.area_units() <= budget_area + 1e-9
            && cfg.power_cap_w.map(|cap| m.peak_power_w() <= cap).unwrap_or(true)
    };
    let all_cells =
        || ALL_ACCELS.iter().flat_map(|&k| ALL_SIZES.iter().map(move |&s| (k, s)));
    // Select the `beam` best of an evaluated batch (deterministic order).
    let select_top = |mixes: &mut Vec<Mix>, ev: &Evaluator| {
        mixes.sort_by(|a, b| {
            let (ma, mb) = (ev.metric(a, ti, fid), ev.metric(b, ti, fid));
            mb.stm_rate
                .total_cmp(&ma.stm_rate)
                .then(ma.energy_j.total_cmp(&mb.energy_j))
                .then(a.area_units().total_cmp(&b.area_units()))
                .then(ev.topos[ti].spec_for(a).cmp(&ev.topos[ti].spec_for(b)))
        });
        mixes.truncate(cfg.beam);
    };

    // Seeds: every single-core mix inside the budget.
    let mut batch: Vec<Mix> =
        all_cells().map(|(k, s)| Mix::default().with_added(k, s)).filter(within).collect();
    let mut truncated = 0usize;
    loop {
        // Cap the batch at the remaining eval budget (logged below).
        let budget_left = evals_cap.saturating_sub(ev.searched(fid));
        if batch.len() > budget_left {
            truncated += batch.len() - budget_left;
            batch.truncate(budget_left);
        }
        if batch.is_empty() {
            break;
        }
        ev.eval_all(&batch, ti, fid)?;
        select_top(&mut batch, ev);
        // Extend each kept beam by one core; already-evaluated mixes
        // cannot reappear (extensions always have one more core than any
        // previous round).
        let mut exts: Vec<Mix> = Vec::new();
        for b in &batch {
            for (k, s) in all_cells() {
                let m = b.with_added(k, s);
                if within(&m) && !exts.contains(&m) {
                    exts.push(m);
                }
            }
        }
        batch = exts;
    }
    if truncated > 0 {
        crate::log_warn!(
            "dse",
            "--max-evals {} reached; {truncated} candidate(s) not searched (raise \
             --max-evals or narrow --budget for an exhaustive pass)",
            cfg.max_evals
        );
    }
    Ok(truncated)
}

/// Per-entry cumulative share of the eval budget: each topology entry
/// gets an equal share so an early entry cannot starve the later ones; an
/// entry's unspent share rolls forward via the cumulative cap.  With the
/// axis off the single entry's cap is exactly `max_evals`.
fn share(cfg: &DseConfig, n_topos: usize, ti: usize) -> usize {
    cfg.max_evals / n_topos + usize::from(ti < cfg.max_evals % n_topos)
}

/// Does the HMAI anchor fit this entry's effective budget?
fn anchor_fits(cfg: &DseConfig, eff_budget: f64) -> bool {
    let hmai = Mix::hmai_std();
    hmai.area_units() <= eff_budget + 1e-9
        && cfg.power_cap_w.map(|cap| hmai.peak_power_w() <= cap).unwrap_or(true)
}

/// Exact-mode body: every searched candidate is evaluated at full
/// fidelity, the anchor last — the pre-fidelity evaluator, preserved
/// bit-for-bit (`tests/dse_fidelity.rs`).
fn run_exact(
    cfg: &DseConfig,
    ev: &mut Evaluator,
    mode: SearchMode,
    axis_active: bool,
) -> Result<usize> {
    let n = ev.topos.len();
    let full = ev.full_fidelity();
    let mut truncated = 0usize;
    match mode {
        SearchMode::Full => {
            let mut cap = 0usize;
            for ti in 0..n {
                cap += share(cfg, n, ti);
                let eff = effective_budget(cfg.budget_area, &ev.topos[ti], axis_active);
                let (mut mixes, over) = enumerate(eff, cfg.power_cap_w, 200_000);
                let left = cap.saturating_sub(ev.evaluated());
                if over || mixes.len() > left {
                    truncated += shortlist_by_capacity(&mut mixes, left, &ev.topos[ti].label);
                }
                ev.eval_all(&mixes, ti, full)?;
            }
        }
        SearchMode::Greedy | SearchMode::Auto => {
            let mut cap = 0usize;
            for ti in 0..n {
                cap += share(cfg, n, ti);
                let eff = effective_budget(cfg.budget_area, &ev.topos[ti], axis_active);
                truncated += greedy_search(cfg, ev, ti, eff, cap, full)?;
            }
        }
    }
    // The paper's HMAI point, for frontier placement (acceptance anchor) —
    // on every topology entry it fits.
    for ti in 0..n {
        let eff = effective_budget(cfg.budget_area, &ev.topos[ti], axis_active);
        if anchor_fits(cfg, eff) {
            ev.eval_all(&[Mix::hmai_std()], ti, full)?;
        }
    }
    Ok(truncated)
}

/// Multi-fidelity body: evaluate the anchor first (it doubles as the
/// bound pruner's reference row), build the candidate pool without
/// simulating it (full search) or from a screening-fidelity greedy
/// search, then run the prune → screen → promote pipeline.
fn run_multi(
    cfg: &DseConfig,
    ev: &mut Evaluator,
    mode: SearchMode,
    axis_active: bool,
) -> Result<(usize, screen::PipelineOutcome)> {
    let n = ev.topos.len();
    let full = ev.full_fidelity();
    for ti in 0..n {
        let eff = effective_budget(cfg.budget_area, &ev.topos[ti], axis_active);
        if anchor_fits(cfg, eff) {
            ev.eval_all(&[Mix::hmai_std()], ti, full)?;
        }
    }
    let mut truncated = 0usize;
    let pool: Vec<(Mix, usize)> = match mode {
        SearchMode::Full => {
            let mut pool: Vec<(Mix, usize)> = Vec::new();
            let mut cap = 0usize;
            for ti in 0..n {
                cap += share(cfg, n, ti);
                let eff = effective_budget(cfg.budget_area, &ev.topos[ti], axis_active);
                let (mut mixes, over) = enumerate(eff, cfg.power_cap_w, 200_000);
                let left = cap.saturating_sub(pool.len());
                if over || mixes.len() > left {
                    truncated += shortlist_by_capacity(&mut mixes, left, &ev.topos[ti].label);
                }
                pool.extend(mixes.into_iter().map(|m| (m, ti)));
            }
            pool
        }
        SearchMode::Greedy | SearchMode::Auto => {
            let fid0 =
                Fidelity { route_frac: screen::rung_frac(cfg.rungs, 0), replicates: 1 };
            let mut cap = 0usize;
            for ti in 0..n {
                cap += share(cfg, n, ti);
                let eff = effective_budget(cfg.budget_area, &ev.topos[ti], axis_active);
                truncated += greedy_search(cfg, ev, ti, eff, cap, fid0)?;
            }
            ev.lf_order.clone()
        }
    };
    let outcome = screen::run_pipeline(cfg, ev, pool)?;
    Ok((truncated, outcome))
}

/// Run the exploration: enumerate or beam-search candidates, evaluate
/// them through the fidelity pipeline, and mark the Pareto frontier.  The
/// HMAI (4,4,3)@Std point is always evaluated (at full fidelity) when it
/// fits the budget, so the paper's pick can be located relative to the
/// frontier.
pub fn run(cfg: &DseConfig, registry: &Registry) -> Result<DseReport> {
    anyhow::ensure!(
        cfg.budget_area >= CoreSize::Half.area_units(),
        "dse: --budget {} admits no core at all (a half core costs {} area units)",
        cfg.budget_area,
        CoreSize::Half.area_units()
    );
    anyhow::ensure!(!cfg.scenarios.is_empty(), "dse: at least one --scenario required");
    anyhow::ensure!(!cfg.distances_m.is_empty(), "dse: at least one --dist required");
    anyhow::ensure!(cfg.max_evals > 0, "dse: --max-evals must be positive");
    anyhow::ensure!(cfg.beam > 0, "dse: --beam must be positive");
    anyhow::ensure!(
        (1..=6).contains(&cfg.rungs),
        "dse: --rungs must be in 1..=6, got {}",
        cfg.rungs
    );
    anyhow::ensure!(
        cfg.keep_frac > 0.0 && cfg.keep_frac <= 1.0,
        "dse: --keep-frac must be in (0, 1], got {}",
        cfg.keep_frac
    );
    anyhow::ensure!(cfg.replicates >= 1, "dse: --replicates must be positive");
    for name in &cfg.scenarios {
        crate::env::scenario::find(name).context("dse --scenario")?;
    }
    let topos = resolve_topologies(&cfg.topologies)?;
    let axis_active = topos.len() > 1;

    let mut ev = Evaluator::new(cfg, registry, &topos)?;
    let mode = match cfg.search {
        SearchMode::Greedy => SearchMode::Greedy,
        SearchMode::Full => SearchMode::Full,
        SearchMode::Auto => {
            // Per-entry effective budgets never exceed the raw budget, so
            // probing it with the eval budget split across the axis gives
            // a sound (and, with the axis off, exactly the legacy) answer.
            let limit = (cfg.max_evals / topos.len()).max(1);
            let (_, over) = enumerate(cfg.budget_area, cfg.power_cap_w, limit);
            if over {
                SearchMode::Greedy
            } else {
                SearchMode::Full
            }
        }
    };
    let (truncated, outcome) = match cfg.fidelity {
        FidelityMode::Exact => (run_exact(cfg, &mut ev, mode, axis_active)?, None),
        FidelityMode::Multi => {
            let (t, o) = run_multi(cfg, &mut ev, mode, axis_active)?;
            (t, Some(o))
        }
    };

    crate::log_info!(
        "dse",
        "evaluator: {} full-fidelity simulation(s), {} screening simulation(s), {} \
         candidate(s) served from the canonical-name memo",
        ev.full_sims,
        ev.lf_sims,
        ev.memo_hits
    );
    let low_fidelity_evals = ev.lf_order.len();
    let mut rows = std::mem::take(&mut ev.rows);
    let frontier = mark_frontier(&mut rows);
    // Report order: frontier first, then by deadline-met rate desc,
    // energy asc, area asc (deterministic tie-break on the spec).
    rows.sort_by(|a, b| {
        b.on_frontier
            .cmp(&a.on_frontier)
            .then(b.stm_rate.total_cmp(&a.stm_rate))
            .then(a.energy_j.total_cmp(&b.energy_j))
            .then(a.area.total_cmp(&b.area))
            .then(a.spec.cmp(&b.spec))
    });
    let evaluated = rows.len();
    let (pool, pruned_rows, screened_out, promoted, rung_log) = match outcome {
        Some(o) => (o.pool, o.pruned_rows, o.screened_out, o.promoted, o.rung_log),
        None => (evaluated, Vec::new(), 0, 0, Vec::new()),
    };
    Ok(DseReport {
        rows,
        frontier,
        evaluated,
        search: mode.name(),
        fidelity: cfg.fidelity.name(),
        rungs: cfg.rungs,
        keep_frac: cfg.keep_frac,
        budget_area: cfg.budget_area,
        power_cap_w: cfg.power_cap_w,
        truncated,
        topologies: topos.iter().map(|t| t.label.clone()).collect(),
        pool,
        pruned_rows,
        screened_out,
        promoted,
        low_fidelity_evals,
        rung_log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_spec_round_trips_through_platform_parse() {
        let mut m = Mix::hmai_std();
        m.counts[AccelKind::SconvOD.index()][CoreSize::Double.index()] = 1;
        m.counts[AccelKind::MconvMC.index()][CoreSize::Half.index()] = 2;
        let spec = m.spec();
        let p = Platform::try_parse(&spec).unwrap();
        assert_eq!(p.len(), m.cores());
        for k in ALL_ACCELS {
            for s in ALL_SIZES {
                assert_eq!(
                    p.count_of_sized(k, s),
                    m.counts[k.index()][s.index()],
                    "{k:?} {s:?} in '{spec}'"
                );
            }
        }
        assert_eq!(p.name, m.platform().name);
        assert!((p.area_units() - m.area_units()).abs() < 1e-12);
    }

    #[test]
    fn hmai_mix_matches_platform_hmai() {
        let m = Mix::hmai_std();
        assert_eq!(m.cores(), 11);
        assert!((m.area_units() - 11.0).abs() < 1e-12);
        assert_eq!(m.spec(), "so:4,si:4,mm:3");
        let p = m.platform();
        assert_eq!(p.count_of(AccelKind::SconvOD), 4);
        assert_eq!(p.count_of(AccelKind::MconvMC), 3);
        assert!(m.worst_capacity_fps() > 0.0);
    }

    #[test]
    fn enumerate_respects_budget_and_dedupes() {
        let (mixes, over) = enumerate(3.0, None, 100_000);
        assert!(!over);
        assert!(!mixes.is_empty());
        for m in &mixes {
            assert!(m.area_units() <= 3.0 + 1e-9, "{}", m.spec());
            assert!(m.cores() >= 1);
        }
        let set: std::collections::HashSet<_> = mixes.iter().collect();
        assert_eq!(set.len(), mixes.len(), "duplicates enumerated");
        // A power cap strictly shrinks the space: every Std-core busy
        // power exceeds 1 W (pinned in accel::energy tests), so a 1 W cap
        // must exclude at least every std/double-core mix.
        let (capped, _) = enumerate(3.0, Some(1.0), 100_000);
        assert!(capped.len() < mixes.len());
        for m in &capped {
            assert!(m.peak_power_w() <= 1.0);
        }
        // The limit flag fires.
        let (some, over) = enumerate(12.0, None, 64);
        assert_eq!(some.len(), 64);
        assert!(over);
    }

    #[test]
    fn frontier_marking_is_sound() {
        let row = |stm: f64, e: f64, a: f64| EvalRow {
            mix: Mix::default(),
            spec: format!("{stm}-{e}-{a}"),
            topology: "mono".to_string(),
            chiplets: 1,
            cores: 1,
            area: a,
            peak_power_w: 1.0,
            stm_rate: stm,
            energy_j: e,
            time_s: 1.0,
            r_balance: 0.5,
            comm_delay_ms_per_task: 0.0,
            comm_gb: 0.0,
            stm_bound: 1.0,
            energy_bound_j: 0.0,
            on_frontier: false,
        };
        let mut rows = vec![
            row(0.9, 10.0, 5.0), // frontier (best stm)
            row(0.8, 8.0, 5.0),  // frontier (cheaper energy)
            row(0.8, 9.0, 5.0),  // dominated by the one above
            row(0.5, 12.0, 2.0), // frontier (smallest area)
        ];
        let n = mark_frontier(&mut rows);
        assert_eq!(n, 3);
        assert!(rows[0].on_frontier && rows[1].on_frontier && rows[3].on_frontier);
        assert!(!rows[2].on_frontier);
    }

    #[test]
    fn tiny_greedy_run_produces_a_frontier() {
        // Runs under the *default* fidelity (multi): greedy search at the
        // screening fraction, pipeline promotion, full-fidelity rows.
        let reg = Registry::new();
        let cfg = DseConfig {
            budget_area: 2.5,
            distances_m: vec![40.0],
            scenarios: vec!["urban-rush".to_string()],
            max_evals: 40,
            beam: 1,
            search: SearchMode::Greedy,
            ..Default::default()
        };
        let report = run(&cfg, &reg).unwrap();
        assert!(report.evaluated > 0);
        assert!(report.frontier >= 1);
        assert!(report.rows.iter().any(|r| r.on_frontier));
        // Frontier rows lead the report.
        assert!(report.rows[0].on_frontier);
        // Every evaluated mix respects the budget, its analytic bounds and
        // the pipeline accounting.
        for r in &report.rows {
            assert!(r.area <= 2.5 + 1e-9, "{}", r.spec);
            assert!(r.stm_rate >= 0.0 && r.stm_rate <= 1.0);
            assert!(r.energy_j > 0.0);
            assert!(r.stm_rate <= r.stm_bound + 1e-9, "{}", r.spec);
            assert!(r.energy_j >= r.energy_bound_j, "{}", r.spec);
        }
        assert_eq!(report.fidelity, "multi");
        assert_eq!(report.pool, report.pruned() + report.screened_out + report.promoted);
        assert!(report.low_fidelity_evals > 0, "greedy searched at screening fidelity");
        // HMAI does not fit a 2.5-unit budget, so it must not be injected.
        assert!(report.find("so:4,si:4,mm:3").is_none());
        // Deterministic: same config, same report.
        let again = run(&cfg, &reg).unwrap();
        assert_eq!(again.evaluated, report.evaluated);
        for (a, b) in report.rows.iter().zip(&again.rows) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.on_frontier, b.on_frontier);
        }
    }

    #[test]
    fn bad_config_is_rejected() {
        let reg = Registry::new();
        let bad = DseConfig { scenarios: vec![], ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { budget_area: 0.0, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { scenarios: vec!["nope".into()], ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { topologies: vec!["torus9".into()], ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { rungs: 0, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { rungs: 7, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { keep_frac: 0.0, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { keep_frac: 1.5, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { replicates: 0, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
    }

    #[test]
    fn fidelity_mode_parse_round_trips() {
        assert_eq!(FidelityMode::parse("multi").unwrap(), FidelityMode::Multi);
        assert_eq!(FidelityMode::parse("MF").unwrap(), FidelityMode::Multi);
        assert_eq!(FidelityMode::parse("Exact").unwrap(), FidelityMode::Exact);
        assert!(FidelityMode::parse("approximate").is_err());
        assert_eq!(FidelityMode::Multi.name(), "multi");
        assert_eq!(FidelityMode::Exact.name(), "exact");
    }

    #[test]
    fn topology_axis_resolution_and_reticle_cap() {
        // Axis off: one implicit mono entry, raw budget untouched.
        let off = resolve_topologies(&[]).unwrap();
        assert_eq!(off.len(), 1);
        assert_eq!(off[0].label, "mono");
        assert_eq!(effective_budget(16.0, &off[0], false), 16.0);
        // Axis on: canonical dedup (mesh2x2@1x == mesh2x2), explicit mono
        // spellings fold into the implicit entry.
        let topos = resolve_topologies(&[
            "mesh2x2".into(),
            "mesh2x2@1x".into(),
            "mono".into(),
            "ring2".into(),
        ])
        .unwrap();
        let labels: Vec<&str> = topos.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, ["mono", "mesh2x2", "ring2"]);
        // Reticle: mono capped at one die, C chiplets get up to C dies.
        assert_eq!(effective_budget(16.0, &topos[0], true), MONO_DIE_AREA_UNITS);
        assert_eq!(effective_budget(16.0, &topos[1], true), 16.0);
        assert_eq!(effective_budget(60.0, &topos[1], true), 4.0 * MONO_DIE_AREA_UNITS);
        assert_eq!(effective_budget(16.0, &topos[2], true), 16.0);
        // Placement-carrying and unknown presets are pointed errors.
        let err = resolve_topologies(&["ring2/0.1".into()]).unwrap_err().to_string();
        assert!(err.contains("placement"), "{err}");
        assert!(resolve_topologies(&["torus9".into()]).is_err());
    }

    #[test]
    fn tiny_topology_axis_run_covers_both_axes() {
        // Pinned to exact fidelity: this test asserts structural coverage
        // of *every* searched candidate (e.g. "some ring2 candidate paid
        // communication"), which screening could legitimately thin out.
        let reg = Registry::new();
        let cfg = DseConfig {
            budget_area: 1.5,
            distances_m: vec![40.0],
            scenarios: vec!["urban-rush".to_string()],
            max_evals: 60,
            beam: 1,
            search: SearchMode::Greedy,
            topologies: vec!["ring2".to_string()],
            fidelity: FidelityMode::Exact,
            ..Default::default()
        };
        let report = run(&cfg, &reg).unwrap();
        assert_eq!(report.topologies, vec!["mono".to_string(), "ring2".to_string()]);
        assert!(report.rows.iter().any(|r| r.topology == "mono"));
        assert!(report.rows.iter().any(|r| r.topology == "ring2"));
        for r in &report.rows {
            if r.topology == "mono" {
                assert_eq!(r.chiplets, 1);
                assert!(!r.spec.contains('+'), "{}", r.spec);
                assert_eq!(r.comm_delay_ms_per_task, 0.0, "{}", r.spec);
                assert_eq!(r.comm_gb, 0.0, "{}", r.spec);
            } else {
                assert_eq!(r.chiplets, 2);
                assert!(r.spec.ends_with("+ring2"), "{}", r.spec);
            }
        }
        // Exact mode: the pipeline is inactive.
        assert_eq!(report.fidelity, "exact");
        assert_eq!(report.pruned(), 0);
        assert_eq!(report.screened_out, 0);
        assert_eq!(report.low_fidelity_evals, 0);
        assert!(report.rung_log.is_empty());
        // Some multi-core ring2 candidate actually moved bytes off-die.
        assert!(
            report
                .rows
                .iter()
                .any(|r| r.topology == "ring2" && r.cores > 1 && r.comm_delay_ms_per_task > 0.0),
            "no chiplet candidate paid any communication"
        );
        // Deterministic re-run, candidate identity included.
        let again = run(&cfg, &reg).unwrap();
        assert_eq!(again.evaluated, report.evaluated);
        for (a, b) in report.rows.iter().zip(&again.rows) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.topology, b.topology);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.comm_delay_ms_per_task.to_bits(), b.comm_delay_ms_per_task.to_bits());
        }
    }
}
