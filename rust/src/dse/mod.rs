//! Design-space exploration over heterogeneous platform mixes (§5, §8):
//! the paper argues the AV substrate "requires a design space exploration
//! for a new form of parallelism" — this module searches the
//! (kind × [`CoreSize`] × count) mix space under an area (and optional
//! peak-power) budget, evaluates each candidate platform on the real
//! [`Engine`] across a scenario-library slice, and reports the Pareto
//! frontier of deadline-met rate vs energy vs area.
//!
//! Two search modes share one evaluator:
//!   * **full** — enumerate every per-kind-uniform-size mix within the
//!     budget (tractable for small budgets / raised `--max-evals`);
//!   * **greedy** — beam search growing mixes one core at a time, the
//!     mode for realistic budgets where enumeration explodes.
//!
//! Evaluation batches every unseen candidate into *one*
//! [`ExperimentPlan`] whose platform axis is the candidate list and runs
//! it through [`Engine::sweep_streaming`], so trials parallelize across
//! `--jobs`, queues are shared through the engine's queue cache, and
//! memory stays flat no matter how many mixes are in flight.
//!
//! ## Topology axis
//!
//! `--topology` adds package topologies ([`Topology`] presets) as a second
//! search axis: every mix is then evaluated monolithically *and* on each
//! listed chiplet topology (spec `"{mix}+{topo}"`), with communication
//! costs paid through the [`crate::interconnect`] model.  The axis also
//! activates the *reticle* constraint: one die can hold at most
//! [`MONO_DIE_AREA_UNITS`] area units, so a monolithic candidate is capped
//! at the reticle while a C-chiplet candidate may spend up to C reticles
//! (still within `--budget`) — the silicon-economics reason dis-integrated
//! packages earn frontier seats despite paying for data movement.  With no
//! `--topology` the axis is off and `hmai dse` behaves exactly as before.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::accel::{self, AccelKind, CoreSize, ALL_ACCELS, ALL_SIZES};
use crate::engine::Engine;
use crate::env::taskgen::DeadlineMode;
use crate::interconnect::{Topology, MONO_DIE_AREA_UNITS};
use crate::metrics::summary::SweepSummary;
use crate::plan::ExperimentPlan;
use crate::platform::Platform;
use crate::sched::{Registry, SchedulerSpec};
use crate::util::json::Json;
use crate::workload::{ModelKind, ALL_MODELS};

/// How `run` explores the mix space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Full enumeration when it fits `max_evals`, greedy otherwise.
    Auto,
    /// Force full enumeration (shortlisted to `max_evals` by static
    /// capacity when the space is larger — logged, never silent).
    Full,
    /// Force the greedy beam search.
    Greedy,
}

impl SearchMode {
    pub fn parse(s: &str) -> Result<SearchMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(SearchMode::Auto),
            "full" => Ok(SearchMode::Full),
            "greedy" | "beam" => Ok(SearchMode::Greedy),
            other => anyhow::bail!("--search: expected auto|full|greedy, got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SearchMode::Auto => "auto",
            SearchMode::Full => "full",
            SearchMode::Greedy => "greedy",
        }
    }
}

/// DSE run parameters.
#[derive(Debug, Clone)]
pub struct DseConfig {
    /// Area budget in standard-core equivalents ([`CoreSize::area_units`]).
    pub budget_area: f64,
    /// Optional peak-power cap (W, [`Platform::peak_power_w`]).
    pub power_cap_w: Option<f64>,
    /// Scenario-library slice each candidate is evaluated on.
    pub scenarios: Vec<String>,
    pub distances_m: Vec<f64>,
    pub deadline: DeadlineMode,
    pub scheduler: SchedulerSpec,
    pub seed: u64,
    pub jobs: usize,
    /// Hard cap on simulated candidates (truncation is logged).
    pub max_evals: usize,
    /// Beam width of the greedy search.
    pub beam: usize,
    pub search: SearchMode,
    /// Chiplet topologies to search alongside the implicit monolithic
    /// candidate ([`Topology::try_parse`] grammar, placement-free).  Empty
    /// disables the topology axis entirely (legacy behavior).
    pub topologies: Vec<String>,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            budget_area: 12.0,
            power_cap_w: None,
            scenarios: vec!["urban-rush".to_string()],
            distances_m: vec![150.0],
            deadline: DeadlineMode::Rss,
            scheduler: SchedulerSpec::MinMin,
            seed: 42,
            jobs: 1,
            max_evals: 256,
            beam: 2,
            search: SearchMode::Auto,
            topologies: Vec::new(),
        }
    }
}

/// One entry on the topology axis: `label` is the canonical topology name
/// (`"mono"` for the implicit monolithic candidate, `topo == None`).
#[derive(Debug, Clone)]
struct TopoEntry {
    label: String,
    topo: Option<Arc<Topology>>,
}

impl TopoEntry {
    /// Platform spec for `mix` on this entry (`Platform::try_parse`
    /// grammar) — monolithic candidates keep the bare mix spec.
    fn spec_for(&self, mix: &Mix) -> String {
        match &self.topo {
            None => mix.spec(),
            Some(_) => format!("{}+{}", mix.spec(), self.label),
        }
    }

    fn chiplets(&self) -> usize {
        self.topo.as_ref().map_or(1, |t| t.chiplets)
    }
}

/// Build the topology axis: always the implicit monolithic entry first,
/// then each parsed `--topology` preset (deduplicated by canonical name,
/// explicit `mono` spellings folded into the implicit entry).
fn resolve_topologies(specs: &[String]) -> Result<Vec<TopoEntry>> {
    let mut out = vec![TopoEntry { label: "mono".to_string(), topo: None }];
    for s in specs {
        anyhow::ensure!(
            !s.contains('/'),
            "dse --topology '{s}': explicit placements cannot be searched (candidate mixes \
             vary their slot count) — use a placement-free preset like mesh2x2 or ring3"
        );
        let t = Topology::try_parse(s).map_err(|e| anyhow::anyhow!("dse --topology: {e}"))?;
        if t.is_mono() || out.iter().any(|e| e.label == t.name) {
            continue;
        }
        out.push(TopoEntry { label: t.name.clone(), topo: Some(Arc::new(t)) });
    }
    Ok(out)
}

/// Area budget a candidate of this topology entry may actually spend.
/// With the topology axis active every die must fit the reticle
/// ([`MONO_DIE_AREA_UNITS`]): a monolithic candidate is one die, a
/// C-chiplet candidate spreads its area over C dies ([`Topology::
/// max_die_area`]).  Without the axis (legacy `hmai dse`) the raw budget
/// passes through untouched.
fn effective_budget(budget_area: f64, entry: &TopoEntry, axis_active: bool) -> f64 {
    if !axis_active {
        return budget_area;
    }
    budget_area.min(MONO_DIE_AREA_UNITS * entry.chiplets() as f64)
}

/// One candidate platform mix: core count per (kind, size) cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Mix {
    /// `counts[kind.index()][size.index()]`.
    pub counts: [[usize; 3]; 3],
}

impl Mix {
    /// The paper's HMAI — (4 SO, 4 SI, 3 MM), all standard cores.
    pub fn hmai_std() -> Mix {
        let mut m = Mix::default();
        m.counts[AccelKind::SconvOD.index()][CoreSize::Std.index()] = 4;
        m.counts[AccelKind::SconvIC.index()][CoreSize::Std.index()] = 4;
        m.counts[AccelKind::MconvMC.index()][CoreSize::Std.index()] = 3;
        m
    }

    pub fn cores(&self) -> usize {
        self.counts.iter().flatten().sum()
    }

    pub fn area_units(&self) -> f64 {
        self.cells().map(|(_, s, n)| n as f64 * s.area_units()).sum()
    }

    pub fn peak_power_w(&self) -> f64 {
        self.cells().map(|(k, s, n)| n as f64 * accel::peak_power_w(k, s)).sum()
    }

    /// Aggregate best-case throughput for `model` (FPS) — the static
    /// capacity proxy the full-mode shortlist ranks by.
    pub fn capacity_fps(&self, model: ModelKind) -> f64 {
        self.cells().map(|(k, s, n)| n as f64 * accel::cost_sized(k, model, s).fps()).sum()
    }

    /// Worst-model static capacity (FPS): the balanced-provisioning proxy.
    pub fn worst_capacity_fps(&self) -> f64 {
        ALL_MODELS.iter().map(|&m| self.capacity_fps(m)).fold(f64::INFINITY, f64::min)
    }

    /// This mix plus one more (kind, size) core.
    pub fn with_added(&self, kind: AccelKind, size: CoreSize) -> Mix {
        let mut m = *self;
        m.counts[kind.index()][size.index()] += 1;
        m
    }

    /// Non-empty (kind, size, count) cells, kind-major then size-major.
    fn cells(&self) -> impl Iterator<Item = (AccelKind, CoreSize, usize)> + '_ {
        ALL_ACCELS.iter().flat_map(move |&k| {
            ALL_SIZES
                .iter()
                .map(move |&s| (k, s, self.counts[k.index()][s.index()]))
                .filter(|(_, _, n)| *n > 0)
        })
    }

    /// Platform-spec string (`Platform::try_parse` grammar), e.g.
    /// `"so:4@2x,si:4,mm:3@0.5x"`.
    pub fn spec(&self) -> String {
        self.cells()
            .map(|(k, s, n)| format!("{}:{}{}", k.short().to_ascii_lowercase(), n, s.suffix()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Resolve to a concrete [`Platform`].
    pub fn platform(&self) -> Platform {
        let mix: Vec<(AccelKind, CoreSize, usize)> = self.cells().collect();
        Platform::from_mix(&format!("custom({})", self.spec()), &mix)
    }
}

/// One evaluated candidate: static characteristics + simulated outcome.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub mix: Mix,
    /// Full candidate spec, topology suffix included (`"so:4,...+mesh2x2"`).
    pub spec: String,
    /// Topology label — `"mono"` for a monolithic candidate.
    pub topology: String,
    /// Die count of the package (1 for mono).
    pub chiplets: usize,
    pub cores: usize,
    pub area: f64,
    pub peak_power_w: f64,
    /// Deadline-met fraction over every run of the slice (Σmet / Σtasks).
    pub stm_rate: f64,
    /// Geometric-mean per-queue energy (J) over the slice.
    pub energy_j: f64,
    /// Geometric-mean wait+compute time (s) over the slice.
    pub time_s: f64,
    pub r_balance: f64,
    /// Mean interconnect delay per task (ms) — 0 on monolithic candidates.
    pub comm_delay_ms_per_task: f64,
    /// Mean bytes moved over the interconnect per trial (GB).
    pub comm_gb: f64,
    /// Non-dominated on (stm_rate ↑, energy_j ↓, area ↓)?
    pub on_frontier: bool,
}

impl EvalRow {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("spec", Json::Str(self.spec.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("chiplets", Json::Num(self.chiplets as f64)),
            ("cores", Json::Num(self.cores as f64)),
            ("area_units", Json::Num(self.area)),
            ("peak_power_w", Json::Num(self.peak_power_w)),
            ("stm_rate", Json::Num(self.stm_rate)),
            ("energy_j", Json::Num(self.energy_j)),
            ("time_s", Json::Num(self.time_s)),
            ("r_balance", Json::Num(self.r_balance)),
            ("comm_delay_ms_per_task", Json::Num(self.comm_delay_ms_per_task)),
            ("comm_gb", Json::Num(self.comm_gb)),
            ("on_frontier", Json::Bool(self.on_frontier)),
        ])
    }
}

/// Outcome of a DSE run: every evaluated mix (frontier rows first, then by
/// descending deadline-met rate) plus run bookkeeping.
#[derive(Debug)]
pub struct DseReport {
    pub rows: Vec<EvalRow>,
    pub frontier: usize,
    pub evaluated: usize,
    pub search: &'static str,
    pub budget_area: f64,
    pub power_cap_w: Option<f64>,
    /// Candidates dropped by `max_evals` (0 = exhaustive within mode).
    pub truncated: usize,
    /// Topology-axis labels, `"mono"` first (just `["mono"]` when the
    /// axis is off).
    pub topologies: Vec<String>,
}

impl DseReport {
    /// Frontier rows, in report order.
    pub fn frontier_rows(&self) -> impl Iterator<Item = &EvalRow> {
        self.rows.iter().filter(|r| r.on_frontier)
    }

    pub fn find(&self, spec: &str) -> Option<&EvalRow> {
        self.rows.iter().find(|r| r.spec == spec)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("budget_area", Json::Num(self.budget_area)),
            (
                "power_cap_w",
                self.power_cap_w.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("search", Json::Str(self.search.to_string())),
            ("evaluated", Json::Num(self.evaluated as f64)),
            ("truncated", Json::Num(self.truncated as f64)),
            (
                "topologies",
                Json::Arr(self.topologies.iter().map(|t| Json::Str(t.clone())).collect()),
            ),
            ("frontier_size", Json::Num(self.frontier as f64)),
            (
                "frontier",
                Json::Arr(self.frontier_rows().map(|r| r.to_json()).collect()),
            ),
            ("rows", Json::Arr(self.rows.iter().map(|r| r.to_json()).collect())),
        ])
    }
}

/// Enumerate every mix with a *uniform size per kind* (the spec-syntax
/// shape) within the area/power budget, up to `limit` candidates.
/// Returns `(mixes, hit_limit)`.
pub fn enumerate(budget_area: f64, power_cap_w: Option<f64>, limit: usize) -> (Vec<Mix>, bool) {
    let mut out = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for so_size in ALL_SIZES {
        for si_size in ALL_SIZES {
            for mm_size in ALL_SIZES {
                let sizes = [so_size, si_size, mm_size];
                let max_n = |s: CoreSize| (budget_area / s.area_units()).floor() as usize;
                for so in 0..=max_n(so_size) {
                    for si in 0..=max_n(si_size) {
                        for mm in 0..=max_n(mm_size) {
                            if so + si + mm == 0 {
                                continue;
                            }
                            let mut mix = Mix::default();
                            for (k, (&n, s)) in
                                ALL_ACCELS.iter().zip([so, si, mm].iter().zip(sizes))
                            {
                                mix.counts[k.index()][s.index()] = n;
                            }
                            if mix.area_units() > budget_area + 1e-9 {
                                break; // mm grows area monotonically
                            }
                            if let Some(cap) = power_cap_w {
                                if mix.peak_power_w() > cap {
                                    break; // power also grows with mm
                                }
                            }
                            if seen.insert(mix) {
                                if out.len() >= limit {
                                    return (out, true);
                                }
                                out.push(mix);
                            }
                        }
                    }
                }
            }
        }
    }
    (out, false)
}

/// Mark the Pareto frontier on (stm_rate max, energy_j min, area min).
pub fn mark_frontier(rows: &mut [EvalRow]) -> usize {
    let n = rows.len();
    let mut frontier = 0;
    for i in 0..n {
        let dominated = (0..n).any(|j| {
            if i == j {
                return false;
            }
            let (a, b) = (&rows[i], &rows[j]);
            b.stm_rate >= a.stm_rate
                && b.energy_j <= a.energy_j
                && b.area <= a.area
                && (b.stm_rate > a.stm_rate || b.energy_j < a.energy_j || b.area < a.area)
        });
        rows[i].on_frontier = !dominated;
        if !dominated {
            frontier += 1;
        }
    }
    frontier
}

/// Batched evaluator with a result cache: every unseen mix of a batch goes
/// through one engine sweep.
struct Evaluator<'a> {
    cfg: &'a DseConfig,
    registry: &'a Registry,
    /// Resolved topology axis (`[mono]` when the axis is off).
    topos: &'a [TopoEntry],
    /// Evaluated rows, in first-evaluation order (deterministic).
    rows: Vec<EvalRow>,
    /// (mix, topology-axis index) → row index.
    index: BTreeMap<(Mix, usize), usize>,
}

impl<'a> Evaluator<'a> {
    fn new(cfg: &'a DseConfig, registry: &'a Registry, topos: &'a [TopoEntry]) -> Evaluator<'a> {
        Evaluator { cfg, registry, topos, rows: Vec::new(), index: BTreeMap::new() }
    }

    fn evaluated(&self) -> usize {
        self.rows.len()
    }

    fn row(&self, mix: &Mix, ti: usize) -> &EvalRow {
        &self.rows[self.index[&(*mix, ti)]]
    }

    /// Evaluate every not-yet-seen mix of `mixes` on topology entry `ti`
    /// in one engine sweep.
    fn eval_all(&mut self, mixes: &[Mix], ti: usize) -> Result<()> {
        let entry = &self.topos[ti];
        let mut fresh: Vec<Mix> = Vec::new();
        for &m in mixes {
            if !self.index.contains_key(&(m, ti)) && !fresh.contains(&m) {
                fresh.push(m);
            }
        }
        if fresh.is_empty() {
            return Ok(());
        }
        let specs: Vec<String> = fresh.iter().map(|m| entry.spec_for(m)).collect();
        let plan = ExperimentPlan::new()
            .scenarios(self.cfg.scenarios.iter().cloned())
            .distances(self.cfg.distances_m.iter().copied())
            .deadline(self.cfg.deadline)
            .platforms(specs.iter().cloned())
            .scheduler(self.cfg.scheduler.clone())
            .seed(self.cfg.seed);
        let sweep = Engine::new(self.registry)
            .jobs(self.cfg.jobs)
            .sweep_streaming(&plan)
            .context("dse candidate sweep")?;
        for (mix, spec) in fresh.into_iter().zip(specs) {
            let row = fold_rows(&mix, entry, spec, &sweep)?;
            self.index.insert((mix, ti), self.rows.len());
            self.rows.push(row);
        }
        Ok(())
    }
}

/// Fold a candidate's sweep rows (one per scenario) into one `EvalRow`.
fn fold_rows(mix: &Mix, entry: &TopoEntry, spec: String, sweep: &SweepSummary) -> Result<EvalRow> {
    // Sweep groups key on the *platform name*: the bare mix name for mono,
    // the `+topology`-suffixed name the platform parser produces otherwise.
    let name = match &entry.topo {
        None => mix.platform().name,
        Some(_) => {
            Platform::try_parse(&spec).map_err(anyhow::Error::msg).context("dse spec")?.name
        }
    };
    let mut met = 0u64;
    let mut tasks = 0u64;
    let mut n = 0u64;
    let mut sum_ln_e = 0.0;
    let mut sum_ln_t = 0.0;
    let mut sum_rb = 0.0;
    let mut sum_comm_delay = 0.0;
    let mut sum_comm_gb = 0.0;
    for g in sweep.groups.iter().filter(|g| g.key.platform == name) {
        met += g.stats.sum_tasks_met;
        tasks += g.stats.sum_tasks;
        n += g.stats.trials;
        sum_ln_e += g.stats.sum_ln_energy;
        sum_ln_t += g.stats.sum_ln_time;
        sum_rb += g.stats.sum_r_balance;
        sum_comm_delay += g.stats.sum_comm_delay;
        sum_comm_gb += g.stats.sum_comm_gb;
    }
    anyhow::ensure!(n > 0, "no sweep rows for candidate '{name}'");
    Ok(EvalRow {
        mix: *mix,
        spec,
        topology: entry.label.clone(),
        chiplets: entry.chiplets(),
        cores: mix.cores(),
        area: mix.area_units(),
        peak_power_w: mix.peak_power_w(),
        stm_rate: if tasks == 0 { 1.0 } else { met as f64 / tasks as f64 },
        energy_j: (sum_ln_e / n as f64).exp(),
        time_s: (sum_ln_t / n as f64).exp(),
        r_balance: sum_rb / n as f64,
        comm_delay_ms_per_task: if tasks == 0 { 0.0 } else { sum_comm_delay / tasks as f64 * 1e3 },
        comm_gb: sum_comm_gb / n as f64,
        on_frontier: false,
    })
}

/// Greedy beam search: grow mixes one (kind, size) core at a time, keeping
/// the `beam` best per step (deadline-met rate, then energy, then area),
/// until the budget admits no extension or `max_evals` is hit.  Every step
/// adds exactly one core, so area strictly grows and the loop terminates.
/// Searches one topology entry `ti` with its effective `budget_area`;
/// `evals_cap` is this entry's cumulative share of `max_evals` (equal to
/// `cfg.max_evals` when the topology axis is off).
fn greedy_search(
    cfg: &DseConfig,
    ev: &mut Evaluator,
    ti: usize,
    budget_area: f64,
    evals_cap: usize,
) -> Result<usize> {
    let within = |m: &Mix| {
        m.area_units() <= budget_area + 1e-9
            && cfg.power_cap_w.map(|cap| m.peak_power_w() <= cap).unwrap_or(true)
    };
    let all_cells =
        || ALL_ACCELS.iter().flat_map(|&k| ALL_SIZES.iter().map(move |&s| (k, s)));
    // Select the `beam` best of an evaluated batch (deterministic order).
    let select_top = |mixes: &mut Vec<Mix>, ev: &Evaluator| {
        mixes.sort_by(|a, b| {
            let (ra, rb) = (ev.row(a, ti), ev.row(b, ti));
            rb.stm_rate
                .total_cmp(&ra.stm_rate)
                .then(ra.energy_j.total_cmp(&rb.energy_j))
                .then(ra.area.total_cmp(&rb.area))
                .then(ra.spec.cmp(&rb.spec))
        });
        mixes.truncate(cfg.beam);
    };

    // Seeds: every single-core mix inside the budget.
    let mut batch: Vec<Mix> =
        all_cells().map(|(k, s)| Mix::default().with_added(k, s)).filter(within).collect();
    let mut truncated = 0usize;
    loop {
        // Cap the batch at the remaining eval budget (logged below).
        let budget_left = evals_cap.saturating_sub(ev.evaluated());
        if batch.len() > budget_left {
            truncated += batch.len() - budget_left;
            batch.truncate(budget_left);
        }
        if batch.is_empty() {
            break;
        }
        ev.eval_all(&batch, ti)?;
        select_top(&mut batch, ev);
        // Extend each kept beam by one core; already-evaluated mixes
        // cannot reappear (extensions always have one more core than any
        // previous round).
        let mut exts: Vec<Mix> = Vec::new();
        for b in &batch {
            for (k, s) in all_cells() {
                let m = b.with_added(k, s);
                if within(&m) && !exts.contains(&m) {
                    exts.push(m);
                }
            }
        }
        batch = exts;
    }
    if truncated > 0 {
        crate::log_warn!(
            "dse",
            "--max-evals {} reached; {truncated} candidate(s) not simulated (raise \
             --max-evals or narrow --budget for an exhaustive pass)",
            cfg.max_evals
        );
    }
    Ok(truncated)
}

/// Run the exploration: enumerate or beam-search candidates, evaluate on
/// the engine, and mark the Pareto frontier.  The HMAI (4,4,3)@Std point
/// is always evaluated when it fits the budget, so the paper's pick can be
/// located relative to the frontier.
pub fn run(cfg: &DseConfig, registry: &Registry) -> Result<DseReport> {
    anyhow::ensure!(
        cfg.budget_area >= CoreSize::Half.area_units(),
        "dse: --budget {} admits no core at all (a half core costs {} area units)",
        cfg.budget_area,
        CoreSize::Half.area_units()
    );
    anyhow::ensure!(!cfg.scenarios.is_empty(), "dse: at least one --scenario required");
    anyhow::ensure!(!cfg.distances_m.is_empty(), "dse: at least one --dist required");
    anyhow::ensure!(cfg.max_evals > 0, "dse: --max-evals must be positive");
    anyhow::ensure!(cfg.beam > 0, "dse: --beam must be positive");
    for name in &cfg.scenarios {
        crate::env::scenario::find(name).context("dse --scenario")?;
    }
    let topos = resolve_topologies(&cfg.topologies)?;
    let axis_active = topos.len() > 1;

    let mut ev = Evaluator::new(cfg, registry, &topos);
    // Each topology entry gets an equal share of the eval budget so an
    // early entry cannot starve the later ones; an entry's unspent share
    // rolls forward via the cumulative cap.  With the axis off the single
    // entry's cap is exactly `max_evals` (legacy behaviour).
    let share =
        |ti: usize| cfg.max_evals / topos.len() + usize::from(ti < cfg.max_evals % topos.len());
    let (mode, mut truncated) = match cfg.search {
        SearchMode::Greedy => (SearchMode::Greedy, 0),
        SearchMode::Full => (SearchMode::Full, 0),
        SearchMode::Auto => {
            // Per-entry effective budgets never exceed the raw budget, so
            // probing it with the eval budget split across the axis gives
            // a sound (and, with the axis off, exactly the legacy) answer.
            let limit = (cfg.max_evals / topos.len()).max(1);
            let (_, over) = enumerate(cfg.budget_area, cfg.power_cap_w, limit);
            (if over { SearchMode::Greedy } else { SearchMode::Full }, 0)
        }
    };
    match mode {
        SearchMode::Full => {
            let mut cap = 0usize;
            for ti in 0..topos.len() {
                cap += share(ti);
                let eff = effective_budget(cfg.budget_area, &topos[ti], axis_active);
                let (mut mixes, over) = enumerate(eff, cfg.power_cap_w, 200_000);
                let left = cap.saturating_sub(ev.evaluated());
                if over || mixes.len() > left {
                    // Shortlist by worst-model static capacity (balanced
                    // provisioning) — logged, never silent.
                    let dropped = mixes.len().saturating_sub(left);
                    crate::log_warn!(
                        "dse",
                        "full enumeration ({}) has {} candidates; simulating the top {left} by \
                         worst-model capacity ({dropped} dropped — use --search greedy or raise \
                         --max-evals)",
                        topos[ti].label,
                        mixes.len(),
                    );
                    // One key build per mix (the list can be huge): positive
                    // finite f64s order identically to their bit patterns, so
                    // `to_bits` keys give capacity-desc / area-asc / spec-asc.
                    mixes.sort_by_cached_key(|m| {
                        (
                            std::cmp::Reverse(m.worst_capacity_fps().to_bits()),
                            m.area_units().to_bits(),
                            m.spec(),
                        )
                    });
                    mixes.truncate(left);
                    truncated += dropped;
                }
                ev.eval_all(&mixes, ti)?;
            }
        }
        SearchMode::Greedy | SearchMode::Auto => {
            let mut cap = 0usize;
            for ti in 0..topos.len() {
                cap += share(ti);
                let eff = effective_budget(cfg.budget_area, &topos[ti], axis_active);
                truncated += greedy_search(cfg, &mut ev, ti, eff, cap)?;
            }
        }
    }

    // The paper's HMAI point, for frontier placement (acceptance anchor) —
    // on every topology entry it fits.
    let hmai = Mix::hmai_std();
    for ti in 0..topos.len() {
        if hmai.area_units() <= effective_budget(cfg.budget_area, &topos[ti], axis_active) + 1e-9
            && cfg.power_cap_w.map(|cap| hmai.peak_power_w() <= cap).unwrap_or(true)
        {
            ev.eval_all(&[hmai], ti)?;
        }
    }

    let mut rows = ev.rows;
    let frontier = mark_frontier(&mut rows);
    // Report order: frontier first, then by deadline-met rate desc,
    // energy asc, area asc (deterministic tie-break on the spec).
    rows.sort_by(|a, b| {
        b.on_frontier
            .cmp(&a.on_frontier)
            .then(b.stm_rate.total_cmp(&a.stm_rate))
            .then(a.energy_j.total_cmp(&b.energy_j))
            .then(a.area.total_cmp(&b.area))
            .then(a.spec.cmp(&b.spec))
    });
    Ok(DseReport {
        evaluated: rows.len(),
        frontier,
        rows,
        search: mode.name(),
        budget_area: cfg.budget_area,
        power_cap_w: cfg.power_cap_w,
        truncated,
        topologies: topos.iter().map(|t| t.label.clone()).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_spec_round_trips_through_platform_parse() {
        let mut m = Mix::hmai_std();
        m.counts[AccelKind::SconvOD.index()][CoreSize::Double.index()] = 1;
        m.counts[AccelKind::MconvMC.index()][CoreSize::Half.index()] = 2;
        let spec = m.spec();
        let p = Platform::try_parse(&spec).unwrap();
        assert_eq!(p.len(), m.cores());
        for k in ALL_ACCELS {
            for s in ALL_SIZES {
                assert_eq!(
                    p.count_of_sized(k, s),
                    m.counts[k.index()][s.index()],
                    "{k:?} {s:?} in '{spec}'"
                );
            }
        }
        assert_eq!(p.name, m.platform().name);
        assert!((p.area_units() - m.area_units()).abs() < 1e-12);
    }

    #[test]
    fn hmai_mix_matches_platform_hmai() {
        let m = Mix::hmai_std();
        assert_eq!(m.cores(), 11);
        assert!((m.area_units() - 11.0).abs() < 1e-12);
        assert_eq!(m.spec(), "so:4,si:4,mm:3");
        let p = m.platform();
        assert_eq!(p.count_of(AccelKind::SconvOD), 4);
        assert_eq!(p.count_of(AccelKind::MconvMC), 3);
        assert!(m.worst_capacity_fps() > 0.0);
    }

    #[test]
    fn enumerate_respects_budget_and_dedupes() {
        let (mixes, over) = enumerate(3.0, None, 100_000);
        assert!(!over);
        assert!(!mixes.is_empty());
        for m in &mixes {
            assert!(m.area_units() <= 3.0 + 1e-9, "{}", m.spec());
            assert!(m.cores() >= 1);
        }
        let set: std::collections::HashSet<_> = mixes.iter().collect();
        assert_eq!(set.len(), mixes.len(), "duplicates enumerated");
        // A power cap strictly shrinks the space: every Std-core busy
        // power exceeds 1 W (pinned in accel::energy tests), so a 1 W cap
        // must exclude at least every std/double-core mix.
        let (capped, _) = enumerate(3.0, Some(1.0), 100_000);
        assert!(capped.len() < mixes.len());
        for m in &capped {
            assert!(m.peak_power_w() <= 1.0);
        }
        // The limit flag fires.
        let (some, over) = enumerate(12.0, None, 64);
        assert_eq!(some.len(), 64);
        assert!(over);
    }

    #[test]
    fn frontier_marking_is_sound() {
        let row = |stm: f64, e: f64, a: f64| EvalRow {
            mix: Mix::default(),
            spec: format!("{stm}-{e}-{a}"),
            topology: "mono".to_string(),
            chiplets: 1,
            cores: 1,
            area: a,
            peak_power_w: 1.0,
            stm_rate: stm,
            energy_j: e,
            time_s: 1.0,
            r_balance: 0.5,
            comm_delay_ms_per_task: 0.0,
            comm_gb: 0.0,
            on_frontier: false,
        };
        let mut rows = vec![
            row(0.9, 10.0, 5.0), // frontier (best stm)
            row(0.8, 8.0, 5.0),  // frontier (cheaper energy)
            row(0.8, 9.0, 5.0),  // dominated by the one above
            row(0.5, 12.0, 2.0), // frontier (smallest area)
        ];
        let n = mark_frontier(&mut rows);
        assert_eq!(n, 3);
        assert!(rows[0].on_frontier && rows[1].on_frontier && rows[3].on_frontier);
        assert!(!rows[2].on_frontier);
    }

    #[test]
    fn tiny_greedy_run_produces_a_frontier() {
        let reg = Registry::new();
        let cfg = DseConfig {
            budget_area: 2.5,
            distances_m: vec![40.0],
            scenarios: vec!["urban-rush".to_string()],
            max_evals: 40,
            beam: 1,
            search: SearchMode::Greedy,
            ..Default::default()
        };
        let report = run(&cfg, &reg).unwrap();
        assert!(report.evaluated > 0);
        assert!(report.frontier >= 1);
        assert!(report.rows.iter().any(|r| r.on_frontier));
        // Frontier rows lead the report.
        assert!(report.rows[0].on_frontier);
        // Every evaluated mix respects the budget.
        for r in &report.rows {
            assert!(r.area <= 2.5 + 1e-9, "{}", r.spec);
            assert!(r.stm_rate >= 0.0 && r.stm_rate <= 1.0);
            assert!(r.energy_j > 0.0);
        }
        // HMAI does not fit a 2.5-unit budget, so it must not be injected.
        assert!(report.find("so:4,si:4,mm:3").is_none());
        // Deterministic: same config, same report.
        let again = run(&cfg, &reg).unwrap();
        assert_eq!(again.evaluated, report.evaluated);
        for (a, b) in report.rows.iter().zip(&again.rows) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.on_frontier, b.on_frontier);
        }
    }

    #[test]
    fn bad_config_is_rejected() {
        let reg = Registry::new();
        let bad = DseConfig { scenarios: vec![], ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { budget_area: 0.0, ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { scenarios: vec!["nope".into()], ..Default::default() };
        assert!(run(&bad, &reg).is_err());
        let bad = DseConfig { topologies: vec!["torus9".into()], ..Default::default() };
        assert!(run(&bad, &reg).is_err());
    }

    #[test]
    fn topology_axis_resolution_and_reticle_cap() {
        // Axis off: one implicit mono entry, raw budget untouched.
        let off = resolve_topologies(&[]).unwrap();
        assert_eq!(off.len(), 1);
        assert_eq!(off[0].label, "mono");
        assert_eq!(effective_budget(16.0, &off[0], false), 16.0);
        // Axis on: canonical dedup (mesh2x2@1x == mesh2x2), explicit mono
        // spellings fold into the implicit entry.
        let topos = resolve_topologies(&[
            "mesh2x2".into(),
            "mesh2x2@1x".into(),
            "mono".into(),
            "ring2".into(),
        ])
        .unwrap();
        let labels: Vec<&str> = topos.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, ["mono", "mesh2x2", "ring2"]);
        // Reticle: mono capped at one die, C chiplets get up to C dies.
        assert_eq!(effective_budget(16.0, &topos[0], true), MONO_DIE_AREA_UNITS);
        assert_eq!(effective_budget(16.0, &topos[1], true), 16.0);
        assert_eq!(effective_budget(60.0, &topos[1], true), 4.0 * MONO_DIE_AREA_UNITS);
        assert_eq!(effective_budget(16.0, &topos[2], true), 16.0);
        // Placement-carrying and unknown presets are pointed errors.
        let err = resolve_topologies(&["ring2/0.1".into()]).unwrap_err().to_string();
        assert!(err.contains("placement"), "{err}");
        assert!(resolve_topologies(&["torus9".into()]).is_err());
    }

    #[test]
    fn tiny_topology_axis_run_covers_both_axes() {
        let reg = Registry::new();
        let cfg = DseConfig {
            budget_area: 1.5,
            distances_m: vec![40.0],
            scenarios: vec!["urban-rush".to_string()],
            max_evals: 60,
            beam: 1,
            search: SearchMode::Greedy,
            topologies: vec!["ring2".to_string()],
            ..Default::default()
        };
        let report = run(&cfg, &reg).unwrap();
        assert_eq!(report.topologies, vec!["mono".to_string(), "ring2".to_string()]);
        assert!(report.rows.iter().any(|r| r.topology == "mono"));
        assert!(report.rows.iter().any(|r| r.topology == "ring2"));
        for r in &report.rows {
            if r.topology == "mono" {
                assert_eq!(r.chiplets, 1);
                assert!(!r.spec.contains('+'), "{}", r.spec);
                assert_eq!(r.comm_delay_ms_per_task, 0.0, "{}", r.spec);
                assert_eq!(r.comm_gb, 0.0, "{}", r.spec);
            } else {
                assert_eq!(r.chiplets, 2);
                assert!(r.spec.ends_with("+ring2"), "{}", r.spec);
            }
        }
        // Some multi-core ring2 candidate actually moved bytes off-die.
        assert!(
            report
                .rows
                .iter()
                .any(|r| r.topology == "ring2" && r.cores > 1 && r.comm_delay_ms_per_task > 0.0),
            "no chiplet candidate paid any communication"
        );
        // Deterministic re-run, candidate identity included.
        let again = run(&cfg, &reg).unwrap();
        assert_eq!(again.evaluated, report.evaluated);
        for (a, b) in report.rows.iter().zip(&again.rows) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.topology, b.topology);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.comm_delay_ms_per_task.to_bits(), b.comm_delay_ms_per_task.to_bits());
        }
    }
}
