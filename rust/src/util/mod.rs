//! Substrate utilities built in-repo (the offline vendor set has no serde /
//! clap / criterion / rand): JSON, PRNG, CLI parsing, logging, statistics,
//! bench harness, table rendering.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod table;
