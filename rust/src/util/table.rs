//! Aligned plain-text table rendering for `hmai report` and the bench
//! binaries — every paper table/figure is regenerated as one of these.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        debug_assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<width$} |", c, width = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across reports.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn times(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "value"]);
        t.row(["a", "1"]).row(["longer-name", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
        assert!(s.contains("longer-name"));
    }

    #[test]
    fn formatters() {
        assert_eq!(f1(1.26), "1.3");
        assert_eq!(f2(1.255), "1.25"); // rounds-to-even edge is fine
        assert_eq!(pct(0.5), "50.0%");
        assert_eq!(times(2.0), "2.00x");
    }
}
