//! Hand-rolled CLI argument parsing (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! typed accessors and a usage printer driven by a declarative option table.

use std::collections::BTreeMap;

/// Parsed arguments: flags/options plus positionals, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative option description for usage text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse a raw arg list.  Anything starting with `--` is an option; if
    /// the next token doesn't start with `--` it is taken as its value,
    /// otherwise it's a bare flag.  `--k=v` is always key/value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let raw: Vec<String> = raw.into_iter().collect();
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    a.opts.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Positionals after the subcommand.
    pub fn rest(&self) -> &[String] {
        if self.positional.is_empty() {
            &[]
        } else {
            &self.positional[1..]
        }
    }
}

/// Render a usage block from option specs.
pub fn usage(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUSAGE:\n    {program} [OPTIONS]\n\nOPTIONS:\n");
    for spec in specs {
        let left = match spec.value {
            Some(v) => format!("--{} <{}>", spec.name, v),
            None => format!("--{}", spec.name),
        };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("    {:<28} {}{}\n", left, spec.help, default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value() {
        let a = parse("--seed 7 --area urban");
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("area"), Some("urban"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("--lr=0.01 --episodes=5");
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.01);
        assert_eq!(a.get_usize("episodes", 0).unwrap(), 5);
    }

    #[test]
    fn parses_flags_and_positionals() {
        // NB: `--flag value`-style ambiguity is resolved as key/value, so
        // bare flags go after positionals or use `--flag=true`.
        let a = parse("train route.json --verbose --fast");
        assert_eq!(a.subcommand(), Some("train"));
        assert!(a.flag("verbose"));
        assert!(a.flag("fast"));
        assert_eq!(a.rest(), &["route.json".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("--n abc");
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_usize("missing", 42).unwrap(), 42);
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b 3");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("3"));
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "hmai",
            "HMAI coordinator",
            &[OptSpec { name: "seed", value: Some("u64"), help: "rng seed", default: Some("0") }],
        );
        assert!(u.contains("--seed <u64>"));
        assert!(u.contains("[default: 0]"));
    }
}
