//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and drive this module.  Each
//! benchmark warms up, then runs timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met, and reports
//! mean / p50 / p95 per-iteration latency plus derived throughput.

use std::time::{Duration, Instant};

use crate::util::stats::percentile;

/// One benchmark's collected samples (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        crate::util::stats::mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        percentile(&s, 50.0)
    }

    pub fn p95(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        percentile(&s, 95.0)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {}  p50 {}  p95 {}  ({} iters)",
            self.name,
            fmt_duration(self.mean()),
            fmt_duration(self.p50()),
            fmt_duration(self.p95()),
            self.samples.len()
        )
    }
}

/// Human-readable seconds.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:8.3} s ", secs)
    } else if secs >= 1e-3 {
        format!("{:8.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:8.3} µs", secs * 1e6)
    } else {
        format!("{:8.1} ns", secs * 1e9)
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for expensive end-to-end benches.
    pub fn heavy() -> Self {
        Self {
            warmup: Duration::ZERO,
            budget: Duration::from_secs(1),
            min_iters: 3,
            max_iters: 50,
            ..Self::default()
        }
    }

    /// Run `f` repeatedly, timing each call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed runs.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while (samples.len() < self.min_iters || t0.elapsed() < self.budget)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), samples };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Print a section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let mut b = Bencher {
            warmup: Duration::ZERO,
            budget: Duration::from_millis(10),
            min_iters: 5,
            max_iters: 1000,
            results: Vec::new(),
        };
        let r = b.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.samples.len() >= 5);
        assert!(r.mean() >= 0.0);
        assert!(r.p95() >= r.p50() * 0.5);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2.0).contains("s"));
        assert!(fmt_duration(2e-3).contains("ms"));
        assert!(fmt_duration(2e-6).contains("µs"));
        assert!(fmt_duration(2e-9).contains("ns"));
    }
}
