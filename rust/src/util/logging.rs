//! Leveled stderr logger with a global level, timestamped relative to
//! process start.  Deliberately tiny: the coordinator's hot path must never
//! pay for logging when the level is off (guarded by an atomic load).

// This module IS the sanctioned stderr channel (package-wide deny carve-out).
#![allow(clippy::print_stderr)]

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_level_from_str(s: &str) -> bool {
    let level = match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => return false,
    };
    set_level(level);
    true
}

#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:>10.4}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error { ($t:expr, $($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, $t, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn level_from_str() {
        assert!(set_level_from_str("debug"));
        assert!(enabled(Level::Debug));
        assert!(!set_level_from_str("bogus"));
        set_level(Level::Info);
    }
}
