//! Small statistics helpers used by metrics and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean of positive values; 0.0 for an empty slice.
/// Non-positive entries are clamped to a tiny epsilon (they would otherwise
/// collapse the whole product — matches how the paper reports geomeans over
/// ratios that are always positive).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p.clamp(0.0, 100.0) / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Min-max normalize `x` into [0,1] given observed bounds; degenerate
/// bounds map to 0.5 (neutral).
pub fn minmax_norm(x: f64, lo: f64, hi: f64) -> f64 {
    if hi - lo <= f64::EPSILON {
        0.5
    } else {
        ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
    }
}

/// Saturating exponential normalization to (0, 1): 1 - exp(-x/scale).
/// Used to squash unbounded quantities (energy, time) for the RL state.
pub fn soft_norm(x: f64, scale: f64) -> f64 {
    if scale <= 0.0 {
        return 0.0;
    }
    1.0 - (-x.max(0.0) / scale).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_clamps_nonpositive() {
        assert!(geomean(&[0.0, 1.0]) > 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
        let s = stddev(&[1.0, 3.0]);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn minmax_norm_clamps() {
        assert_eq!(minmax_norm(5.0, 0.0, 10.0), 0.5);
        assert_eq!(minmax_norm(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(minmax_norm(11.0, 0.0, 10.0), 1.0);
        assert_eq!(minmax_norm(3.0, 3.0, 3.0), 0.5);
    }

    #[test]
    fn soft_norm_monotone_bounded() {
        let a = soft_norm(1.0, 10.0);
        let b = soft_norm(5.0, 10.0);
        assert!(0.0 < a && a < b && b < 1.0);
        assert_eq!(soft_norm(0.0, 10.0), 0.0);
    }
}
