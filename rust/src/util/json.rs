//! Minimal JSON parser + writer.
//!
//! The offline vendor set has no `serde` facade, so configs, checkpoints and
//! reports go through this hand-rolled implementation.  It supports the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) and preserves object key order (insertion order) so emitted
//! reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep insertion order via a parallel key list.
    Obj(JsonObj),
}

/// Insertion-ordered string-keyed map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

/// Parse / access error.  (Hand-implemented `Display`/`Error`: the
/// offline vendor set has no `thiserror`.)
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    MissingKey(String),
    Type { key: String, expected: &'static str },
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::MissingKey(key) => write!(f, "json: missing key '{key}'"),
            JsonError::Type { key, expected } => {
                write!(f, "json: type mismatch at '{key}': expected {expected}")
            }
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut o = JsonObj::new();
        for (k, v) in pairs {
            o.insert(k, v);
        }
        Json::Obj(o)
    }

    pub fn array_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn array_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn array_str(v: &[&str]) -> Json {
        Json::Arr(v.iter().map(|&s| Json::Str(s.to_string())).collect())
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()
            .ok_or_else(|| JsonError::Type { key: key.into(), expected: "object" })?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.into()))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)?
            .as_f64()
            .ok_or(JsonError::Type { key: key.into(), expected: "number" })
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)?
            .as_usize()
            .ok_or(JsonError::Type { key: key.into(), expected: "usize" })
    }

    pub fn get_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)?
            .as_str()
            .ok_or(JsonError::Type { key: key.into(), expected: "string" })
    }

    pub fn get_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)?
            .as_arr()
            .ok_or(JsonError::Type { key: key.into(), expected: "array" })
    }

    pub fn get_f32_vec(&self, key: &str) -> Result<Vec<f32>, JsonError> {
        let arr = self.get_arr(key)?;
        arr.iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as f32)
                    .ok_or(JsonError::Type { key: key.into(), expected: "number[]" })
            })
            .collect()
    }

    // -- parsing -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- writing -----------------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    /// Write the pretty rendering to a file — the one JSON writer behind
    /// the CLI's `--json <path>` reports, the `BENCH_*.json` artifacts and
    /// the fleet checkpoints, so every machine-readable output shares one
    /// format.  Atomic: a reader (or a kill) never observes a truncated
    /// file.
    pub fn write_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        write_atomic(path, &self.to_pretty())
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{}", x));
        }
    } else {
        // JSON has no NaN/Inf; emit null like most writers.
        out.push_str("null");
    }
}

/// Atomically replace `path` with `text`: write a hidden temp file in the
/// same directory (same filesystem, so the rename is atomic) and rename it
/// over the target.  A process killed mid-write leaves either the old
/// file or the new one — never a truncated mix — which is what makes
/// fleet checkpoints safe to resume from after a kill.
pub fn write_atomic(path: &std::path::Path, text: &str) -> std::io::Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidInput, "path has no file name"))?
        .to_string_lossy()
        .into_owned();
    let tmp_name = format!(".{name}.tmp.{}", std::process::id());
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    std::fs::write(&tmp, text)?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    Ok(())
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut o = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            o.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(o)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex in \\u"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get_arr("a").unwrap().len(), 3);
        assert_eq!(v.get_str("c").unwrap(), "x\ny");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\"\\ A");
        // Surrogate pair: U+1F600
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld 日本\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 日本");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"hmai","dims":[134,256,64,16],"lr":0.01,"ok":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "a": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.get_usize("n").unwrap(), 7);
        assert_eq!(v.get_str("s").unwrap(), "x");
        assert_eq!(v.get_f32_vec("a").unwrap(), vec![1.5, 2.5]);
        assert!(matches!(v.get("missing"), Err(JsonError::MissingKey(_))));
        assert!(v.get_f64("s").is_err());
    }

    #[test]
    fn error_display_names_the_problem() {
        let e = Json::parse("{").unwrap_err();
        assert!(format!("{e}").contains("json parse error"));
        let v = Json::parse("{}").unwrap();
        assert!(format!("{}", v.get("k").unwrap_err()).contains("missing key 'k'"));
        assert!(format!("{}", v.get_f64("k").unwrap_err()).contains("k"));
        // anyhow interop: JsonError is a std Error.
        let _: &dyn std::error::Error = &e;
    }

    #[test]
    fn write_to_roundtrips_through_a_file() {
        let dir = std::env::temp_dir().join("hmai_json_write_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let v = Json::parse(r#"{"a": [1, 2], "b": "x"}"#).unwrap();
        v.write_to(&path).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join("hmai_json_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        super::write_atomic(&path, "{\"v\": 1}\n").unwrap();
        super::write_atomic(&path, "{\"v\": 2}\n").unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get_f64("v").unwrap(), 2.0);
        // No temp droppings survive a successful replace.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(0.25).to_string(), "0.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
