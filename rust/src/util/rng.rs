//! Deterministic PRNGs (no `rand` crate offline): SplitMix64 for seeding and
//! a Xoshiro256++ main generator.  Every stochastic component (route
//! generation, ε-greedy, GA/SA, replay sampling) takes an explicit `Rng` so
//! experiments are reproducible from a single u64 seed.

/// SplitMix64 — used to expand one seed into stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (for parallel/per-component rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's method (unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "rng.below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Sample one index weighted by non-negative weights (sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            counts[r.below(4)] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(9);
        for _ in 0..500 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(10);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
