//! Q-network parameter set: host tensors + a version id.
//!
//! The version id keys the runtime's device-buffer cache: parameters are
//! uploaded to the PJRT device once per version and every subsequent
//! inference reuses the resident buffers — the scheduler hot path only
//! materializes the (tiny) state buffer per decision.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::util::json::{Json, JsonObj};

#[cfg(feature = "pjrt")]
use super::Meta;

static NEXT_VERSION: AtomicU64 = AtomicU64::new(1);

/// EvalNet/TargNet parameters.
#[derive(Debug)]
pub struct Params {
    tensors: Vec<Vec<f32>>,
    shapes: Vec<Vec<usize>>,
    /// Unique id for device-cache keying; changes on every new set.
    version: u64,
}

impl Clone for Params {
    fn clone(&self) -> Self {
        // A clone is a distinct logical set (it may diverge), so it gets
        // its own version and its own device upload on first use.
        Params::from_host(self.tensors.clone(), self.shapes.clone())
            .expect("clone of valid params")
    }
}

impl Params {
    /// Build from host tensors + shapes (validates element counts).
    pub fn from_host(tensors: Vec<Vec<f32>>, shapes: Vec<Vec<usize>>) -> Result<Params> {
        anyhow::ensure!(tensors.len() == shapes.len(), "tensor/shape count mismatch");
        for (t, s) in tensors.iter().zip(&shapes) {
            let want: usize = s.iter().product();
            anyhow::ensure!(t.len() == want, "tensor len {} != shape {:?}", t.len(), s);
        }
        Ok(Params {
            tensors,
            shapes,
            version: NEXT_VERSION.fetch_add(1, Ordering::Relaxed),
        })
    }

    /// Build from executable outputs in meta order (PJRT builds only).
    #[cfg(feature = "pjrt")]
    pub fn from_literals(meta: &Meta, lits: Vec<xla::Literal>) -> Result<Params> {
        anyhow::ensure!(
            lits.len() == meta.param_shapes.len(),
            "got {} literals, want {}",
            lits.len(),
            meta.param_shapes.len()
        );
        let mut tensors = Vec::with_capacity(lits.len());
        for (lit, shape) in lits.iter().zip(&meta.param_shapes) {
            let v = lit.to_vec::<f32>()?;
            anyhow::ensure!(
                v.len() == shape.iter().product::<usize>(),
                "literal len {} != shape {:?}",
                v.len(),
                shape
            );
            tensors.push(v);
        }
        Params::from_host(tensors, meta.param_shapes.clone())
    }

    pub fn tensors(&self) -> &[Vec<f32>] {
        &self.tensors
    }

    pub fn shapes(&self) -> &[Vec<usize>] {
        &self.shapes
    }

    /// Device-cache key (unique per parameter set).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// L2 distance to another parameter set (target-sync diagnostics).
    pub fn l2_distance(&self, other: &Params) -> f64 {
        self.tensors
            .iter()
            .zip(&other.tensors)
            .flat_map(|(a, b)| a.iter().zip(b))
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    /// Serialize for checkpoints.
    pub fn to_json(&self, names: &[String]) -> Json {
        let mut o = JsonObj::new();
        for ((name, t), s) in names.iter().zip(&self.tensors).zip(&self.shapes) {
            let mut entry = JsonObj::new();
            entry.insert("shape", Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect()));
            entry.insert("data", Json::array_f32(t));
            o.insert(name.clone(), Json::Obj(entry));
        }
        Json::Obj(o)
    }

    /// Deserialize a checkpoint produced by `to_json`.
    pub fn from_json(j: &Json, names: &[String]) -> Result<Params> {
        let o = j.as_obj().ok_or_else(|| anyhow::anyhow!("params: not an object"))?;
        let mut tensors = Vec::new();
        let mut shapes = Vec::new();
        for name in names {
            let entry = o
                .get(name)
                .filter(|v| v.as_obj().is_some())
                .ok_or_else(|| anyhow::anyhow!("params: missing '{name}'"))?;
            let shape: Vec<usize> = entry
                .get_arr("shape")
                .map_err(|e| anyhow::anyhow!("{name}.shape: {e:?}"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let data = entry
                .get_f32_vec("data")
                .map_err(|e| anyhow::anyhow!("{name}.data: {e:?}"))?;
            shapes.push(shape);
            tensors.push(data);
        }
        Params::from_host(tensors, shapes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Params {
        Params::from_host(
            vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![0.5, -0.5]],
            vec![vec![3, 2], vec![2]],
        )
        .unwrap()
    }

    #[test]
    fn versions_are_unique() {
        let p = sample();
        let q = sample();
        let r = p.clone();
        assert_ne!(p.version(), q.version());
        assert_ne!(p.version(), r.version());
    }

    #[test]
    fn rejects_shape_mismatch() {
        assert!(Params::from_host(vec![vec![1.0; 5]], vec![vec![3, 2]]).is_err());
    }

    #[test]
    fn clone_is_deep() {
        let p = sample();
        let q = p.clone();
        assert_eq!(p.tensors(), q.tensors());
        assert!((p.l2_distance(&q)).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let p = sample();
        let names = vec!["w".to_string(), "b".to_string()];
        let j = p.to_json(&names);
        let q = Params::from_json(&Json::parse(&j.to_string()).unwrap(), &names).unwrap();
        assert_eq!(p.tensors(), q.tensors());
        assert_eq!(p.shapes(), q.shapes());
    }

    #[test]
    fn l2_distance_detects_change() {
        let p = sample();
        let mut t = p.tensors().to_vec();
        t[0][0] += 3.0;
        let q = Params::from_host(t, p.shapes().to_vec()).unwrap();
        assert!((p.l2_distance(&q) - 3.0).abs() < 1e-6);
    }
}
