//! Q-network runtime: the contract between the scheduler and the compiled
//! AOT artifacts (`qnet_infer`, `qnet_infer_batch`, `qnet_train`,
//! `qnet_init`).
//!
//! Two interchangeable implementations sit behind the same `Runtime` API:
//!
//! * **`pjrt`** (feature `pjrt`): loads `artifacts/*.hlo.txt` + `meta.json`
//!   produced by `make artifacts` and executes them through the PJRT C API
//!   (`xla` bindings).  See `pjrt.rs` for the HLO-text interchange and the
//!   device-buffer caching rationale.
//! * **stub** (default): a no-dependency placeholder whose `load()` fails
//!   with a clear message.  Everything that doesn't need FlexAI — the
//!   environment, platform model, baselines, plan/engine sweeps, reports —
//!   works without the feature; FlexAI paths error out (and tests
//!   self-skip) instead of failing to build.

pub mod meta;
pub mod params;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

use std::path::PathBuf;

pub use meta::Meta;
pub use params::Params;

/// Default artifact location relative to the repo root.  Honours
/// `HMAI_ARTIFACTS` for tests/benches run from other cwds.
pub fn default_artifact_dir() -> PathBuf {
    // lint:allow(env-read-in-sim): artifact-dir discovery at load time, once,
    // before any trial runs — results never depend on it mid-simulation.
    if let Ok(d) = std::env::var("HMAI_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from("artifacts")
}

/// One replay-batch of transitions, laid out exactly as `qnet_train`
/// expects: `s[B,IN] a[B] r[B] s2[B,IN] done[B]`.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub s: Vec<f32>,
    pub a: Vec<i32>,
    pub r: Vec<f32>,
    pub s2: Vec<f32>,
    pub done: Vec<f32>,
}

impl TrainBatch {
    pub fn zeros(meta: &Meta) -> TrainBatch {
        let b = meta.train_batch;
        TrainBatch {
            s: vec![0.0; b * meta.in_dim],
            a: vec![0; b],
            r: vec![0.0; b],
            s2: vec![0.0; b * meta.in_dim],
            done: vec![0.0; b],
        }
    }
}
