//! Stub runtime (feature `pjrt` disabled): presents the exact `Runtime`
//! API of `pjrt.rs` so FlexAI and the harness compile unchanged, but every
//! entry point fails with a message explaining how to enable the real path.
//!
//! `load()` always errs, so no `Runtime` value (and hence no FlexAI agent)
//! can exist in a stub build: the unreachable compute methods only keep the
//! API surface identical.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::{default_artifact_dir, Meta, Params, TrainBatch};

const UNAVAILABLE: &str = "PJRT runtime unavailable: hmai was built without the `pjrt` feature \
     (enable the `xla` dependency in rust/Cargo.toml and build with \
     `--features pjrt`, after `make artifacts`)";

/// Placeholder for the compiled Q-network executables.
pub struct Runtime {
    pub meta: Meta,
}

impl Runtime {
    /// Default artifact location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// Always fails in stub builds.
    pub fn load(_dir: &Path) -> Result<Runtime> {
        bail!(UNAVAILABLE)
    }

    /// Always fails in stub builds.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&Self::default_dir())
    }

    pub fn init_params(&self, _seed: i32) -> Result<Params> {
        bail!(UNAVAILABLE)
    }

    pub fn infer(&self, _params: &Params, _state: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn infer_batch(&self, _params: &Params, _states: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn train_step(
        &self,
        _params: &Params,
        _targ: &Params,
        _batch: &TrainBatch,
    ) -> Result<(Params, f32)> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_guidance() {
        let err = Runtime::load_default().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
