//! `artifacts/meta.json`: the dimension/hyper-parameter contract between
//! `python/compile/aot.py` (which writes it) and the rust runtime (which
//! must feed the executables exactly those shapes).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Parsed meta.json.
#[derive(Debug, Clone, PartialEq)]
pub struct Meta {
    pub n_slots: usize,
    pub task_feats: usize,
    pub slot_feats: usize,
    pub in_dim: usize,
    pub h1: usize,
    pub h2: usize,
    pub out_dim: usize,
    pub train_batch: usize,
    pub infer_batch: usize,
    pub gamma: f64,
    pub lr: f64,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
}

impl Meta {
    pub fn load(path: &Path) -> Result<Meta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Meta> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("meta.json: {e:?}"))?;
        let o = j.as_obj().context("meta.json: not an object")?;
        let get = |k: &str| -> Result<usize> {
            o.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("meta.json: missing usize '{k}'"))
        };
        let param_names = o
            .get("param_names")
            .and_then(|v| v.as_arr())
            .context("meta.json: param_names")?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect::<Vec<_>>();
        let param_shapes = o
            .get("param_shapes")
            .and_then(|v| v.as_arr())
            .context("meta.json: param_shapes")?
            .iter()
            .map(|row| {
                row.as_arr()
                    .context("shape row")
                    .map(|r| r.iter().filter_map(|v| v.as_usize()).collect::<Vec<_>>())
            })
            .collect::<Result<Vec<_>>>()?;
        let meta = Meta {
            n_slots: get("n_slots")?,
            task_feats: get("task_feats")?,
            slot_feats: get("slot_feats")?,
            in_dim: get("in_dim")?,
            h1: get("h1")?,
            h2: get("h2")?,
            out_dim: get("out_dim")?,
            train_batch: get("train_batch")?,
            infer_batch: get("infer_batch")?,
            gamma: o.get("gamma").and_then(|v| v.as_f64()).context("gamma")?,
            lr: o.get("lr").and_then(|v| v.as_f64()).context("lr")?,
            param_names,
            param_shapes,
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Cross-check internal consistency (the same invariants model.py holds).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.in_dim == self.task_feats + self.slot_feats * self.n_slots,
            "in_dim {} != {} + {}*{}",
            self.in_dim,
            self.task_feats,
            self.slot_feats,
            self.n_slots
        );
        anyhow::ensure!(self.out_dim == self.n_slots, "out_dim != n_slots");
        anyhow::ensure!(
            self.param_shapes.len() == self.param_names.len(),
            "param names/shapes mismatch"
        );
        let want = [
            vec![self.in_dim, self.h1],
            vec![self.h1],
            vec![self.h1, self.h2],
            vec![self.h2],
            vec![self.h2, self.out_dim],
            vec![self.out_dim],
        ];
        anyhow::ensure!(
            self.param_shapes == want,
            "param_shapes {:?} != expected {:?}",
            self.param_shapes,
            want
        );
        Ok(())
    }

    /// Element count of parameter tensor `i`.
    pub fn param_len(&self, i: usize) -> usize {
        self.param_shapes[i].iter().product()
    }

    /// Total parameter count of the Q-network.
    pub fn total_params(&self) -> usize {
        (0..self.param_shapes.len()).map(|i| self.param_len(i)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "n_slots": 16, "task_feats": 6, "slot_feats": 8,
        "in_dim": 134, "h1": 256, "h2": 64, "out_dim": 16,
        "train_batch": 64, "infer_batch": 30,
        "gamma": 0.95, "lr": 0.01,
        "param_names": ["w1","b1","w2","b2","w3","b3"],
        "param_shapes": [[134,256],[256],[256,64],[64],[64,16],[16]]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Meta::parse(SAMPLE).unwrap();
        assert_eq!(m.in_dim, 134);
        assert_eq!(m.param_len(0), 134 * 256);
        assert_eq!(
            m.total_params(),
            134 * 256 + 256 + 256 * 64 + 64 + 64 * 16 + 16
        );
    }

    #[test]
    fn rejects_inconsistent_dims() {
        let bad = SAMPLE.replace("\"in_dim\": 134", "\"in_dim\": 999");
        assert!(Meta::parse(&bad).is_err());
    }

    #[test]
    fn real_artifact_meta_is_consistent() {
        let path = std::path::Path::new("artifacts/meta.json");
        if path.exists() {
            let m = Meta::load(path).unwrap();
            assert_eq!(m.out_dim, m.n_slots);
        }
    }
}
