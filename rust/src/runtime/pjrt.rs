//! PJRT runtime: load the AOT artifacts `make artifacts` produced
//! (`artifacts/*.hlo.txt` + `meta.json`) and execute them from the rust
//! request path.  Python never runs here — the Q-network forward pass, the
//! full DQN train step and the parameter init are all compiled HLO.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).
//!
//! Execution uses `execute_b` over *device-resident* buffers, never the
//! literal-argument `execute`: the vendored C shim of `execute` leaks every
//! input device buffer (`buffer.release()` without a matching free), and
//! re-uploading ~210 KB of parameters per decision is also the single
//! largest hot-path cost.  Parameters are uploaded once per version and
//! cached; per-call inputs are small owned buffers that free on drop.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::{default_artifact_dir, Meta, Params, TrainBatch};

/// The compiled Q-network executables on the PJRT CPU client.
pub struct Runtime {
    client: PjRtClient,
    infer: PjRtLoadedExecutable,
    infer_batch: PjRtLoadedExecutable,
    train: PjRtLoadedExecutable,
    init: PjRtLoadedExecutable,
    /// Device-resident parameter buffers keyed by `Params::version()`.
    param_cache: Mutex<HashMap<u64, std::sync::Arc<Vec<PjRtBuffer>>>>,
    pub meta: Meta,
}

/// Entries kept in the device parameter cache (EvalNet + TargNet + slack).
const PARAM_CACHE_CAP: usize = 6;

impl Runtime {
    /// Default artifact location relative to the repo root.
    pub fn default_dir() -> std::path::PathBuf {
        default_artifact_dir()
    }

    /// Load and compile every entry point from `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let meta = Meta::load(&dir.join("meta.json"))
            .with_context(|| format!("loading {}/meta.json (run `make artifacts`)", dir.display()))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {name}"))
        };
        Ok(Runtime {
            infer: compile("qnet_infer")?,
            infer_batch: compile("qnet_infer_batch")?,
            train: compile("qnet_train")?,
            init: compile("qnet_init")?,
            param_cache: Mutex::new(HashMap::new()),
            client,
            meta,
        })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&Self::default_dir())
    }

    /// Upload an f32 tensor to the device.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Device-resident buffers for a parameter set, uploaded once per
    /// `Params::version()` and shared afterwards.
    fn device_params(&self, params: &Params) -> Result<std::sync::Arc<Vec<PjRtBuffer>>> {
        let mut cache = self.param_cache.lock().expect("param cache poisoned");
        if let Some(bufs) = cache.get(&params.version()) {
            return Ok(bufs.clone());
        }
        let mut bufs = Vec::with_capacity(params.tensors().len());
        for (t, s) in params.tensors().iter().zip(params.shapes()) {
            bufs.push(self.upload_f32(t, s)?);
        }
        if cache.len() >= PARAM_CACHE_CAP {
            cache.clear(); // stale versions; live Arcs stay valid
        }
        let bufs = std::sync::Arc::new(bufs);
        cache.insert(params.version(), bufs.clone());
        Ok(bufs)
    }

    /// Run an executable over device buffers and return the tuple elements.
    fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let out = exe.execute_b::<&PjRtBuffer>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Seeded parameter init (`qnet_init` entry).
    pub fn init_params(&self, seed: i32) -> Result<Params> {
        let seed_buf = self.client.buffer_from_host_buffer(&[seed], &[], None)?;
        let out = self.run(&self.init, &[&seed_buf])?;
        Params::from_literals(&self.meta, out)
    }

    /// Q(s, ·) for one state (`qnet_infer`): `state.len() == in_dim`,
    /// returns `out_dim` Q values.
    pub fn infer(&self, params: &Params, state: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            state.len() == self.meta.in_dim,
            "state len {} != in_dim {}",
            state.len(),
            self.meta.in_dim
        );
        let dev = self.device_params(params)?;
        let x = self.upload_f32(state, &[1, self.meta.in_dim])?;
        let mut args: Vec<&PjRtBuffer> = dev.iter().collect();
        args.push(&x);
        let mut out = self.run(&self.infer, &args)?;
        anyhow::ensure!(out.len() == 1, "infer returned {} outputs", out.len());
        Ok(out.pop().expect("one output").to_vec::<f32>()?)
    }

    /// Q(s, ·) for a burst of `infer_batch` states (`qnet_infer_batch`):
    /// `states.len() == infer_batch * in_dim`, returns row-major
    /// `[infer_batch, out_dim]` Q values.
    pub fn infer_batch(&self, params: &Params, states: &[f32]) -> Result<Vec<f32>> {
        let want = self.meta.infer_batch * self.meta.in_dim;
        anyhow::ensure!(states.len() == want, "states len {} != {}", states.len(), want);
        let dev = self.device_params(params)?;
        let x = self.upload_f32(states, &[self.meta.infer_batch, self.meta.in_dim])?;
        let mut args: Vec<&PjRtBuffer> = dev.iter().collect();
        args.push(&x);
        let mut out = self.run(&self.infer_batch, &args)?;
        anyhow::ensure!(out.len() == 1, "infer_batch returned {} outputs", out.len());
        Ok(out.pop().expect("one output").to_vec::<f32>()?)
    }

    /// One DQN SGD step (`qnet_train`): EvalNet params are updated against
    /// the frozen TargNet; returns (new EvalNet params, scalar TD loss).
    pub fn train_step(
        &self,
        params: &Params,
        targ: &Params,
        batch: &TrainBatch,
    ) -> Result<(Params, f32)> {
        let m = &self.meta;
        anyhow::ensure!(batch.s.len() == m.train_batch * m.in_dim, "bad batch.s");
        anyhow::ensure!(batch.a.len() == m.train_batch, "bad batch.a");
        anyhow::ensure!(batch.r.len() == m.train_batch, "bad batch.r");
        anyhow::ensure!(batch.s2.len() == m.train_batch * m.in_dim, "bad batch.s2");
        anyhow::ensure!(batch.done.len() == m.train_batch, "bad batch.done");
        let dev_p = self.device_params(params)?;
        let dev_t = self.device_params(targ)?;
        let s = self.upload_f32(&batch.s, &[m.train_batch, m.in_dim])?;
        let a = self.client.buffer_from_host_buffer(&batch.a, &[m.train_batch], None)?;
        let r = self.upload_f32(&batch.r, &[m.train_batch])?;
        let s2 = self.upload_f32(&batch.s2, &[m.train_batch, m.in_dim])?;
        let done = self.upload_f32(&batch.done, &[m.train_batch])?;

        let mut args: Vec<&PjRtBuffer> = dev_p.iter().collect();
        args.extend(dev_t.iter());
        args.extend([&s, &a, &r, &s2, &done]);

        let mut out = self.run(&self.train, &args)?;
        anyhow::ensure!(
            out.len() == m.param_shapes.len() + 1,
            "train returned {} outputs",
            out.len()
        );
        let loss_lit = out.pop().expect("loss output");
        let loss = loss_lit.to_vec::<f32>()?[0];
        let new_params = Params::from_literals(m, out)?;
        Ok((new_params, loss))
    }
}

#[cfg(test)]
#[allow(clippy::print_stderr)] // self-skipping tests explain themselves
mod tests {
    use super::*;

    /// Skip (with a message) when the AOT artifacts are absent.
    fn runtime() -> Option<Runtime> {
        match Runtime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping PJRT test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn loads_and_inits() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.meta.in_dim, rt.meta.task_feats + rt.meta.slot_feats * rt.meta.n_slots);
        let p = rt.init_params(7).unwrap();
        assert_eq!(p.tensors().len(), rt.meta.param_shapes.len());
        // He init: non-degenerate weights, zero biases.
        let w1 = &p.tensors()[0];
        assert!(w1.iter().any(|&x| x != 0.0));
        assert!(p.tensors()[1].iter().all(|&x| x == 0.0));
        // Seeded determinism.
        let p2 = rt.init_params(7).unwrap();
        assert_eq!(p.tensors()[0], p2.tensors()[0]);
        let p3 = rt.init_params(8).unwrap();
        assert_ne!(p.tensors()[0], p3.tensors()[0]);
    }

    #[test]
    fn infer_shapes_and_finiteness() {
        let Some(rt) = runtime() else { return };
        let p = rt.init_params(1).unwrap();
        let state = vec![0.1f32; rt.meta.in_dim];
        let q = rt.infer(&p, &state).unwrap();
        assert_eq!(q.len(), rt.meta.out_dim);
        assert!(q.iter().all(|x| x.is_finite()));
        // Batch path agrees with the single path on replicated rows.
        let mut states = Vec::new();
        for _ in 0..rt.meta.infer_batch {
            states.extend_from_slice(&state);
        }
        let qb = rt.infer_batch(&p, &states).unwrap();
        assert_eq!(qb.len(), rt.meta.infer_batch * rt.meta.out_dim);
        for row in qb.chunks(rt.meta.out_dim) {
            for (a, b) in row.iter().zip(&q) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn train_step_reduces_td_loss_on_fixed_batch() {
        let Some(rt) = runtime() else { return };
        let mut p = rt.init_params(3).unwrap();
        let targ = p.clone();
        // Synthetic batch with a consistent target.
        let mut batch = TrainBatch::zeros(&rt.meta);
        for (i, v) in batch.s.iter_mut().enumerate() {
            *v = ((i % 17) as f32) / 17.0;
        }
        batch.s2.copy_from_slice(&batch.s);
        for (i, a) in batch.a.iter_mut().enumerate() {
            *a = (i % rt.meta.out_dim) as i32;
        }
        for r in batch.r.iter_mut() {
            *r = 1.0;
        }
        let (_, first_loss) = rt.train_step(&p, &targ, &batch).unwrap();
        let mut last = first_loss;
        for _ in 0..20 {
            let (np, l) = rt.train_step(&p, &targ, &batch).unwrap();
            p = np;
            last = l;
        }
        assert!(last.is_finite());
        assert!(last < first_loss, "loss {first_loss} -> {last} did not fall");
    }

    #[test]
    fn rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        let p = rt.init_params(0).unwrap();
        assert!(rt.infer(&p, &[0.0; 3]).is_err());
        assert!(rt.infer_batch(&p, &[0.0; 3]).is_err());
        let mut batch = TrainBatch::zeros(&rt.meta);
        batch.a.pop();
        assert!(rt.train_step(&p, &p, &batch).is_err());
    }

    #[test]
    fn param_cache_reuses_uploads_and_evicts() {
        let Some(rt) = runtime() else { return };
        let p = rt.init_params(2).unwrap();
        let d1 = rt.device_params(&p).unwrap();
        let d2 = rt.device_params(&p).unwrap();
        assert!(std::sync::Arc::ptr_eq(&d1, &d2), "same version must share buffers");
        // Flood the cache past capacity; the original stays usable via Arc.
        for seed in 10..20 {
            let q = rt.init_params(seed).unwrap();
            rt.device_params(&q).unwrap();
        }
        let state = vec![0.2f32; rt.meta.in_dim];
        assert!(rt.infer(&p, &state).is_ok());
    }

    #[test]
    fn no_rss_growth_over_many_inferences() {
        // Regression test for the vendored `execute` input-buffer leak:
        // 2000 inferences must not grow RSS by more than a few MB.
        let Some(rt) = runtime() else { return };
        let rss_kb = || -> f64 {
            let s = std::fs::read_to_string("/proc/self/statm").unwrap();
            let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
            pages * 4.096
        };
        let p = rt.init_params(1).unwrap();
        let state = vec![0.1f32; rt.meta.in_dim];
        for _ in 0..100 {
            rt.infer(&p, &state).unwrap(); // warmup allocator pools
        }
        let before = rss_kb();
        for _ in 0..2000 {
            rt.infer(&p, &state).unwrap();
        }
        let grown = rss_kb() - before;
        assert!(grown < 64_000.0, "RSS grew {grown} KB over 2000 inferences");
    }
}
