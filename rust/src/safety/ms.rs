//! Matching Score (§6.1, Fig. 7): how well a task's *response time* (wait +
//! schedule + compute) matches its camera's *safety time*.
//!
//! Object detection (Fig. 7a): inside the accepted-time region [0, ST] the
//! score grows linearly with response time — slower is *better* as long as
//! the deadline holds, because energy drops with relaxed latency (§6.1,
//! citing [72]).  Past ST the score plummets to -1.
//!
//! Object tracking (Fig. 7b): a step function.  NOTE the paper's text says
//! MS = -1 *inside* ACTime and +1 outside, which would reward deadline
//! misses; we implement the evident intent (+1 in ACTime, -1 in UACTime) —
//! recorded as a deviation in DESIGN.md.

/// Task category for MS purposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskCategory {
    Detection,
    Tracking,
}

/// Matching score of a task with `response_time` against `safety_time`.
pub fn matching_score(cat: TaskCategory, response_time: f64, safety_time: f64) -> f64 {
    debug_assert!(safety_time > 0.0);
    match cat {
        TaskCategory::Detection => {
            if response_time <= safety_time {
                (response_time / safety_time).clamp(0.0, 1.0)
            } else {
                -1.0
            }
        }
        TaskCategory::Tracking => {
            if response_time <= safety_time {
                1.0
            } else {
                -1.0
            }
        }
    }
}

/// Whether the response met the deadline (used by STMRate, §8.4).
pub fn meets_safety_time(response_time: f64, safety_time: f64) -> bool {
    response_time <= safety_time
}

/// Criticality tier: Detection feeds the braking/perception pipeline
/// (safety-critical — its deadline protects the §8.5 braking distance);
/// Tracking is comfort-tier and may be shed by the graceful-degradation
/// controller when platform capacity drops under faults.
pub fn is_safety_critical(cat: TaskCategory) -> bool {
    matches!(cat, TaskCategory::Detection)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_ramp() {
        // Linear growth inside ACTime (Fig. 7a).
        assert_eq!(matching_score(TaskCategory::Detection, 0.0, 2.0), 0.0);
        assert_eq!(matching_score(TaskCategory::Detection, 1.0, 2.0), 0.5);
        assert_eq!(matching_score(TaskCategory::Detection, 2.0, 2.0), 1.0);
    }

    #[test]
    fn detection_plummets_past_deadline() {
        assert_eq!(matching_score(TaskCategory::Detection, 2.001, 2.0), -1.0);
        assert_eq!(matching_score(TaskCategory::Detection, 100.0, 2.0), -1.0);
    }

    #[test]
    fn tracking_step() {
        assert_eq!(matching_score(TaskCategory::Tracking, 0.5, 2.0), 1.0);
        assert_eq!(matching_score(TaskCategory::Tracking, 2.0, 2.0), 1.0);
        assert_eq!(matching_score(TaskCategory::Tracking, 2.5, 2.0), -1.0);
    }

    #[test]
    fn stmrate_predicate() {
        assert!(meets_safety_time(1.0, 2.0));
        assert!(!meets_safety_time(3.0, 2.0));
    }

    #[test]
    fn criticality_tiers() {
        assert!(is_safety_critical(TaskCategory::Detection));
        assert!(!is_safety_critical(TaskCategory::Tracking));
    }
}
