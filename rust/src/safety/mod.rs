//! Safety substrate: the RSS safety model (Eq. 1), per-camera safety times,
//! the Matching Score (§6.1, Fig. 7) and the braking model (§8.4, Fig. 14).

pub mod braking;
pub mod ms;
pub mod rss;

pub use ms::{matching_score, TaskCategory};
pub use rss::{safety_time, RssParams};
