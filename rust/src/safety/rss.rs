//! Responsibility-Sensitive Safety (RSS) model — paper Eq. 1 — and the
//! per-camera safety-time solver.
//!
//! Eq. 1 gives the minimal safe distance between two vehicles closing head-on
//! as a function of the rear car's *processing time* ρ:
//!
//!   d_min(ρ) = (v1 + v1ρ)/2 · ρ + v1ρ²/(2a_brake)
//!            + (|v2| + v2ρ)/2 · ρ + v2ρ²/(2a_brake),
//!   v1ρ = v1 + ρ·a_accel,   v2ρ = |v2| + ρ·a_accel.
//!
//! The paper sets d_min to each camera's max sensing distance and solves for
//! ρ — the **safety time** — the longest the perception pipeline may take
//! before a worst-case obstacle at the edge of the camera's range can no
//! longer be braked for.  d_min(ρ) is strictly increasing in ρ, so we solve
//! by bisection.
//!
//! Opposing-speed assumptions per camera group (the paper only pins the
//! forward case; the others follow its "rear and side cameras ... computed
//! through Equation (1) like forward cameras" with the natural worst case):
//!   forward: v2 = area max velocity (head-on traffic);
//!   side:    v2 = 0 (crossing/static hazards), own speed capped by the
//!            scenario (turning <= 50 km/h);
//!   rear:    same-direction RSS (follower at area max velocity closing on
//!            us) — head-on from behind is not a physical scenario.

use crate::env::{Area, CameraGroup, Scenario};

/// Kinematic constants (§6.1).
#[derive(Debug, Clone, Copy)]
pub struct RssParams {
    /// Max acceleration during the response time, m/s^2 (Tesla: 8.382).
    pub a_max_accel: f64,
    /// Braking deceleration of our vehicle, m/s^2 (6.2).
    pub a_min_brake_correct: f64,
    /// Braking deceleration of the other vehicle, m/s^2 (6.2).
    pub a_min_brake: f64,
}

impl Default for RssParams {
    fn default() -> Self {
        Self { a_max_accel: 8.382, a_min_brake_correct: 6.2, a_min_brake: 6.2 }
    }
}

/// Eq. 1: minimal safe distance for processing time `rho`, opposite-direction.
pub fn d_min_opposite(v1: f64, v2: f64, rho: f64, p: &RssParams) -> f64 {
    let v1r = v1 + rho * p.a_max_accel;
    let v2r = v2.abs() + rho * p.a_max_accel;
    (v1 + v1r) / 2.0 * rho + v1r * v1r / (2.0 * p.a_min_brake_correct)
        + (v2.abs() + v2r) / 2.0 * rho
        + v2r * v2r / (2.0 * p.a_min_brake)
}

/// Same-direction RSS (standard formulation): follower at `v_rear` closing
/// on our vehicle at `v_front`, both braking at their respective limits.
pub fn d_min_same_direction(v_front: f64, v_rear: f64, rho: f64, p: &RssParams) -> f64 {
    let v_r = v_rear + rho * p.a_max_accel;
    let gain = v_rear * rho + 0.5 * p.a_max_accel * rho * rho + v_r * v_r / (2.0 * p.a_min_brake)
        - v_front * v_front / (2.0 * p.a_min_brake_correct);
    gain.max(0.0)
}

/// Solve `d(rho) = d_target` for rho by bisection over the monotone `d`.
/// Returns `None` if even rho = 0 is unsafe (the camera's range cannot
/// cover the scenario's stopping distance).
fn solve_rho(d_target: f64, d: impl Fn(f64) -> f64) -> Option<f64> {
    if d(0.0) >= d_target {
        return None;
    }
    let (mut lo, mut hi) = (0.0_f64, 1.0_f64);
    while d(hi) < d_target {
        hi *= 2.0;
        if hi > 1e4 {
            return Some(1e4); // effectively unconstrained
        }
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if d(mid) < d_target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

/// Floor applied when a camera's range cannot cover the stopping distance
/// even at rho = 0: the pipeline must still respond *as fast as the
/// platform possibly can*; we budget one frame at the fastest camera rate.
pub const SAFETY_TIME_FLOOR_S: f64 = 1.0 / 40.0;

/// Safety time (maximum allowed response time, seconds) for one camera
/// group under (area, scenario) — §6.1.
pub fn safety_time(area: Area, scenario: Scenario, group: CameraGroup) -> f64 {
    safety_time_with(area, scenario, group, &RssParams::default())
}

pub fn safety_time_with(
    area: Area,
    scenario: Scenario,
    group: CameraGroup,
    p: &RssParams,
) -> f64 {
    let v_own = area.max_velocity_ms().min(scenario.velocity_cap_ms());
    let d_cam = group.max_distance_m();
    let rho = if group == CameraGroup::Rc {
        // Rear: same-direction follower at area max velocity.
        let v_rear = area.max_velocity_ms();
        solve_rho(d_cam, |r| d_min_same_direction(v_own, v_rear, r, p))
    } else if group.is_side() {
        // Side: crossing/static hazard; own speed capped harder while
        // turning/reversing.
        solve_rho(d_cam, |r| d_min_opposite(v_own, 0.0, r, p))
    } else {
        // Forward: worst-case head-on closing at area max velocity.
        solve_rho(d_cam, |r| d_min_opposite(v_own, area.max_velocity_ms(), r, p))
    };
    rho.unwrap_or(SAFETY_TIME_FLOOR_S).max(SAFETY_TIME_FLOOR_S)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{ALL_AREAS, ALL_GROUPS, ALL_SCENARIOS};

    #[test]
    fn dmin_monotone_in_rho() {
        let p = RssParams::default();
        let mut last = 0.0;
        for i in 0..20 {
            let rho = i as f64 * 0.25;
            let d = d_min_opposite(16.67, 16.67, rho, &p);
            assert!(d > last);
            last = d;
        }
    }

    #[test]
    fn forward_camera_urban_around_1_8s() {
        // Hand-computed from Eq. 1: 250 m head-on at 60 km/h both ways,
        // a_accel 8.382, a_brake 6.2 -> rho ~= 1.8 s.
        let st = safety_time(Area::Urban, Scenario::GoStraight, CameraGroup::Fc);
        assert!((1.6..2.0).contains(&st), "st = {st}");
    }

    #[test]
    fn safety_time_decreases_with_speed() {
        // §6.1: ST_250FC-UB > ST_250FC-UHW > ST_250FC-HW.
        let ub = safety_time(Area::Urban, Scenario::GoStraight, CameraGroup::Fc);
        let uhw = safety_time(Area::UndividedHighway, Scenario::GoStraight, CameraGroup::Fc);
        let hw = safety_time(Area::Highway, Scenario::GoStraight, CameraGroup::Fc);
        assert!(ub > uhw && uhw > hw, "ub={ub} uhw={uhw} hw={hw}");
    }

    #[test]
    fn forward_sees_farther_but_not_longer() {
        // Different groups have different safety times (§6.1).
        let fc = safety_time(Area::Highway, Scenario::GoStraight, CameraGroup::Fc);
        let rc = safety_time(Area::Highway, Scenario::GoStraight, CameraGroup::Rc);
        let sc = safety_time(Area::Highway, Scenario::GoStraight, CameraGroup::Flsc);
        assert_ne!(fc, rc);
        assert_ne!(fc, sc);
    }

    #[test]
    fn all_safety_times_positive_and_bounded() {
        for a in ALL_AREAS {
            for s in ALL_SCENARIOS {
                for g in ALL_GROUPS {
                    let st = safety_time(a, s, g);
                    assert!(
                        (SAFETY_TIME_FLOOR_S..=1e4).contains(&st),
                        "{a:?} {s:?} {g:?}: {st}"
                    );
                }
            }
        }
    }

    #[test]
    fn same_direction_zero_at_zero_rho_equal_braking() {
        let p = RssParams::default();
        assert_eq!(d_min_same_direction(20.0, 20.0, 0.0, &p), 0.0);
    }
}
