//! Braking model (§8.4, Fig. 14): total braking time breakdown and the
//! resulting braking distance.
//!
//! The paper's scenario: after 1 km of driving the forward camera sees an
//! obstacle 250 m ahead; the vehicle is doing 60 km/h and brakes at
//! 6.2 m/s².  Total braking time = T_wait + T_schedule + T_compute +
//! T_data (CAN bus, 1 ms) + T_mech (mechanical lag, 19 ms); the distance
//! covered is v·T_total + v²/(2a).

/// CAN-bus command transmission time, seconds (§8.4, [81]).
pub const T_DATA_S: f64 = 0.001;
/// Mechanical actuation lag, seconds (§8.4).
pub const T_MECH_S: f64 = 0.019;
/// Braking deceleration, m/s² (§8.4).
pub const BRAKE_DECEL: f64 = 6.2;

/// Per-phase breakdown of the reaction chain (Fig. 14b).
#[derive(Debug, Clone, Copy, Default)]
pub struct BrakingBreakdown {
    /// Queue wait of the detection task on the platform.
    pub t_wait: f64,
    /// Scheduler decision latency.
    pub t_schedule: f64,
    /// Detection-task execution time on its accelerator.
    pub t_compute: f64,
    /// CAN-bus transmission.
    pub t_data: f64,
    /// Mechanical lag.
    pub t_mech: f64,
}

impl BrakingBreakdown {
    pub fn new(t_wait: f64, t_schedule: f64, t_compute: f64) -> Self {
        Self { t_wait, t_schedule, t_compute, t_data: T_DATA_S, t_mech: T_MECH_S }
    }

    /// Total reaction time before deceleration starts.
    pub fn total(&self) -> f64 {
        self.t_wait + self.t_schedule + self.t_compute + self.t_data + self.t_mech
    }
}

/// Braking distance: reaction roll + kinematic stopping distance.
pub fn braking_distance_m(v_ms: f64, breakdown: &BrakingBreakdown) -> f64 {
    v_ms * breakdown.total() + v_ms * v_ms / (2.0 * BRAKE_DECEL)
}

/// Did the vehicle stop within the sensing distance (no collision)?
pub fn stops_within(v_ms: f64, breakdown: &BrakingBreakdown, sensing_distance_m: f64) -> bool {
    braking_distance_m(v_ms, breakdown) <= sensing_distance_m
}

#[cfg(test)]
mod tests {
    use super::*;

    const V60: f64 = 60.0 / 3.6; // 16.67 m/s

    #[test]
    fn kinematic_floor() {
        // Zero-latency pipeline: distance = v²/2a + v*(data+mech) ~= 22.7 m.
        let b = BrakingBreakdown::new(0.0, 0.0, 0.0);
        let d = braking_distance_m(V60, &b);
        assert!((22.0..24.0).contains(&d), "d = {d}");
    }

    #[test]
    fn paper_flexai_operating_point() {
        // Fig. 14a: FlexAI's braking distance is 47.08 m — which implies
        // ~1.43 s of reaction chain at 60 km/h.  A zero-wait pipeline with
        // compute ~= a deep queue flush lands in that band; sanity: some
        // plausible breakdown reproduces 47 m.
        let b = BrakingBreakdown::new(0.0, 0.0005, 1.44);
        let d = braking_distance_m(V60, &b);
        assert!((44.0..50.0).contains(&d), "d = {d}");
    }

    #[test]
    fn wait_time_dominates_distance() {
        // Fig. 14b's story: T_wait is what separates schedulers.
        let fast = BrakingBreakdown::new(0.0, 0.001, 0.01);
        let slow = BrakingBreakdown::new(10.0, 0.001, 0.01);
        assert!(braking_distance_m(V60, &slow) > braking_distance_m(V60, &fast) + 100.0);
    }

    #[test]
    fn collision_predicate() {
        let ok = BrakingBreakdown::new(0.0, 0.0, 0.05);
        assert!(stops_within(V60, &ok, 250.0));
        let bad = BrakingBreakdown::new(60.0, 0.0, 0.05);
        assert!(!stops_within(V60, &bad, 250.0));
    }
}
