//! Stochastic fault campaigns: a seeded MTBF/MTTR fault process that
//! compiles to the same [`PlatformEvent`] timelines scenario archetypes
//! emit, so Monte-Carlo fault sweeps reuse the entire event machinery
//! ([`EventTimeline`](crate::sim::events::EventTimeline) → `Sim` →
//! `ShadowState`) unchanged.
//!
//! Determinism contract: the model draws from `Rng::fork` streams keyed by
//! *entity* (accelerator slot or link index), all derived from one trial
//! seed.  Forking per entity means slot 3's outage pattern does not depend
//! on how many links the platform has — the same seed produces the same
//! per-entity timelines on any platform shape, and crucially the timelines
//! are **paired** across schedulers and across degradation on/off arms of
//! a campaign (both arms are built from `trial.seed`, not the trial id).
//!
//! Each entity alternates exponential up-times (mean MTBF) and repair
//! times (mean MTTR) until the route ends; every transition emits a
//! `Fail`/`Recover` (accelerators) or `LinkFail`/`LinkRecover` (links)
//! event.  A non-positive or non-finite MTBF disables that fault class.

use crate::platform::Platform;
use crate::sim::events::{EventAction, PlatformEvent};
use crate::util::rng::Rng;

/// Hard cap on events per entity per route — a backstop against degenerate
/// parameters (e.g. MTBF and MTTR both ~0), far above any realistic draw.
const MAX_EVENTS_PER_ENTITY: usize = 10_000;

/// Exponential draw with the given mean.  Uses `1 - u` so `u = 0` cannot
/// produce `ln(0)`; an infinite mean yields an infinite (or NaN) draw,
/// which the `past_end` guards below treat as "never fires".
fn exp_draw(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean_s
}

/// A seeded per-accelerator and per-link MTBF/MTTR fault process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    /// Mean time between accelerator failures (s); `<= 0` or non-finite
    /// disables accelerator faults.
    pub accel_mtbf_s: f64,
    /// Mean accelerator repair time (s).
    pub accel_mttr_s: f64,
    /// Mean time between link failures (s); `<= 0` or non-finite disables
    /// link faults (they are inherently absent on monolithic platforms).
    pub link_mtbf_s: f64,
    /// Mean link repair time (s).
    pub link_mttr_s: f64,
}

impl Default for FaultModel {
    /// Defaults sized for urban routes a few hundred meters long (tens of
    /// seconds): most trials see one or two outages, some see none.
    fn default() -> FaultModel {
        FaultModel { accel_mtbf_s: 30.0, accel_mttr_s: 10.0, link_mtbf_s: 60.0, link_mttr_s: 10.0 }
    }
}

impl FaultModel {
    /// Compile this model into a fault-event list for one trial: `slots`
    /// accelerators and `links` interconnect links over a route of
    /// `duration_s` seconds, all drawn from `seed`.  The list is not
    /// time-sorted across entities — `EventTimeline::new` sorts.
    pub fn events_for(
        &self,
        seed: u64,
        duration_s: f64,
        slots: usize,
        links: usize,
    ) -> Vec<PlatformEvent> {
        let mut events = Vec::new();
        let mut parent = Rng::new(seed);
        let mut accel_parent = parent.fork(1);
        let mut link_parent = parent.fork(2);
        if self.accel_mtbf_s > 0.0 && self.accel_mtbf_s.is_finite() {
            for accel in 0..slots {
                let mut rng = accel_parent.fork(accel as u64);
                entity_events(
                    &mut rng,
                    duration_s,
                    self.accel_mtbf_s,
                    self.accel_mttr_s,
                    EventAction::Fail { accel },
                    EventAction::Recover { accel },
                    &mut events,
                );
            }
        }
        if self.link_mtbf_s > 0.0 && self.link_mtbf_s.is_finite() {
            for link in 0..links {
                let mut rng = link_parent.fork(link as u64);
                entity_events(
                    &mut rng,
                    duration_s,
                    self.link_mtbf_s,
                    self.link_mttr_s,
                    EventAction::LinkFail { link },
                    EventAction::LinkRecover { link },
                    &mut events,
                );
            }
        }
        events
    }

    /// [`FaultModel::events_for`] sized from a platform: one fault process
    /// per accelerator slot and per interconnect link (none on monolithic
    /// platforms).
    pub fn events_for_platform(
        &self,
        seed: u64,
        duration_s: f64,
        platform: &Platform,
    ) -> Vec<PlatformEvent> {
        let links = platform.topology.as_ref().map_or(0, |t| t.links.len());
        self.events_for(seed, duration_s, platform.accels.len(), links)
    }

}

/// One entity's alternating up/down renewal process: exponential up-times
/// (mean `mtbf_s`) and repair times (mean `mttr_s`), emitting a
/// `fail`/`recover` pair per outage inside the route window.
fn entity_events(
    rng: &mut Rng,
    duration_s: f64,
    mtbf_s: f64,
    mttr_s: f64,
    fail: EventAction,
    recover: EventAction,
    events: &mut Vec<PlatformEvent>,
) {
    let mttr_s = mttr_s.max(0.0);
    // `is_nan || >=` rather than `!(t < duration)`: an infinite/NaN draw
    // (degenerate mean) must terminate the process, never emit an event.
    let past_end = |t: f64| t.is_nan() || t >= duration_s;
    let mut t = 0.0;
    for _ in 0..MAX_EVENTS_PER_ENTITY {
        t += exp_draw(rng, mtbf_s);
        if past_end(t) {
            break;
        }
        events.push(PlatformEvent { at_s: t, action: fail });
        t += exp_draw(rng, mttr_s);
        if past_end(t) {
            break; // the outage outlives the route: no recovery event
        }
        events.push(PlatformEvent { at_s: t, action: recover });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::events::EventTimeline;

    #[test]
    fn same_seed_same_timeline() {
        let m = FaultModel::default();
        let a = m.events_for(42, 120.0, 11, 4);
        let b = m.events_for(42, 120.0, 11, 4);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "120 s at MTBF 30 s across 11 slots must fault");
        let c = m.events_for(43, 120.0, 11, 4);
        assert_ne!(a, c, "different seeds draw different timelines");
    }

    #[test]
    fn entity_streams_are_independent_of_platform_shape() {
        // Slot 3's pattern must not change when links are added: the
        // campaigns stay paired across mono and chiplet spellings.
        let m = FaultModel::default();
        let pick = |events: &[PlatformEvent]| -> Vec<(u64, EventAction)> {
            events
                .iter()
                .filter(|e| {
                    matches!(
                        e.action,
                        EventAction::Fail { accel: 3 } | EventAction::Recover { accel: 3 }
                    )
                })
                .map(|e| (e.at_s.to_bits(), e.action))
                .collect()
        };
        let mono = m.events_for(7, 200.0, 11, 0);
        let noc = m.events_for(7, 200.0, 11, 4);
        assert_eq!(pick(&mono), pick(&noc));
        assert!(
            mono.iter().all(|e| !matches!(
                e.action,
                EventAction::LinkFail { .. } | EventAction::LinkRecover { .. }
            )),
            "no links, no link faults"
        );
        assert!(noc.iter().any(|e| matches!(e.action, EventAction::LinkFail { .. })));
    }

    #[test]
    fn disabled_classes_and_short_routes_draw_nothing() {
        let off = FaultModel {
            accel_mtbf_s: 0.0,
            accel_mttr_s: 1.0,
            link_mtbf_s: f64::INFINITY,
            link_mttr_s: 1.0,
        };
        assert!(off.events_for(1, 1e6, 11, 8).is_empty());
        let m = FaultModel::default();
        assert!(m.events_for(1, 0.0, 11, 8).is_empty(), "zero-length route");
    }

    #[test]
    fn events_pair_fail_before_recover_per_entity() {
        let m = FaultModel { accel_mtbf_s: 5.0, accel_mttr_s: 2.0, ..FaultModel::default() };
        let events = m.events_for(11, 300.0, 4, 0);
        let mut tl = EventTimeline::new(events.clone());
        assert_eq!(tl.len(), events.len());
        // Per entity: strictly increasing times, alternating fail/recover
        // starting with a fail.
        for accel in 0..4 {
            let mine: Vec<&PlatformEvent> = events
                .iter()
                .filter(|e| {
                    matches!(
                        e.action,
                        EventAction::Fail { accel: a } | EventAction::Recover { accel: a }
                        if a == accel
                    )
                })
                .collect();
            for (k, e) in mine.iter().enumerate() {
                let is_fail = matches!(e.action, EventAction::Fail { .. });
                assert_eq!(is_fail, k % 2 == 0, "slot {accel} event {k}");
                if k > 0 {
                    assert!(e.at_s > mine[k - 1].at_s, "slot {accel} event {k}");
                }
                assert!(e.at_s > 0.0 && e.at_s < 300.0);
            }
        }
        // The timeline drains them all by the end of the route.
        let platform = crate::platform::Platform::hmai();
        let mut state =
            crate::sim::ShadowState::new(&platform, crate::metrics::NormScales::unit());
        let fired = tl.apply_until(300.0, &mut state);
        assert_eq!(fired, events.len());
    }
}
