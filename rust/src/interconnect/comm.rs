//! Communication-aware pricing: the [`PlatformCostModel`] seam and the
//! dynamic link-occupancy state the simulator threads through every
//! scheduler.
//!
//! [`ComputeOnly`] is today's model — a bare `accel::CostModel`.
//! [`CommCostModel`] composes that same compute model with a
//! [`Topology`]: every task additionally pays store-and-forward transfers
//! for its input (and, on a residency miss, its weights) from the ingress
//! to the executing chiplet, and for its output back.  `ShadowState`
//! consults the seam at construction: no topology ⇒ no [`CommState`] ⇒
//! the exact pre-interconnect instruction stream (bit-identity pinned by
//! `tests/interconnect.rs`).
//!
//! The pricing discipline matches the rest of the crate: *estimates and
//! commits share one op sequence*.  [`CommState::plan`] computes the full
//! per-hop timeline without mutating anything; [`CommState::commit`]
//! writes exactly the planned times back.  `ShadowState::apply`,
//! `ShadowState::est_response` and the `RolloutCtx` fast paths all price
//! through the same `plan`, so scheduler predictions stay exact under
//! contention — the property `est_response_matches_apply`-style tests pin.

use std::sync::Arc;

use crate::accel::CostModel;
use crate::workload::ModelKind;

use super::{traffic, Topology, MAX_ROUTE_LINKS};

/// How a platform prices work: a compute cost model, optionally composed
/// with an interconnect topology.  `Platform::pricing` hands one to
/// `ShadowState::new`.
pub trait PlatformCostModel {
    /// Per-slot compute cost rows (always present).
    fn compute(&self) -> &Arc<CostModel>;
    /// Interconnect topology, when transfers are priced too.
    fn topology(&self) -> Option<&Arc<Topology>>;
}

/// Compute-only pricing — the pre-interconnect model, unchanged.
pub struct ComputeOnly {
    pub compute: Arc<CostModel>,
}

impl PlatformCostModel for ComputeOnly {
    fn compute(&self) -> &Arc<CostModel> {
        &self.compute
    }

    fn topology(&self) -> Option<&Arc<Topology>> {
        None
    }
}

/// Compute composed with inter-chiplet communication.
pub struct CommCostModel {
    pub compute: Arc<CostModel>,
    pub topology: Arc<Topology>,
}

impl PlatformCostModel for CommCostModel {
    fn compute(&self) -> &Arc<CostModel> {
        &self.compute
    }

    fn topology(&self) -> Option<&Arc<Topology>> {
        Some(&self.topology)
    }
}

/// The planned timeline of one task's transfers + execution: per-hop
/// inbound/outbound link-free times, exec window and delivery time.
/// Produced by [`CommState::plan`], committed verbatim by
/// [`CommState::commit`] — the two never diverge.
#[derive(Debug, Clone, Copy)]
pub struct CommPlan {
    /// When the input (and any missed weights) lands on the chiplet.
    pub arrive_s: f64,
    /// Execution start: `max(slot drain, arrive_s)`.
    pub start_s: f64,
    /// Execution finish (what the slot's FIFO drains to).
    pub finish_s: f64,
    /// When the output lands back at the ingress — the response endpoint.
    pub done_s: f64,
    /// Total bytes moved (input + missed weights + output).
    pub bytes: f64,
    /// Time in flight: inbound + outbound transfer time.
    pub comm_s: f64,
    hops: usize,
    inbound: [f64; MAX_ROUTE_LINKS],
    outbound: [f64; MAX_ROUTE_LINKS],
}

/// Fault-aware ingress routes: per-chiplet link lists + masks recomputed
/// around the current dead-link set ([`Topology::routes_avoiding`]).
type ActiveRoutes = (Vec<Vec<usize>>, Vec<u64>);

/// The route `chiplet` currently uses: the fault-aware override when one
/// is active, else the static parse-time route.  A free function (not a
/// method) so `commit` can hold it while mutating sibling fields.
#[inline]
fn route_of<'a>(
    active: &'a Option<ActiveRoutes>,
    topo: &'a Topology,
    chiplet: usize,
) -> &'a [usize] {
    match active {
        Some((routes, _)) => routes.get(chiplet).map(|r| r.as_slice()).unwrap_or(&[]),
        None => topo.route(chiplet),
    }
}

/// Dynamic interconnect state: per-link occupancy and per-slot weight
/// residency, plus the run accumulators the summary reports.  Cloning is
/// cheap (a few short `Vec`s), which is what GA/SA rollouts need.
#[derive(Debug, Clone)]
pub struct CommState {
    topo: Arc<Topology>,
    /// Resolved slot → chiplet placement (validated at platform parse).
    chiplet_of: Vec<usize>,
    /// Per link: time at which it is free (store-and-forward serial).
    pub link_busy: Vec<f64>,
    /// Per link: speed factor — 1.0 nominal (bit-exact: `bw * 1.0 == bw`),
    /// (0, 1) derated bandwidth, 0.0 dead (hops price at `+inf`).
    link_speed: Vec<f64>,
    /// Fault-aware route override, present iff ≥1 link is dead.  `None`
    /// executes the exact static-route instruction stream, which is what
    /// keeps event-free runs bit-identical.
    active: Option<ActiveRoutes>,
    /// Per slot: the model whose weights are resident (None = cold).
    pub resident: Vec<Option<ModelKind>>,
    /// Σ per-task in-flight time (s) — the run's comm-delay accumulator.
    pub delay_s: f64,
    /// Σ bytes moved over the interconnect.
    pub bytes: f64,
}

impl CommState {
    pub fn new(topo: Arc<Topology>, slots: usize) -> CommState {
        let chiplet_of = (0..slots).map(|s| topo.chiplet_of(s)).collect();
        let links = topo.links.len();
        CommState {
            topo,
            chiplet_of,
            link_busy: vec![0.0; links],
            link_speed: vec![1.0; links],
            active: None,
            resident: vec![None; slots],
            delay_s: 0.0,
            bytes: 0.0,
        }
    }

    /// The topology this state tracks.
    pub fn topology(&self) -> &Arc<Topology> {
        &self.topo
    }

    /// Speed factor of link `link` (1.0 for out-of-range indices).
    #[inline]
    pub fn link_speed(&self, link: usize) -> f64 {
        self.link_speed.get(link).copied().unwrap_or(1.0)
    }

    /// Set link `link`'s speed factor: 1.0 nominal, (0, 1) derated
    /// bandwidth, 0.0 dead.  Out-of-range indices are ignored so link
    /// events written for a large topology degrade gracefully on a
    /// smaller one.  Whenever the dead-link set changes, ingress routes
    /// are rebuilt around it ([`Topology::routes_avoiding`]); with no
    /// dead links the static routes are used verbatim.
    pub fn set_link_speed(&mut self, link: usize, speed: f64) {
        let Some(s) = self.link_speed.get_mut(link) else {
            return;
        };
        *s = speed.clamp(0.0, 1.0);
        let mut dead = 0u64;
        for (li, &sp) in self.link_speed.iter().enumerate() {
            if sp == 0.0 {
                dead |= 1u64 << li;
            }
        }
        self.active =
            if dead == 0 { None } else { Some(self.topo.routes_avoiding(dead)) };
    }

    /// Time to push `bytes` across link `li` at its current speed factor.
    /// Nominal speed multiplies bandwidth by exactly 1.0 (bit-exact); a
    /// dead link divides by zero bandwidth and prices `+inf`.
    #[inline]
    fn hop_s(&self, li: usize, bytes: f64) -> f64 {
        let l = &self.topo.links[li];
        l.latency_s + bytes / (l.bytes_per_s * self.link_speed[li])
    }

    /// Bitmask of the links on `slot`'s ingress route (0 for slots on the
    /// ingress chiplet) — what incremental Min-Min intersects to find
    /// cached bests invalidated by contention.  Reads the fault-aware
    /// routes when links are down.
    #[inline]
    pub fn route_mask(&self, slot: usize) -> u64 {
        let chiplet = self.chiplet_of.get(slot).copied().unwrap_or(0);
        match &self.active {
            Some((_, masks)) => masks.get(chiplet).copied().unwrap_or(0),
            None => self.topo.route_mask(chiplet),
        }
    }

    /// Would dispatching `model` to `slot` move its weights (residency
    /// miss on a non-ingress slot)?
    #[inline]
    pub fn weight_miss(&self, slot: usize, model: ModelKind) -> bool {
        self.resident.get(slot).copied().flatten() != Some(model)
            && self.route_mask(slot) != 0
    }

    /// Price `model` on `slot` at clock `now` against the current link
    /// occupancy: store-and-forward inbound walk (input + weights on a
    /// residency miss), execution behind the slot's FIFO (`busy_until`,
    /// `compute_s`), then the outbound walk for the output.  Pure — reads
    /// only.  `None` when `slot` sits on the ingress chiplet: no hops, no
    /// comm cost, and crucially no new float ops on that path.
    #[inline]
    pub fn plan(
        &self,
        slot: usize,
        model: ModelKind,
        now: f64,
        busy_until: f64,
        compute_s: f64,
    ) -> Option<CommPlan> {
        let chiplet = self.chiplet_of.get(slot).copied().unwrap_or(0);
        let route = route_of(&self.active, &self.topo, chiplet);
        if route.is_empty() {
            return None;
        }
        let tr = traffic::of(model);
        let miss = self.resident.get(slot).copied().flatten() != Some(model);
        let in_bytes =
            if miss { tr.input_bytes + tr.weight_bytes } else { tr.input_bytes };
        let out_bytes = tr.output_bytes;
        let mut inbound = [0.0_f64; MAX_ROUTE_LINKS];
        let mut outbound = [0.0_f64; MAX_ROUTE_LINKS];
        let mut t = now;
        for (k, &li) in route.iter().enumerate() {
            t = t.max(self.link_busy[li]) + self.hop_s(li, in_bytes);
            inbound[k] = t;
        }
        let arrive = t;
        let start = busy_until.max(arrive);
        let finish = start + compute_s;
        let mut t = finish;
        for (k, &li) in route.iter().enumerate().rev() {
            t = t.max(inbound[k]) + self.hop_s(li, out_bytes);
            outbound[k] = t;
        }
        Some(CommPlan {
            arrive_s: arrive,
            start_s: start,
            finish_s: finish,
            done_s: t,
            bytes: in_bytes + out_bytes,
            comm_s: (arrive - now) + (t - finish),
            hops: route.len(),
            inbound,
            outbound,
        })
    }

    /// Commit a plan: reserve the links (each route link's free time
    /// becomes its outbound-pass time — the later of the two passes),
    /// mark the weights resident and fold the accumulators.
    #[inline]
    pub fn commit(&mut self, slot: usize, model: ModelKind, plan: &CommPlan) {
        let chiplet = self.chiplet_of.get(slot).copied().unwrap_or(0);
        let route = route_of(&self.active, &self.topo, chiplet);
        debug_assert_eq!(route.len(), plan.hops);
        for (k, &li) in route.iter().enumerate() {
            self.link_busy[li] = plan.outbound[k];
        }
        if let Some(r) = self.resident.get_mut(slot) {
            *r = Some(model);
        }
        self.delay_s += plan.comm_s;
        self.bytes += plan.bytes;
    }

    /// Reset the rolling view to `origin`'s occupancy/residency (the
    /// per-genome reset of `RolloutCtx::rollout_cost`).  Accumulators
    /// restart from zero — rollouts never report them.
    pub fn reset_from(&mut self, origin: &CommState) {
        self.link_busy.copy_from_slice(&origin.link_busy);
        self.link_speed.copy_from_slice(&origin.link_speed);
        self.active.clone_from(&origin.active);
        self.resident.copy_from_slice(&origin.resident);
        self.delay_s = 0.0;
        self.bytes = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ALL_MODELS;

    fn mesh_state() -> CommState {
        let topo = Arc::new(Topology::try_parse("mesh2x2").unwrap());
        CommState::new(topo, 11)
    }

    #[test]
    fn ingress_slots_plan_nothing() {
        let s = mesh_state();
        // Round-robin on 4 chiplets: slots 0, 4, 8 sit on the ingress.
        for slot in [0usize, 4, 8] {
            assert!(s.plan(slot, ModelKind::Yolo, 0.0, 0.0, 1e-3).is_none());
            assert_eq!(s.route_mask(slot), 0);
            assert!(!s.weight_miss(slot, ModelKind::Yolo));
        }
        assert!(s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).is_some());
    }

    #[test]
    fn plan_is_pure_and_commit_reserves() {
        let mut s = mesh_state();
        let p1 = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        let p2 = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        assert_eq!(p1.done_s.to_bits(), p2.done_s.to_bits(), "plan must not mutate");
        s.commit(1, ModelKind::Yolo, &p1);
        let p3 = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        // Second task on the same link queues behind the first transfer
        // — and hits a warm slot, so it moves fewer bytes.
        assert!(p3.arrive_s > p1.arrive_s);
        assert!(p3.bytes < p1.bytes, "residency must drop the weight bytes");
        assert!((s.delay_s - p1.comm_s).abs() < 1e-15);
        assert!((s.bytes - p1.bytes).abs() < 1e-6);
    }

    #[test]
    fn residency_is_per_slot_and_per_model() {
        let mut s = mesh_state();
        assert!(s.weight_miss(1, ModelKind::Yolo));
        let p = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        s.commit(1, ModelKind::Yolo, &p);
        assert!(!s.weight_miss(1, ModelKind::Yolo), "warm for the same model");
        assert!(s.weight_miss(1, ModelKind::Ssd), "cold for a different model");
        assert!(s.weight_miss(2, ModelKind::Yolo), "other slots stay cold");
    }

    #[test]
    fn timeline_is_causal() {
        let s = mesh_state();
        // Slot 3 sits on chiplet 3 (two hops) with a busy FIFO.
        let p = s.plan(3, ModelKind::Ssd, 1.0, 5.0, 2e-3).unwrap();
        assert!(p.arrive_s > 1.0, "transfers take time");
        assert_eq!(p.start_s.to_bits(), p.arrive_s.max(5.0).to_bits());
        assert!((p.finish_s - (p.start_s + 2e-3)).abs() < 1e-15);
        assert!(p.done_s > p.finish_s, "output still has to travel");
        assert!(p.comm_s > 0.0);
        assert_eq!(p.hops, 2);
    }

    #[test]
    fn far_slots_pay_more() {
        let s = mesh_state();
        for model in ALL_MODELS {
            // Chiplet 1 (slot 1) is one hop; chiplet 3 (slot 3) is two.
            let near = s.plan(1, model, 0.0, 0.0, 1e-3).unwrap();
            let far = s.plan(3, model, 0.0, 0.0, 1e-3).unwrap();
            assert!(far.comm_s > near.comm_s, "{model:?}");
            assert!(far.done_s > near.done_s, "{model:?}");
        }
    }

    #[test]
    fn link_derate_scales_bandwidth_and_recover_is_bit_exact() {
        let mut s = mesh_state();
        let nominal = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        s.set_link_speed(0, 0.5);
        // Slot 1's one-hop route uses some link; derating every link is a
        // safe superset for the comparison.
        for li in 0..4 {
            s.set_link_speed(li, 0.5);
        }
        let slow = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        assert!(slow.comm_s > nominal.comm_s, "{} !> {}", slow.comm_s, nominal.comm_s);
        assert!(slow.done_s > nominal.done_s);
        // Recovery restores the exact nominal pricing (bw * 1.0 == bw).
        for li in 0..4 {
            s.set_link_speed(li, 1.0);
        }
        let back = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        assert_eq!(back.done_s.to_bits(), nominal.done_s.to_bits());
        assert_eq!(back.comm_s.to_bits(), nominal.comm_s.to_bits());
        // Out-of-range link indices are ignored.
        s.set_link_speed(999, 0.0);
        assert_eq!(s.link_speed(999), 1.0);
    }

    #[test]
    fn dead_link_reroutes_or_prices_infinite() {
        let mut s = mesh_state();
        let topo = Arc::clone(s.topology());
        let li = topo.route(1)[0];
        let nominal = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        s.set_link_speed(li, 0.0);
        // Chiplet 1 survives via the 3-hop detour: finite but slower, and
        // its route mask no longer touches the dead link.
        let rerouted = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        assert!(rerouted.done_s.is_finite());
        assert!(rerouted.done_s > nominal.done_s);
        assert_eq!(rerouted.hops, 3);
        assert_eq!(s.route_mask(1) & (1u64 << li), 0);
        // Recovery restores the static route and the exact pricing.
        s.set_link_speed(li, 1.0);
        let back = s.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        assert_eq!(back.done_s.to_bits(), nominal.done_s.to_bits());
        // A severed ring2: the far chiplet keeps its static route, which
        // now prices +inf — the lost-task signal, never a panic.
        let mut ring = CommState::new(Arc::new(Topology::try_parse("ring2").unwrap()), 2);
        ring.set_link_speed(0, 0.0);
        let cut = ring.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        assert!(cut.done_s.is_infinite());
    }

    #[test]
    fn reset_from_restores_the_origin_view() {
        let mut origin = mesh_state();
        let p = origin.plan(1, ModelKind::Yolo, 0.0, 0.0, 1e-3).unwrap();
        origin.commit(1, ModelKind::Yolo, &p);
        origin.set_link_speed(2, 0.0);
        let mut rolling = origin.clone();
        let q = rolling.plan(3, ModelKind::Ssd, 0.0, 0.0, 1e-3).unwrap();
        rolling.commit(3, ModelKind::Ssd, &q);
        rolling.set_link_speed(2, 1.0);
        rolling.reset_from(&origin);
        for (a, b) in rolling.link_busy.iter().zip(&origin.link_busy) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(rolling.resident, origin.resident);
        assert_eq!(rolling.link_speed(2), 0.0, "fault view follows the origin");
        assert_eq!(rolling.route_mask(2), origin.route_mask(2));
        assert_eq!(rolling.delay_s, 0.0);
    }
}
