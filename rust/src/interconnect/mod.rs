//! Chiplet/package interconnect model: topologies, link occupancy and
//! communication-aware task pricing.
//!
//! The paper's HMAI substrate prices compute only — every accelerator is a
//! zero-distance slot.  The multi-chiplet NPU literature (PAPERS.md: arXiv
//! 2411.16007) shows inter-chiplet transfer latency and bandwidth
//! contention dominate at exactly the camera scale the ROADMAP north-star
//! targets, and the dataflow-accelerator line (arXiv 2109.07047) argues
//! placement/locality must be a first-class scheduling input.  This module
//! supplies the missing layer:
//!
//! * [`Topology`] — a chiplet/package graph (monolithic, `mesh<R>x<C>`,
//!   `ring<N>`, `package<N>` presets) with per-link latency/bandwidth,
//!   per-slot chiplet placement and precomputed ingress routes.  The spec
//!   grammar rides on the platform grammar: `hmai+mesh2x2`,
//!   `so:4@2x,si:4,mm:3+ring4@2x`, `hmai+mesh2x2/0.1.2.3.0.1.2.3.0.1.2`.
//! * [`traffic`] — per-task input/weight/output movement bytes derived
//!   from the `workload::layer` shapes (16-bit datums).
//! * [`comm`] — the [`PlatformCostModel`] seam: [`ComputeOnly`] (today's
//!   model, bit-identical) vs [`CommCostModel`] (compute composed with
//!   link transfers), plus the dynamic [`CommState`] (link occupancy +
//!   weight residency) that `ShadowState` threads through every scheduler.
//!
//! A monolithic topology parses to *no* topology at all — the platform
//! keeps its bare name and `ShadowState` carries no `CommState` — so the
//! compute-only path executes the exact pre-interconnect instruction
//! stream (bit-identity pinned by `tests/interconnect.rs`).

pub mod comm;
pub mod traffic;

pub use comm::{CommCostModel, CommPlan, CommState, ComputeOnly, PlatformCostModel};
pub use traffic::Traffic;

use crate::accel::CoreSize;

/// Reticle/yield ceiling of a single die, in [`CoreSize::area_units`].
/// A monolithic platform cannot exceed this (the economic reason chiplet
/// packages exist: small dies yield, one huge die does not); a chiplet
/// package is instead limited per die, so its *total* core area can grow
/// past the ceiling at the price of inter-chiplet transfers.  `hmai dse`
/// enforces this whenever a topology sweep is active.
pub const MONO_DIE_AREA_UNITS: f64 = 12.0;

/// Hard cap on chiplets per package (keeps link sets in a `u64` route
/// mask and routes within [`MAX_ROUTE_LINKS`]).
pub const MAX_CHIPLETS: usize = 16;

/// Longest ingress→chiplet route any preset can produce (ring16: 8 hops);
/// sized with headroom so `CommPlan` can hold per-hop times on the stack.
pub const MAX_ROUTE_LINKS: usize = 16;

/// Silicon-interposer D2D link (mesh/ring presets): per-hop latency.
const D2D_LATENCY_S: f64 = 2.0e-7;
/// Silicon-interposer D2D link bandwidth, GB/s.
const D2D_GBYTES_PER_S: f64 = 32.0;
/// Organic-substrate package link (package preset): per-hop latency.
const PKG_LATENCY_S: f64 = 4.0e-7;
/// Organic-substrate package link bandwidth, GB/s.
const PKG_GBYTES_PER_S: f64 = 16.0;

/// One undirected chiplet-to-chiplet link.
#[derive(Debug, Clone)]
pub struct Link {
    pub a: usize,
    pub b: usize,
    /// Per-hop fixed latency (s).
    pub latency_s: f64,
    /// Serialization bandwidth (bytes/s).
    pub bytes_per_s: f64,
}

impl Link {
    /// Time to push `bytes` across this link (store-and-forward hop).
    #[inline]
    pub fn hop_s(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bytes_per_s
    }
}

/// A chiplet/package topology: the static interconnect graph plus the
/// slot→chiplet placement and precomputed ingress routes.
///
/// Chiplet 0 hosts the sensor/DRAM ingress: task inputs (and non-resident
/// weights) enter there and outputs return there.  Routes are BFS
/// shortest paths from the ingress with a deterministic lowest-neighbor
/// tie-break, fixed at parse time.
#[derive(Debug)]
pub struct Topology {
    /// Canonical spec, e.g. `mesh2x2`, `ring4@2x`, `package3/0.1.2.0`.
    pub name: String,
    pub chiplets: usize,
    pub links: Vec<Link>,
    /// Explicit slot→chiplet override (`/c0.c1...`); `None` = round-robin
    /// `slot % chiplets`.
    placement: Option<Vec<usize>>,
    /// Per chiplet: link indices of the ingress→chiplet route, in hop
    /// order (empty for the ingress chiplet itself).
    routes: Vec<Vec<usize>>,
    /// Per chiplet: bitmask over link indices of that route.
    masks: Vec<u64>,
}

impl Topology {
    /// Parse a topology spec: `mono | mesh<R>x<C> | ring<N> | package<N>`
    /// with an optional `@0.5x|@1x|@2x` link-speed scale and an optional
    /// `/c0.c1...` per-slot placement.  Placement arity is validated
    /// against the platform in [`Topology::bind`] (the slot count is not
    /// known here).  Errors name the offending component, mirroring
    /// `Platform::try_parse`.
    pub fn try_parse(spec: &str) -> Result<Topology, String> {
        let expected = "expected mono | mesh<R>x<C> | ring<N> | package<N>, optionally \
                        \"@0.5x|1x|2x\" link speed and \"/c0.c1...\" per-slot placement \
                        — e.g. \"mesh2x2\", \"ring4@2x\", \"package3/0.1.2.0\"";
        let lc = spec.trim().to_ascii_lowercase();
        let err = |what: &str| format!("'{lc}' topology: {what} — {expected}");
        if lc.is_empty() {
            return Err(err("empty spec"));
        }
        let (head, placement_s) = match lc.split_once('/') {
            Some((h, p)) => (h.trim(), Some(p.trim())),
            None => (lc.as_str(), None),
        };
        let (preset, scale) = match head.split_once('@') {
            Some((p, sz)) => {
                let scale = CoreSize::parse(sz.trim())
                    .ok_or_else(|| err(&format!("unknown link speed '{}'", sz.trim())))?;
                (p.trim(), scale)
            }
            None => (head, CoreSize::Std),
        };
        let dim = |s: &str, what: &str| -> Result<usize, String> {
            let n: usize =
                s.parse().map_err(|_| err(&format!("bad {what} '{s}' in preset '{preset}'")))?;
            if n == 0 {
                return Err(err(&format!(
                    "zero-chiplet preset '{preset}' — a topology needs at least one chiplet"
                )));
            }
            Ok(n)
        };
        let d2d = |a: usize, b: usize| Link {
            a,
            b,
            latency_s: D2D_LATENCY_S,
            bytes_per_s: D2D_GBYTES_PER_S * scale.scale() * 1e9,
        };
        let (canon_preset, chiplets, links) = if preset == "mono" {
            ("mono".to_string(), 1, Vec::new())
        } else if let Some(rc) = preset.strip_prefix("mesh") {
            let (r_s, c_s) =
                rc.split_once('x').ok_or_else(|| err(&format!("bad mesh spec '{preset}'")))?;
            let (rows, cols) = (dim(r_s, "row count")?, dim(c_s, "column count")?);
            let mut links = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    let id = r * cols + c;
                    if c + 1 < cols {
                        links.push(d2d(id, id + 1));
                    }
                    if r + 1 < rows {
                        links.push(d2d(id, id + cols));
                    }
                }
            }
            (format!("mesh{rows}x{cols}"), rows * cols, links)
        } else if let Some(n_s) = preset.strip_prefix("ring") {
            let n = dim(n_s, "chiplet count")?;
            let mut links = Vec::new();
            for i in 0..n {
                let next = (i + 1) % n;
                if next != i && !(n == 2 && i == 1) {
                    links.push(d2d(i, next));
                }
            }
            (format!("ring{n}"), n, links)
        } else if let Some(n_s) = preset.strip_prefix("package") {
            // Multi-die package: dies on an organic substrate, star-routed
            // through die 0 (the I/O die hosting the ingress).
            let n = dim(n_s, "chiplet count")?;
            let links = (1..n)
                .map(|i| Link {
                    a: 0,
                    b: i,
                    latency_s: PKG_LATENCY_S,
                    bytes_per_s: PKG_GBYTES_PER_S * scale.scale() * 1e9,
                })
                .collect();
            (format!("package{n}"), n, links)
        } else {
            return Err(err(&format!("unknown preset '{preset}'")));
        };
        if chiplets > MAX_CHIPLETS {
            return Err(err(&format!(
                "preset '{preset}' has {chiplets} chiplets — more than the {MAX_CHIPLETS} cap"
            )));
        }
        let placement = match placement_s {
            None => None,
            Some(p_s) => {
                let mut placement = Vec::new();
                for (i, comp) in p_s.split('.').enumerate() {
                    let c: usize = comp.trim().parse().map_err(|_| {
                        err(&format!("placement entry {} ('{comp}') is not a chiplet index", i + 1))
                    })?;
                    if c >= chiplets {
                        return Err(err(&format!(
                            "placement entry {} ('{comp}') exceeds chiplet count {chiplets}",
                            i + 1
                        )));
                    }
                    placement.push(c);
                }
                Some(placement)
            }
        };
        let mut name = canon_preset;
        name.push_str(scale.suffix());
        if let Some(p) = &placement {
            name.push('/');
            name.push_str(
                &p.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("."),
            );
        }
        Topology::build(name, chiplets, links, placement).map_err(|what| err(&what))
    }

    /// Wire routes and masks: BFS shortest paths from the ingress
    /// (chiplet 0), neighbors visited in ascending order so tie-breaks
    /// are deterministic.
    fn build(
        name: String,
        chiplets: usize,
        links: Vec<Link>,
        placement: Option<Vec<usize>>,
    ) -> Result<Topology, String> {
        if links.len() >= 64 {
            return Err(format!("{} links exceed the u64 route-mask width", links.len()));
        }
        let mut adj: Vec<Vec<(usize, usize)>> = vec![Vec::new(); chiplets];
        for (li, l) in links.iter().enumerate() {
            if l.a >= chiplets || l.b >= chiplets {
                return Err(format!("link {li} endpoints outside 0..{chiplets}"));
            }
            adj[l.a].push((l.b, li));
            adj[l.b].push((l.a, li));
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        let mut prev: Vec<Option<(usize, usize)>> = vec![None; chiplets];
        let mut seen = vec![false; chiplets];
        let mut frontier = std::collections::VecDeque::new();
        seen[0] = true;
        frontier.push_back(0usize);
        while let Some(c) = frontier.pop_front() {
            for &(nb, li) in &adj[c] {
                if !seen[nb] {
                    seen[nb] = true;
                    prev[nb] = Some((c, li));
                    frontier.push_back(nb);
                }
            }
        }
        if let Some(orphan) = seen.iter().position(|&s| !s) {
            return Err(format!("chiplet {orphan} is unreachable from the ingress"));
        }
        let mut routes = Vec::with_capacity(chiplets);
        let mut masks = Vec::with_capacity(chiplets);
        for c in 0..chiplets {
            let mut route = Vec::new();
            let mut cur = c;
            while let Some((parent, li)) = prev[cur] {
                route.push(li);
                cur = parent;
            }
            route.reverse();
            if route.len() > MAX_ROUTE_LINKS {
                return Err(format!("route to chiplet {c} exceeds {MAX_ROUTE_LINKS} hops"));
            }
            masks.push(route.iter().fold(0u64, |m, &li| m | (1u64 << li)));
            routes.push(route);
        }
        Ok(Topology { name, chiplets, links, placement, routes, masks })
    }

    /// A single-chiplet topology prices no transfers: the platform
    /// normalizes it away entirely (no `CommState`, bare platform name).
    pub fn is_mono(&self) -> bool {
        self.chiplets <= 1
    }

    /// Recompute ingress routes avoiding the links in `dead` (a bitmask
    /// over link indices) — same BFS and lowest-neighbor tie-break as the
    /// static routes, so `dead == 0` reproduces them exactly.  A chiplet
    /// unreachable on surviving links keeps its *static* route: that route
    /// crosses a dead link, so pricing yields `+inf` there and dispatches
    /// become lost tasks rather than FIFO poison (the same containment
    /// discipline as a failed accelerator).  Routes longer than
    /// [`MAX_ROUTE_LINKS`] likewise fall back to the static route — the
    /// detour would not fit a [`CommPlan`].
    pub fn routes_avoiding(&self, dead: u64) -> (Vec<Vec<usize>>, Vec<u64>) {
        let mut adj: Vec<Vec<(usize, usize)>> = vec![Vec::new(); self.chiplets];
        for (li, l) in self.links.iter().enumerate() {
            if dead & (1u64 << li) != 0 {
                continue;
            }
            adj[l.a].push((l.b, li));
            adj[l.b].push((l.a, li));
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        let mut prev: Vec<Option<(usize, usize)>> = vec![None; self.chiplets];
        let mut seen = vec![false; self.chiplets];
        let mut frontier = std::collections::VecDeque::new();
        seen[0] = true;
        frontier.push_back(0usize);
        while let Some(c) = frontier.pop_front() {
            for &(nb, li) in &adj[c] {
                if !seen[nb] {
                    seen[nb] = true;
                    prev[nb] = Some((c, li));
                    frontier.push_back(nb);
                }
            }
        }
        let mut routes = Vec::with_capacity(self.chiplets);
        let mut masks = Vec::with_capacity(self.chiplets);
        for c in 0..self.chiplets {
            if !seen[c] {
                routes.push(self.routes[c].clone());
                masks.push(self.masks[c]);
                continue;
            }
            let mut route = Vec::new();
            let mut cur = c;
            while let Some((parent, li)) = prev[cur] {
                route.push(li);
                cur = parent;
            }
            route.reverse();
            if route.len() > MAX_ROUTE_LINKS {
                routes.push(self.routes[c].clone());
                masks.push(self.masks[c]);
                continue;
            }
            masks.push(route.iter().fold(0u64, |m, &li| m | (1u64 << li)));
            routes.push(route);
        }
        (routes, masks)
    }

    /// Chiplet hosting accelerator `slot` (round-robin unless an explicit
    /// placement was given; out-of-range reads degrade to the ingress).
    pub fn chiplet_of(&self, slot: usize) -> usize {
        match &self.placement {
            Some(p) => p.get(slot).copied().unwrap_or(0),
            None => slot % self.chiplets.max(1),
        }
    }

    /// Link indices of the ingress→`chiplet` route, in hop order.
    pub fn route(&self, chiplet: usize) -> &[usize] {
        self.routes.get(chiplet).map(|r| r.as_slice()).unwrap_or(&[])
    }

    /// Bitmask over link indices of `chiplet`'s ingress route.
    pub fn route_mask(&self, chiplet: usize) -> u64 {
        self.masks.get(chiplet).copied().unwrap_or(0)
    }

    /// Validate the explicit placement (if any) against a platform's slot
    /// count — the arity error the CLI surfaces.
    pub fn bind(&self, slots: usize) -> Result<(), String> {
        if let Some(p) = &self.placement {
            if p.len() != slots {
                return Err(format!(
                    "'{}' placement: {} entries for {slots} accelerator slots — need \
                     exactly one chiplet index per slot",
                    self.name,
                    p.len()
                ));
            }
        }
        Ok(())
    }

    /// Area of the largest die when `total` core area spreads across the
    /// package (round-robin placement ⇒ an even split) — the quantity
    /// `hmai dse` holds under [`MONO_DIE_AREA_UNITS`].
    pub fn max_die_area(&self, total: f64) -> f64 {
        total / self.chiplets.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_preset_shapes() {
        let t = Topology::try_parse("mesh2x2").unwrap();
        assert_eq!(t.name, "mesh2x2");
        assert_eq!(t.chiplets, 4);
        assert_eq!(t.links.len(), 4);
        assert!(!t.is_mono());
        // Ingress route to chiplet 0 is empty; to every other, non-empty.
        assert!(t.route(0).is_empty());
        assert_eq!(t.route_mask(0), 0);
        for c in 1..4 {
            assert!(!t.route(c).is_empty(), "chiplet {c}");
            assert_ne!(t.route_mask(c), 0, "chiplet {c}");
        }
        // Chiplet 3 (diagonal) is two hops away.
        assert_eq!(t.route(3).len(), 2);
        assert_eq!(t.route_mask(3).count_ones(), 2);
    }

    #[test]
    fn ring_and_package_presets() {
        let r = Topology::try_parse("ring4").unwrap();
        assert_eq!((r.chiplets, r.links.len()), (4, 4));
        // BFS shortest: the far side of a ring4 is 2 hops, not 3.
        assert_eq!(r.route(2).len(), 2);
        let r2 = Topology::try_parse("ring2").unwrap();
        assert_eq!((r2.chiplets, r2.links.len()), (2, 1));
        let p = Topology::try_parse("package3").unwrap();
        assert_eq!((p.chiplets, p.links.len()), (3, 2));
        // Star: every die is one (slower) substrate hop from the I/O die.
        assert_eq!(p.route(2).len(), 1);
        assert!(p.links[0].latency_s > r.links[0].latency_s);
        assert!(p.links[0].bytes_per_s < r.links[0].bytes_per_s);
    }

    #[test]
    fn mono_normalizes() {
        assert!(Topology::try_parse("mono").unwrap().is_mono());
        assert!(Topology::try_parse("mesh1x1").unwrap().is_mono());
        assert!(Topology::try_parse("ring1").unwrap().is_mono());
    }

    #[test]
    fn link_speed_scale_applies() {
        let std = Topology::try_parse("mesh2x2").unwrap();
        let fast = Topology::try_parse("mesh2x2@2x").unwrap();
        assert_eq!(fast.name, "mesh2x2@2x");
        assert_eq!(
            fast.links[0].bytes_per_s.to_bits(),
            (std.links[0].bytes_per_s * 2.0).to_bits()
        );
        // Latency is a PHY property, not a lane-count one.
        assert_eq!(fast.links[0].latency_s.to_bits(), std.links[0].latency_s.to_bits());
        assert!(Topology::try_parse("mesh2x2@1x").unwrap().name == "mesh2x2");
    }

    #[test]
    fn placement_override_and_round_robin() {
        let t = Topology::try_parse("mesh2x2").unwrap();
        assert_eq!((0..6).map(|s| t.chiplet_of(s)).collect::<Vec<_>>(), [0, 1, 2, 3, 0, 1]);
        assert!(t.bind(11).is_ok(), "round-robin binds any slot count");
        let p = Topology::try_parse("ring2/0.0.1").unwrap();
        assert_eq!(p.name, "ring2/0.0.1");
        assert_eq!((0..3).map(|s| p.chiplet_of(s)).collect::<Vec<_>>(), [0, 0, 1]);
        assert!(p.bind(3).is_ok());
        let e = p.bind(11).unwrap_err();
        assert!(e.contains("3 entries for 11 accelerator slots"), "{e}");
    }

    #[test]
    fn errors_name_the_offending_component() {
        let e = Topology::try_parse("torus3").unwrap_err();
        assert!(e.contains("unknown preset 'torus3'"), "{e}");
        let e = Topology::try_parse("ring0").unwrap_err();
        assert!(e.contains("zero-chiplet") && e.contains("ring0"), "{e}");
        let e = Topology::try_parse("mesh0x2").unwrap_err();
        assert!(e.contains("zero-chiplet"), "{e}");
        let e = Topology::try_parse("meshAxB").unwrap_err();
        assert!(e.contains("bad row count 'a'"), "{e}");
        let e = Topology::try_parse("mesh2x2@9x").unwrap_err();
        assert!(e.contains("unknown link speed '9x'"), "{e}");
        let e = Topology::try_parse("ring2/0.z").unwrap_err();
        assert!(e.contains("placement entry 2 ('z')"), "{e}");
        let e = Topology::try_parse("ring2/0.5").unwrap_err();
        assert!(e.contains("placement entry 2 ('5') exceeds chiplet count 2"), "{e}");
        let e = Topology::try_parse("ring99").unwrap_err();
        assert!(e.contains("more than the 16 cap"), "{e}");
        assert!(Topology::try_parse("").is_err());
    }

    #[test]
    fn routes_are_bfs_shortest_with_deterministic_tiebreak() {
        let t = Topology::try_parse("mesh3x3").unwrap();
        // Manhattan distance from the ingress corner.
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(t.route(r * 3 + c).len(), r + c, "chiplet ({r},{c})");
            }
        }
        // Tie-break: the diagonal's first hop goes through the
        // lowest-numbered neighbor (right before down).
        let again = Topology::try_parse("mesh3x3").unwrap();
        for c in 0..9 {
            assert_eq!(t.route(c), again.route(c), "parse is deterministic");
        }
    }

    #[test]
    fn routes_avoiding_reroutes_and_falls_back() {
        let t = Topology::try_parse("mesh2x2").unwrap();
        // dead == 0 reproduces the static routes exactly.
        let (routes, masks) = t.routes_avoiding(0);
        for c in 0..t.chiplets {
            assert_eq!(routes[c], t.route(c), "chiplet {c}");
            assert_eq!(masks[c], t.route_mask(c), "chiplet {c}");
        }
        // Kill chiplet 1's direct link: the detour goes the long way round
        // (2 extra hops on a 2x2 mesh) and avoids the dead link.
        let li = t.route(1)[0];
        let (routes, masks) = t.routes_avoiding(1u64 << li);
        assert_eq!(routes[1].len(), 3, "detour on a 2x2 mesh is 3 hops");
        assert!(!routes[1].contains(&li));
        assert_eq!(masks[1] & (1u64 << li), 0);
        // A ring2's far chiplet has no surviving path once its only link
        // dies: it keeps the static route (which prices +inf).
        let r = Topology::try_parse("ring2").unwrap();
        let (routes, masks) = r.routes_avoiding(1);
        assert_eq!(routes[1], r.route(1));
        assert_eq!(masks[1], r.route_mask(1));
    }

    #[test]
    fn die_area_splits_evenly() {
        let t = Topology::try_parse("mesh2x2").unwrap();
        assert!((t.max_die_area(16.0) - 4.0).abs() < 1e-12);
        assert!(t.max_die_area(16.0) < MONO_DIE_AREA_UNITS);
    }
}
