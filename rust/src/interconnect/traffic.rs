//! Per-task data-movement model: the bytes a task moves over the
//! interconnect, derived from the `workload::layer` shapes.
//!
//! Three flows per dispatched task (the EXMC/off-chip path of the
//! accelerator model, lifted to the package level):
//!
//! * **input** — the first layer's input tensor, sensor/DRAM ingress →
//!   the executing chiplet (per task, always);
//! * **weights** — the whole parameter set, ingress → chiplet, but only
//!   on a *residency miss* (the slot last ran a different model; see
//!   [`CommState::resident`](super::CommState));
//! * **output** — the last layer's activation volume, chiplet → ingress
//!   (detections/track states returned to the planner).
//!
//! All tensors move as 16-bit datums ([`BYTES_PER_ELEM`]), matching the
//! fixed-point accelerator arithmetic the cost model assumes.  Slots on
//! the ingress chiplet move nothing — their route is empty, which is what
//! keeps monolithic platforms bit-identical to the compute-only model.

use std::sync::OnceLock;

use crate::workload::{model, ModelKind, ALL_MODELS};

/// Bytes per tensor element: 16-bit activations and weights.
pub const BYTES_PER_ELEM: f64 = 2.0;

/// Movement bytes of one task of a given model.
#[derive(Debug, Clone, Copy)]
pub struct Traffic {
    /// First-layer input tensor, ingress → chiplet (every task).
    pub input_bytes: f64,
    /// Full parameter set, ingress → chiplet (residency miss only).
    pub weight_bytes: f64,
    /// Last-layer activations, chiplet → ingress (every task).
    pub output_bytes: f64,
}

impl Traffic {
    fn derive(kind: ModelKind) -> Traffic {
        let m = model(kind);
        let input = m.layers.first().map(|l| l.input_elems()).unwrap_or(0);
        let output = m.layers.last().map(|l| l.neurons()).unwrap_or(0);
        Traffic {
            input_bytes: input as f64 * BYTES_PER_ELEM,
            weight_bytes: m.total_weights as f64 * BYTES_PER_ELEM,
            output_bytes: output as f64 * BYTES_PER_ELEM,
        }
    }
}

/// Cached per-model traffic row (layer shapes are immutable).
pub fn of(kind: ModelKind) -> Traffic {
    static TABLE: OnceLock<[Traffic; ALL_MODELS.len()]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut rows = [Traffic { input_bytes: 0.0, weight_bytes: 0.0, output_bytes: 0.0 };
            ALL_MODELS.len()];
        for m in ALL_MODELS {
            rows[m.index()] = Traffic::derive(m);
        }
        rows
    });
    table[kind.index()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_follows_layer_shapes() {
        for kind in ALL_MODELS {
            let t = of(kind);
            let m = model(kind);
            assert_eq!(
                t.input_bytes.to_bits(),
                (m.layers[0].input_elems() as f64 * BYTES_PER_ELEM).to_bits(),
                "{kind:?}"
            );
            assert_eq!(
                t.weight_bytes.to_bits(),
                (m.total_weights as f64 * BYTES_PER_ELEM).to_bits(),
                "{kind:?}"
            );
            assert!(t.output_bytes > 0.0, "{kind:?}");
            // Weights dominate activations for every network in Table 1 —
            // which is why residency (weight reuse) is the locality lever.
            assert!(t.weight_bytes > t.input_bytes, "{kind:?}");
        }
    }

    #[test]
    fn cached_table_is_stable() {
        let a = of(ModelKind::Yolo);
        let b = of(ModelKind::Yolo);
        assert_eq!(a.input_bytes.to_bits(), b.input_bytes.to_bits());
        assert_eq!(a.weight_bytes.to_bits(), b.weight_bytes.to_bits());
    }
}
