//! Bounded-memory streaming quantiles for fleet-scale sweeps.
//!
//! [`QuantileHistogram`] is a fixed-bucket log-spaced histogram: recording
//! is O(1), memory is a few hundred `u64` counters regardless of sample
//! count, and two histograms over disjoint sample sets merge by elementwise
//! addition — the exact property `fleet merge` needs to reassemble shard
//! summaries into the single-process result.
//!
//! Bucketing is pure bit manipulation on the IEEE-754 representation (no
//! `ln`/`log10`, whose last-bit behavior libm does not specify), so the
//! bucket index of a value is identical on every platform: the unbiased
//! exponent selects an octave and the top three mantissa bits split each
//! octave into [`PER_OCTAVE`] mantissa-linear sub-buckets.  The widest
//! bucket spans a ratio of 9/8, so a reported quantile (bucket midpoint,
//! clamped to the observed min/max) is within ~6.25% relative error of the
//! exact sort-based quantile — pinned by tests here and in
//! `tests/fleet.rs`.
//!
//! Non-finite samples (a lost task's `response_s` is `+inf`) land in a
//! dedicated top bucket, so "P99.9 is infinite" is representable — the
//! tail-latency safety claim (§8.4) fails loudly instead of averaging away.

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Sub-buckets per octave (top 3 mantissa bits → 8 mantissa-linear cells).
pub const PER_OCTAVE: usize = 8;

/// A mergeable fixed-bucket histogram over positive f64 samples.
///
/// Tracks `[2^lo_exp, 2^(lo_exp+octaves))` in log-spaced buckets, with
/// dedicated counters for underflow (including zero and negatives),
/// finite overflow, and non-finite samples, plus the exact finite min/max.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileHistogram {
    lo_exp: i32,
    octaves: usize,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    nonfinite: u64,
    total: u64,
    min: f64,
    max: f64,
}

impl QuantileHistogram {
    /// Histogram over `[2^lo_exp, 2^(lo_exp+octaves))`.
    pub fn new(lo_exp: i32, octaves: usize) -> QuantileHistogram {
        QuantileHistogram {
            lo_exp,
            octaves,
            counts: vec![0; octaves * PER_OCTAVE],
            underflow: 0,
            overflow: 0,
            nonfinite: 0,
            total: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Task response times: ~6e-8 s .. ~1.7e3 s (2^-24 .. 2^10).
    pub fn response() -> QuantileHistogram {
        QuantileHistogram::new(-24, 34)
    }

    /// Braking distances: ~1e-3 m .. ~1.3e5 m (2^-10 .. 2^17).
    pub fn braking() -> QuantileHistogram {
        QuantileHistogram::new(-10, 27)
    }

    /// Total recorded samples (including underflow/overflow/non-finite).
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Samples that were not finite (lost tasks: `response_s = +inf`).
    pub fn nonfinite_count(&self) -> u64 {
        self.nonfinite
    }

    /// Exact minimum over finite samples (`+inf` when none).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum over finite samples (`-inf` when none).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Record one sample.  O(1), no allocation.
    pub fn record(&mut self, v: f64) {
        self.total += 1;
        if !v.is_finite() {
            self.nonfinite += 1;
            return;
        }
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        // Zero, negatives and subnormals below the range: underflow.
        if v <= 0.0 {
            self.underflow += 1;
            return;
        }
        let bits = v.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i32 - 1023;
        if e < self.lo_exp {
            self.underflow += 1;
        } else if e >= self.lo_exp + self.octaves as i32 {
            self.overflow += 1;
        } else {
            let j = ((bits >> 49) & 0x7) as usize;
            self.counts[(e - self.lo_exp) as usize * PER_OCTAVE + j] += 1;
        }
    }

    /// Fold another histogram in: elementwise `u64` addition plus exact
    /// min/max — commutative and associative, so any shard partition
    /// merges to the identical histogram.  Panics on a bucket-layout
    /// mismatch (a programming error: layouts are compile-time choices).
    pub fn merge(&mut self, other: &QuantileHistogram) {
        assert_eq!(
            (self.lo_exp, self.octaves),
            (other.lo_exp, other.octaves),
            "merging histograms with different bucket layouts"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.nonfinite += other.nonfinite;
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The q-quantile (q in [0,1]) as a bucket midpoint clamped to the
    /// observed finite range; `+inf` when the rank falls among non-finite
    /// samples, 0.0 when empty.  Matches the exact sort-based definition
    /// `sorted[ceil(q*n)-1]` to within one bucket width (≤ ~6.25%
    /// relative).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = self.underflow;
        if rank <= cum {
            // Underflow samples include the global minimum.
            return if self.min.is_finite() { self.min } else { 0.0 };
        }
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank <= cum {
                let e = self.lo_exp + (i / PER_OCTAVE) as i32;
                let j = (i % PER_OCTAVE) as f64;
                let scale = f64::from_bits(((e + 1023) as u64) << 52); // 2^e
                let mid = scale * (1.0 + (j + 0.5) / PER_OCTAVE as f64);
                return mid.clamp(self.min, self.max);
            }
        }
        cum += self.overflow;
        if rank <= cum {
            return self.max; // finite overflow: the exact max bounds it
        }
        f64::INFINITY
    }

    /// Fold every counter and the min/max bits into an FNV-1a style hash —
    /// the histogram's contribution to a run's content hash.
    pub fn fold_hash(&self, mut h: u64) -> u64 {
        let mut word = |w: u64| {
            h ^= w;
            h = h.wrapping_mul(0x100_0000_01b3);
        };
        word(self.lo_exp as u64);
        word(self.octaves as u64);
        word(self.underflow);
        word(self.overflow);
        word(self.nonfinite);
        word(self.total);
        word(self.min.to_bits());
        word(self.max.to_bits());
        for &c in &self.counts {
            word(c);
        }
        h
    }

    /// Exact serialized state (checkpoint form): counters as JSON numbers
    /// (exact below 2^53), min/max as bit-level hex so `+inf`/`-inf`
    /// sentinels survive the round trip.
    pub fn state_json(&self) -> Json {
        Json::from_pairs(vec![
            ("lo_exp", Json::Num(self.lo_exp as f64)),
            ("octaves", Json::Num(self.octaves as f64)),
            ("underflow", Json::Num(self.underflow as f64)),
            ("overflow", Json::Num(self.overflow as f64)),
            ("nonfinite", Json::Num(self.nonfinite as f64)),
            ("total", Json::Num(self.total as f64)),
            ("min_bits", Json::Str(format!("{:016x}", self.min.to_bits()))),
            ("max_bits", Json::Str(format!("{:016x}", self.max.to_bits()))),
            ("counts", Json::Arr(self.counts.iter().map(|&c| Json::Num(c as f64)).collect())),
        ])
    }

    /// Parse the checkpoint form back (exact inverse of
    /// [`QuantileHistogram::state_json`]).
    pub fn from_state_json(j: &Json) -> Result<QuantileHistogram> {
        let lo_exp = j.get_f64("lo_exp").context("histogram lo_exp")? as i32;
        let octaves = j.get_usize("octaves").context("histogram octaves")?;
        let counts_j = j.get_arr("counts").context("histogram counts")?;
        anyhow::ensure!(
            counts_j.len() == octaves * PER_OCTAVE,
            "histogram counts: expected {} buckets, got {}",
            octaves * PER_OCTAVE,
            counts_j.len()
        );
        let counts: Vec<u64> = counts_j
            .iter()
            .map(|c| c.as_f64().map(|x| x as u64).context("histogram count: not a number"))
            .collect::<Result<_>>()?;
        Ok(QuantileHistogram {
            lo_exp,
            octaves,
            counts,
            underflow: j.get_f64("underflow")? as u64,
            overflow: j.get_f64("overflow")? as u64,
            nonfinite: j.get_f64("nonfinite")? as u64,
            total: j.get_f64("total")? as u64,
            min: f64::from_bits(parse_bits_hex(j.get_str("min_bits")?)?),
            max: f64::from_bits(parse_bits_hex(j.get_str("max_bits")?)?),
        })
    }
}

/// Parse a 64-bit hex string written by `format!("{:016x}", v)`.
pub fn parse_bits_hex(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The exact quantile definition the histogram approximates.
    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let n = sorted.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        sorted[rank - 1]
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = QuantileHistogram::response();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn quantiles_pin_against_exact_sort() {
        let mut h = QuantileHistogram::response();
        let mut rng = Rng::new(77);
        let mut xs: Vec<f64> = (0..5000)
            .map(|_| {
                // Log-uniform over ~1e-4 .. ~10 s (response-time territory).
                let u = rng.next_u64() as f64 / u64::MAX as f64;
                1e-4 * (10.0f64 / 1e-4).powf(u)
            })
            .collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.9, 0.99, 0.999] {
            let got = h.quantile(q);
            let want = exact_quantile(&xs, q);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.07, "q={q}: got {got}, want {want} (rel {rel})");
        }
        assert_eq!(h.count(), 5000);
        assert_eq!(h.min(), xs[0]);
        assert_eq!(h.max(), xs[xs.len() - 1]);
    }

    #[test]
    fn merge_equals_single_feed() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> =
            (0..800).map(|_| (rng.next_u64() % 100_000) as f64 * 1e-5 + 1e-6).collect();
        let mut whole = QuantileHistogram::braking();
        for &x in &xs {
            whole.record(x);
        }
        // Any partition, merged in any order, is the identical histogram.
        let mut a = QuantileHistogram::braking();
        let mut b = QuantileHistogram::braking();
        let mut c = QuantileHistogram::braking();
        for (i, &x) in xs.iter().enumerate() {
            [&mut a, &mut b, &mut c][i % 3].record(x);
        }
        let mut merged = QuantileHistogram::braking();
        merged.merge(&c);
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged, whole);
        assert_eq!(merged.fold_hash(0xcbf2_9ce4_8422_2325), whole.fold_hash(0xcbf2_9ce4_8422_2325));
    }

    #[test]
    fn nonfinite_samples_surface_in_the_tail() {
        let mut h = QuantileHistogram::response();
        for _ in 0..99 {
            h.record(0.01);
        }
        h.record(f64::INFINITY); // one lost task
        assert_eq!(h.nonfinite_count(), 1);
        assert!((h.quantile(0.5) - 0.01).abs() / 0.01 < 0.07);
        assert_eq!(h.quantile(1.0), f64::INFINITY, "P100 sees the lost task");
    }

    #[test]
    fn underflow_and_overflow_are_bounded_by_min_max() {
        let mut h = QuantileHistogram::new(-4, 8); // [2^-4, 2^4)
        h.record(0.0);
        h.record(1e-6); // underflow
        h.record(1.0);
        h.record(1e9); // finite overflow
        assert_eq!(h.count(), 4);
        assert_eq!(h.quantile(0.25), 0.0, "rank 1 is the exact min");
        assert_eq!(h.quantile(1.0), 1e9, "overflow rank returns the exact max");
    }

    #[test]
    fn state_json_roundtrip_is_exact() {
        let mut h = QuantileHistogram::response();
        let mut rng = Rng::new(11);
        for _ in 0..300 {
            h.record((rng.next_u64() % 1000) as f64 * 1e-4);
        }
        h.record(f64::INFINITY);
        let j = h.state_json();
        let text = j.to_pretty();
        let back =
            QuantileHistogram::from_state_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, h);
        assert_eq!(back.fold_hash(1), h.fold_hash(1));
    }

    #[test]
    fn bucket_layout_mismatch_is_rejected() {
        let j = QuantileHistogram::response().state_json();
        // Corrupt the bucket count.
        let mut o = j.as_obj().unwrap().clone();
        o.insert("octaves", Json::Num(2.0));
        assert!(QuantileHistogram::from_state_json(&Json::Obj(o)).is_err());
    }
}
