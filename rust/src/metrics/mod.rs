//! System design criteria (§6) and their accumulation rules (§7.2):
//! energy E, makespan T, resource-utilization balance rate R_Balance,
//! Matching Score MS, the Global State Value
//! `Gvalue = (-E - T + R_Balance)/3` (after normalization), and the
//! Safety-Time-Meet-Rate (STMRate, §8.4).

pub mod quantile;
pub mod summary;

use crate::env::taskgen::TaskQueue;
use crate::platform::Platform;

/// Normalization scales for Gvalue (§6.2 "after normalization").
///
/// The paper normalizes E and T before combining them with R_Balance
/// (which is already in [0, 1]) but does not give the scales; we pin them
/// to queue-intrinsic ideals so Gvalue is comparable across schedulers on
/// the same queue:
///   * `e_scale` — the energy if every task ran on its energy-cheapest
///     sub-accelerator (no scheduler can do better);
///   * `t_scale` — the perfectly-balanced makespan: total best-case compute
///     divided by the number of accelerators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NormScales {
    pub e_scale: f64,
    pub t_scale: f64,
    /// Mean best-case energy per task (J) — the per-decision energy unit
    /// of the RL reward.
    pub e_task: f64,
    /// Mean best-case compute per task (s) — the per-decision time unit of
    /// the RL reward; one unit of waiting costs like one extra inference.
    pub t_task: f64,
}

impl NormScales {
    /// Scales for one (queue, platform) pair.
    pub fn for_queue(queue: &TaskQueue, platform: &Platform) -> NormScales {
        // Per-model best case over the platform's (kind, size) cores,
        // folded once in slot order — the same minima the old per-task
        // inner loop produced (min is order-insensitive for finite f64),
        // in O(models × accels) instead of O(tasks × accels).
        let mut best = [(f64::INFINITY, f64::INFINITY); 3]; // (energy, time)
        for a in &platform.accels {
            for m in crate::workload::ALL_MODELS {
                let c = crate::accel::cost_sized(a.kind, m, a.size);
                let b = &mut best[m.index()];
                b.0 = b.0.min(c.energy_j);
                b.1 = b.1.min(c.time_s);
            }
        }
        let mut e = 0.0;
        let mut t = 0.0;
        for task in &queue.tasks {
            let (best_e, best_t) = best[task.model.index()];
            e += best_e;
            t += best_t;
        }
        let n = queue.len().max(1) as f64;
        NormScales {
            e_scale: e.max(1e-12),
            t_scale: (t / platform.len().max(1) as f64).max(1e-12),
            e_task: (e / n).max(1e-12),
            t_task: (t / n).max(1e-12),
        }
    }

    /// Unit scales (useful in tests and for raw-value reporting).
    pub fn unit() -> NormScales {
        NormScales { e_scale: 1.0, t_scale: 1.0, e_task: 1.0, t_task: 1.0 }
    }

    /// Gvalue from raw aggregates (§6.2).
    pub fn gvalue(&self, energy_j: f64, makespan_s: f64, r_balance: f64) -> f64 {
        (-energy_j / self.e_scale - makespan_s / self.t_scale + r_balance) / 3.0
    }
}

/// Running §7.2 metric state for one accelerator `H_i`:
/// `E_i += e_j; T_i += t_j; MS_i += ms_j;
///  R_Balance_i = (r_j + R_Balance_i)/num`.
#[derive(Debug, Clone, Copy, Default)]
pub struct AccelMetrics {
    /// Total energy consumed by tasks run here (J).
    pub energy_j: f64,
    /// Total busy (execution) time (s).
    pub busy_s: f64,
    /// §7.2 `T_i`: total *response* time (wait + execute) of tasks run
    /// here (s).  The paper's reward uses this T — it must see waiting, or
    /// the agent learns to ride deadlines instead of draining queues
    /// (Fig. 14b: FlexAI's T_wait is 0).
    pub resp_s: f64,
    /// Sum of matching scores of tasks run here.
    pub ms_sum: f64,
    /// Running average of per-task balance rates `r_j`.
    pub r_balance: f64,
    /// Number of tasks executed (the paper's `num`).
    pub num_tasks: u64,
}

impl AccelMetrics {
    /// Apply the §7.2 per-task update.
    pub fn update(&mut self, e_j: f64, t_j: f64, resp_j: f64, ms_j: f64, r_j: f64) {
        self.energy_j += e_j;
        self.busy_s += t_j;
        self.resp_s += resp_j;
        self.ms_sum += ms_j;
        self.num_tasks += 1;
        // R_Balance_i = (r_j + R_Balance_i) / num — the paper's literal
        // recurrence (an exponentially-fading average for num >= 2; exact
        // average for the first task).
        self.r_balance = (r_j + self.r_balance) / self.num_tasks.min(2) as f64;
    }
}

/// Whole-platform aggregates (§7.2):
/// `E = ΣE_i; T = max{T_i}; MS = ΣMS_i; R_Balance = mean{R_Balance_i}`.
#[derive(Debug, Clone)]
pub struct PlatformMetrics {
    pub per_accel: Vec<AccelMetrics>,
    pub scales: NormScales,
}

impl PlatformMetrics {
    pub fn new(n_accels: usize, scales: NormScales) -> PlatformMetrics {
        PlatformMetrics { per_accel: vec![AccelMetrics::default(); n_accels], scales }
    }

    pub fn energy_j(&self) -> f64 {
        self.per_accel.iter().map(|a| a.energy_j).sum()
    }

    /// Hardware makespan: max total *busy* time over accelerators.
    pub fn makespan_s(&self) -> f64 {
        self.per_accel.iter().map(|a| a.busy_s).fold(0.0, f64::max)
    }

    /// §7.2 `T = max{T_1..T_N}` over response-time sums — the Gvalue /
    /// reward T term (sees queueing, unlike `makespan_s`).
    pub fn resp_makespan_s(&self) -> f64 {
        self.per_accel.iter().map(|a| a.resp_s).fold(0.0, f64::max)
    }

    pub fn ms_total(&self) -> f64 {
        self.per_accel.iter().map(|a| a.ms_sum).sum()
    }

    /// `R_Balance = (1/N) Σ R_Balance_i`.
    pub fn r_balance(&self) -> f64 {
        if self.per_accel.is_empty() {
            return 0.0;
        }
        self.per_accel.iter().map(|a| a.r_balance).sum::<f64>() / self.per_accel.len() as f64
    }

    /// `Gvalue = (-E - T + R_Balance)/3` after normalization (§6.2), with
    /// T the response-time makespan.
    pub fn gvalue(&self) -> f64 {
        self.scales.gvalue(self.energy_j(), self.resp_makespan_s(), self.r_balance())
    }

    pub fn total_tasks(&self) -> u64 {
        self.per_accel.iter().map(|a| a.num_tasks).sum()
    }
}

/// STMRate (§8.4): fraction of tasks whose response time is within their
/// safety time.
pub fn stm_rate(met: u64, total: u64) -> f64 {
    if total == 0 {
        1.0
    } else {
        met as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::route::{Route, RouteParams};
    use crate::env::Area;
    use crate::util::rng::Rng;

    fn small_queue() -> TaskQueue {
        let route =
            Route::generate(RouteParams::for_area(Area::Urban, 30.0), &mut Rng::new(1));
        crate::env::taskgen::generate(&route)
    }

    #[test]
    fn scales_positive_and_queue_dependent() {
        let q = small_queue();
        let s = NormScales::for_queue(&q, &Platform::hmai());
        assert!(s.e_scale > 0.0 && s.t_scale > 0.0);
        // More accelerators => smaller ideal makespan, same ideal energy.
        let s26 = NormScales::for_queue(&q, &Platform::from_counts("big", 10, 10, 6));
        assert!(s26.t_scale < s.t_scale);
        assert!((s26.e_scale - s.e_scale).abs() < 1e-9);
    }

    #[test]
    fn gvalue_prefers_lower_energy_time_higher_balance() {
        let s = NormScales::unit();
        let base = s.gvalue(1.0, 1.0, 0.5);
        assert!(s.gvalue(0.5, 1.0, 0.5) > base);
        assert!(s.gvalue(1.0, 0.5, 0.5) > base);
        assert!(s.gvalue(1.0, 1.0, 0.9) > base);
    }

    #[test]
    fn accel_update_rules() {
        let mut a = AccelMetrics::default();
        a.update(1.0, 2.0, 2.0, 0.5, 0.8);
        assert_eq!(a.energy_j, 1.0);
        assert_eq!(a.busy_s, 2.0);
        assert_eq!(a.ms_sum, 0.5);
        // First task: R_Balance = r_j exactly.
        assert!((a.r_balance - 0.8).abs() < 1e-12);
        a.update(1.0, 2.0, 2.0, 0.5, 0.4);
        // Second: (0.4 + 0.8)/2 = 0.6.
        assert!((a.r_balance - 0.6).abs() < 1e-12);
        assert_eq!(a.num_tasks, 2);
    }

    #[test]
    fn platform_aggregation() {
        let mut m = PlatformMetrics::new(3, NormScales::unit());
        m.per_accel[0].update(1.0, 5.0, 5.0, 1.0, 1.0);
        m.per_accel[1].update(2.0, 3.0, 3.0, -1.0, 0.5);
        assert!((m.energy_j() - 3.0).abs() < 1e-12);
        assert!((m.makespan_s() - 5.0).abs() < 1e-12); // max, not sum
        assert!((m.ms_total() - 0.0).abs() < 1e-12);
        assert!((m.r_balance() - 0.5).abs() < 1e-12); // (1.0+0.5+0)/3
        assert_eq!(m.total_tasks(), 2);
    }

    #[test]
    fn stm_rate_edges() {
        assert_eq!(stm_rate(0, 0), 1.0);
        assert_eq!(stm_rate(5, 10), 0.5);
        assert_eq!(stm_rate(10, 10), 1.0);
    }
}
