//! Per-run summary: the quantities Figures 12-14 report, aggregated from a
//! simulation's task records — plus the sweep-level aggregator
//! (`SweepSummary`) the experiment engine streams trial results into.

use crate::util::json::Json;
use crate::util::stats::geomean;

use super::{stm_rate, PlatformMetrics};

/// Aggregate results of scheduling one task queue on one platform with one
/// scheduler — the row unit of Figures 12 and 13.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub scheduler: String,
    pub platform: String,
    pub tasks: u64,
    /// Tasks whose response time met their safety time.
    pub tasks_met: u64,
    /// Total energy E (J).
    pub energy_j: f64,
    /// Makespan T = max accelerator busy time (s).
    pub makespan_s: f64,
    /// Figure 12(a) "time": Σ(wait + execute) over tasks + scheduler
    /// runtime (s).
    pub total_time_s: f64,
    /// Σ waiting time over tasks (s).
    pub wait_s: f64,
    /// Σ execution time over tasks (s).
    pub compute_s: f64,
    /// Measured scheduler runtime (wall clock, s).
    pub sched_s: f64,
    pub r_balance: f64,
    pub ms_total: f64,
    pub gvalue: f64,
    /// Mean response time (s).
    pub mean_response_s: f64,
    /// Max response time (s).
    pub max_response_s: f64,
}

impl RunSummary {
    pub fn from_metrics(
        scheduler: &str,
        platform: &str,
        m: &PlatformMetrics,
        tasks_met: u64,
        wait_s: f64,
        sched_s: f64,
        mean_response_s: f64,
        max_response_s: f64,
    ) -> RunSummary {
        let compute_s: f64 = m.per_accel.iter().map(|a| a.busy_s).sum();
        RunSummary {
            scheduler: scheduler.to_string(),
            platform: platform.to_string(),
            tasks: m.total_tasks(),
            tasks_met,
            energy_j: m.energy_j(),
            makespan_s: m.makespan_s(),
            total_time_s: wait_s + compute_s + sched_s,
            wait_s,
            compute_s,
            sched_s,
            r_balance: m.r_balance(),
            ms_total: m.ms_total(),
            gvalue: m.gvalue(),
            mean_response_s,
            max_response_s,
        }
    }

    /// STMRate (§8.4).
    pub fn stm_rate(&self) -> f64 {
        stm_rate(self.tasks_met, self.tasks)
    }

    /// Mean MS per task (comparable across queue lengths).
    pub fn ms_per_task(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.ms_total / self.tasks as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("platform", Json::Str(self.platform.clone())),
            ("tasks", Json::Num(self.tasks as f64)),
            ("tasks_met", Json::Num(self.tasks_met as f64)),
            ("stm_rate", Json::Num(self.stm_rate())),
            ("energy_j", Json::Num(self.energy_j)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("total_time_s", Json::Num(self.total_time_s)),
            ("wait_s", Json::Num(self.wait_s)),
            ("compute_s", Json::Num(self.compute_s)),
            ("sched_s", Json::Num(self.sched_s)),
            ("r_balance", Json::Num(self.r_balance)),
            ("ms_total", Json::Num(self.ms_total)),
            ("gvalue", Json::Num(self.gvalue)),
            ("mean_response_s", Json::Num(self.mean_response_s)),
            ("max_response_s", Json::Num(self.max_response_s)),
        ])
    }

    /// Fold this run's *deterministic* fields into an FNV-1a hash.
    /// Wall-clock fields (`sched_s`, and `total_time_s` which includes it)
    /// are excluded, so the fingerprint is invariant under `--jobs`.
    pub fn fold_fingerprint(&self, mut h: u64) -> u64 {
        let mut word = |w: u64| {
            h ^= w;
            h = h.wrapping_mul(0x100_0000_01b3);
        };
        for b in self.scheduler.bytes().chain(self.platform.bytes()) {
            word(b as u64);
        }
        word(self.tasks);
        word(self.tasks_met);
        for f in [
            self.energy_j,
            self.makespan_s,
            self.wait_s,
            self.compute_s,
            self.r_balance,
            self.ms_total,
            self.gvalue,
            self.mean_response_s,
            self.max_response_s,
        ] {
            word(f.to_bits());
        }
        h
    }

    /// Deterministic wait + compute time (the Fig. 12(a) "time" metric
    /// without the measured scheduler wall clock).
    pub fn work_time_s(&self) -> f64 {
        self.wait_s + self.compute_s
    }
}

/// Grouping key of a sweep row: everything a trial can vary besides the
/// queue replicate (distance index and seed aggregate *within* a row).
/// `scenario` is the library archetype name ("-" for plain area cells) —
/// the per-scenario breakdown dimension of the sweep table.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub scheduler: String,
    pub platform: String,
    pub scenario: String,
    pub area: String,
    pub deadline: String,
}

/// One row of a sweep: all run summaries sharing a `SweepKey`, in trial-id
/// order, plus the aggregate statistics the figures report.
#[derive(Debug, Clone)]
pub struct SweepGroup {
    pub key: SweepKey,
    pub runs: Vec<RunSummary>,
}

impl SweepGroup {
    pub fn trials(&self) -> usize {
        self.runs.len()
    }

    /// Geometric mean of wait+compute time (Fig. 12(a)'s M column, minus
    /// the nondeterministic scheduler wall clock).
    pub fn geomean_time_s(&self) -> f64 {
        geomean(&self.runs.iter().map(|s| s.work_time_s().max(1e-12)).collect::<Vec<_>>())
    }

    /// Geometric mean energy (Fig. 12(d)).
    pub fn geomean_energy_j(&self) -> f64 {
        geomean(&self.runs.iter().map(|s| s.energy_j.max(1e-12)).collect::<Vec<_>>())
    }

    pub fn mean_stm_rate(&self) -> f64 {
        self.mean(|s| s.stm_rate())
    }

    pub fn mean_r_balance(&self) -> f64 {
        self.mean(|s| s.r_balance)
    }

    pub fn mean_ms_per_task(&self) -> f64 {
        self.mean(|s| s.ms_per_task())
    }

    pub fn mean_gvalue(&self) -> f64 {
        self.mean(|s| s.gvalue)
    }

    fn mean<F: Fn(&RunSummary) -> f64>(&self, f: F) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().map(f).sum::<f64>() / self.runs.len() as f64
    }
}

/// Aggregate of a whole sweep: rows in first-seen (trial-id) order.
#[derive(Debug, Clone, Default)]
pub struct SweepSummary {
    pub groups: Vec<SweepGroup>,
}

impl SweepSummary {
    pub fn new() -> SweepSummary {
        SweepSummary { groups: Vec::new() }
    }

    /// Stream one run into its group (creating the group on first sight —
    /// insertion order is trial-id order when fed sequentially).
    pub fn push(&mut self, key: SweepKey, run: RunSummary) {
        match self.groups.iter_mut().find(|g| g.key == key) {
            Some(g) => g.runs.push(run),
            None => self.groups.push(SweepGroup { key, runs: vec![run] }),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Total runs across all groups.
    pub fn total_runs(&self) -> usize {
        self.groups.iter().map(|g| g.runs.len()).sum()
    }

    /// Find a group by scheduler display name (first match).
    pub fn by_scheduler(&self, scheduler: &str) -> Option<&SweepGroup> {
        self.groups.iter().find(|g| g.key.scheduler == scheduler)
    }

    /// Order-and-bit-exact fingerprint over every deterministic field of
    /// every run.  `Engine` guarantees this is identical for any `--jobs`.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV offset basis
        for g in &self.groups {
            for b in g
                .key
                .scheduler
                .bytes()
                .chain(g.key.platform.bytes())
                .chain(g.key.scenario.bytes())
                .chain(g.key.area.bytes())
                .chain(g.key.deadline.bytes())
            {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            for run in &g.runs {
                h = run.fold_fingerprint(h);
            }
        }
        h
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.groups
                .iter()
                .map(|g| {
                    Json::from_pairs(vec![
                        ("scheduler", Json::Str(g.key.scheduler.clone())),
                        ("platform", Json::Str(g.key.platform.clone())),
                        ("scenario", Json::Str(g.key.scenario.clone())),
                        ("area", Json::Str(g.key.area.clone())),
                        ("deadline", Json::Str(g.key.deadline.clone())),
                        ("trials", Json::Num(g.trials() as f64)),
                        ("geomean_time_s", Json::Num(g.geomean_time_s())),
                        ("geomean_energy_j", Json::Num(g.geomean_energy_j())),
                        ("mean_stm_rate", Json::Num(g.mean_stm_rate())),
                        ("mean_r_balance", Json::Num(g.mean_r_balance())),
                        ("mean_ms_per_task", Json::Num(g.mean_ms_per_task())),
                        ("mean_gvalue", Json::Num(g.mean_gvalue())),
                        ("runs", Json::Arr(g.runs.iter().map(|r| r.to_json()).collect())),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;

    fn summary() -> RunSummary {
        let mut m = PlatformMetrics::new(2, NormScales::unit());
        m.per_accel[0].update(1.0, 2.0, 2.0, 1.0, 0.9);
        m.per_accel[1].update(1.0, 1.0, 1.0, -1.0, 0.6);
        RunSummary::from_metrics("test", "p", &m, 1, 0.5, 0.1, 1.5, 2.0)
    }

    #[test]
    fn totals_compose() {
        let s = summary();
        assert_eq!(s.tasks, 2);
        assert!((s.compute_s - 3.0).abs() < 1e-12);
        assert!((s.total_time_s - (0.5 + 3.0 + 0.1)).abs() < 1e-12);
        assert!((s.stm_rate() - 0.5).abs() < 1e-12);
        assert!((s.ms_per_task() - 0.0).abs() < 1e-12);
    }

    fn key(sched: &str) -> SweepKey {
        SweepKey {
            scheduler: sched.to_string(),
            platform: "p".to_string(),
            scenario: "-".to_string(),
            area: "UB".to_string(),
            deadline: "rss".to_string(),
        }
    }

    #[test]
    fn scenario_splits_sweep_groups_and_fingerprints() {
        let mut a = SweepSummary::new();
        a.push(key("x"), summary());
        let mut b = SweepSummary::new();
        b.push(SweepKey { scenario: "night-rain".into(), ..key("x") }, summary());
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Different scenarios never merge into one row.
        let mut c = SweepSummary::new();
        c.push(key("x"), summary());
        c.push(SweepKey { scenario: "night-rain".into(), ..key("x") }, summary());
        assert_eq!(c.groups.len(), 2);
    }

    #[test]
    fn sweep_groups_by_key_in_insertion_order() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), summary());
        sw.push(key("b"), summary());
        sw.push(key("a"), summary());
        assert_eq!(sw.groups.len(), 2);
        assert_eq!(sw.total_runs(), 3);
        assert_eq!(sw.groups[0].key.scheduler, "a");
        assert_eq!(sw.by_scheduler("a").unwrap().trials(), 2);
        assert!(sw.by_scheduler("zzz").is_none());
    }

    #[test]
    fn fingerprint_ignores_wall_clock_but_not_results() {
        let mk = |sched_s: f64, energy_bump: f64| {
            let mut s = summary();
            s.sched_s = sched_s;
            s.total_time_s += sched_s;
            s.energy_j += energy_bump;
            let mut sw = SweepSummary::new();
            sw.push(key("a"), s);
            sw
        };
        assert_eq!(mk(0.1, 0.0).fingerprint(), mk(9.9, 0.0).fingerprint());
        assert_ne!(mk(0.1, 0.0).fingerprint(), mk(0.1, 1.0).fingerprint());
    }

    #[test]
    fn sweep_aggregates_match_hand_math() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), summary());
        sw.push(key("a"), summary());
        let g = sw.by_scheduler("a").unwrap();
        let s = summary();
        assert!((g.geomean_time_s() - s.work_time_s()).abs() < 1e-9);
        assert!((g.mean_stm_rate() - s.stm_rate()).abs() < 1e-12);
        assert!((g.geomean_energy_j() - s.energy_j).abs() < 1e-9);
        // JSON renders one row with both runs.
        let j = sw.to_json().to_string();
        assert!(j.contains("geomean_time_s"));
    }

    #[test]
    fn json_roundtrip_fields() {
        let s = summary();
        let j = s.to_json();
        let o = j.as_obj().unwrap();
        assert_eq!(o.get("scheduler").unwrap().as_str(), Some("test"));
        assert!((o.get("stm_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        // Render + parse back.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!((parsed.as_obj().unwrap().get("energy_j").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
    }
}
