//! Per-run summary: the quantities Figures 12-14 report, aggregated from a
//! simulation's task records — plus the sweep-level aggregator
//! (`SweepSummary`) the experiment engine streams trial results into.
//!
//! `SweepSummary` is a commutative merge monoid: each group row holds
//! mergeable moments ([`GroupStats`] — counts, log-sums for geomeans,
//! plain sums for means, tail histograms) instead of retained runs, so
//! partial summaries built over disjoint trial ranges recombine with
//! [`SweepSummary::merge`].  The fleet service (`fleet` module) leans on
//! two precise guarantees:
//!
//! * **Fingerprint partition-invariance.**  [`SweepSummary::fingerprint`]
//!   folds, per group, the key bytes, the integer counts and a
//!   commutative content hash (a wrapping sum of mixed per-run hashes),
//!   combining groups commutatively too.  Every folded quantity is
//!   integer-exact under any partition and merge order, so a merged fleet
//!   sweep fingerprints identically to the single-process sweep.
//! * **Monolithic bit-identity.**  The f64 moment sums accumulate in push
//!   (trial-id) order, in exactly the evaluation order the old
//!   retained-runs aggregation used, so single-process reports reproduce
//!   pre-refactor values bit-for-bit.  Across a shard *merge* the moment
//!   sums may differ in final ulps (f64 addition is not associative) —
//!   which is why they inform reports but never the fingerprint.

use crate::util::json::Json;

use super::quantile::{parse_bits_hex, QuantileHistogram};
use super::{stm_rate, PlatformMetrics};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

/// SplitMix64 finalizer: avalanches a word so wrapping-sum combination of
/// per-run/per-group hashes doesn't cancel structure.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Aggregate results of scheduling one task queue on one platform with one
/// scheduler — the row unit of Figures 12 and 13.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub scheduler: String,
    pub platform: String,
    pub tasks: u64,
    /// Tasks whose response time met their safety time.
    pub tasks_met: u64,
    /// Total energy E (J).
    pub energy_j: f64,
    /// Makespan T = max accelerator busy time (s).
    pub makespan_s: f64,
    /// Figure 12(a) "time": Σ(wait + execute) over tasks + scheduler
    /// runtime (s).
    pub total_time_s: f64,
    /// Σ waiting time over tasks (s).
    pub wait_s: f64,
    /// Σ execution time over tasks (s).
    pub compute_s: f64,
    /// Measured scheduler runtime (wall clock, s).
    pub sched_s: f64,
    pub r_balance: f64,
    pub ms_total: f64,
    pub gvalue: f64,
    /// Mean response time (s).
    pub mean_response_s: f64,
    /// Max response time (s).
    pub max_response_s: f64,
    /// Σ interconnect delay over tasks (s) — 0.0 on monolithic platforms
    /// (no chiplet topology attached).
    pub comm_delay_s: f64,
    /// Total bytes moved over the interconnect, in GB — 0.0 monolithically.
    pub comm_gb: f64,
    /// Per-task response-time histogram (deterministic; filled by the
    /// engine's tails probe — empty when built outside the engine).
    pub response_hist: QuantileHistogram,
    /// Per-task braking-distance histogram (deterministic components
    /// only; see `engine::TailsProbe`).
    pub braking_hist: QuantileHistogram,
    /// Safety-critical (Detection-tier) tasks in the run — the survival
    /// denominator of fault campaigns.  Report-only: like every survival
    /// field below, derived from the same records as `tasks`/`tasks_met`
    /// and deliberately outside `fold_fingerprint`/`content_hash`, so
    /// pre-faults fingerprints reproduce bit-for-bit.
    pub safety_tasks: u64,
    /// Safety-critical tasks that met their safety time.
    pub safety_met: u64,
    /// Tasks lost outright (`response = +inf`: dead accelerator or severed
    /// interconnect route).
    pub lost_tasks: u64,
    /// Set when the trial did not produce a result (its scheduler
    /// panicked); the engine fabricates an otherwise-empty summary so the
    /// failure is *counted* (`GroupStats::failed_trials`) instead of
    /// killing the sweep.
    pub failed: bool,
}

impl RunSummary {
    #[allow(clippy::too_many_arguments)]
    pub fn from_metrics(
        scheduler: &str,
        platform: &str,
        m: &PlatformMetrics,
        tasks_met: u64,
        wait_s: f64,
        sched_s: f64,
        mean_response_s: f64,
        max_response_s: f64,
    ) -> RunSummary {
        let compute_s: f64 = m.per_accel.iter().map(|a| a.busy_s).sum();
        RunSummary {
            scheduler: scheduler.to_string(),
            platform: platform.to_string(),
            tasks: m.total_tasks(),
            tasks_met,
            energy_j: m.energy_j(),
            makespan_s: m.makespan_s(),
            total_time_s: wait_s + compute_s + sched_s,
            wait_s,
            compute_s,
            sched_s,
            r_balance: m.r_balance(),
            ms_total: m.ms_total(),
            gvalue: m.gvalue(),
            mean_response_s,
            max_response_s,
            comm_delay_s: 0.0,
            comm_gb: 0.0,
            response_hist: QuantileHistogram::response(),
            braking_hist: QuantileHistogram::braking(),
            safety_tasks: 0,
            safety_met: 0,
            lost_tasks: 0,
            failed: false,
        }
    }

    /// The summary of a trial that produced no result (its scheduler
    /// panicked mid-simulation): empty moments, `failed` set.  Grouped
    /// under the same sweep key as its healthy siblings so
    /// [`GroupStats::push`] counts it in `failed_trials` without folding
    /// anything else.
    pub fn failed(scheduler: String, platform: String) -> RunSummary {
        RunSummary {
            scheduler,
            platform,
            tasks: 0,
            tasks_met: 0,
            energy_j: 0.0,
            makespan_s: 0.0,
            total_time_s: 0.0,
            wait_s: 0.0,
            compute_s: 0.0,
            sched_s: 0.0,
            r_balance: 0.0,
            ms_total: 0.0,
            gvalue: 0.0,
            mean_response_s: 0.0,
            max_response_s: 0.0,
            comm_delay_s: 0.0,
            comm_gb: 0.0,
            response_hist: QuantileHistogram::response(),
            braking_hist: QuantileHistogram::braking(),
            safety_tasks: 0,
            safety_met: 0,
            lost_tasks: 0,
            failed: true,
        }
    }

    /// STMRate (§8.4).
    pub fn stm_rate(&self) -> f64 {
        stm_rate(self.tasks_met, self.tasks)
    }

    /// Mean MS per task (comparable across queue lengths).
    pub fn ms_per_task(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.ms_total / self.tasks as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("platform", Json::Str(self.platform.clone())),
            ("tasks", Json::Num(self.tasks as f64)),
            ("tasks_met", Json::Num(self.tasks_met as f64)),
            ("stm_rate", Json::Num(self.stm_rate())),
            ("energy_j", Json::Num(self.energy_j)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("total_time_s", Json::Num(self.total_time_s)),
            ("wait_s", Json::Num(self.wait_s)),
            ("compute_s", Json::Num(self.compute_s)),
            ("sched_s", Json::Num(self.sched_s)),
            ("r_balance", Json::Num(self.r_balance)),
            ("ms_total", Json::Num(self.ms_total)),
            ("gvalue", Json::Num(self.gvalue)),
            ("mean_response_s", Json::Num(self.mean_response_s)),
            ("max_response_s", Json::Num(self.max_response_s)),
            ("comm_delay_s", Json::Num(self.comm_delay_s)),
            ("comm_gb", Json::Num(self.comm_gb)),
            ("safety_tasks", Json::Num(self.safety_tasks as f64)),
            ("safety_met", Json::Num(self.safety_met as f64)),
            ("lost_tasks", Json::Num(self.lost_tasks as f64)),
            ("failed", Json::Bool(self.failed)),
        ])
    }

    /// Fold this run's *deterministic* scalar fields into an FNV-1a hash.
    /// Wall-clock fields (`sched_s`, and `total_time_s` which includes it)
    /// are excluded, so the fingerprint is invariant under `--jobs`.
    /// The survival counters (`safety_tasks`/`safety_met`/`lost_tasks`)
    /// are excluded too: they are report-only derivations of the same
    /// records, and folding them would break bit-identity with every
    /// pre-faults fingerprint.
    pub fn fold_fingerprint(&self, mut h: u64) -> u64 {
        let mut word = |w: u64| {
            h ^= w;
            h = h.wrapping_mul(FNV_PRIME);
        };
        for b in self.scheduler.bytes().chain(self.platform.bytes()) {
            word(b as u64);
        }
        word(self.tasks);
        word(self.tasks_met);
        for f in [
            self.energy_j,
            self.makespan_s,
            self.wait_s,
            self.compute_s,
            self.r_balance,
            self.ms_total,
            self.gvalue,
            self.mean_response_s,
            self.max_response_s,
            self.comm_delay_s,
            self.comm_gb,
        ] {
            word(f.to_bits());
        }
        h
    }

    /// Complete deterministic content hash of this run: the scalar fields
    /// plus both tail histograms.  Per-run content hashes combine
    /// *commutatively* into [`GroupStats::content_hash`], which is what
    /// makes the sweep fingerprint partition-invariant.
    pub fn content_hash(&self) -> u64 {
        let mut h = self.fold_fingerprint(FNV_OFFSET);
        h = self.response_hist.fold_hash(h);
        h = self.braking_hist.fold_hash(h);
        h
    }

    /// Deterministic wait + compute time (the Fig. 12(a) "time" metric
    /// without the measured scheduler wall clock).
    pub fn work_time_s(&self) -> f64 {
        self.wait_s + self.compute_s
    }
}

/// Grouping key of a sweep row: everything a trial can vary besides the
/// queue replicate (distance index and seed aggregate *within* a row).
/// `scenario` is the library archetype name ("-" for plain area cells) —
/// the per-scenario breakdown dimension of the sweep table.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub scheduler: String,
    pub platform: String,
    pub scenario: String,
    pub area: String,
    pub deadline: String,
}

impl SweepKey {
    fn state_json(&self) -> Json {
        Json::from_pairs(vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("platform", Json::Str(self.platform.clone())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("area", Json::Str(self.area.clone())),
            ("deadline", Json::Str(self.deadline.clone())),
        ])
    }

    fn from_state_json(j: &Json) -> anyhow::Result<SweepKey> {
        Ok(SweepKey {
            scheduler: j.get_str("scheduler")?.to_string(),
            platform: j.get_str("platform")?.to_string(),
            scenario: j.get_str("scenario")?.to_string(),
            area: j.get_str("area")?.to_string(),
            deadline: j.get_str("deadline")?.to_string(),
        })
    }
}

/// Mergeable moments of one sweep row.  Counts, the commutative content
/// hash and the histograms are integer-exact under any merge partition;
/// the f64 sums accumulate in push order (bit-identical monolithically,
/// ulp-level drift across shard merges — excluded from fingerprints).
#[derive(Debug, Clone)]
pub struct GroupStats {
    pub trials: u64,
    pub sum_tasks: u64,
    pub sum_tasks_met: u64,
    /// Σ ln(max(wait+compute, 1e-12)) — geomean numerator.
    pub sum_ln_time: f64,
    /// Σ ln(max(energy, 1e-12)).
    pub sum_ln_energy: f64,
    pub sum_stm_rate: f64,
    pub sum_r_balance: f64,
    pub sum_ms_per_task: f64,
    pub sum_gvalue: f64,
    /// Σ per-run interconnect delay (s) — 0.0 across monolithic rows.
    pub sum_comm_delay: f64,
    /// Σ per-run interconnect traffic (GB).
    pub sum_comm_gb: f64,
    /// Wrapping sum of `mix(run.content_hash())` over member runs — a
    /// commutative, associative digest of the row's exact contents.
    pub content_hash: u64,
    /// Merged per-task response-time histogram.
    pub response: QuantileHistogram,
    /// Merged per-task braking-distance histogram.
    pub braking: QuantileHistogram,
    /// Σ safety-critical tasks over member runs (report-only — survival
    /// counters never enter the fingerprint; see `RunSummary`).
    pub sum_safety_tasks: u64,
    /// Σ safety-critical tasks that met their safety time.
    pub sum_safety_met: u64,
    /// Σ tasks lost outright (`response = +inf`).
    pub sum_lost_tasks: u64,
    /// Trials that panicked instead of completing: counted here, folded
    /// nowhere else (`trials` and every moment exclude them), and outside
    /// the fingerprint — a sweep with one bad trial still merges and
    /// fingerprints identically to one re-run without it.
    pub failed_trials: u64,
}

impl GroupStats {
    pub fn new() -> GroupStats {
        GroupStats {
            trials: 0,
            sum_tasks: 0,
            sum_tasks_met: 0,
            sum_ln_time: 0.0,
            sum_ln_energy: 0.0,
            sum_stm_rate: 0.0,
            sum_r_balance: 0.0,
            sum_ms_per_task: 0.0,
            sum_gvalue: 0.0,
            sum_comm_delay: 0.0,
            sum_comm_gb: 0.0,
            content_hash: 0,
            response: QuantileHistogram::response(),
            braking: QuantileHistogram::braking(),
            sum_safety_tasks: 0,
            sum_safety_met: 0,
            sum_lost_tasks: 0,
            failed_trials: 0,
        }
    }

    /// Fold one run in (push order = trial-id order when fed by the
    /// engine).  The clamp-then-`ln` per element matches
    /// `util::stats::geomean` exactly, so monolithic aggregates keep their
    /// pre-refactor bits.  A `failed` run only bumps `failed_trials`: its
    /// empty moments would otherwise poison the geomeans (`ln(1e-12)`
    /// per zeroed field) and dilute every mean.
    pub fn push(&mut self, run: &RunSummary) {
        if run.failed {
            self.failed_trials += 1;
            return;
        }
        self.trials += 1;
        self.sum_tasks += run.tasks;
        self.sum_tasks_met += run.tasks_met;
        self.sum_ln_time += run.work_time_s().max(1e-12).ln();
        self.sum_ln_energy += run.energy_j.max(1e-12).ln();
        self.sum_stm_rate += run.stm_rate();
        self.sum_r_balance += run.r_balance;
        self.sum_ms_per_task += run.ms_per_task();
        self.sum_gvalue += run.gvalue;
        self.sum_comm_delay += run.comm_delay_s;
        self.sum_comm_gb += run.comm_gb;
        self.content_hash = self.content_hash.wrapping_add(mix(run.content_hash()));
        self.response.merge(&run.response_hist);
        self.braking.merge(&run.braking_hist);
        self.sum_safety_tasks += run.safety_tasks;
        self.sum_safety_met += run.safety_met;
        self.sum_lost_tasks += run.lost_tasks;
    }

    /// Fold another partial aggregate in (commutative and associative on
    /// every integer field; f64 sums may differ in ulps across orders).
    pub fn merge(&mut self, other: &GroupStats) {
        self.trials += other.trials;
        self.sum_tasks += other.sum_tasks;
        self.sum_tasks_met += other.sum_tasks_met;
        self.sum_ln_time += other.sum_ln_time;
        self.sum_ln_energy += other.sum_ln_energy;
        self.sum_stm_rate += other.sum_stm_rate;
        self.sum_r_balance += other.sum_r_balance;
        self.sum_ms_per_task += other.sum_ms_per_task;
        self.sum_gvalue += other.sum_gvalue;
        self.sum_comm_delay += other.sum_comm_delay;
        self.sum_comm_gb += other.sum_comm_gb;
        self.content_hash = self.content_hash.wrapping_add(other.content_hash);
        self.response.merge(&other.response);
        self.braking.merge(&other.braking);
        self.sum_safety_tasks += other.sum_safety_tasks;
        self.sum_safety_met += other.sum_safety_met;
        self.sum_lost_tasks += other.sum_lost_tasks;
        self.failed_trials += other.failed_trials;
    }

    fn mean_of(&self, sum: f64) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            sum / self.trials as f64
        }
    }

    fn geomean_of(&self, sum_ln: f64) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            (sum_ln / self.trials as f64).exp()
        }
    }

    /// Exact checkpoint form: integer counters as JSON numbers (exact
    /// below 2^53), f64 sums and the content hash as bit-level hex so
    /// resume reproduces the in-memory state bit-for-bit.
    pub fn state_json(&self) -> Json {
        Json::from_pairs(vec![
            ("trials", Json::Num(self.trials as f64)),
            ("sum_tasks", Json::Num(self.sum_tasks as f64)),
            ("sum_tasks_met", Json::Num(self.sum_tasks_met as f64)),
            ("sum_ln_time_bits", Json::Str(format!("{:016x}", self.sum_ln_time.to_bits()))),
            ("sum_ln_energy_bits", Json::Str(format!("{:016x}", self.sum_ln_energy.to_bits()))),
            ("sum_stm_rate_bits", Json::Str(format!("{:016x}", self.sum_stm_rate.to_bits()))),
            ("sum_r_balance_bits", Json::Str(format!("{:016x}", self.sum_r_balance.to_bits()))),
            (
                "sum_ms_per_task_bits",
                Json::Str(format!("{:016x}", self.sum_ms_per_task.to_bits())),
            ),
            ("sum_gvalue_bits", Json::Str(format!("{:016x}", self.sum_gvalue.to_bits()))),
            (
                "sum_comm_delay_bits",
                Json::Str(format!("{:016x}", self.sum_comm_delay.to_bits())),
            ),
            ("sum_comm_gb_bits", Json::Str(format!("{:016x}", self.sum_comm_gb.to_bits()))),
            ("content_hash", Json::Str(format!("{:016x}", self.content_hash))),
            ("sum_safety_tasks", Json::Num(self.sum_safety_tasks as f64)),
            ("sum_safety_met", Json::Num(self.sum_safety_met as f64)),
            ("sum_lost_tasks", Json::Num(self.sum_lost_tasks as f64)),
            ("failed_trials", Json::Num(self.failed_trials as f64)),
            ("response", self.response.state_json()),
            ("braking", self.braking.state_json()),
        ])
    }

    pub fn from_state_json(j: &Json) -> anyhow::Result<GroupStats> {
        let f = |key: &str| -> anyhow::Result<f64> {
            Ok(f64::from_bits(parse_bits_hex(j.get_str(key)?)?))
        };
        // The comm sums postdate the v1 checkpoint format; a pre-interconnect
        // checkpoint simply has none (0.0 — malformed hex still errors).
        let f_new = |key: &str| -> anyhow::Result<f64> {
            match j.get_str(key) {
                Ok(s) => Ok(f64::from_bits(parse_bits_hex(s)?)),
                Err(_) => Ok(0.0),
            }
        };
        // Integer survival counters postdate the comm sums; same
        // missing-key-means-zero treatment for pre-faults checkpoints.
        let u_new = |key: &str| -> u64 { j.get_f64(key).map(|v| v as u64).unwrap_or(0) };
        Ok(GroupStats {
            trials: j.get_f64("trials")? as u64,
            sum_tasks: j.get_f64("sum_tasks")? as u64,
            sum_tasks_met: j.get_f64("sum_tasks_met")? as u64,
            sum_ln_time: f("sum_ln_time_bits")?,
            sum_ln_energy: f("sum_ln_energy_bits")?,
            sum_stm_rate: f("sum_stm_rate_bits")?,
            sum_r_balance: f("sum_r_balance_bits")?,
            sum_ms_per_task: f("sum_ms_per_task_bits")?,
            sum_gvalue: f("sum_gvalue_bits")?,
            sum_comm_delay: f_new("sum_comm_delay_bits")?,
            sum_comm_gb: f_new("sum_comm_gb_bits")?,
            content_hash: parse_bits_hex(j.get_str("content_hash")?)?,
            response: QuantileHistogram::from_state_json(j.get("response")?)?,
            braking: QuantileHistogram::from_state_json(j.get("braking")?)?,
            sum_safety_tasks: u_new("sum_safety_tasks"),
            sum_safety_met: u_new("sum_safety_met"),
            sum_lost_tasks: u_new("sum_lost_tasks"),
            failed_trials: u_new("failed_trials"),
        })
    }
}

impl Default for GroupStats {
    fn default() -> Self {
        Self::new()
    }
}

/// One row of a sweep: the mergeable aggregate of every run sharing a
/// `SweepKey`.
#[derive(Debug, Clone)]
pub struct SweepGroup {
    pub key: SweepKey,
    pub stats: GroupStats,
}

impl SweepGroup {
    pub fn trials(&self) -> usize {
        self.stats.trials as usize
    }

    /// Geometric mean of wait+compute time (Fig. 12(a)'s M column, minus
    /// the nondeterministic scheduler wall clock).
    pub fn geomean_time_s(&self) -> f64 {
        self.stats.geomean_of(self.stats.sum_ln_time)
    }

    /// Geometric mean energy (Fig. 12(d)).
    pub fn geomean_energy_j(&self) -> f64 {
        self.stats.geomean_of(self.stats.sum_ln_energy)
    }

    pub fn mean_stm_rate(&self) -> f64 {
        self.stats.mean_of(self.stats.sum_stm_rate)
    }

    pub fn mean_r_balance(&self) -> f64 {
        self.stats.mean_of(self.stats.sum_r_balance)
    }

    pub fn mean_ms_per_task(&self) -> f64 {
        self.stats.mean_of(self.stats.sum_ms_per_task)
    }

    pub fn mean_gvalue(&self) -> f64 {
        self.stats.mean_of(self.stats.sum_gvalue)
    }

    /// Mean per-trial interconnect delay (s) — 0.0 on monolithic rows.
    pub fn mean_comm_delay_s(&self) -> f64 {
        self.stats.mean_of(self.stats.sum_comm_delay)
    }

    /// Mean per-trial interconnect traffic (GB).
    pub fn mean_comm_gb(&self) -> f64 {
        self.stats.mean_of(self.stats.sum_comm_gb)
    }

    /// STMRate over safety-critical (Detection-tier) tasks only — the
    /// survival headline of a fault campaign.  1.0 when the row saw no
    /// safety tasks (nothing to miss).
    pub fn safety_stm_rate(&self) -> f64 {
        if self.stats.sum_safety_tasks == 0 {
            1.0
        } else {
            self.stats.sum_safety_met as f64 / self.stats.sum_safety_tasks as f64
        }
    }

    /// Fraction of tasks lost outright (`response = +inf`).
    pub fn lost_rate(&self) -> f64 {
        if self.stats.sum_tasks == 0 {
            0.0
        } else {
            self.stats.sum_lost_tasks as f64 / self.stats.sum_tasks as f64
        }
    }

    /// Trials that panicked instead of completing (outside `trials()`).
    pub fn failed_trials(&self) -> u64 {
        self.stats.failed_trials
    }

    /// Streaming response-time quantile (q in [0,1]); `+inf` when the
    /// rank falls among lost tasks.
    pub fn response_quantile_s(&self, q: f64) -> f64 {
        self.stats.response.quantile(q)
    }

    /// Streaming braking-distance quantile (q in [0,1]).
    pub fn braking_quantile_m(&self, q: f64) -> f64 {
        self.stats.braking.quantile(q)
    }
}

/// Aggregate of a whole sweep: rows in first-seen (trial-id) order.
#[derive(Debug, Clone, Default)]
pub struct SweepSummary {
    pub groups: Vec<SweepGroup>,
}

impl SweepSummary {
    pub fn new() -> SweepSummary {
        SweepSummary { groups: Vec::new() }
    }

    /// Stream one run into its group (creating the group on first sight —
    /// insertion order is trial-id order when fed sequentially).
    pub fn push(&mut self, key: SweepKey, run: RunSummary) {
        match self.groups.iter_mut().find(|g| g.key == key) {
            Some(g) => g.stats.push(&run),
            None => {
                let mut stats = GroupStats::new();
                stats.push(&run);
                self.groups.push(SweepGroup { key, stats });
            }
        }
    }

    /// Fold another summary in, group by group.  Commutative and
    /// associative on every fingerprint-relevant field (see the module
    /// docs for the f64-moment caveat) — the `fleet merge` primitive.
    pub fn merge(&mut self, other: &SweepSummary) {
        for g in &other.groups {
            match self.groups.iter_mut().find(|m| m.key == g.key) {
                Some(m) => m.stats.merge(&g.stats),
                None => self.groups.push(g.clone()),
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Total runs across all groups.
    pub fn total_runs(&self) -> usize {
        self.groups.iter().map(|g| g.trials()).sum()
    }

    /// Find a group by scheduler display name (first match).
    pub fn by_scheduler(&self, scheduler: &str) -> Option<&SweepGroup> {
        self.groups.iter().find(|g| g.key.scheduler == scheduler)
    }

    /// Bit-exact fingerprint over every deterministic field of every run,
    /// invariant under `--jobs`, shard partition and merge order: each
    /// group contributes `mix(fnv(key) · counts · content_hash)` to a
    /// wrapping sum, and each run's contribution to `content_hash` is
    /// itself a commutative wrapping sum.
    pub fn fingerprint(&self) -> u64 {
        let mut acc: u64 = 0;
        for g in &self.groups {
            let mut h: u64 = FNV_OFFSET;
            for b in g
                .key
                .scheduler
                .bytes()
                .chain(g.key.platform.bytes())
                .chain(g.key.scenario.bytes())
                .chain(g.key.area.bytes())
                .chain(g.key.deadline.bytes())
            {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
            for w in [g.stats.trials, g.stats.sum_tasks, g.stats.sum_tasks_met, g.stats.content_hash]
            {
                h ^= w;
                h = h.wrapping_mul(FNV_PRIME);
            }
            acc = acc.wrapping_add(mix(h));
        }
        mix(acc ^ FNV_OFFSET)
    }

    /// Report form: one object per row with the derived aggregates and
    /// the streaming tail percentiles.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.groups
                .iter()
                .map(|g| {
                    Json::from_pairs(vec![
                        ("scheduler", Json::Str(g.key.scheduler.clone())),
                        ("platform", Json::Str(g.key.platform.clone())),
                        ("scenario", Json::Str(g.key.scenario.clone())),
                        ("area", Json::Str(g.key.area.clone())),
                        ("deadline", Json::Str(g.key.deadline.clone())),
                        ("trials", Json::Num(g.trials() as f64)),
                        ("tasks", Json::Num(g.stats.sum_tasks as f64)),
                        ("tasks_met", Json::Num(g.stats.sum_tasks_met as f64)),
                        ("geomean_time_s", Json::Num(g.geomean_time_s())),
                        ("geomean_energy_j", Json::Num(g.geomean_energy_j())),
                        ("mean_stm_rate", Json::Num(g.mean_stm_rate())),
                        ("mean_r_balance", Json::Num(g.mean_r_balance())),
                        ("mean_ms_per_task", Json::Num(g.mean_ms_per_task())),
                        ("mean_gvalue", Json::Num(g.mean_gvalue())),
                        ("mean_comm_delay_s", Json::Num(g.mean_comm_delay_s())),
                        ("mean_comm_gb", Json::Num(g.mean_comm_gb())),
                        ("safety_tasks", Json::Num(g.stats.sum_safety_tasks as f64)),
                        ("safety_met", Json::Num(g.stats.sum_safety_met as f64)),
                        ("safety_stm_rate", Json::Num(g.safety_stm_rate())),
                        ("lost_tasks", Json::Num(g.stats.sum_lost_tasks as f64)),
                        ("failed_trials", Json::Num(g.failed_trials() as f64)),
                        ("p50_response_s", Json::Num(g.response_quantile_s(0.50))),
                        ("p99_response_s", Json::Num(g.response_quantile_s(0.99))),
                        ("p999_response_s", Json::Num(g.response_quantile_s(0.999))),
                        ("p50_braking_m", Json::Num(g.braking_quantile_m(0.50))),
                        ("p99_braking_m", Json::Num(g.braking_quantile_m(0.99))),
                        ("p999_braking_m", Json::Num(g.braking_quantile_m(0.999))),
                        ("content_hash", Json::Str(format!("{:016x}", g.stats.content_hash))),
                    ])
                })
                .collect(),
        )
    }

    /// Exact checkpoint form (see [`GroupStats::state_json`]); the inverse
    /// [`SweepSummary::from_state_json`] reproduces the in-memory summary
    /// bit-for-bit, fingerprint included.
    pub fn state_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", Json::Num(1.0)),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            Json::from_pairs(vec![
                                ("key", g.key.state_json()),
                                ("stats", g.stats.state_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_state_json(j: &Json) -> anyhow::Result<SweepSummary> {
        let version = j.get_f64("version").map_err(anyhow::Error::from)? as u64;
        anyhow::ensure!(version == 1, "unsupported summary state version {version}");
        let mut groups = Vec::new();
        for g in j.get_arr("groups")? {
            groups.push(SweepGroup {
                key: SweepKey::from_state_json(g.get("key")?)?,
                stats: GroupStats::from_state_json(g.get("stats")?)?,
            });
        }
        Ok(SweepSummary { groups })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;

    fn summary() -> RunSummary {
        let mut m = PlatformMetrics::new(2, NormScales::unit());
        m.per_accel[0].update(1.0, 2.0, 2.0, 1.0, 0.9);
        m.per_accel[1].update(1.0, 1.0, 1.0, -1.0, 0.6);
        RunSummary::from_metrics("test", "p", &m, 1, 0.5, 0.1, 1.5, 2.0)
    }

    /// A run with distinct content (energy bump + a few histogram samples).
    fn varied(bump: f64) -> RunSummary {
        let mut s = summary();
        s.energy_j += bump;
        s.response_hist.record(0.01 + bump * 1e-3);
        s.braking_hist.record(5.0 + bump);
        s
    }

    #[test]
    fn totals_compose() {
        let s = summary();
        assert_eq!(s.tasks, 2);
        assert!((s.compute_s - 3.0).abs() < 1e-12);
        assert!((s.total_time_s - (0.5 + 3.0 + 0.1)).abs() < 1e-12);
        assert!((s.stm_rate() - 0.5).abs() < 1e-12);
        assert!((s.ms_per_task() - 0.0).abs() < 1e-12);
    }

    fn key(sched: &str) -> SweepKey {
        SweepKey {
            scheduler: sched.to_string(),
            platform: "p".to_string(),
            scenario: "-".to_string(),
            area: "UB".to_string(),
            deadline: "rss".to_string(),
        }
    }

    #[test]
    fn scenario_splits_sweep_groups_and_fingerprints() {
        let mut a = SweepSummary::new();
        a.push(key("x"), summary());
        let mut b = SweepSummary::new();
        b.push(SweepKey { scenario: "night-rain".into(), ..key("x") }, summary());
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Different scenarios never merge into one row.
        let mut c = SweepSummary::new();
        c.push(key("x"), summary());
        c.push(SweepKey { scenario: "night-rain".into(), ..key("x") }, summary());
        assert_eq!(c.groups.len(), 2);
    }

    #[test]
    fn sweep_groups_by_key_in_insertion_order() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), summary());
        sw.push(key("b"), summary());
        sw.push(key("a"), summary());
        assert_eq!(sw.groups.len(), 2);
        assert_eq!(sw.total_runs(), 3);
        assert_eq!(sw.groups[0].key.scheduler, "a");
        assert_eq!(sw.by_scheduler("a").unwrap().trials(), 2);
        assert!(sw.by_scheduler("zzz").is_none());
    }

    #[test]
    fn fingerprint_ignores_wall_clock_but_not_results() {
        let mk = |sched_s: f64, energy_bump: f64| {
            let mut s = summary();
            s.sched_s = sched_s;
            s.total_time_s += sched_s;
            s.energy_j += energy_bump;
            let mut sw = SweepSummary::new();
            sw.push(key("a"), s);
            sw
        };
        assert_eq!(mk(0.1, 0.0).fingerprint(), mk(9.9, 0.0).fingerprint());
        assert_ne!(mk(0.1, 0.0).fingerprint(), mk(0.1, 1.0).fingerprint());
    }

    #[test]
    fn fingerprint_sees_histogram_content() {
        let mk = |sample: f64| {
            let mut s = summary();
            s.response_hist.record(sample);
            let mut sw = SweepSummary::new();
            sw.push(key("a"), s);
            sw
        };
        assert_ne!(mk(0.01).fingerprint(), mk(10.0).fingerprint());
    }

    #[test]
    fn sweep_aggregates_match_hand_math() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), summary());
        sw.push(key("a"), summary());
        let g = sw.by_scheduler("a").unwrap();
        let s = summary();
        assert!((g.geomean_time_s() - s.work_time_s()).abs() < 1e-9);
        assert!((g.mean_stm_rate() - s.stm_rate()).abs() < 1e-12);
        assert!((g.geomean_energy_j() - s.energy_j).abs() < 1e-9);
        // JSON renders one row with the aggregates and percentiles.
        let j = sw.to_json().to_string();
        assert!(j.contains("geomean_time_s"));
        assert!(j.contains("p99_response_s"));
    }

    #[test]
    fn json_roundtrip_fields() {
        let s = summary();
        let j = s.to_json();
        let o = j.as_obj().unwrap();
        assert_eq!(o.get("scheduler").unwrap().as_str(), Some("test"));
        assert!((o.get("stm_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        // Render + parse back.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!((parsed.as_obj().unwrap().get("energy_j").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        // Three partial summaries over disjoint "trial" sets, with two
        // groups interleaved differently in each part.
        let part = |bumps: &[(f64, &str)]| {
            let mut sw = SweepSummary::new();
            for &(b, k) in bumps {
                sw.push(key(k), varied(b));
            }
            sw
        };
        let a = part(&[(1.0, "x"), (2.0, "y")]);
        let b = part(&[(3.0, "y"), (4.0, "x"), (5.0, "x")]);
        let c = part(&[(6.0, "y")]);

        let fold = |parts: &[&SweepSummary]| {
            let mut m = SweepSummary::new();
            for p in parts {
                m.merge(p);
            }
            m
        };
        let abc = fold(&[&a, &b, &c]);
        let cba = fold(&[&c, &b, &a]);
        let bac = fold(&[&b, &a, &c]);
        // (a·b)·c == a·(b·c)
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);

        let f = abc.fingerprint();
        for (name, m) in [("cba", &cba), ("bac", &bac), ("(ab)c", &ab_c), ("a(bc)", &a_bc)] {
            assert_eq!(m.fingerprint(), f, "merge order {name} drifted");
            assert_eq!(m.total_runs(), 6, "{name}");
        }
        // And the monolithic push order agrees.
        let mono = part(&[(1.0, "x"), (2.0, "y"), (3.0, "y"), (4.0, "x"), (5.0, "x"), (6.0, "y")]);
        assert_eq!(mono.fingerprint(), f, "merged != monolithic");
        // Integer moments agree exactly with the monolithic fold.
        for (gm, gg) in mono.groups.iter().zip(&abc.groups) {
            assert_eq!(gm.key, gg.key);
            assert_eq!(gm.stats.trials, gg.stats.trials);
            assert_eq!(gm.stats.sum_tasks, gg.stats.sum_tasks);
            assert_eq!(gm.stats.content_hash, gg.stats.content_hash);
            assert_eq!(gm.stats.response, gg.stats.response);
            assert_eq!(gm.stats.braking, gg.stats.braking);
        }
    }

    #[test]
    fn state_json_roundtrip_preserves_fingerprint() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), varied(0.25));
        sw.push(key("b"), varied(1.75));
        sw.push(key("a"), varied(3.5));
        let text = sw.state_json().to_pretty();
        let back = SweepSummary::from_state_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), sw.fingerprint());
        assert_eq!(back.total_runs(), sw.total_runs());
        for (x, y) in sw.groups.iter().zip(&back.groups) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.stats.sum_ln_time.to_bits(), y.stats.sum_ln_time.to_bits());
            assert_eq!(x.stats.sum_gvalue.to_bits(), y.stats.sum_gvalue.to_bits());
            assert_eq!(x.stats.response, y.stats.response);
        }
    }

    #[test]
    fn comm_fields_flow_into_groups_and_fingerprints() {
        let mk = |d: f64| {
            let mut s = summary();
            s.comm_delay_s = d;
            s.comm_gb = d * 2.0;
            let mut sw = SweepSummary::new();
            sw.push(key("a"), s);
            sw
        };
        let (a, b) = (mk(0.0), mk(0.5));
        // Interconnect delay is a result, not wall clock: it fingerprints.
        assert_ne!(a.fingerprint(), b.fingerprint());
        let g = b.by_scheduler("a").unwrap();
        assert!((g.mean_comm_delay_s() - 0.5).abs() < 1e-12);
        assert!((g.mean_comm_gb() - 1.0).abs() < 1e-12);
        let back =
            SweepSummary::from_state_json(&Json::parse(&b.state_json().to_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.groups[0].stats.sum_comm_delay.to_bits(), 0.5f64.to_bits());
        assert!(b.to_json().to_string().contains("mean_comm_delay_s"));
    }

    #[test]
    fn pre_interconnect_checkpoints_still_parse() {
        // A checkpoint written before the comm sums existed lacks the two
        // `sum_comm_*_bits` keys; it must load with zeroed comm moments and
        // an unchanged fingerprint (the f64 sums never fingerprint).
        let mut sw = SweepSummary::new();
        sw.push(key("a"), varied(1.0));
        let text = sw.state_json().to_pretty();
        let old: String =
            text.lines().filter(|l| !l.contains("sum_comm")).collect::<Vec<_>>().join("\n");
        let back = SweepSummary::from_state_json(&Json::parse(&old).unwrap()).unwrap();
        assert_eq!(back.groups[0].stats.sum_comm_delay, 0.0);
        assert_eq!(back.fingerprint(), sw.fingerprint());
    }

    #[test]
    fn survival_counters_are_report_only() {
        let mk = |met: u64| {
            let mut s = summary();
            s.safety_tasks = 2;
            s.safety_met = met;
            s.lost_tasks = 1;
            let mut sw = SweepSummary::new();
            sw.push(key("a"), s);
            sw
        };
        let (a, b) = (mk(1), mk(2));
        // Survival counters never fingerprint (pre-faults bit-identity).
        assert_eq!(a.fingerprint(), b.fingerprint());
        let g = a.by_scheduler("a").unwrap();
        assert!((g.safety_stm_rate() - 0.5).abs() < 1e-12);
        assert!((g.lost_rate() - 0.5).abs() < 1e-12);
        // No safety tasks at all: nothing missed.
        let empty = SweepSummary::new();
        assert!(empty.groups.is_empty());
        let plain = {
            let mut sw = SweepSummary::new();
            sw.push(key("a"), summary());
            sw
        };
        assert_eq!(plain.by_scheduler("a").unwrap().safety_stm_rate(), 1.0);
        // Counters survive checkpoint roundtrips and appear in reports.
        let back =
            SweepSummary::from_state_json(&Json::parse(&a.state_json().to_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.groups[0].stats.sum_safety_tasks, 2);
        assert_eq!(back.groups[0].stats.sum_lost_tasks, 1);
        assert!(a.to_json().to_string().contains("safety_stm_rate"));
    }

    #[test]
    fn failed_runs_count_separately_and_never_fingerprint() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), varied(1.0));
        let f = sw.fingerprint();
        sw.push(key("a"), RunSummary::failed("a".into(), "p".into()));
        assert_eq!(sw.fingerprint(), f, "failed trials are outside the fingerprint");
        let g = sw.by_scheduler("a").unwrap();
        assert_eq!(g.failed_trials(), 1);
        assert_eq!(g.trials(), 1, "failed runs are not completed trials");
        // Merge carries the counter; checkpoints roundtrip it and old
        // checkpoints without the key load as zero.
        let mut m = SweepSummary::new();
        m.merge(&sw);
        m.merge(&sw);
        assert_eq!(m.by_scheduler("a").unwrap().failed_trials(), 2);
        let back =
            SweepSummary::from_state_json(&Json::parse(&sw.state_json().to_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.groups[0].stats.failed_trials, 1);
        assert_eq!(back.fingerprint(), sw.fingerprint());
        let stripped: String = sw
            .state_json()
            .to_pretty()
            .lines()
            .filter(|l| !l.contains("failed_trials"))
            .collect::<Vec<_>>()
            .join("\n");
        let old = SweepSummary::from_state_json(&Json::parse(&stripped).unwrap()).unwrap();
        assert_eq!(old.groups[0].stats.failed_trials, 0);
        assert_eq!(old.fingerprint(), sw.fingerprint());
    }

    #[test]
    fn empty_merge_is_identity() {
        let mut sw = SweepSummary::new();
        sw.push(key("a"), varied(1.0));
        let f = sw.fingerprint();
        sw.merge(&SweepSummary::new());
        assert_eq!(sw.fingerprint(), f);
        let mut e = SweepSummary::new();
        e.merge(&sw);
        assert_eq!(e.fingerprint(), f);
    }
}
