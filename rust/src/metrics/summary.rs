//! Per-run summary: the quantities Figures 12-14 report, aggregated from a
//! simulation's task records.

use crate::util::json::Json;

use super::{stm_rate, PlatformMetrics};

/// Aggregate results of scheduling one task queue on one platform with one
/// scheduler — the row unit of Figures 12 and 13.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub scheduler: String,
    pub platform: String,
    pub tasks: u64,
    /// Tasks whose response time met their safety time.
    pub tasks_met: u64,
    /// Total energy E (J).
    pub energy_j: f64,
    /// Makespan T = max accelerator busy time (s).
    pub makespan_s: f64,
    /// Figure 12(a) "time": Σ(wait + execute) over tasks + scheduler
    /// runtime (s).
    pub total_time_s: f64,
    /// Σ waiting time over tasks (s).
    pub wait_s: f64,
    /// Σ execution time over tasks (s).
    pub compute_s: f64,
    /// Measured scheduler runtime (wall clock, s).
    pub sched_s: f64,
    pub r_balance: f64,
    pub ms_total: f64,
    pub gvalue: f64,
    /// Mean response time (s).
    pub mean_response_s: f64,
    /// Max response time (s).
    pub max_response_s: f64,
}

impl RunSummary {
    pub fn from_metrics(
        scheduler: &str,
        platform: &str,
        m: &PlatformMetrics,
        tasks_met: u64,
        wait_s: f64,
        sched_s: f64,
        mean_response_s: f64,
        max_response_s: f64,
    ) -> RunSummary {
        let compute_s: f64 = m.per_accel.iter().map(|a| a.busy_s).sum();
        RunSummary {
            scheduler: scheduler.to_string(),
            platform: platform.to_string(),
            tasks: m.total_tasks(),
            tasks_met,
            energy_j: m.energy_j(),
            makespan_s: m.makespan_s(),
            total_time_s: wait_s + compute_s + sched_s,
            wait_s,
            compute_s,
            sched_s,
            r_balance: m.r_balance(),
            ms_total: m.ms_total(),
            gvalue: m.gvalue(),
            mean_response_s,
            max_response_s,
        }
    }

    /// STMRate (§8.4).
    pub fn stm_rate(&self) -> f64 {
        stm_rate(self.tasks_met, self.tasks)
    }

    /// Mean MS per task (comparable across queue lengths).
    pub fn ms_per_task(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.ms_total / self.tasks as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("platform", Json::Str(self.platform.clone())),
            ("tasks", Json::Num(self.tasks as f64)),
            ("tasks_met", Json::Num(self.tasks_met as f64)),
            ("stm_rate", Json::Num(self.stm_rate())),
            ("energy_j", Json::Num(self.energy_j)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("total_time_s", Json::Num(self.total_time_s)),
            ("wait_s", Json::Num(self.wait_s)),
            ("compute_s", Json::Num(self.compute_s)),
            ("sched_s", Json::Num(self.sched_s)),
            ("r_balance", Json::Num(self.r_balance)),
            ("ms_total", Json::Num(self.ms_total)),
            ("gvalue", Json::Num(self.gvalue)),
            ("mean_response_s", Json::Num(self.mean_response_s)),
            ("max_response_s", Json::Num(self.max_response_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;

    fn summary() -> RunSummary {
        let mut m = PlatformMetrics::new(2, NormScales::unit());
        m.per_accel[0].update(1.0, 2.0, 2.0, 1.0, 0.9);
        m.per_accel[1].update(1.0, 1.0, 1.0, -1.0, 0.6);
        RunSummary::from_metrics("test", "p", &m, 1, 0.5, 0.1, 1.5, 2.0)
    }

    #[test]
    fn totals_compose() {
        let s = summary();
        assert_eq!(s.tasks, 2);
        assert!((s.compute_s - 3.0).abs() < 1e-12);
        assert!((s.total_time_s - (0.5 + 3.0 + 0.1)).abs() < 1e-12);
        assert!((s.stm_rate() - 0.5).abs() < 1e-12);
        assert!((s.ms_per_task() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_fields() {
        let s = summary();
        let j = s.to_json();
        let o = j.as_obj().unwrap();
        assert_eq!(o.get("scheduler").unwrap().as_str(), Some("test"));
        assert!((o.get("stm_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        // Render + parse back.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!((parsed.as_obj().unwrap().get("energy_j").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
    }
}
