//! Task-queue construction (§8.1, Fig. 9): every camera emits frames at its
//! Camera_HZ(area, scenario, group) rate along the route; each frame yields
//! one detection task (YOLO and SSD alternating per camera, §2.1/§8.1) and —
//! where tracking applies — one GOTURN tracking task.  Tasks carry the
//! Task-Info triple the RL agent consumes: Amount, LayerNum, safety time.

use super::camera_hz::camera_hz;
use super::route::Route;
use super::scenario::CameraProfile;
use super::{CameraGroup, Scenario, ALL_GROUPS};
use crate::safety::ms::TaskCategory;
use crate::safety::rss::safety_time;
use crate::workload::{model, ModelKind};

/// One CNN task released by a camera frame.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: u32,
    pub group: CameraGroup,
    /// Camera index within its group.
    pub cam_idx: u8,
    /// Release (frame arrival) time, seconds from route start.
    pub release_s: f64,
    pub model: ModelKind,
    pub category: TaskCategory,
    /// Scenario active when the frame was captured.
    pub scenario: Scenario,
    /// Maximum allowed response time (RSS-derived, §6.1).
    pub safety_time_s: f64,
}

impl Task {
    /// Task-Info "Amount": computation amount in GMACs (§7.1).
    pub fn amount_gmacs(&self) -> f64 {
        model(self.model).gmacs()
    }

    /// Task-Info "LayerNum" (§7.1).
    pub fn layer_num(&self) -> usize {
        model(self.model).num_layers()
    }

    /// Absolute deadline on the route clock.
    pub fn deadline_s(&self) -> f64 {
        self.release_s + self.safety_time_s
    }
}

/// A task queue: all tasks of one driving route, sorted by release time.
#[derive(Debug, Clone)]
pub struct TaskQueue {
    pub tasks: Vec<Task>,
    pub route_duration_s: f64,
}

impl TaskQueue {
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

/// Deadline regime for task safety times.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeadlineMode {
    /// RSS-derived safety time (§6.1) — the paper's stated model.
    Rss,
    /// Real-time regime: the RSS bound additionally capped at two frame
    /// periods of the emitting camera — a task that takes longer than
    /// ~2 frames to answer stalls the sustained pipeline even when RSS
    /// still tolerates it.  This is the regime under which the paper's
    /// Fig. 13 baseline spread (heuristics 21% / GA 34% / SA 51%)
    /// becomes visible; pure-RSS deadlines are loose enough that every
    /// load-balancing scheduler meets them on HMAI.
    FrameBudget,
}

impl DeadlineMode {
    pub fn name(&self) -> &'static str {
        match self {
            DeadlineMode::Rss => "rss",
            DeadlineMode::FrameBudget => "frame",
        }
    }

    pub fn parse(s: &str) -> Option<DeadlineMode> {
        match s.to_ascii_lowercase().as_str() {
            "rss" => Some(DeadlineMode::Rss),
            "frame" | "frame-budget" | "framebudget" => Some(DeadlineMode::FrameBudget),
            _ => None,
        }
    }
}

/// Generate the task queue for a route (Fig. 9) under the default RSS
/// deadline regime.
pub fn generate(route: &Route) -> TaskQueue {
    generate_with_deadline(route, DeadlineMode::Rss)
}

/// Generate the task queue for a route with an explicit deadline regime.
pub fn generate_with_deadline(route: &Route, mode: DeadlineMode) -> TaskQueue {
    generate_with_profile(route, mode, CameraProfile::default())
}

/// Generate with an explicit camera profile (scenario library): the rig
/// sets cameras per group (12/20/30-camera vehicles, §7) and `hz_scale`
/// uniformly degrades frame rates (night-rain).  The default profile is
/// bit-identical to `generate_with_deadline` — the frame-clock walk,
/// YOLO/SSD alternation and deadline rules are unchanged.
pub fn generate_with_profile(
    route: &Route,
    mode: DeadlineMode,
    profile: CameraProfile,
) -> TaskQueue {
    let area = route.params.area;
    let mut tasks: Vec<Task> = Vec::new();
    let mut id: u32 = 0;

    for group in ALL_GROUPS {
        for cam_idx in 0..profile.rig.count(group) as u8 {
            // Walk this camera's frame clock through the route, re-sampling
            // the rate whenever the scenario changes.
            let mut t = 0.0_f64;
            // Alternate YOLO / SSD per camera frame (§8.1: "we alternately
            // use YOLO and SSD to process the DET tasks for each camera").
            let mut det_flip = (cam_idx as u32) % 2 == 0;
            while t < route.duration_s {
                let scenario = route.scenario_at(t);
                let hz = camera_hz(area, scenario, group) * profile.hz_scale;
                if hz <= 0.0 {
                    // Camera idle in this scenario: skip to next segment.
                    let seg_end = route
                        .segments
                        .iter()
                        .find(|s| t >= s.start_s && t < s.end_s())
                        .map(|s| s.end_s())
                        .unwrap_or(route.duration_s);
                    t = seg_end.max(t + 1e-3);
                    continue;
                }
                let det_model = if det_flip { ModelKind::Yolo } else { ModelKind::Ssd };
                det_flip = !det_flip;
                let st = match mode {
                    DeadlineMode::Rss => safety_time(area, scenario, group),
                    DeadlineMode::FrameBudget => {
                        safety_time(area, scenario, group).min(2.0 / hz)
                    }
                };
                tasks.push(Task {
                    id,
                    group,
                    cam_idx,
                    release_s: t,
                    model: det_model,
                    category: TaskCategory::Detection,
                    scenario,
                    safety_time_s: st,
                });
                id += 1;
                if group.tracks_in(scenario) {
                    tasks.push(Task {
                        id,
                        group,
                        cam_idx,
                        release_s: t,
                        model: ModelKind::Goturn,
                        category: TaskCategory::Tracking,
                        scenario,
                        safety_time_s: st,
                    });
                    id += 1;
                }
                t += 1.0 / hz;
            }
        }
    }

    // Release order; ties broken by id for determinism.
    tasks.sort_by(|a, b| a.release_s.total_cmp(&b.release_s).then(a.id.cmp(&b.id)));
    TaskQueue { tasks, route_duration_s: route.duration_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::route::RouteParams;
    use crate::env::Area;
    use crate::util::rng::Rng;

    fn queue(area: Area, dist: f64, seed: u64) -> TaskQueue {
        let route = Route::generate(RouteParams::for_area(area, dist), &mut Rng::new(seed));
        generate(&route)
    }

    #[test]
    fn sorted_by_release() {
        let q = queue(Area::Urban, 200.0, 1);
        assert!(q.tasks.windows(2).all(|w| w[0].release_s <= w[1].release_s));
    }

    #[test]
    fn task_rate_matches_table5() {
        // A pure go-straight route in UB must produce ~(870 + 840) tasks/s.
        let mut r = Route::generate(RouteParams::for_area(Area::Urban, 500.0), &mut Rng::new(2));
        // Force go-straight everywhere.
        r.segments = vec![super::super::route::Segment {
            scenario: Scenario::GoStraight,
            start_s: 0.0,
            duration_s: r.duration_s,
        }];
        let q = generate(&r);
        let rate = q.len() as f64 / r.duration_s;
        assert!((rate / 1710.0 - 1.0).abs() < 0.05, "rate = {rate}");
    }

    #[test]
    fn detection_alternates_yolo_ssd() {
        let q = queue(Area::Urban, 100.0, 3);
        // Per camera, consecutive DET tasks alternate models.
        let dets: Vec<&Task> = q
            .tasks
            .iter()
            .filter(|t| {
                t.category == TaskCategory::Detection
                    && t.group == CameraGroup::Fc
                    && t.cam_idx == 0
            })
            .collect();
        assert!(dets.len() > 4);
        for w in dets.windows(2) {
            assert_ne!(w[0].model, w[1].model);
        }
    }

    #[test]
    fn yolo_ssd_split_is_even() {
        let q = queue(Area::Urban, 300.0, 4);
        let yolo = q.tasks.iter().filter(|t| t.model == ModelKind::Yolo).count() as f64;
        let ssd = q.tasks.iter().filter(|t| t.model == ModelKind::Ssd).count() as f64;
        assert!((yolo / ssd - 1.0).abs() < 0.05, "yolo={yolo} ssd={ssd}");
    }

    #[test]
    fn rear_cameras_track_only_in_reverse() {
        let q = queue(Area::Urban, 1000.0, 5);
        for t in &q.tasks {
            if t.group == CameraGroup::Rc && t.category == TaskCategory::Tracking {
                assert_eq!(t.scenario, Scenario::Reverse);
            }
        }
    }

    #[test]
    fn tasks_carry_rss_safety_times() {
        let q = queue(Area::Urban, 100.0, 6);
        for t in &q.tasks {
            assert!(t.safety_time_s > 0.0);
            assert_eq!(
                t.safety_time_s,
                safety_time(Area::Urban, t.scenario, t.group)
            );
        }
    }

    #[test]
    fn task_info_fields() {
        let q = queue(Area::Urban, 50.0, 7);
        let t = &q.tasks[0];
        assert!(t.amount_gmacs() > 1.0);
        assert!(t.layer_num() >= 11);
    }

    #[test]
    fn profile_rig_and_rate_scale_apply() {
        use crate::env::scenario::{CameraProfile, CameraRig};
        let route = Route::generate(RouteParams::for_area(Area::Urban, 150.0), &mut Rng::new(9));
        let full = generate_with_profile(&route, DeadlineMode::Rss, CameraProfile::default());
        let small = generate_with_profile(
            &route,
            DeadlineMode::Rss,
            CameraProfile { rig: CameraRig::min12(), hz_scale: 1.0 },
        );
        assert!(small.len() < full.len() / 2, "{} vs {}", small.len(), full.len());
        let slow = generate_with_profile(
            &route,
            DeadlineMode::Rss,
            CameraProfile { rig: CameraRig::full30(), hz_scale: 0.5 },
        );
        let ratio = slow.len() as f64 / full.len() as f64;
        assert!((0.4..0.62).contains(&ratio), "ratio = {ratio}");
        // Frame-budget deadlines see the degraded rate (longer budget).
        let fb_full = generate_with_profile(&route, DeadlineMode::FrameBudget, CameraProfile::default());
        let fb_slow = generate_with_profile(
            &route,
            DeadlineMode::FrameBudget,
            CameraProfile { rig: CameraRig::full30(), hz_scale: 0.5 },
        );
        let min_st = |q: &TaskQueue| {
            q.tasks.iter().map(|t| t.safety_time_s).fold(f64::INFINITY, f64::min)
        };
        assert!(min_st(&fb_slow) >= min_st(&fb_full));
    }

    #[test]
    fn km_scale_queue_size() {
        // §8.3: a 1-2 km route yields a task queue in the tens of thousands.
        let q = queue(Area::Urban, 1000.0, 8);
        assert!(q.len() > 30_000, "len = {}", q.len());
        assert!(q.len() < 150_000, "len = {}", q.len());
    }
}
