//! Driving-route generation (§8.1, Table 12/13, Fig. 9): a route through one
//! area with randomly placed turn / reverse segments, giving the scenario
//! timeline that modulates every camera's frame rate.

use super::{Area, Scenario};
use crate::util::rng::Rng;

/// Generation parameters — Table 12 (parameters) with Table 13 defaults.
#[derive(Debug, Clone)]
pub struct RouteParams {
    pub area: Area,
    /// Route length in meters (§8.2/8.3: 1-2 km).
    pub distance_m: f64,
    /// Cruise velocity in m/s (§8.3: 60/80/120 km/h by area).
    pub velocity_ms: f64,
    /// Maximum number of turn segments (Table 13: 10).
    pub max_times_turn: usize,
    /// Maximum number of reverse segments (Table 13: 10).
    pub max_times_reverse: usize,
    /// Longest single turn, seconds (Table 13: 10).
    pub max_duration_turn: f64,
    /// Longest single reverse, seconds (Table 13: 20).
    pub max_duration_reverse: f64,
}

impl RouteParams {
    /// Paper defaults for an area (velocity from §8.3, limits from Table 13).
    pub fn for_area(area: Area, distance_m: f64) -> Self {
        Self {
            area,
            distance_m,
            velocity_ms: area.max_velocity_ms(),
            max_times_turn: 10,
            max_times_reverse: 10,
            max_duration_turn: 10.0,
            max_duration_reverse: 20.0,
        }
    }
}

/// One scenario segment on the timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub scenario: Scenario,
    pub start_s: f64,
    pub duration_s: f64,
}

impl Segment {
    pub fn end_s(&self) -> f64 {
        self.start_s + self.duration_s
    }
}

/// A generated route: contiguous scenario segments covering [0, duration].
#[derive(Debug, Clone)]
pub struct Route {
    pub params: RouteParams,
    pub duration_s: f64,
    /// Sorted, non-overlapping, covering the whole duration.
    pub segments: Vec<Segment>,
}

impl Route {
    /// Generate a route: pick turn/reverse counts and durations at random
    /// (Fig. 9: "the start time and lasting time of each scenario is
    /// randomly determined"), fill the gaps with go-straight.
    pub fn generate(params: RouteParams, rng: &mut Rng) -> Route {
        let duration_s = params.distance_m / params.velocity_ms;
        let mut events: Vec<Segment> = Vec::new();

        // Effective caps scale with the configured maxima (3/5 of max
        // turns, 3/10 of max reverses): Table 13 defaults (10 / 10) keep
        // the seed repo's effective caps (6 turns, 3 reverses), so legacy
        // routes are bit-identical, while scenario-library overrides
        // (env::scenario `turn_scale` / `reverse_scale`) can raise or
        // lower the density.
        let n_turns = rng.int_range(0, params.max_times_turn * 3 / 5);
        let n_revs = if params.area.allows_reverse() {
            rng.int_range(0, params.max_times_reverse * 3 / 10)
        } else {
            0
        };
        let place = |scenario: Scenario, max_dur: f64, rng: &mut Rng, events: &mut Vec<Segment>| {
            // Up to a few attempts to find a non-overlapping slot.
            for _ in 0..16 {
                let dur = rng.range_f64(1.0, max_dur).min(duration_s * 0.2);
                let start = rng.range_f64(0.0, (duration_s - dur).max(0.0));
                let cand = Segment { scenario, start_s: start, duration_s: dur };
                let overlaps = events
                    .iter()
                    .any(|e| cand.start_s < e.end_s() && e.start_s < cand.end_s());
                if !overlaps {
                    events.push(cand);
                    return;
                }
            }
        };
        for _ in 0..n_turns {
            place(Scenario::Turn, params.max_duration_turn, rng, &mut events);
        }
        for _ in 0..n_revs {
            place(Scenario::Reverse, params.max_duration_reverse, rng, &mut events);
        }
        events.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));

        // Fill gaps with go-straight to cover [0, duration].
        let mut segments = Vec::new();
        let mut t = 0.0;
        for e in events {
            if e.start_s > t + 1e-9 {
                segments.push(Segment {
                    scenario: Scenario::GoStraight,
                    start_s: t,
                    duration_s: e.start_s - t,
                });
            }
            t = e.end_s();
            segments.push(e);
        }
        if t < duration_s - 1e-9 {
            segments.push(Segment {
                scenario: Scenario::GoStraight,
                start_s: t,
                duration_s: duration_s - t,
            });
        }
        Route { params, duration_s, segments }
    }

    /// Scenario active at time `t`.
    pub fn scenario_at(&self, t: f64) -> Scenario {
        self.segments
            .iter()
            .find(|s| t >= s.start_s && t < s.end_s())
            .map(|s| s.scenario)
            .unwrap_or(Scenario::GoStraight)
    }

    /// Vehicle velocity at time `t` (cruise speed capped by the scenario).
    pub fn velocity_at(&self, t: f64) -> f64 {
        self.params
            .velocity_ms
            .min(self.scenario_at(t).velocity_cap_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(area: Area, seed: u64) -> Route {
        Route::generate(RouteParams::for_area(area, 1000.0), &mut Rng::new(seed))
    }

    #[test]
    fn covers_full_duration() {
        for seed in 0..20 {
            let r = mk(Area::Urban, seed);
            let mut t = 0.0;
            for s in &r.segments {
                assert!((s.start_s - t).abs() < 1e-6, "gap at {t}");
                assert!(s.duration_s > 0.0);
                t = s.end_s();
            }
            assert!((t - r.duration_s).abs() < 1e-6);
        }
    }

    #[test]
    fn no_reverse_on_highway() {
        for seed in 0..20 {
            let r = mk(Area::Highway, seed);
            assert!(r.segments.iter().all(|s| s.scenario != Scenario::Reverse));
        }
    }

    #[test]
    fn urban_routes_have_variety() {
        // Across seeds, urban routes include turns and reverses.
        let mut saw_turn = false;
        let mut saw_rev = false;
        for seed in 0..30 {
            let r = mk(Area::Urban, seed);
            saw_turn |= r.segments.iter().any(|s| s.scenario == Scenario::Turn);
            saw_rev |= r.segments.iter().any(|s| s.scenario == Scenario::Reverse);
        }
        assert!(saw_turn && saw_rev);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = mk(Area::Urban, 7);
        let b = mk(Area::Urban, 7);
        assert_eq!(a.segments, b.segments);
    }

    #[test]
    fn scenario_lookup() {
        let r = mk(Area::Urban, 3);
        assert_eq!(r.scenario_at(-1.0), Scenario::GoStraight); // out of range
        for s in &r.segments {
            let mid = s.start_s + s.duration_s / 2.0;
            assert_eq!(r.scenario_at(mid), s.scenario);
        }
    }

    #[test]
    fn turn_velocity_capped() {
        let r = mk(Area::Highway, 11);
        if let Some(s) = r.segments.iter().find(|s| s.scenario == Scenario::Turn) {
            let v = r.velocity_at(s.start_s + 0.5 * s.duration_s);
            assert!(v <= 50.0 / 3.6 + 1e-9);
        }
    }

    #[test]
    fn duration_matches_distance() {
        let r = mk(Area::Urban, 1);
        assert!((r.duration_s - 1000.0 / (60.0 / 3.6)).abs() < 1e-6);
    }
}
