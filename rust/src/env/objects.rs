//! Object projection model (Table 2): how a vehicle's / pedestrian's pixel
//! area changes with distance, and which detector the §2.1 rule picks.
//!
//! A pinhole camera projects a physical cross-section of area A at distance
//! d to A·(f/d)² pixels.  We anchor each object class at the paper's
//! near-distance datum (vehicle: 42 000 px @ 17.98 m; pedestrian: 42 000 px
//! @ 15.48 m).  NOTE: the paper's far-distance rows (4 620 px @ 163 m) are
//! linear rather than quadratic in distance — a physical impossibility we
//! treat as a typo; `table2_rows()` reports both the paper's figures and
//! the pinhole-model values (see EXPERIMENTS.md).

use crate::workload::accuracy::ObjectSize;

/// Image geometry used by the paper (§2.1): 640 x 480.
pub const IMAGE_W: f64 = 640.0;
pub const IMAGE_H: f64 = 480.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectClass {
    Vehicle,
    Pedestrian,
}

impl ObjectClass {
    pub fn name(&self) -> &'static str {
        match self {
            ObjectClass::Vehicle => "Vehicle",
            ObjectClass::Pedestrian => "Pedestrian",
        }
    }

    /// Anchor datum from Table 2: (area_px, distance_m).
    fn anchor(&self) -> (f64, f64) {
        match self {
            ObjectClass::Vehicle => (42_000.0, 17.98),
            ObjectClass::Pedestrian => (42_000.0, 15.48),
        }
    }
}

/// Projected pixel area at distance `d` meters (pinhole model).
pub fn area_px(class: ObjectClass, d: f64) -> f64 {
    let (a0, d0) = class.anchor();
    a0 * (d0 / d) * (d0 / d)
}

/// Fraction of the image the object covers.
pub fn area_fraction(class: ObjectClass, d: f64) -> f64 {
    area_px(class, d) / (IMAGE_W * IMAGE_H)
}

/// COCO size class of the object at distance `d`.
pub fn size_at(class: ObjectClass, d: f64) -> ObjectSize {
    ObjectSize::from_area_px(area_px(class, d))
}

/// Distance beyond which the object becomes "small" (area < 32^2 px).
pub fn small_threshold_m(class: ObjectClass) -> f64 {
    let (a0, d0) = class.anchor();
    d0 * (a0 / (32.0 * 32.0)).sqrt()
}

/// A Table 2 row: paper figures + our pinhole-model values.
pub struct Table2Row {
    pub class: ObjectClass,
    pub distance_m: f64,
    pub paper_area_px: f64,
    pub model_area_px: f64,
}

pub fn table2_rows() -> Vec<Table2Row> {
    let rows = [
        (ObjectClass::Vehicle, 163.0, 4620.0),
        (ObjectClass::Vehicle, 17.98, 42_000.0),
        (ObjectClass::Pedestrian, 140.0, 4620.0),
        (ObjectClass::Pedestrian, 15.48, 42_000.0),
    ];
    rows.iter()
        .map(|&(class, d, paper)| Table2Row {
            class,
            distance_m: d,
            paper_area_px: paper,
            model_area_px: area_px(class, d),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reproduce() {
        assert!((area_px(ObjectClass::Vehicle, 17.98) - 42_000.0).abs() < 1.0);
        assert!((area_px(ObjectClass::Pedestrian, 15.48) - 42_000.0).abs() < 1.0);
    }

    #[test]
    fn near_objects_are_large() {
        // Table 2: 42 000 px (~3% of image) at 17.98 m is a large object.
        assert_eq!(size_at(ObjectClass::Vehicle, 17.98), ObjectSize::Large);
        assert!((area_fraction(ObjectClass::Vehicle, 17.98) - 0.137).abs() < 0.01);
    }

    #[test]
    fn far_objects_are_small() {
        // §2.1: at 163 m the vehicle is processed as a small object.
        assert_eq!(size_at(ObjectClass::Vehicle, 163.0), ObjectSize::Small);
        assert_eq!(size_at(ObjectClass::Pedestrian, 140.0), ObjectSize::Small);
    }

    #[test]
    fn area_monotonically_decreasing() {
        let mut last = f64::INFINITY;
        for d in [10.0, 20.0, 50.0, 100.0, 200.0] {
            let a = area_px(ObjectClass::Vehicle, d);
            assert!(a < last);
            last = a;
        }
    }

    #[test]
    fn small_threshold_within_camera_range() {
        // The transition to "small" must happen inside the 20..200 m camera
        // vision band (§2.1) — that is what forces heterogeneous CNNs.
        let t = small_threshold_m(ObjectClass::Vehicle);
        assert!((20.0..200.0).contains(&t), "threshold = {t}");
    }
}
