//! Dynamic driving environment (§2, §8.1): areas, scenarios, camera groups,
//! per-group frame-rate tables, object projection, route generation and
//! task-queue construction.

pub mod camera_hz;
pub mod objects;
pub mod route;
pub mod scenario;
pub mod taskgen;

/// Driving area (§2.2): urban, undivided-highway, highway.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Area {
    Urban,
    UndividedHighway,
    Highway,
}

pub const ALL_AREAS: [Area; 3] = [Area::Urban, Area::UndividedHighway, Area::Highway];

impl Area {
    pub fn name(&self) -> &'static str {
        match self {
            Area::Urban => "UB",
            Area::UndividedHighway => "UHW",
            Area::Highway => "HW",
        }
    }

    pub fn parse(s: &str) -> Option<Area> {
        match s.to_ascii_lowercase().as_str() {
            "ub" | "urban" => Some(Area::Urban),
            "uhw" | "undivided-highway" | "undivided" => Some(Area::UndividedHighway),
            "hw" | "highway" => Some(Area::Highway),
            _ => None,
        }
    }

    /// Maximum velocity allowed (§6.1: 60 / 80 / 120 km/h), in m/s.
    pub fn max_velocity_ms(&self) -> f64 {
        match self {
            Area::Urban => 60.0 / 3.6,
            Area::UndividedHighway => 80.0 / 3.6,
            Area::Highway => 120.0 / 3.6,
        }
    }

    /// Reversing is not allowed on the highway (§2.2).
    pub fn allows_reverse(&self) -> bool {
        !matches!(self, Area::Highway)
    }
}

/// Driving scenario (§2.2).  Turning left and right share requirements
/// (Table 5 note), so a single `Turn` variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    GoStraight,
    Turn,
    Reverse,
}

pub const ALL_SCENARIOS: [Scenario; 3] = [Scenario::GoStraight, Scenario::Turn, Scenario::Reverse];

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::GoStraight => "GS",
            Scenario::Turn => "TL",
            Scenario::Reverse => "RE",
        }
    }

    /// Maximum velocity while in this scenario (turning capped at 50 km/h,
    /// §6.1; reversing is slow).
    pub fn velocity_cap_ms(&self) -> f64 {
        match self {
            Scenario::GoStraight => f64::INFINITY,
            Scenario::Turn => 50.0 / 3.6,
            Scenario::Reverse => 10.0 / 3.6,
        }
    }
}

/// Camera function groups (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CameraGroup {
    /// Forward cameras.
    Fc,
    /// Forward left side.
    Flsc,
    /// Rearward left side.
    Rlsc,
    /// Forward right side.
    Frsc,
    /// Rearward right side.
    Rrsc,
    /// Rear cameras.
    Rc,
}

pub const ALL_GROUPS: [CameraGroup; 6] = [
    CameraGroup::Fc,
    CameraGroup::Flsc,
    CameraGroup::Rlsc,
    CameraGroup::Frsc,
    CameraGroup::Rrsc,
    CameraGroup::Rc,
];

impl CameraGroup {
    pub fn name(&self) -> &'static str {
        match self {
            CameraGroup::Fc => "FC",
            CameraGroup::Flsc => "FLSC",
            CameraGroup::Rlsc => "RLSC",
            CameraGroup::Frsc => "FRSC",
            CameraGroup::Rrsc => "RRSC",
            CameraGroup::Rc => "RC",
        }
    }

    /// Cameras per group (Table 4: 11 + 4 + 4 + 4 + 4 + 3 = 30).
    pub fn count(&self) -> usize {
        match self {
            CameraGroup::Fc => 11,
            CameraGroup::Flsc | CameraGroup::Rlsc | CameraGroup::Frsc | CameraGroup::Rrsc => 4,
            CameraGroup::Rc => 3,
        }
    }

    /// Maximum sensing distance in meters (§6.1: FC 250 m, RC 100 m,
    /// side 80 m — the ST_250FC / ST_100RC / ST_80SC subscripts).
    pub fn max_distance_m(&self) -> f64 {
        match self {
            CameraGroup::Fc => 250.0,
            CameraGroup::Rc => 100.0,
            _ => 80.0,
        }
    }

    pub fn is_side(&self) -> bool {
        matches!(
            self,
            CameraGroup::Flsc | CameraGroup::Rlsc | CameraGroup::Frsc | CameraGroup::Rrsc
        )
    }

    /// Object tracking is not performed for rear cameras except while
    /// reversing (§2.2: TRA totals exclude RC when going straight/turning,
    /// but the reverse rows of Table 5 have DET == TRA).
    pub fn tracks_in(&self, scenario: Scenario) -> bool {
        *self != CameraGroup::Rc || scenario == Scenario::Reverse
    }
}

/// Total number of cameras (Table 4).
pub fn total_cameras() -> usize {
    ALL_GROUPS.iter().map(|g| g.count()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_camera_counts() {
        assert_eq!(CameraGroup::Fc.count(), 11);
        assert_eq!(CameraGroup::Rc.count(), 3);
        assert_eq!(total_cameras(), 30);
    }

    #[test]
    fn area_velocities() {
        assert!((Area::Urban.max_velocity_ms() - 16.6667).abs() < 1e-3);
        assert!((Area::Highway.max_velocity_ms() - 33.3333).abs() < 1e-3);
    }

    #[test]
    fn no_reverse_on_highway() {
        assert!(Area::Urban.allows_reverse());
        assert!(!Area::Highway.allows_reverse());
    }

    #[test]
    fn rc_tracking_rule() {
        assert!(!CameraGroup::Rc.tracks_in(Scenario::GoStraight));
        assert!(CameraGroup::Rc.tracks_in(Scenario::Reverse));
        assert!(CameraGroup::Fc.tracks_in(Scenario::GoStraight));
    }

    #[test]
    fn parse_roundtrip() {
        for a in ALL_AREAS {
            assert_eq!(Area::parse(a.name()), Some(a));
        }
    }
}
