//! Scenario-variability library — the ROADMAP's "as many scenarios as you
//! can imagine" step.
//!
//! A library of named route **archetypes** (rush-hour urban, highway
//! cruise, multi-area composites, degraded night-rain camera rates,
//! mid-route sensor dropout/recovery) plus parameterized **camera rigs**
//! (the 12/20/30-camera variants of §7).  Each archetype *compiles down*
//! to the existing [`RouteParams`]/`Segment` timeline — one concrete
//! [`Route`] per leg — and a [`CameraProfile`], so `taskgen` and the
//! simulator need no semantic changes: the default profile reproduces the
//! legacy Table 4 queue bit-for-bit.
//!
//! Archetypes can also declare timed **platform events** ([`EventSpec`]:
//! accelerator failure / recovery / frequency derating as route-duration
//! fractions) — the fault archetypes `accel-failure` and
//! `thermal-throttle` exercise them; the engine applies them to the
//! simulation's `ShadowState` between bursts when run with events enabled
//! (CLI `--events`).
//!
//! Wiring: `plan::ExperimentPlan::scenarios([...])` sweeps archetypes by
//! name, the CLI exposes `--scenario <name|all>` (and `env list`) on
//! `schedule` / `platform` / `braking` / `env`, and
//! `metrics::summary::SweepKey` / `reports::sweep_table` carry a
//! per-scenario breakdown column.

use anyhow::{Context, Result};

use super::route::{Route, RouteParams, Segment};
use super::taskgen::{self, DeadlineMode, Task, TaskQueue};
use super::{Area, CameraGroup};
use crate::sim::events::{EventAction, PlatformEvent};
use crate::util::rng::Rng;

/// Cameras per function group, in `ALL_GROUPS` order (FC, FLSC, RLSC,
/// FRSC, RRSC, RC).  Table 4's 30-camera rig is the default; §7 also
/// evaluates 20- and 12-camera vehicles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CameraRig {
    pub counts: [usize; 6],
}

impl CameraRig {
    /// Table 4: 11 + 4 + 4 + 4 + 4 + 3 = 30 cameras.
    pub const fn full30() -> CameraRig {
        CameraRig { counts: [11, 4, 4, 4, 4, 3] }
    }

    /// A 20-camera rig (§7): thinner forward array, single rear camera.
    pub const fn mid20() -> CameraRig {
        CameraRig { counts: [7, 3, 3, 3, 3, 1] }
    }

    /// A 12-camera rig (§7): minimal coverage of every function group.
    pub const fn min12() -> CameraRig {
        CameraRig { counts: [3, 2, 2, 2, 2, 1] }
    }

    /// Rig preset for one of the paper's camera counts (12 / 20 / 30).
    pub fn for_total(total: usize) -> Option<CameraRig> {
        match total {
            12 => Some(Self::min12()),
            20 => Some(Self::mid20()),
            30 => Some(Self::full30()),
            _ => None,
        }
    }

    /// Cameras in one function group.
    pub fn count(&self, group: CameraGroup) -> usize {
        self.counts[group_index(group)]
    }

    /// Total cameras on the vehicle.
    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }
}

impl Default for CameraRig {
    fn default() -> Self {
        Self::full30()
    }
}

/// Index of a group within `ALL_GROUPS` (and `CameraRig::counts`).
fn group_index(group: CameraGroup) -> usize {
    match group {
        CameraGroup::Fc => 0,
        CameraGroup::Flsc => 1,
        CameraGroup::Rlsc => 2,
        CameraGroup::Frsc => 3,
        CameraGroup::Rrsc => 4,
        CameraGroup::Rc => 5,
    }
}

/// Camera-side generation knobs threaded through `taskgen`: the rig and a
/// global frame-rate scale (night-rain degradation — cameras drop to a
/// fraction of their Camera_HZ rate).  `Default` reproduces the legacy
/// Table 4 behaviour bit-for-bit (`hz * 1.0` is exact in IEEE 754).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CameraProfile {
    pub rig: CameraRig,
    pub hz_scale: f64,
}

impl Default for CameraProfile {
    fn default() -> Self {
        CameraProfile { rig: CameraRig::full30(), hz_scale: 1.0 }
    }
}

/// A mid-route sensor-dropout window: cameras of `group` (`None` = every
/// group) emit no frames while the window is active and resume on
/// recovery.  Bounds are fractions of the total route duration, so the
/// same archetype scales to any route distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dropout {
    pub group: Option<CameraGroup>,
    pub start_frac: f64,
    pub end_frac: f64,
}

/// A timed platform event declared by an archetype: `action` fires when
/// the route clock reaches `at_frac` of the total route duration, so the
/// same archetype scales to any route distance (like [`Dropout`], but on
/// the *compute* side — [`sim::events`](crate::sim::events) applies it to
/// the platform state between bursts when the engine runs with events
/// enabled).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventSpec {
    pub at_frac: f64,
    pub action: EventAction,
}

/// One leg of an archetype's (possibly multi-area) composite route.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LegSpec {
    pub area: Area,
    /// Share of the total route distance (normalized over the archetype).
    pub weight: f64,
    /// Scale on Table 13's max turn count for this leg.
    pub turn_scale: f64,
    /// Scale on Table 13's max reverse count for this leg.
    pub reverse_scale: f64,
}

impl LegSpec {
    pub fn new(area: Area, weight: f64) -> LegSpec {
        LegSpec { area, weight, turn_scale: 1.0, reverse_scale: 1.0 }
    }
}

/// A named scenario archetype: route legs × camera rig × frame-rate scale
/// × dropout events.  `compile` turns it into concrete per-leg routes.
#[derive(Debug, Clone, PartialEq)]
pub struct Archetype {
    /// Library name (CLI `--scenario` value), lowercase.
    pub name: String,
    /// One-line description for usage text and the tour example.
    pub help: &'static str,
    pub legs: Vec<LegSpec>,
    pub rig: CameraRig,
    pub hz_scale: f64,
    pub dropouts: Vec<Dropout>,
    /// Timed platform-capacity events (accelerator failure / recovery /
    /// derating), as route-duration fractions.
    pub events: Vec<EventSpec>,
}

impl Archetype {
    /// Dominant (highest-weight, earliest on ties) leg area — the sweep
    /// table's "Area" column for library trials.
    pub fn primary_area(&self) -> Area {
        let mut best: Option<LegSpec> = None;
        for leg in &self.legs {
            if best.map(|b| leg.weight > b.weight).unwrap_or(true) {
                best = Some(*leg);
            }
        }
        best.map(|l| l.area).unwrap_or(Area::Urban)
    }

    /// Compile to concrete per-leg routes for a total distance, consuming
    /// `rng` — deterministic for a given stream.
    pub fn compile(&self, distance_m: f64, rng: &mut Rng) -> CompiledScenario {
        let total_w: f64 = self.legs.iter().map(|l| l.weight).sum::<f64>().max(1e-12);
        let mut legs = Vec::with_capacity(self.legs.len());
        let mut offset_s = 0.0;
        for spec in &self.legs {
            let mut params = RouteParams::for_area(spec.area, distance_m * spec.weight / total_w);
            params.max_times_turn = scale_count(params.max_times_turn, spec.turn_scale);
            params.max_times_reverse = scale_count(params.max_times_reverse, spec.reverse_scale);
            let route = Route::generate(params, rng);
            let start_s = offset_s;
            offset_s += route.duration_s;
            legs.push(CompiledLeg { start_s, route });
        }
        CompiledScenario {
            name: self.name.clone(),
            profile: CameraProfile { rig: self.rig, hz_scale: self.hz_scale },
            dropouts: self.dropouts.clone(),
            duration_s: offset_s,
            legs,
        }
    }

    /// (composite-clock time, leg area) at route position `at_m` of a
    /// `distance_m` route: each leg is driven at its own area's cruise
    /// velocity, matching `Route::generate`'s duration model — so a
    /// brake point in meters lands in the correct leg of a multi-area
    /// composite instead of being converted at one global speed.
    pub fn at_distance(&self, distance_m: f64, at_m: f64) -> (f64, Area) {
        let total_w: f64 = self.legs.iter().map(|l| l.weight).sum::<f64>().max(1e-12);
        let mut t = 0.0;
        let mut remaining = at_m.max(0.0);
        let mut last_area = self.primary_area();
        for leg in &self.legs {
            let d = distance_m * leg.weight / total_w;
            let v = leg.area.max_velocity_ms();
            last_area = leg.area;
            if remaining <= d {
                return (t + remaining / v, leg.area);
            }
            remaining -= d;
            t += d / v;
        }
        (t, last_area)
    }

    /// Compile this archetype's event specs to absolute route-clock
    /// [`PlatformEvent`]s for a queue of `duration_s` (the engine calls
    /// this with the generated queue's own composite duration).
    pub fn platform_events(&self, duration_s: f64) -> Vec<PlatformEvent> {
        self.events
            .iter()
            .map(|e| PlatformEvent { at_s: e.at_frac * duration_s, action: e.action })
            .collect()
    }

    /// Task queue `index` of a distance list, using the same `Rng::fork`
    /// seed derivation as `plan::queue_for` (skip `index` parent draws,
    /// fork stream `index`) — so library queues compose into plans with
    /// the legacy determinism contract.
    pub fn queue_for(
        &self,
        distance_m: f64,
        index: usize,
        mode: DeadlineMode,
        seed: u64,
    ) -> TaskQueue {
        let mut rng = Rng::new(seed);
        for _ in 0..index {
            rng.next_u64(); // each earlier fork consumed one parent draw
        }
        let mut stream = rng.fork(index as u64);
        self.compile(distance_m, &mut stream).queue(mode)
    }
}

fn scale_count(base: usize, scale: f64) -> usize {
    (base as f64 * scale).round() as usize
}

/// Truncate a task queue to the first `frac` of its route: keep the
/// release-ordered prefix of tasks released before `frac *
/// route_duration_s` and shrink the route horizon to match.  This is the
/// low-fidelity screening signal for multi-fidelity DSE — the truncated
/// queue exercises the same per-frame contention as the full route, just
/// for a shorter window.
///
/// `frac >= 1.0` (and any non-positive or non-finite `frac`) is the
/// identity: the queue passes through untouched, so full-fidelity plans
/// are bit-identical to pre-fidelity ones.  A truncation never returns an
/// empty queue when the input had tasks: at least the first release
/// survives, so every candidate still sees real work.
pub fn truncate_queue(queue: TaskQueue, frac: f64) -> TaskQueue {
    if !(frac > 0.0) || frac >= 1.0 || !frac.is_finite() {
        return queue;
    }
    let horizon = queue.route_duration_s * frac;
    let mut keep = queue.tasks.iter().take_while(|t| t.release_s < horizon).count();
    if keep == 0 && !queue.tasks.is_empty() {
        keep = 1; // never screen a candidate against an empty queue
    }
    let mut tasks = queue.tasks;
    tasks.truncate(keep);
    TaskQueue { tasks, route_duration_s: horizon }
}

/// One compiled leg: a concrete route whose timeline starts at `start_s`
/// on the composite clock.
#[derive(Debug, Clone)]
pub struct CompiledLeg {
    pub start_s: f64,
    pub route: Route,
}

/// A compiled scenario: per-leg routes + camera profile + dropout windows.
/// `queue` produces the merged task queue through the unchanged `taskgen`.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    pub name: String,
    pub profile: CameraProfile,
    pub dropouts: Vec<Dropout>,
    pub duration_s: f64,
    pub legs: Vec<CompiledLeg>,
}

impl CompiledScenario {
    /// All scenario segments across legs, with absolute start times.
    pub fn segments(&self) -> Vec<Segment> {
        let mut out = Vec::new();
        for leg in &self.legs {
            for s in &leg.route.segments {
                out.push(Segment {
                    scenario: s.scenario,
                    start_s: s.start_s + leg.start_s,
                    duration_s: s.duration_s,
                });
            }
        }
        out
    }

    fn dropout_active(&self, group: CameraGroup, t: f64) -> bool {
        self.dropouts.iter().any(|d| {
            d.group.map(|g| g == group).unwrap_or(true)
                && t >= d.start_frac * self.duration_s
                && t < d.end_frac * self.duration_s
        })
    }

    /// Merged task queue under `mode`: each leg generated by the unchanged
    /// `taskgen` (with this scenario's camera profile), time-offset onto
    /// the composite clock, dropout-filtered, then re-identified in
    /// release order.
    pub fn queue(&self, mode: DeadlineMode) -> TaskQueue {
        let mut tasks: Vec<(usize, Task)> = Vec::new();
        for (leg_idx, leg) in self.legs.iter().enumerate() {
            let q = taskgen::generate_with_profile(&leg.route, mode, self.profile);
            for mut t in q.tasks {
                t.release_s += leg.start_s;
                tasks.push((leg_idx, t));
            }
        }
        tasks.retain(|(_, t)| !self.dropout_active(t.group, t.release_s));
        // Release order; ties broken by (leg, per-leg id) for determinism.
        tasks.sort_by(|(la, a), (lb, b)| {
            a.release_s.total_cmp(&b.release_s).then(la.cmp(lb)).then(a.id.cmp(&b.id))
        });
        let mut out: Vec<Task> = tasks.into_iter().map(|(_, t)| t).collect();
        for (i, t) in out.iter_mut().enumerate() {
            t.id = i as u32;
        }
        TaskQueue { tasks: out, route_duration_s: self.duration_s }
    }
}

/// THE scenario library.  Names are stable CLI/API surface; add new
/// archetypes here and every layer (plan expansion, `--scenario all`,
/// sweep reports, the fingerprint tests, bench_scenarios, scenario_tour)
/// picks them up.
pub fn library() -> Vec<Archetype> {
    let plain = |name: &str, help: &'static str, legs: Vec<LegSpec>| Archetype {
        name: name.to_string(),
        help,
        legs,
        rig: CameraRig::full30(),
        hz_scale: 1.0,
        dropouts: Vec::new(),
        events: Vec::new(),
    };
    let rush_legs = || {
        vec![LegSpec {
            area: Area::Urban,
            weight: 1.0,
            turn_scale: 2.0,
            reverse_scale: 2.0,
        }]
    };
    vec![
        Archetype {
            name: "urban-rush".into(),
            help: "dense urban traffic: double turn/reverse density",
            legs: rush_legs(),
            rig: CameraRig::full30(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: Vec::new(),
        },
        plain(
            "highway-cruise",
            "steady highway cruising, sparse lane changes",
            vec![LegSpec {
                area: Area::Highway,
                weight: 1.0,
                turn_scale: 0.5,
                reverse_scale: 0.0,
            }],
        ),
        plain(
            "suburban-mixed",
            "half urban, half undivided-highway commute",
            vec![LegSpec::new(Area::Urban, 0.5), LegSpec::new(Area::UndividedHighway, 0.5)],
        ),
        Archetype {
            name: "night-rain".into(),
            help: "urban route at half camera rates (degraded visibility)",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            rig: CameraRig::full30(),
            hz_scale: 0.5,
            dropouts: Vec::new(),
            events: Vec::new(),
        },
        Archetype {
            name: "sensor-dropout".into(),
            help: "urban route; forward cameras dark for the middle fifth, then recover",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            rig: CameraRig::full30(),
            hz_scale: 1.0,
            dropouts: vec![Dropout {
                group: Some(CameraGroup::Fc),
                start_frac: 0.4,
                end_frac: 0.6,
            }],
            events: Vec::new(),
        },
        plain(
            "cross-country",
            "urban → undivided-highway → highway composite",
            vec![
                LegSpec::new(Area::Urban, 0.3),
                LegSpec::new(Area::UndividedHighway, 0.3),
                LegSpec::new(Area::Highway, 0.4),
            ],
        ),
        Archetype {
            name: "urban-rush-20cam".into(),
            help: "urban-rush on the 20-camera rig (§7)",
            legs: rush_legs(),
            rig: CameraRig::mid20(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: Vec::new(),
        },
        Archetype {
            name: "urban-rush-20cam-hd".into(),
            help: "urban-rush, 20-camera rig at doubled per-camera rates (sensor upgrade: \
                   ~14 std-core-equivalents of affine demand, beyond one reticle)",
            legs: rush_legs(),
            rig: CameraRig::mid20(),
            hz_scale: 2.0,
            dropouts: Vec::new(),
            events: Vec::new(),
        },
        Archetype {
            name: "urban-rush-12cam".into(),
            help: "urban-rush on the 12-camera rig (§7)",
            legs: rush_legs(),
            rig: CameraRig::min12(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: Vec::new(),
        },
        Archetype {
            name: "accel-failure".into(),
            help: "urban route; accelerator 0 fails at 35% of the route, recovers at 70%",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            rig: CameraRig::full30(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: vec![
                EventSpec { at_frac: 0.35, action: EventAction::Fail { accel: 0 } },
                EventSpec { at_frac: 0.70, action: EventAction::Recover { accel: 0 } },
            ],
        },
        Archetype {
            name: "thermal-throttle".into(),
            help: "urban route; accelerators 0 and 4 derate to half speed for the middle half",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            rig: CameraRig::full30(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: vec![
                EventSpec { at_frac: 0.25, action: EventAction::Derate { accel: 0, speed: 0.5 } },
                EventSpec { at_frac: 0.25, action: EventAction::Derate { accel: 4, speed: 0.5 } },
                EventSpec { at_frac: 0.75, action: EventAction::Recover { accel: 0 } },
                EventSpec { at_frac: 0.75, action: EventAction::Recover { accel: 4 } },
            ],
        },
        Archetype {
            name: "link-failure".into(),
            help: "urban route; interconnect link 0 severed at 30% of the route, restored at 70% \
                   (chiplet platforms reroute; monolithic platforms are unaffected)",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            rig: CameraRig::full30(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: vec![
                EventSpec { at_frac: 0.30, action: EventAction::LinkFail { link: 0 } },
                EventSpec { at_frac: 0.70, action: EventAction::LinkRecover { link: 0 } },
            ],
        },
        Archetype {
            name: "degraded-comfort".into(),
            help: "urban route; accelerator 0 down for most of the route — the regime where a \
                   degradation-aware scheduler sheds comfort work to protect the safety tier",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            rig: CameraRig::full30(),
            hz_scale: 1.0,
            dropouts: Vec::new(),
            events: vec![
                EventSpec { at_frac: 0.25, action: EventAction::Fail { accel: 0 } },
                EventSpec { at_frac: 0.85, action: EventAction::Recover { accel: 0 } },
            ],
        },
    ]
}

/// Library archetype names, in library order.
pub fn names() -> Vec<String> {
    library().into_iter().map(|a| a.name).collect()
}

/// Look up an archetype by name (case-insensitive).
pub fn find(name: &str) -> Result<Archetype> {
    let lc = name.to_ascii_lowercase();
    library().into_iter().find(|a| a.name == lc).with_context(|| {
        format!("unknown scenario '{}' (known: {})", name, names().join(", "))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::Scenario;

    #[test]
    fn library_names_are_unique_and_findable() {
        let lib = library();
        let mut seen = std::collections::BTreeSet::new();
        for a in &lib {
            assert!(seen.insert(a.name.clone()), "dup name {}", a.name);
            assert!(!a.legs.is_empty(), "{} has no legs", a.name);
            let found = find(&a.name).unwrap();
            assert_eq!(found.name, a.name);
            // Case-insensitive.
            assert_eq!(find(&a.name.to_ascii_uppercase()).unwrap().name, a.name);
        }
        let err = find("definitely-not-a-scenario").unwrap_err();
        assert!(format!("{err:#}").contains("urban-rush"), "{err:#}");
    }

    #[test]
    fn rig_presets_total_12_20_30() {
        assert_eq!(CameraRig::full30().total(), 30);
        assert_eq!(CameraRig::mid20().total(), 20);
        assert_eq!(CameraRig::min12().total(), 12);
        for n in [12, 20, 30] {
            assert_eq!(CameraRig::for_total(n).unwrap().total(), n);
        }
        assert!(CameraRig::for_total(7).is_none());
        // Rig counts agree with the CameraGroup table for the full rig.
        for g in crate::env::ALL_GROUPS {
            assert_eq!(CameraRig::full30().count(g), g.count(), "{g:?}");
        }
    }

    #[test]
    fn default_profile_is_bit_identical_to_legacy_taskgen() {
        let route = Route::generate(
            RouteParams::for_area(Area::Urban, 120.0),
            &mut Rng::new(11),
        );
        let legacy = taskgen::generate_with_deadline(&route, DeadlineMode::Rss);
        let profiled =
            taskgen::generate_with_profile(&route, DeadlineMode::Rss, CameraProfile::default());
        assert_eq!(legacy.len(), profiled.len());
        for (a, b) in legacy.tasks.iter().zip(&profiled.tasks) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.release_s.to_bits(), b.release_s.to_bits());
            assert_eq!(a.model, b.model);
            assert_eq!(a.safety_time_s.to_bits(), b.safety_time_s.to_bits());
        }
    }

    #[test]
    fn compile_covers_duration_with_contiguous_segments() {
        for arch in library() {
            let c = arch.compile(300.0, &mut Rng::new(3));
            let legs_total: f64 = c.legs.iter().map(|l| l.route.duration_s).sum();
            assert!((c.duration_s - legs_total).abs() < 1e-9, "{}", arch.name);
            let mut t = 0.0;
            for s in c.segments() {
                assert!((s.start_s - t).abs() < 1e-6, "{}: gap at {t}", arch.name);
                t = s.end_s();
            }
            assert!((t - c.duration_s).abs() < 1e-6, "{}", arch.name);
        }
    }

    #[test]
    fn queues_are_deterministic_per_seed() {
        for arch in library() {
            let a = arch.queue_for(150.0, 2, DeadlineMode::Rss, 9);
            let b = arch.queue_for(150.0, 2, DeadlineMode::Rss, 9);
            assert_eq!(a.len(), b.len(), "{}", arch.name);
            assert!(!a.is_empty(), "{} produced an empty queue", arch.name);
            for (x, y) in a.tasks.iter().zip(&b.tasks) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.release_s.to_bits(), y.release_s.to_bits());
                assert_eq!(x.model, y.model);
            }
            // Queue ids are the contiguous re-identification.
            assert!(a.tasks.iter().enumerate().all(|(i, t)| t.id == i as u32));
            assert!(a.tasks.windows(2).all(|w| w[0].release_s <= w[1].release_s));
        }
    }

    #[test]
    fn night_rain_halves_the_task_rate() {
        let plain = find("suburban-mixed").unwrap(); // any full-rate urbanish route
        let rain = find("night-rain").unwrap();
        let urban = Archetype {
            name: "urban-plain".into(),
            help: "",
            legs: vec![LegSpec::new(Area::Urban, 1.0)],
            ..plain.clone()
        };
        let q_full = urban.queue_for(300.0, 0, DeadlineMode::Rss, 5);
        let q_rain = rain.queue_for(300.0, 0, DeadlineMode::Rss, 5);
        let rate = |q: &TaskQueue| q.len() as f64 / q.route_duration_s;
        let ratio = rate(&q_rain) / rate(&q_full);
        assert!((0.4..0.62).contains(&ratio), "rate ratio = {ratio}");
    }

    #[test]
    fn sensor_dropout_blacks_out_fc_then_recovers() {
        let arch = find("sensor-dropout").unwrap();
        let q = arch.queue_for(400.0, 0, DeadlineMode::Rss, 7);
        let dur = q.route_duration_s;
        let (w0, w1) = (0.4 * dur, 0.6 * dur);
        let fc = |lo: f64, hi: f64| {
            q.tasks
                .iter()
                .filter(|t| {
                    t.group == CameraGroup::Fc && t.release_s >= lo && t.release_s < hi
                })
                .count()
        };
        assert_eq!(fc(w0, w1), 0, "FC tasks inside the dropout window");
        assert!(fc(0.0, w0) > 0, "no FC tasks before dropout");
        assert!(fc(w1, dur) > 0, "FC never recovered");
        // Other groups keep emitting through the window.
        assert!(q
            .tasks
            .iter()
            .any(|t| t.group != CameraGroup::Fc && t.release_s >= w0 && t.release_s < w1));
    }

    #[test]
    fn smaller_rigs_produce_fewer_tasks() {
        let q30 = find("urban-rush").unwrap().queue_for(200.0, 0, DeadlineMode::Rss, 4);
        let q20 = find("urban-rush-20cam").unwrap().queue_for(200.0, 0, DeadlineMode::Rss, 4);
        let q12 = find("urban-rush-12cam").unwrap().queue_for(200.0, 0, DeadlineMode::Rss, 4);
        assert!(q30.len() > q20.len(), "{} !> {}", q30.len(), q20.len());
        assert!(q20.len() > q12.len(), "{} !> {}", q20.len(), q12.len());
    }

    #[test]
    fn cross_country_concatenates_all_three_areas() {
        let arch = find("cross-country").unwrap();
        assert_eq!(arch.primary_area(), Area::Highway); // dominant 0.4 leg
        let c = arch.compile(600.0, &mut Rng::new(1));
        assert_eq!(c.legs.len(), 3);
        assert_eq!(c.legs[0].route.params.area, Area::Urban);
        assert_eq!(c.legs[2].route.params.area, Area::Highway);
        // Legs sit end-to-end on the composite clock.
        for w in c.legs.windows(2) {
            assert!((w[1].start_s - (w[0].start_s + w[0].route.duration_s)).abs() < 1e-9);
        }
        // The highway leg never reverses.
        let hw_start = c.legs[2].start_s;
        let q = c.queue(DeadlineMode::Rss);
        assert!(q
            .tasks
            .iter()
            .filter(|t| t.release_s >= hw_start)
            .all(|t| t.scenario != Scenario::Reverse));
    }

    #[test]
    fn at_distance_walks_legs_at_their_own_speeds() {
        let arch = find("cross-country").unwrap();
        // Leg split of a 1000 m route: 300 m UB, 300 m UHW, 400 m HW.
        let (t0, a0) = arch.at_distance(1000.0, 0.0);
        assert_eq!(t0, 0.0);
        assert_eq!(a0, Area::Urban);
        let (_, a_mid) = arch.at_distance(1000.0, 450.0);
        assert_eq!(a_mid, Area::UndividedHighway);
        let (_, a_end) = arch.at_distance(1000.0, 950.0);
        assert_eq!(a_end, Area::Highway);
        // End-of-route time equals the compiled composite duration.
        let (t_end, _) = arch.at_distance(1000.0, 1000.0);
        let c = arch.compile(1000.0, &mut Rng::new(2));
        assert!((t_end - c.duration_s).abs() < 1e-9, "{t_end} vs {}", c.duration_s);
        // Single-leg archetypes reduce to distance / cruise speed.
        let urban = find("urban-rush").unwrap();
        let (t, a) = urban.at_distance(500.0, 250.0);
        assert_eq!(a, Area::Urban);
        assert!((t - 250.0 / Area::Urban.max_velocity_ms()).abs() < 1e-9);
    }

    #[test]
    fn fault_archetypes_compile_events_to_absolute_times() {
        let fail = find("accel-failure").unwrap();
        assert_eq!(fail.events.len(), 2);
        let evts = fail.platform_events(1000.0);
        assert_eq!(evts.len(), 2);
        assert!((evts[0].at_s - 350.0).abs() < 1e-9);
        assert!((evts[1].at_s - 700.0).abs() < 1e-9);
        assert_eq!(evts[0].action, EventAction::Fail { accel: 0 });
        assert_eq!(evts[1].action, EventAction::Recover { accel: 0 });

        let throttle = find("thermal-throttle").unwrap();
        let evts = throttle.platform_events(400.0);
        assert_eq!(evts.len(), 4);
        assert!(evts
            .iter()
            .any(|e| e.action == EventAction::Derate { accel: 4, speed: 0.5 }));

        let link = find("link-failure").unwrap();
        let evts = link.platform_events(1000.0);
        assert_eq!(evts.len(), 2);
        assert!((evts[0].at_s - 300.0).abs() < 1e-9);
        assert_eq!(evts[0].action, EventAction::LinkFail { link: 0 });
        assert_eq!(evts[1].action, EventAction::LinkRecover { link: 0 });
        assert_eq!(
            find("degraded-comfort").unwrap().events[0].action,
            EventAction::Fail { accel: 0 }
        );
        // Event-free archetypes stay event-free.
        assert!(find("urban-rush").unwrap().platform_events(500.0).is_empty());
    }

    #[test]
    fn urban_rush_is_denser_than_plain_urban() {
        // Doubled turn density must show up in the compiled timeline
        // (across seeds — any single seed can draw few turns).
        let rush = find("urban-rush").unwrap();
        let mut rush_turns = 0usize;
        let mut plain_turns = 0usize;
        for seed in 0..10 {
            let c = rush.compile(1000.0, &mut Rng::new(seed));
            rush_turns +=
                c.segments().iter().filter(|s| s.scenario == Scenario::Turn).count();
            let plain = Route::generate(
                RouteParams::for_area(Area::Urban, 1000.0),
                &mut Rng::new(seed),
            );
            plain_turns +=
                plain.segments.iter().filter(|s| s.scenario == Scenario::Turn).count();
        }
        assert!(rush_turns > plain_turns, "rush {rush_turns} !> plain {plain_turns}");
    }

    #[test]
    fn truncate_queue_keeps_a_release_ordered_prefix() {
        let arch = find("urban-rush").unwrap();
        let full = arch.queue_for(200.0, 0, DeadlineMode::Rss, 3);
        let half = truncate_queue(full.clone(), 0.5);
        assert!(!half.is_empty());
        assert!(half.len() < full.len(), "{} !< {}", half.len(), full.len());
        assert_eq!(half.route_duration_s.to_bits(), (full.route_duration_s * 0.5).to_bits());
        let horizon = half.route_duration_s;
        assert!(half.tasks.iter().all(|t| t.release_s < horizon));
        for (a, b) in half.tasks.iter().zip(&full.tasks) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.release_s.to_bits(), b.release_s.to_bits());
        }
        // The first task past the horizon was the cut point.
        assert!(full.tasks[half.len()].release_s >= horizon);
    }

    #[test]
    fn truncate_queue_full_and_degenerate_fracs_are_identity() {
        let arch = find("night-rain").unwrap();
        let full = arch.queue_for(150.0, 1, DeadlineMode::Rss, 9);
        for frac in [1.0, 1.5, 0.0, -0.25, f64::NAN] {
            let q = truncate_queue(full.clone(), frac);
            assert_eq!(q.len(), full.len(), "frac {frac}");
            assert_eq!(q.route_duration_s.to_bits(), full.route_duration_s.to_bits());
        }
        // Tiny fractions still keep at least one task.
        let sliver = truncate_queue(full, 1e-9);
        assert_eq!(sliver.len(), 1);
    }
}
