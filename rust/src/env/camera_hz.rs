//! Per-group camera frame rates — Camera_HZ(A, S, C) of Table 12, the data
//! behind Fig. 1 — reconstructed to exactly reproduce Table 5's aggregate
//! FPS requirements for the urban area:
//!
//!   UB go-straight: DET 870, TRA 840  (FC 40 x11, sides 25 x16, RC 10 x3)
//!   UB turn:        DET 950, TRA 920  (FC 40 x11, sides 30 x16, RC 10 x3)
//!   UB reverse:     DET 740, TRA 740  (FC 20 x11, sides 25 x16, RC 40 x3;
//!                                      TRA includes RC while reversing)
//!
//! UHW/HW rows follow the same construction: forward rates stay high, side
//! rates scale with lane-change risk, rear rates drop (no reversing on HW).

use super::{Area, CameraGroup, Scenario};
use crate::workload::ModelKind;

/// Frame rate (Hz = FPS) of one camera in group `c` under (area, scenario).
pub fn camera_hz(area: Area, scenario: Scenario, group: CameraGroup) -> f64 {
    use Area::*;
    use CameraGroup::*;
    use Scenario::*;
    let side_fwd = matches!(group, Flsc | Frsc);
    match (area, scenario, group) {
        // ---- Urban (reproduces Table 5 exactly) ----
        (Urban, GoStraight, Fc) => 40.0,
        (Urban, GoStraight, Rc) => 10.0,
        (Urban, GoStraight, _) => 25.0,
        (Urban, Turn, Fc) => 40.0,
        (Urban, Turn, Rc) => 10.0,
        (Urban, Turn, _) => 30.0,
        (Urban, Reverse, Fc) => 20.0,
        (Urban, Reverse, Rc) => 40.0,
        (Urban, Reverse, _) => 25.0,
        // ---- Undivided highway: faster closing speeds -> forward-side up ----
        (UndividedHighway, GoStraight, Fc) => 40.0,
        (UndividedHighway, GoStraight, Rc) => 10.0,
        (UndividedHighway, GoStraight, _) if side_fwd => 30.0,
        (UndividedHighway, GoStraight, _) => 20.0,
        (UndividedHighway, Turn, Fc) => 40.0,
        (UndividedHighway, Turn, Rc) => 10.0,
        (UndividedHighway, Turn, _) => 30.0,
        (UndividedHighway, Reverse, Fc) => 20.0,
        (UndividedHighway, Reverse, Rc) => 40.0,
        (UndividedHighway, Reverse, _) => 25.0,
        // ---- Highway: no reversing; overtaking dominates ----
        (Highway, GoStraight, Fc) => 40.0,
        (Highway, GoStraight, Rc) => 10.0,
        (Highway, GoStraight, _) if side_fwd => 25.0,
        (Highway, GoStraight, _) => 20.0,
        (Highway, Turn, Fc) => 40.0, // lane change
        (Highway, Turn, Rc) => 10.0,
        (Highway, Turn, _) => 30.0,
        (Highway, Reverse, _) => 0.0, // not allowed (§2.2)
    }
}

/// Aggregate FPS requirement across all cameras for a task category
/// (Table 5 rows: DET = all cameras; TRA = cameras with tracking enabled).
pub fn aggregate_fps(area: Area, scenario: Scenario, track: bool) -> f64 {
    super::ALL_GROUPS
        .iter()
        .filter(|g| !track || g.tracks_in(scenario))
        .map(|g| g.count() as f64 * camera_hz(area, scenario, *g))
        .sum()
}

/// Per-model FPS requirement (Table 5 bottom rows): detection alternates
/// YOLO/SSD per frame (half each); GOTURN carries all tracking frames.
pub fn model_fps_requirement(area: Area, scenario: Scenario, kind: ModelKind) -> f64 {
    match kind {
        ModelKind::Yolo | ModelKind::Ssd => aggregate_fps(area, scenario, false) / 2.0,
        ModelKind::Goturn => aggregate_fps(area, scenario, true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{ALL_AREAS, ALL_SCENARIOS};

    #[test]
    fn table5_urban_exact() {
        let a = Area::Urban;
        assert_eq!(aggregate_fps(a, Scenario::GoStraight, false), 870.0);
        assert_eq!(aggregate_fps(a, Scenario::GoStraight, true), 840.0);
        assert_eq!(aggregate_fps(a, Scenario::Turn, false), 950.0);
        assert_eq!(aggregate_fps(a, Scenario::Turn, true), 920.0);
        assert_eq!(aggregate_fps(a, Scenario::Reverse, false), 740.0);
        assert_eq!(aggregate_fps(a, Scenario::Reverse, true), 740.0);
    }

    #[test]
    fn table5_urban_per_model() {
        let a = Area::Urban;
        assert_eq!(model_fps_requirement(a, Scenario::GoStraight, ModelKind::Yolo), 435.0);
        assert_eq!(model_fps_requirement(a, Scenario::GoStraight, ModelKind::Ssd), 435.0);
        assert_eq!(model_fps_requirement(a, Scenario::GoStraight, ModelKind::Goturn), 840.0);
        assert_eq!(model_fps_requirement(a, Scenario::Turn, ModelKind::Yolo), 475.0);
        assert_eq!(model_fps_requirement(a, Scenario::Reverse, ModelKind::Goturn), 740.0);
    }

    #[test]
    fn rates_within_camera_limits() {
        // §2.2: cameras generate 10..40 FPS.
        for a in ALL_AREAS {
            for s in ALL_SCENARIOS {
                for g in crate::env::ALL_GROUPS {
                    let hz = camera_hz(a, s, g);
                    if a == Area::Highway && s == Scenario::Reverse {
                        assert_eq!(hz, 0.0);
                    } else {
                        assert!((10.0..=40.0).contains(&hz), "{a:?} {s:?} {g:?}: {hz}");
                    }
                }
            }
        }
    }

    #[test]
    fn total_peak_below_1200(){
        // §3.1: 30 cameras x 40 FPS = 1200 FPS is the design ceiling.
        for a in ALL_AREAS {
            for s in ALL_SCENARIOS {
                assert!(aggregate_fps(a, s, false) <= 1200.0);
            }
        }
    }
}
