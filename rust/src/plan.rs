//! Typed experiment plans: the sweep the paper's figures are made of —
//! scenarios (area × route distance × deadline regime) × platforms ×
//! scheduler specs × seed replicates, expanded into independent [`Trial`]s.
//!
//! Every trial is self-contained: it knows how to regenerate its own task
//! queue and platform, and carries a deterministically derived scheduler
//! seed.  That independence is what lets `engine::Engine` execute trials on
//! any number of worker threads with bit-identical results.
//!
//! Queue-seed derivation is the seed repo's original scheme (kept so every
//! figure reproduces unchanged): queue `i` of a distance list is generated
//! from the `i`-th `Rng::fork` of `Rng::new(seed)`, so adding distances
//! never perturbs earlier queues.  Seed replicates beyond the base seed are
//! also `Rng::fork`-derived (see [`ExperimentPlan::replicates`]).

use anyhow::Result;

use crate::env::route::{Route, RouteParams};
use crate::env::scenario::{self, Archetype};
use crate::env::taskgen::{self, DeadlineMode, TaskQueue};
use crate::env::Area;
use crate::platform::Platform;
use crate::sched::SchedulerSpec;
use crate::util::rng::Rng;

/// Evaluation fidelity for a plan's trials: a fraction of each route to
/// simulate plus a seed-replicate count.  Full fidelity (`route_frac >=
/// 1.0`) is the exact legacy evaluation — queues are bit-identical to a
/// plan without a fidelity axis.  Lower fractions truncate every task
/// queue to the releases inside the first `route_frac` of its route
/// (see [`scenario::truncate_queue`]), which is the cheap screening
/// signal the DSE's successive-halving rungs run on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fidelity {
    /// Fraction of each route's duration to keep, clamped to (0, 1].
    pub route_frac: f64,
    /// Seed replicates this fidelity evaluates
    /// (see [`ExperimentPlan::fidelity`]).
    pub replicates: usize,
}

impl Fidelity {
    /// The exact evaluation: whole route, single replicate.
    pub fn full() -> Fidelity {
        Fidelity { route_frac: 1.0, replicates: 1 }
    }

    /// Whether queues pass through untruncated.
    pub fn is_full(&self) -> bool {
        !(self.route_frac < 1.0)
    }

    /// Cache-key bits for the route fraction (full fidelity normalises
    /// to 1.0 so every "no truncation" spelling shares queue-cache keys).
    pub fn frac_bits(&self) -> u64 {
        if self.is_full() { 1.0f64.to_bits() } else { self.route_frac.to_bits() }
    }
}

impl Default for Fidelity {
    fn default() -> Self {
        Fidelity::full()
    }
}

/// One scenario cell of a sweep: either a plain (area, distance, deadline)
/// cell — the legacy axis — or a library archetype
/// ([`env::scenario`](crate::env::scenario)) resolved at plan expansion,
/// with `area` set to the archetype's primary area for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Library archetype, when this cell came from
    /// `ExperimentPlan::scenarios` (None = plain area/distance cell).
    pub archetype: Option<Archetype>,
    pub area: Area,
    pub distance_m: f64,
    pub deadline: DeadlineMode,
}

impl Scenario {
    /// Sweep-table label: the archetype name for library cells, "-" for
    /// plain area/distance cells.
    pub fn scenario_name(&self) -> String {
        self.archetype.as_ref().map(|a| a.name.clone()).unwrap_or_else(|| "-".to_string())
    }
}

/// The seed list [`ExperimentPlan::replicates`] expands to: replicate 0 is
/// `base` itself, replicate k > 0 the k-th `Rng::fork` stream.  Shared
/// with `config` and the fleet planner so every caller derives the same
/// seeds for the same `(base, n)`.
pub fn replicate_seeds(base: u64, n: usize) -> Vec<u64> {
    let mut parent = Rng::new(base);
    (0..n).map(|k| if k == 0 { base } else { parent.fork(k as u64).next_u64() }).collect()
}

/// Build the task queue for queue-index `index` of a distance list, using
/// the same seed derivation as the legacy `harness::make_queues`: skip the
/// first `index` parent draws, then fork stream `index`.
pub fn queue_for(
    area: Area,
    distance_m: f64,
    index: usize,
    deadline: DeadlineMode,
    seed: u64,
) -> TaskQueue {
    let mut rng = Rng::new(seed);
    for _ in 0..index {
        rng.next_u64(); // each earlier fork consumed one parent draw
    }
    let mut stream = rng.fork(index as u64);
    let route = Route::generate(RouteParams::for_area(area, distance_m), &mut stream);
    taskgen::generate_with_deadline(&route, deadline)
}

/// One fully-specified unit of work: one scheduler on one task queue on one
/// platform.  `Engine` runs trials; `id` is the deterministic expansion
/// index results are re-ordered by.
#[derive(Debug, Clone)]
pub struct Trial {
    pub id: usize,
    pub scenario: Scenario,
    /// Index of `scenario.distance_m` within the plan's distance list
    /// (drives queue-seed derivation).
    pub queue_index: usize,
    /// Platform spec string (`Platform::parse` form).
    pub platform: String,
    pub scheduler: SchedulerSpec,
    /// Environment seed (queue generation).
    pub seed: u64,
    /// Scheduler-construction seed.  Equal to `seed` for the base
    /// replicate — the legacy behavior, where `reset()` re-seeded every
    /// queue identically — and `Rng::fork`-derived for later replicates.
    pub sched_seed: u64,
    /// Evaluation fidelity (route truncation).  `Fidelity::full()` for
    /// every plan that never called [`ExperimentPlan::fidelity`].
    pub fidelity: Fidelity,
}

impl Trial {
    /// Regenerate this trial's task queue (deterministic).  Library
    /// scenarios compile their archetype with the same fork-derived stream
    /// the legacy path uses, so both axes share one determinism contract.
    pub fn queue(&self) -> TaskQueue {
        let full = match &self.scenario.archetype {
            Some(arch) => arch.queue_for(
                self.scenario.distance_m,
                self.queue_index,
                self.scenario.deadline,
                self.seed,
            ),
            None => queue_for(
                self.scenario.area,
                self.scenario.distance_m,
                self.queue_index,
                self.scenario.deadline,
                self.seed,
            ),
        };
        scenario::truncate_queue(full, self.fidelity.route_frac)
    }

    /// Resolve this trial's platform.
    pub fn platform(&self) -> Result<Platform> {
        Platform::try_parse(&self.platform)
            .map_err(|e| anyhow::anyhow!("trial {}: bad platform: {e}", self.id))
    }

    /// Short human label (progress lines).
    pub fn label(&self) -> String {
        let place = self
            .scenario
            .archetype
            .as_ref()
            .map(|a| a.name.clone())
            .unwrap_or_else(|| self.scenario.area.name().to_string());
        format!(
            "{}/{}@{}m/{}/q{}/seed{}",
            self.scheduler.canonical(),
            place,
            self.scenario.distance_m,
            self.scenario.deadline.name(),
            self.queue_index + 1,
            self.seed
        )
    }
}

/// Builder for a sweep.  Defaults: urban area, the paper's five eval
/// distances, RSS deadlines, the HMAI platform, seed 42, no schedulers
/// (callers must pick at least one).
///
/// `scenarios` sweeps library archetypes by name; when non-empty it
/// replaces the plain `areas` axis in the cross product (each archetype
/// carries its own area mix).
#[derive(Debug, Clone)]
pub struct ExperimentPlan {
    areas: Vec<Area>,
    scenarios: Vec<String>,
    distances_m: Vec<f64>,
    deadlines: Vec<DeadlineMode>,
    platforms: Vec<String>,
    schedulers: Vec<SchedulerSpec>,
    seeds: Vec<u64>,
    fidelity: Fidelity,
}

impl Default for ExperimentPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl ExperimentPlan {
    pub fn new() -> ExperimentPlan {
        ExperimentPlan {
            areas: vec![Area::Urban],
            scenarios: Vec::new(),
            distances_m: vec![1000.0, 1250.0, 1500.0, 1750.0, 2000.0],
            deadlines: vec![DeadlineMode::Rss],
            platforms: vec!["hmai".to_string()],
            schedulers: Vec::new(),
            seeds: vec![42],
            fidelity: Fidelity::full(),
        }
    }

    pub fn areas<I: IntoIterator<Item = Area>>(mut self, areas: I) -> Self {
        self.areas = areas.into_iter().collect();
        self
    }

    pub fn area(self, area: Area) -> Self {
        self.areas([area])
    }

    /// Sweep library scenario archetypes by name (resolved and validated
    /// at `trials()`).  Non-empty replaces the `areas` axis.
    pub fn scenarios<I, S>(mut self, names: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.scenarios = names.into_iter().map(Into::into).collect();
        self
    }

    /// Sweep every archetype in the scenario library.
    pub fn all_scenarios(self) -> Self {
        let names = scenario::names();
        self.scenarios(names)
    }

    pub fn distances<I: IntoIterator<Item = f64>>(mut self, d: I) -> Self {
        self.distances_m = d.into_iter().collect();
        self
    }

    pub fn deadlines<I: IntoIterator<Item = DeadlineMode>>(mut self, m: I) -> Self {
        self.deadlines = m.into_iter().collect();
        self
    }

    pub fn deadline(self, m: DeadlineMode) -> Self {
        self.deadlines([m])
    }

    pub fn platforms<I, S>(mut self, p: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.platforms = p.into_iter().map(Into::into).collect();
        self
    }

    pub fn platform<S: Into<String>>(self, p: S) -> Self {
        self.platforms([p.into()])
    }

    pub fn schedulers<I: IntoIterator<Item = SchedulerSpec>>(mut self, s: I) -> Self {
        self.schedulers = s.into_iter().collect();
        self
    }

    pub fn scheduler(self, s: SchedulerSpec) -> Self {
        self.schedulers([s])
    }

    /// Add a scheduler to the sweep (keeps earlier ones).
    pub fn also_scheduler(mut self, s: SchedulerSpec) -> Self {
        self.schedulers.push(s);
        self
    }

    pub fn seeds<I: IntoIterator<Item = u64>>(mut self, s: I) -> Self {
        self.seeds = s.into_iter().collect();
        self
    }

    pub fn seed(self, s: u64) -> Self {
        self.seeds([s])
    }

    /// `n` seed replicates derived from `base` via `Rng::fork`: replicate 0
    /// is `base` itself (legacy-compatible), replicate k > 0 is the k-th
    /// forked stream.
    pub fn replicates(mut self, base: u64, n: usize) -> Self {
        self.seeds = replicate_seeds(base, n);
        self
    }

    /// Set the evaluation fidelity.  `f.route_frac` stamps every expanded
    /// trial (truncating its queue); `f.replicates > 1` additionally
    /// re-derives the seed axis as [`replicate_seeds`] of the plan's
    /// first seed — call after `seed()`/`seeds()`.  `Fidelity::full()`
    /// leaves the plan bit-identical to one that never set a fidelity.
    pub fn fidelity(mut self, f: Fidelity) -> Self {
        self.fidelity = f;
        if f.replicates > 1 {
            if let Some(&base) = self.seeds.first() {
                self.seeds = replicate_seeds(base, f.replicates);
            }
        }
        self
    }

    /// Number of trials this plan expands to.
    pub fn len(&self) -> usize {
        let scenario_axis =
            if self.scenarios.is_empty() { self.areas.len() } else { self.scenarios.len() };
        self.seeds.len()
            * self.platforms.len()
            * self.schedulers.len()
            * scenario_axis
            * self.deadlines.len()
            * self.distances_m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand into trials (validates schedulers, platform specs and
    /// library scenario names).
    ///
    /// Expansion order — seeds ▸ platforms ▸ schedulers ▸ scenarios (or
    /// areas) ▸ deadlines ▸ distances — is part of the API: trial ids, and
    /// therefore result ordering and `SweepSummary` row order, follow it.
    pub fn trials(&self) -> Result<Vec<Trial>> {
        anyhow::ensure!(!self.schedulers.is_empty(), "plan has no schedulers");
        anyhow::ensure!(!self.distances_m.is_empty(), "plan has no route distances");
        for p in &self.platforms {
            Platform::try_parse(p).map_err(|e| anyhow::anyhow!("plan: bad platform: {e}"))?;
        }
        let archetypes: Vec<Archetype> =
            self.scenarios.iter().map(|n| scenario::find(n)).collect::<Result<_>>()?;
        // The scenario axis: each library archetype, or each plain area.
        let cells: Vec<(Option<Archetype>, Area)> = if archetypes.is_empty() {
            self.areas.iter().map(|&a| (None, a)).collect()
        } else {
            archetypes
                .into_iter()
                .map(|a| {
                    let area = a.primary_area();
                    (Some(a), area)
                })
                .collect()
        };
        let mut out = Vec::with_capacity(self.len());
        for &seed in &self.seeds {
            for platform in &self.platforms {
                for sched in &self.schedulers {
                    for (archetype, area) in &cells {
                        for &deadline in &self.deadlines {
                            for (qi, &distance_m) in self.distances_m.iter().enumerate() {
                                out.push(Trial {
                                    id: out.len(),
                                    scenario: Scenario {
                                        archetype: archetype.clone(),
                                        area: *area,
                                        distance_m,
                                        deadline,
                                    },
                                    queue_index: qi,
                                    platform: platform.clone(),
                                    scheduler: sched.clone(),
                                    seed,
                                    sched_seed: seed,
                                    fidelity: self.fidelity,
                                });
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_is_the_full_cross_product() {
        let plan = ExperimentPlan::new()
            .areas([Area::Urban, Area::Highway])
            .distances([100.0, 200.0, 300.0])
            .deadlines([DeadlineMode::Rss, DeadlineMode::FrameBudget])
            .platforms(["hmai", "13so"])
            .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Sa])
            .seeds([1, 2]);
        let trials = plan.trials().unwrap();
        assert_eq!(trials.len(), 2 * 3 * 2 * 2 * 2 * 2);
        assert_eq!(trials.len(), plan.len());
        // Ids are the expansion order.
        assert!(trials.iter().enumerate().all(|(i, t)| t.id == i));
        // Distances cycle fastest.
        assert_eq!(trials[0].scenario.distance_m, 100.0);
        assert_eq!(trials[1].scenario.distance_m, 200.0);
        assert_eq!(trials[1].queue_index, 1);
    }

    #[test]
    fn queue_derivation_matches_legacy_make_queues() {
        // Legacy scheme: one parent rng, fork per distance index.
        let (seed, area) = (5, Area::Urban);
        let dists = [100.0, 200.0, 300.0];
        let mut parent = Rng::new(seed);
        let legacy: Vec<TaskQueue> = dists
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                let mut stream = parent.fork(i as u64);
                let route = Route::generate(RouteParams::for_area(area, d), &mut stream);
                taskgen::generate(&route)
            })
            .collect();
        for (i, &d) in dists.iter().enumerate() {
            let q = queue_for(area, d, i, DeadlineMode::Rss, seed);
            assert_eq!(q.len(), legacy[i].len(), "queue {i}");
            for (a, b) in q.tasks.iter().zip(&legacy[i].tasks) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.release_s.to_bits(), b.release_s.to_bits());
                assert_eq!(a.model, b.model);
            }
        }
    }

    #[test]
    fn trial_queue_is_deterministic() {
        let plan = ExperimentPlan::new()
            .distances([80.0, 120.0])
            .scheduler(SchedulerSpec::RoundRobin)
            .seed(9);
        let trials = plan.trials().unwrap();
        for t in &trials {
            let a = t.queue();
            let b = t.queue();
            assert_eq!(a.len(), b.len());
            assert!(!a.is_empty());
        }
        // Different queue indices produce different queues.
        assert_ne!(trials[0].queue().len(), trials[1].queue().len());
    }

    #[test]
    fn replicates_fork_deterministically() {
        let a = ExperimentPlan::new().replicates(7, 3);
        let b = ExperimentPlan::new().replicates(7, 3);
        let (ta, tb) = (
            a.scheduler(SchedulerSpec::MinMin).trials().unwrap(),
            b.scheduler(SchedulerSpec::MinMin).trials().unwrap(),
        );
        let seeds_a: Vec<u64> = ta.iter().map(|t| t.seed).collect();
        let seeds_b: Vec<u64> = tb.iter().map(|t| t.seed).collect();
        assert_eq!(seeds_a, seeds_b);
        assert_eq!(ta[0].seed, 7, "replicate 0 is the base seed");
        let uniq: std::collections::BTreeSet<u64> = seeds_a.iter().copied().collect();
        assert_eq!(uniq.len(), 3, "replicate seeds are distinct");
    }

    #[test]
    fn scenario_axis_replaces_areas_in_the_cross_product() {
        let plan = ExperimentPlan::new()
            .areas([Area::Urban, Area::Highway]) // overridden by scenarios
            .scenarios(["urban-rush", "night-rain", "cross-country"])
            .distances([100.0, 200.0])
            .schedulers([SchedulerSpec::MinMin, SchedulerSpec::RoundRobin])
            .seed(1);
        assert_eq!(plan.len(), 3 * 2 * 2);
        let trials = plan.trials().unwrap();
        assert_eq!(trials.len(), plan.len());
        assert!(trials.iter().all(|t| t.scenario.archetype.is_some()));
        assert_eq!(trials[0].scenario.scenario_name(), "urban-rush");
        // The archetype's primary area labels the cell.
        let cc = trials
            .iter()
            .find(|t| t.scenario.scenario_name() == "cross-country")
            .unwrap();
        assert_eq!(cc.scenario.area, Area::Highway);
        assert!(cc.label().contains("cross-country"));
    }

    #[test]
    fn scenario_trial_queues_are_deterministic() {
        let plan = ExperimentPlan::new()
            .scenarios(["sensor-dropout"])
            .distances([120.0])
            .scheduler(SchedulerSpec::MinMin)
            .seed(8);
        let trials = plan.trials().unwrap();
        let t = &trials[0];
        let (a, b) = (t.queue(), t.queue());
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.release_s.to_bits(), y.release_s.to_bits());
        }
    }

    #[test]
    fn all_scenarios_covers_the_library() {
        let plan = ExperimentPlan::new()
            .all_scenarios()
            .distances([50.0])
            .scheduler(SchedulerSpec::RoundRobin);
        let trials = plan.trials().unwrap();
        assert_eq!(trials.len(), crate::env::scenario::names().len());
    }

    #[test]
    fn full_fidelity_is_the_identity() {
        let base = ExperimentPlan::new()
            .scenarios(["urban-rush"])
            .distances([60.0])
            .scheduler(SchedulerSpec::MinMin)
            .seed(4);
        let with = base.clone().fidelity(Fidelity::full());
        let (ta, tb) = (base.trials().unwrap(), with.trials().unwrap());
        assert_eq!(ta.len(), tb.len());
        for (a, b) in ta.iter().zip(&tb) {
            let (qa, qb) = (a.queue(), b.queue());
            assert_eq!(qa.len(), qb.len());
            assert_eq!(qa.route_duration_s.to_bits(), qb.route_duration_s.to_bits());
            for (x, y) in qa.tasks.iter().zip(&qb.tasks) {
                assert_eq!(x.release_s.to_bits(), y.release_s.to_bits());
            }
        }
    }

    #[test]
    fn reduced_fidelity_truncates_to_a_queue_prefix() {
        let plan = ExperimentPlan::new()
            .scenarios(["urban-rush"])
            .distances([120.0])
            .scheduler(SchedulerSpec::MinMin)
            .seed(4);
        let full = plan.clone().trials().unwrap()[0].queue();
        let half_plan = plan.fidelity(Fidelity { route_frac: 0.5, replicates: 1 });
        let half = half_plan.trials().unwrap()[0].queue();
        assert!(half.len() < full.len(), "{} !< {}", half.len(), full.len());
        assert!(!half.is_empty());
        assert!(half.route_duration_s < full.route_duration_s);
        for (a, b) in half.tasks.iter().zip(&full.tasks) {
            assert_eq!(a.id, b.id, "truncation keeps a prefix");
            assert_eq!(a.release_s.to_bits(), b.release_s.to_bits());
        }
    }

    #[test]
    fn fidelity_replicates_match_the_replicates_builder() {
        let via_fid = ExperimentPlan::new()
            .scheduler(SchedulerSpec::MinMin)
            .distances([50.0])
            .seed(7)
            .fidelity(Fidelity { route_frac: 1.0, replicates: 3 });
        let via_reps = ExperimentPlan::new()
            .scheduler(SchedulerSpec::MinMin)
            .distances([50.0])
            .replicates(7, 3);
        let (a, b) = (via_fid.trials().unwrap(), via_reps.trials().unwrap());
        assert_eq!(a.len(), b.len());
        let sa: Vec<u64> = a.iter().map(|t| t.seed).collect();
        let sb: Vec<u64> = b.iter().map(|t| t.seed).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn unknown_scenario_is_rejected() {
        let err = ExperimentPlan::new()
            .scenarios(["not-a-scenario"])
            .scheduler(SchedulerSpec::MinMin)
            .trials()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown scenario"), "{err:#}");
    }

    #[test]
    fn empty_plans_are_rejected() {
        assert!(ExperimentPlan::new().trials().is_err(), "no schedulers");
        assert!(ExperimentPlan::new()
            .scheduler(SchedulerSpec::MinMin)
            .platform("not-a-platform")
            .trials()
            .is_err());
    }
}
