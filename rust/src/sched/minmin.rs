//! Min-Min [46] (Braun et al., the heuristic the paper calls "optimal" among
//! the eleven static heuristics): repeatedly take the (task, accelerator)
//! pair with the globally minimum completion time, assign it, update the
//! machine-available times, and repeat until the burst is mapped.
//!
//! As the paper notes (§7), Min-Min sees only per-task completion time —
//! never resource balance or matching score — which is exactly the blind
//! spot FlexAI exploits in Figures 12-14.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::Scheduler;

#[derive(Debug, Default)]
pub struct MinMin;

impl MinMin {
    pub fn new() -> MinMin {
        MinMin
    }
}

impl Scheduler for MinMin {
    fn name(&self) -> String {
        "Min-Min".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        if state.is_empty() {
            // Degenerate zero-accelerator platform: there is no completion
            // time to minimize — fall back to accel 0 for every task
            // instead of panicking mid-sweep.
            return vec![0; tasks.len()];
        }
        let mut rolling = state.clone();
        let mut out = vec![usize::MAX; tasks.len()];
        let mut unassigned: Vec<usize> = (0..tasks.len()).collect();

        while !unassigned.is_empty() {
            // Global minimum completion time over (unassigned task, accel).
            let mut best: Option<(usize, usize, f64)> = None; // (pos, accel, ct)
            for (pos, &ti) in unassigned.iter().enumerate() {
                for a in 0..rolling.len() {
                    let ct = rolling.est_completion(&tasks[ti], a);
                    if best.map(|(_, _, b)| ct < b).unwrap_or(true) {
                        best = Some((pos, a, ct));
                    }
                }
            }
            let Some((pos, accel, _)) = best else {
                break; // unreachable: platform non-empty is checked above
            };
            let ti = unassigned.swap_remove(pos);
            rolling.apply(&tasks[ti], accel);
            out[ti] = accel;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sim::{simulate, SimOptions};

    #[test]
    fn assigns_single_task_to_fastest_accel() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        // GOTURN is fastest on MconvMC (Table 8): slots 8..11 on HMAI.
        let goturn = q
            .tasks
            .iter()
            .find(|t| t.model == crate::workload::ModelKind::Goturn)
            .unwrap()
            .clone();
        let mut s = MinMin::new();
        let a = s.schedule_batch(std::slice::from_ref(&goturn), &state);
        assert!(a[0] >= 8, "GOTURN should go to an MconvMC slot, got {}", a[0]);
    }

    #[test]
    fn beats_worst_case_on_makespan() {
        let q = crate::sched::tests::small_queue(2);
        let platform = Platform::hmai();
        let mm = simulate(&q, &platform, &mut MinMin::new(), SimOptions::default());
        let wc = simulate(
            &q,
            &platform,
            &mut crate::sched::worst::WorstCase::new(),
            SimOptions::default(),
        );
        assert!(mm.summary.makespan_s < wc.summary.makespan_s);
        assert!(mm.summary.wait_s < wc.summary.wait_s);
    }

    #[test]
    fn zero_accelerator_platform_does_not_panic() {
        // Regression: the global-min search used to unwrap an empty min.
        let platform = Platform::from_counts("empty", 0, 0, 0);
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let burst: Vec<_> = q.tasks.iter().take(5).cloned().collect();
        let a = MinMin::new().schedule_batch(&burst, &state);
        assert_eq!(a, vec![0; 5]);
    }

    #[test]
    fn burst_spreads_over_multiple_accels() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(3);
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        let mut s = MinMin::new();
        let a = s.schedule_batch(&burst, &state);
        let distinct: std::collections::HashSet<_> = a.iter().collect();
        assert!(distinct.len() >= 6, "Min-Min should spread a 30-task burst");
    }
}
