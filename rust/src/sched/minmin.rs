//! Min-Min [46] (Braun et al., the heuristic the paper calls "optimal" among
//! the eleven static heuristics): repeatedly take the (task, accelerator)
//! pair with the globally minimum completion time, assign it, update the
//! machine-available times, and repeat until the burst is mapped.
//!
//! As the paper notes (§7), Min-Min sees only per-task completion time —
//! never resource balance or matching score — which is exactly the blind
//! spot FlexAI exploits in Figures 12-14.
//!
//! ## Incremental inner loop
//!
//! The textbook formulation rescans every (unassigned task, accel) pair per
//! assignment — O(B²·N) per burst.  This implementation caches, per
//! unassigned task, its best `(accel, completion)` pair and exploits two
//! monotonicity facts that hold within one burst (the clock is fixed and
//! FIFO drains only grow):
//!
//! * assigning a task to accelerator `a` changes *only* `a`'s drain time,
//!   and only upward — so a task whose cached best is some `b ≠ a` keeps
//!   exactly its cached pair (value *and* first-accel tie-break, since the
//!   only changed column got worse);
//! * a task whose cached best *is* `a` may have lost its minimum, so only
//!   those tasks re-scan their row.
//!
//! The per-assignment cost drops to O(B) for the cached-minima sweep plus
//! O(K·N) for the K tasks whose best sat on the chosen accelerator —
//! O(B²+B·K·N) per burst instead of O(B²·N), with K ≪ B in practice.  The
//! tie-break is provably the global scan's: the global scan picks the
//! first (task-position, accel) pair in lexicographic scan order attaining
//! the minimum; first-accel-per-task composed with first-position-across-
//! tasks selects the same pair.  `reference::RefMinMin` keeps the global
//! rescan as the executable spec and the tests below (plus
//! `tests/perf_equiv.rs`) pin exact assignment equality.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::{RolloutCtx, Scheduler};

#[derive(Debug, Default)]
pub struct MinMin;

impl MinMin {
    pub fn new() -> MinMin {
        MinMin
    }
}

impl Scheduler for MinMin {
    fn name(&self) -> String {
        "Min-Min".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        if state.is_empty() {
            // Degenerate zero-accelerator platform: there is no completion
            // time to minimize — fall back to accel 0 for every task
            // instead of panicking mid-sweep.
            return vec![0; tasks.len()];
        }
        let mut ctx = RolloutCtx::new(state);
        let mut out = vec![usize::MAX; tasks.len()];
        // Per-task cached best (accel, completion): the first accel (in
        // ascending slot order) attaining the task's minimal completion.
        let mut cached: Vec<(usize, f64)> =
            tasks.iter().map(|t| ctx.best_completion(t)).collect();
        let mut unassigned: Vec<usize> = (0..tasks.len()).collect();

        while !unassigned.is_empty() {
            // First position (in unassigned order) with the strictly
            // minimal cached completion — the global scan's tie-break.
            let mut best: Option<(usize, f64)> = None; // (pos, ct)
            for (pos, &ti) in unassigned.iter().enumerate() {
                let ct = cached[ti].1;
                if best.map(|(_, b)| ct < b).unwrap_or(true) {
                    best = Some((pos, ct));
                }
            }
            // lint:allow(panic-in-hot-path): the loop runs while unassigned
            // is non-empty, so a best candidate always exists.
            let (pos, _) = best.expect("unassigned is non-empty");
            let ti = unassigned.swap_remove(pos);
            let accel = cached[ti].0;
            ctx.push(&tasks[ti], accel);
            out[ti] = accel;
            // Only `accel`'s drain moved (upward): every cached best on a
            // different accelerator is still exact, tasks that sat on
            // `accel` re-scan their row.  On a chiplet platform the commit
            // also (a) reserved `accel`'s route links — any slot sharing a
            // link saw its column worsen — and (b) made `accel`'s weights
            // resident, so a *same-model* task's `accel` column may have
            // IMPROVED; both kinds of row re-scan.  Rows whose cached-best
            // column is link-disjoint from the route and whose model
            // differs saw their best column unchanged and other columns
            // only worsen or stay, so cached value and first-of-min
            // tie-break both survive.  `accel_mask == 0` (monolithic, or
            // an ingress slot) reduces this to exactly the old condition.
            let accel_mask = ctx.route_mask(accel);
            let model = tasks[ti].model;
            for &tj in &unassigned {
                if cached[tj].0 == accel
                    || (accel_mask != 0
                        && (ctx.route_mask(cached[tj].0) & accel_mask != 0
                            || tasks[tj].model == model))
                {
                    cached[tj] = ctx.best_completion(&tasks[tj]);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::reference::RefMinMin;
    use crate::sim::{simulate, SimOptions};

    #[test]
    fn assigns_single_task_to_fastest_accel() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        // GOTURN is fastest on MconvMC (Table 8): slots 8..11 on HMAI.
        let goturn = q
            .tasks
            .iter()
            .find(|t| t.model == crate::workload::ModelKind::Goturn)
            .unwrap()
            .clone();
        let mut s = MinMin::new();
        let a = s.schedule_batch(std::slice::from_ref(&goturn), &state);
        assert!(a[0] >= 8, "GOTURN should go to an MconvMC slot, got {}", a[0]);
    }

    #[test]
    fn beats_worst_case_on_makespan() {
        let q = crate::sched::tests::small_queue(2);
        let platform = Platform::hmai();
        let mm = simulate(&q, &platform, &mut MinMin::new(), SimOptions::default());
        let wc = simulate(
            &q,
            &platform,
            &mut crate::sched::worst::WorstCase::new(),
            SimOptions::default(),
        );
        assert!(mm.summary.makespan_s < wc.summary.makespan_s);
        assert!(mm.summary.wait_s < wc.summary.wait_s);
    }

    #[test]
    fn zero_accelerator_platform_does_not_panic() {
        // Regression: the global-min search used to unwrap an empty min.
        let platform = Platform::from_counts("empty", 0, 0, 0);
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let burst: Vec<_> = q.tasks.iter().take(5).cloned().collect();
        let a = MinMin::new().schedule_batch(&burst, &state);
        assert_eq!(a, vec![0; 5]);
    }

    #[test]
    fn burst_spreads_over_multiple_accels() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(3);
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        let mut s = MinMin::new();
        let a = s.schedule_batch(&burst, &state);
        let distinct: std::collections::HashSet<_> = a.iter().collect();
        assert!(distinct.len() >= 6, "Min-Min should spread a 30-task burst");
    }

    #[test]
    fn matches_reference_global_rescan_exactly() {
        // The HMAI platform is tie-heavy (4 identical SconvOD slots, 4
        // identical SconvIC slots), so this pins the first-of-equal-minima
        // tie-break, across burst sizes, backlog, derating and failures.
        let q = crate::sched::tests::small_queue(4);
        for spec in ["hmai", "so:2@2x,si:2,mm:2@0.5x", "1,1,1", "so:2@2x,si:2,mm:2@0.5x+mesh2x2"]
        {
            let platform = Platform::parse(spec).unwrap();
            let mut state = ShadowState::new(&platform, NormScales::unit());
            for (round, take) in [1usize, 2, 7, 30, 61].into_iter().enumerate() {
                let burst: Vec<_> = q.tasks.iter().take(take).cloned().collect();
                let fast = MinMin::new().schedule_batch(&burst, &state);
                let slow = RefMinMin::new().schedule_batch(&burst, &state);
                assert_eq!(fast, slow, "{spec} burst of {take}");
                // Evolve the state between rounds: backlog + faults.
                state.apply(&burst[0], round % state.len());
                if round == 2 {
                    state.set_speed(0, 0.0);
                }
                if round == 3 {
                    state.set_speed(1 % state.len(), 0.5);
                }
            }
        }
    }
}
