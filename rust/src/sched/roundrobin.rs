//! Round-robin: rotate through accelerators regardless of fit.  Not a paper
//! baseline, but a useful sanity floor — it balances load blindly, paying
//! for dataflow mismatch.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::Scheduler;

#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> String {
        "RoundRobin".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        tasks
            .iter()
            .map(|_| {
                let a = self.next;
                self.next = (self.next + 1) % state.len();
                a
            })
            .collect()
    }

    fn reset(&mut self) {
        self.next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    #[test]
    fn cycles_through_all_accels() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let burst: Vec<_> = q.tasks.iter().take(22).cloned().collect();
        let mut rr = RoundRobin::new();
        let a = rr.schedule_batch(&burst, &state);
        assert_eq!(&a[..11], &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(a[11], 0);
        rr.reset();
        assert_eq!(rr.schedule_batch(&burst[..1], &state), vec![0]);
    }
}
