//! Round-robin: rotate through accelerators regardless of fit.  Not a paper
//! baseline, but a useful sanity floor — it balances load blindly, paying
//! for dataflow mismatch.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::Scheduler;

#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> String {
        "RoundRobin".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let n = state.len();
        tasks
            .iter()
            .map(|_| {
                // Rotate to the next *up* accelerator (platform events can
                // fail one mid-route): the first up slot at or past the
                // cursor, wrapping to the first up slot overall; with
                // everything up this is the plain `next, next+1, ...`
                // cycle, and with everything down the cursor itself.
                let start = self.next % n;
                let a = state
                    .up_iter()
                    .find(|&i| i >= start)
                    .or_else(|| state.up_iter().next())
                    .unwrap_or(start);
                self.next = (a + 1) % n;
                a
            })
            .collect()
    }

    fn reset(&mut self) {
        self.next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    #[test]
    fn cycles_through_all_accels() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let burst: Vec<_> = q.tasks.iter().take(22).cloned().collect();
        let mut rr = RoundRobin::new();
        let a = rr.schedule_batch(&burst, &state);
        assert_eq!(&a[..11], &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(a[11], 0);
        rr.reset();
        assert_eq!(rr.schedule_batch(&burst[..1], &state), vec![0]);
    }

    #[test]
    fn skips_failed_accels_and_resumes_on_recovery() {
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(2);
        let burst: Vec<_> = q.tasks.iter().take(11).cloned().collect();
        state.set_speed(0, 0.0);
        state.set_speed(3, 0.0);
        let mut rr = RoundRobin::new();
        let a = rr.schedule_batch(&burst, &state);
        assert!(a.iter().all(|&i| i != 0 && i != 3), "assigned a failed accel: {a:?}");
        assert_eq!(a[0], 1, "cursor rolls past the dead slot");
        // Recovery: the cycle includes every accelerator again.
        state.set_speed(0, 1.0);
        state.set_speed(3, 1.0);
        let b = rr.schedule_batch(&burst, &state);
        assert!(b.contains(&0) && b.contains(&3));
    }
}
