//! `RolloutCtx` — the shared per-burst scheduling context behind the
//! hot-path overhaul: per-burst cost-row caching plus a slim rollout view
//! of the platform FIFO state.
//!
//! Every burst scheduler used to drive its inner loop through a full
//! `ShadowState` clone (kinds + sizes + busy_until + speed + the whole
//! `PlatformMetrics` vector) and re-divide `cost.time_s / speed` on every
//! (task, accelerator) probe.  GA and SA paid that clone once *per genome*;
//! Min-Min, ATA and EDP once per burst plus a metrics update per applied
//! task.  None of that state is observable in the result: a scheduler only
//! returns an assignment vector, and the engine re-applies it to the real
//! state.
//!
//! `RolloutCtx` keeps exactly what the inner loops read:
//!
//! * `compute[i][m]` — speed-adjusted execution seconds of model `m` on
//!   slot `i` (`cost.time_s / speed[i]`, `+inf` for a failed slot), cached
//!   once per burst.  Speeds cannot change while a scheduler holds
//!   `&ShadowState`, so the cache is exact — and division by a speed of
//!   1.0 is bit-exact in IEEE 754, so caching the quotient changes no bits.
//! * `energy[i][m]` — the speed-independent energy row.
//! * `busy` — a scratch drain vector seeded from `state.busy_until`
//!   (reset per rollout), the only mutable platform state a rollout needs.
//! * the genome-invariant Σ per-task best-case (time, energy) fold of
//!   [`rollout_cost`](crate::sched::fitness::rollout_cost), hoisted out of
//!   the per-genome loop (it depends on the burst and the cost rows only).
//!
//! Bit-identity with the pre-overhaul paths is pinned by
//! `tests/perf_equiv.rs` against the executable specs in
//! [`reference`](crate::sched::reference).

use crate::env::taskgen::Task;
use crate::interconnect::CommState;
use crate::sim::ShadowState;
use crate::workload::ALL_MODELS;

/// Energy weight of the GA/SA rollout cost (see
/// [`fitness`](crate::sched::fitness)): joules are converted to
/// "equivalent seconds" via the burst's own best-case time/energy ratio,
/// then discounted so makespan dominates and energy breaks ties.
pub(crate) const ENERGY_WEIGHT: f64 = 0.25;

/// Number of workload models (the width of a cost row).
const M: usize = ALL_MODELS.len();

/// Per-burst scheduling context: cached cost rows + a slim rollout view.
///
/// Construct once per `schedule_batch` call (the state cannot change while
/// the scheduler borrows it); probe with [`RolloutCtx::est_response`] /
/// [`RolloutCtx::est_completion`] / [`RolloutCtx::est_energy`], commit
/// sequential picks with [`RolloutCtx::push`], and price whole assignment
/// vectors with [`RolloutCtx::rollout_cost`] — all without cloning the
/// `ShadowState` or touching its metrics.
pub struct RolloutCtx<'a> {
    state: &'a ShadowState,
    n: usize,
    now: f64,
    /// `compute[i * M + m]`: speed-adjusted execution seconds of model `m`
    /// on slot `i` (`+inf` on a failed slot).
    compute: Vec<f64>,
    /// `energy[i * M + m]`: energy of model `m` on slot `i` (speed- and
    /// backlog-independent).
    energy: Vec<f64>,
    /// Rolling drain times, seeded from `state.busy_until`.
    busy: Vec<f64>,
    /// Genome-invariant Σ per-task best-case time (s) — only meaningful
    /// when built with [`RolloutCtx::for_burst`].
    best_t: f64,
    /// Genome-invariant Σ per-task best-case energy (J).
    best_e: f64,
    /// Rolling interconnect scratch (link occupancy + weight residency),
    /// cloned from the state's comm view; `None` on monolithic platforms,
    /// where every expression below is textually the compute-only one.
    /// Mirrors `ShadowState`'s comm handling op for op, so estimates and
    /// pushes stay bit-identical to a cloned-state replay.
    comm: Option<CommState>,
}

impl<'a> RolloutCtx<'a> {
    /// Context for sequential scans (Min-Min, ATA, EDP, SA's greedy
    /// start): cost rows + rolling drain view, no best-case fold.
    pub fn new(state: &'a ShadowState) -> RolloutCtx<'a> {
        let n = state.len();
        let mut compute = vec![0.0; n * M];
        let mut energy = vec![0.0; n * M];
        for i in 0..n {
            for m in ALL_MODELS {
                let c = state.cost(i, m);
                compute[i * M + m.index()] = c.time_s / state.speed[i];
                energy[i * M + m.index()] = c.energy_j;
            }
        }
        RolloutCtx {
            state,
            n,
            now: state.now,
            compute,
            energy,
            busy: state.busy_until.clone(),
            best_t: 0.0,
            best_e: 0.0,
            comm: state.comm.clone(),
        }
    }

    /// Context for GA/SA fitness rollouts over `tasks`: everything
    /// [`RolloutCtx::new`] caches, plus the genome-invariant Σ per-task
    /// best-case (time, energy) fold that prices energy in "equivalent
    /// seconds".  The fold walks slots in ascending order per model — the
    /// same minima, in the same order, the old per-genome inner loop
    /// produced, so [`RolloutCtx::rollout_cost`] is bit-identical.  The
    /// fold stays compute-only on chiplet platforms: it is a genome-
    /// invariant normalization constant, not a per-candidate estimate, so
    /// interconnect delays do not belong in it.
    pub fn for_burst(tasks: &[Task], state: &'a ShadowState) -> RolloutCtx<'a> {
        let mut ctx = RolloutCtx::new(state);
        let mut best = [(f64::INFINITY, f64::INFINITY); M]; // (time, energy)
        for i in 0..ctx.n {
            for m in ALL_MODELS {
                let c = state.cost(i, m);
                let b = &mut best[m.index()];
                b.0 = b.0.min(c.time_s);
                b.1 = b.1.min(c.energy_j);
            }
        }
        for task in tasks {
            let (bt, be) = best[task.model.index()];
            ctx.best_t += bt;
            ctx.best_e += be;
        }
        ctx
    }

    /// Number of accelerator slots.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Predicted response time (wait + compute) of `task` on slot `i`
    /// against the *rolling* drain view — bit-identical to
    /// `ShadowState::est_response` on a clone that applied the same picks
    /// (including the interconnect plan on chiplet platforms).
    #[inline]
    pub fn est_response(&self, task: &Task, i: usize) -> f64 {
        let compute = self.compute[i * M + task.model.index()];
        if let Some(comm) = &self.comm {
            if compute.is_finite() {
                if let Some(p) = comm.plan(i, task.model, self.now, self.busy[i], compute) {
                    return p.done_s - self.now;
                }
            }
        }
        (self.busy[i] - self.now).max(0.0) + compute
    }

    /// Predicted completion-time point on the route clock.
    #[inline]
    pub fn est_completion(&self, task: &Task, i: usize) -> f64 {
        self.now + self.est_response(task, i)
    }

    /// Energy `task` would consume on slot `i`.
    #[inline]
    pub fn est_energy(&self, task: &Task, i: usize) -> f64 {
        self.energy[i * M + task.model.index()]
    }

    /// First slot (ascending order) minimizing `task`'s completion time,
    /// with that minimal completion time.  The strict `<` keeps the first
    /// of equal minima — the exact tie-break of a `(task, accel)` scan in
    /// ascending accel order.  Panics on an empty platform (callers guard).
    pub fn best_completion(&self, task: &Task) -> (usize, f64) {
        let mut best: Option<(usize, f64)> = None;
        for a in 0..self.n {
            let ct = self.est_completion(task, a);
            if best.map(|(_, b)| ct < b).unwrap_or(true) {
                best = Some((a, ct));
            }
        }
        // lint:allow(panic-in-hot-path): the accelerator loop above always
        // yields a candidate on a non-empty platform — callers guard.
        best.expect("non-empty platform")
    }

    /// Commit `task` to slot `i` in the rolling view: the FIFO update of
    /// `ShadowState::apply`, minus the metrics.  A failed slot loses the
    /// task and leaves its (dead) FIFO untouched, exactly like `apply`; on
    /// a chiplet platform the route's links and residency are reserved,
    /// exactly like `apply`.
    #[inline]
    pub fn push(&mut self, task: &Task, i: usize) {
        let compute = self.compute[i * M + task.model.index()];
        if !compute.is_finite() {
            return; // dead slot: the task is lost, the FIFO stays clean
        }
        if let Some(comm) = &mut self.comm {
            if let Some(p) = comm.plan(i, task.model, self.now, self.busy[i], compute) {
                if !p.done_s.is_finite() {
                    return; // severed route: task lost, FIFO stays clean
                }
                comm.commit(i, task.model, &p);
                self.busy[i] = p.finish_s;
                return;
            }
        }
        let start = self.busy[i].max(self.now);
        self.busy[i] = start + compute;
    }

    /// Link-route mask of slot `i` (0 on monolithic platforms or
    /// ingress-chiplet slots) — Min-Min's incremental rescan consults this
    /// to find rows a commit's link/residency changes could have touched.
    #[inline]
    pub fn route_mask(&self, i: usize) -> u64 {
        self.comm.as_ref().map_or(0, |c| c.route_mask(i))
    }

    /// Cost of mapping `tasks` with `assignment`: burst-local makespan
    /// (when the last accelerator drains) plus normalized energy — the
    /// GA/SA fitness of
    /// [`fitness::rollout_cost`](super::fitness::rollout_cost), evaluated
    /// against the slim view.  Resets the rolling drain view first, so one context
    /// prices any number of genomes.  Requires [`RolloutCtx::for_burst`]
    /// construction (the best-case fold) over the same `tasks`.
    pub fn rollout_cost(&mut self, tasks: &[Task], assignment: &[usize]) -> f64 {
        debug_assert_eq!(tasks.len(), assignment.len());
        self.busy.copy_from_slice(&self.state.busy_until);
        if let (Some(scratch), Some(orig)) = (self.comm.as_mut(), self.state.comm.as_ref()) {
            scratch.reset_from(orig);
        }
        let mut energy = 0.0;
        for (task, &a) in tasks.iter().zip(assignment) {
            let m = task.model.index();
            let compute = self.compute[a * M + m];
            if !compute.is_finite() {
                // Mapping any task to a failed accelerator loses it: the
                // candidate is unexecutable, so it prices at +inf (dead
                // slots leave the drain untouched, so without this guard
                // they would look *free*).
                return f64::INFINITY;
            }
            let mut committed = false;
            if let Some(comm) = &mut self.comm {
                if let Some(p) = comm.plan(a, task.model, self.now, self.busy[a], compute) {
                    if !p.done_s.is_finite() {
                        // A severed route loses the task just like a dead
                        // slot: the candidate is unexecutable.
                        return f64::INFINITY;
                    }
                    comm.commit(a, task.model, &p);
                    self.busy[a] = p.finish_s;
                    committed = true;
                }
            }
            if !committed {
                let start = self.busy[a].max(self.now);
                self.busy[a] = start + compute;
            }
            energy += self.energy[a * M + m];
        }
        let drain = self.busy.iter().fold(0.0_f64, |m, &b| m.max(b - self.now));
        let sec_per_joule = if self.best_e > 0.0 { self.best_t / self.best_e } else { 0.0 };
        drain + ENERGY_WEIGHT * energy * sec_per_joule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::tests::small_queue;

    fn mixed_state() -> ShadowState {
        let p = Platform::parse("so:2@2x,si:2,mm:2@0.5x").unwrap();
        ShadowState::new(&p, NormScales::unit())
    }

    #[test]
    fn cached_rows_match_state_estimates_bit_for_bit() {
        let q = small_queue(1);
        let mut state = mixed_state();
        state.set_speed(1, 0.5); // derated
        state.set_speed(4, 0.0); // failed
        for t in q.tasks.iter().take(7) {
            state.apply(t, 0); // backlog on slot 0
        }
        let ctx = RolloutCtx::new(&state);
        for task in q.tasks.iter().take(20) {
            for i in 0..state.len() {
                assert_eq!(
                    ctx.est_response(task, i).to_bits(),
                    state.est_response(task, i).to_bits(),
                    "slot {i}"
                );
                assert_eq!(
                    ctx.est_completion(task, i).to_bits(),
                    state.est_completion(task, i).to_bits()
                );
                assert_eq!(
                    ctx.est_energy(task, i).to_bits(),
                    state.est_energy(task, i).to_bits()
                );
            }
        }
    }

    #[test]
    fn push_tracks_apply_fifo_updates() {
        let q = small_queue(2);
        let state = {
            let mut s = mixed_state();
            s.set_speed(3, 0.0);
            s
        };
        let mut rolling = state.clone();
        let mut ctx = RolloutCtx::new(&state);
        for (k, task) in q.tasks.iter().take(24).enumerate() {
            let a = k % state.len(); // hits the dead slot too
            rolling.apply(task, a);
            ctx.push(task, a);
            for i in 0..state.len() {
                assert_eq!(ctx.busy[i].to_bits(), rolling.busy_until[i].to_bits(), "slot {i}");
            }
        }
    }

    #[test]
    fn best_completion_matches_brute_force_first_min() {
        let q = small_queue(3);
        let mut state = mixed_state();
        state.set_speed(2, 0.0);
        let mut ctx = RolloutCtx::new(&state);
        for task in q.tasks.iter().take(30) {
            let (a, ct) = ctx.best_completion(task);
            let mut want: Option<(usize, f64)> = None;
            for i in 0..state.len() {
                let c = ctx.est_completion(task, i);
                if want.map(|(_, b)| c < b).unwrap_or(true) {
                    want = Some((i, c));
                }
            }
            let (wa, wct) = want.unwrap();
            assert_eq!(a, wa);
            assert_eq!(ct.to_bits(), wct.to_bits());
            ctx.push(task, a);
        }
    }

    #[test]
    fn rollout_cost_resets_between_genomes() {
        let q = small_queue(4);
        let state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(12).cloned().collect();
        let spread: Vec<usize> = (0..12).map(|i| i % 11).collect();
        let piled = vec![0usize; 12];
        let mut ctx = RolloutCtx::for_burst(&burst, &state);
        let a1 = ctx.rollout_cost(&burst, &spread);
        let _ = ctx.rollout_cost(&burst, &piled);
        let a2 = ctx.rollout_cost(&burst, &spread);
        assert_eq!(a1.to_bits(), a2.to_bits(), "stale drain state leaked");
    }

    fn noc_state() -> ShadowState {
        let p = Platform::parse("so:2@2x,si:2,mm:2@0.5x+mesh2x2").unwrap();
        ShadowState::new(&p, NormScales::unit())
    }

    #[test]
    fn comm_estimates_and_pushes_track_shadow_state() {
        // On a chiplet platform the slim context must mirror a full
        // ShadowState replay bit for bit: same estimates before each pick,
        // same FIFO drains after, with links and residency in lockstep.
        let q = small_queue(6);
        let state = noc_state();
        let mut rolling = state.clone();
        let mut ctx = RolloutCtx::new(&state);
        for (k, task) in q.tasks.iter().take(24).enumerate() {
            for i in 0..state.len() {
                assert_eq!(
                    ctx.est_response(task, i).to_bits(),
                    rolling.est_response(task, i).to_bits(),
                    "task {k} slot {i}"
                );
            }
            let a = k % state.len();
            rolling.apply(task, a);
            ctx.push(task, a);
            for i in 0..state.len() {
                assert_eq!(ctx.busy[i].to_bits(), rolling.busy_until[i].to_bits(), "slot {i}");
            }
        }
        assert!(ctx.route_mask(1) != 0, "off-ingress slot has links");
        assert_eq!(ctx.route_mask(0), 0, "ingress slot moves nothing");
    }

    #[test]
    fn comm_rollout_cost_resets_scratch() {
        let q = small_queue(7);
        let state = noc_state();
        let n = state.len();
        let burst: Vec<_> = q.tasks.iter().take(10).cloned().collect();
        let spread: Vec<usize> = (0..10).map(|i| i % n).collect();
        let piled = vec![1usize; 10];
        let mut ctx = RolloutCtx::for_burst(&burst, &state);
        let a1 = ctx.rollout_cost(&burst, &spread);
        let b = ctx.rollout_cost(&burst, &piled);
        let a2 = ctx.rollout_cost(&burst, &spread);
        assert_eq!(a1.to_bits(), a2.to_bits(), "stale link/residency scratch leaked");
        assert_ne!(a1.to_bits(), b.to_bits());
    }

    #[test]
    fn dead_slot_genomes_price_at_infinity() {
        let q = small_queue(5);
        let mut state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        state.set_speed(6, 0.0);
        let burst: Vec<_> = q.tasks.iter().take(8).cloned().collect();
        let mut ctx = RolloutCtx::for_burst(&burst, &state);
        let mut genome: Vec<usize> = (0..8).collect();
        assert!(ctx.rollout_cost(&burst, &genome).is_finite());
        genome[3] = 6;
        assert!(ctx.rollout_cost(&burst, &genome).is_infinite());
    }
}
