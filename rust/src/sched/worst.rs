//! The "unscheduled worse case" baseline (§8.3): no load balancing at all —
//! every task piles onto the accelerator that is currently the most
//! backlogged (ties broken toward index 0, so an empty platform degenerates
//! to "everything on accelerator 0").  This is the pathological mapping the
//! paper uses as the floor of Figures 12-14.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::{sequential, Scheduler};

#[derive(Debug, Default)]
pub struct WorstCase;

impl WorstCase {
    pub fn new() -> WorstCase {
        WorstCase
    }
}

impl Scheduler for WorstCase {
    fn name(&self) -> String {
        "WorstCase".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        sequential(tasks, state, |_, s| {
            // Most-backlogged *up* accelerator (worst case still has to be
            // a case the platform can execute); ties keep the lowest index,
            // and an all-down platform degenerates to accel 0 as before.
            let mut best: Option<usize> = None;
            for i in s.up_iter() {
                if best.map(|b| s.queue_delay(i) > s.queue_delay(b)).unwrap_or(true) {
                    best = Some(i);
                }
            }
            best.unwrap_or(0)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    #[test]
    fn piles_everything_on_one_accel() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let burst: Vec<_> = q.tasks.iter().take(20).cloned().collect();
        let mut s = WorstCase::new();
        let a = s.schedule_batch(&burst, &state);
        // From an idle platform, everything lands on accel 0.
        assert!(a.iter().all(|&i| i == 0));
    }

    #[test]
    fn piles_onto_an_up_accel_when_zero_fails() {
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        state.set_speed(0, 0.0);
        let q = crate::sched::tests::small_queue(2);
        let burst: Vec<_> = q.tasks.iter().take(10).cloned().collect();
        let a = WorstCase::new().schedule_batch(&burst, &state);
        assert!(a.iter().all(|&i| i == 1), "worst case moves to the next up accel: {a:?}");
    }
}
