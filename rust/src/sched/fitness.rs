//! Shared fitness/cost function for the guided random-search baselines
//! (GA, SA).  As the paper notes (§7): "a fitness equation in GA and a
//! cost function in SA are needed ... thus the global performance like
//! resource utilization of HMAI can't be taken into account" — so this
//! cost deliberately covers only *time and energy* (Table 11), never
//! R_Balance or MS.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

/// Cost of mapping the burst `tasks` with `assignment`: the burst-local
/// makespan (when the last accelerator drains) plus normalized energy.
/// Lower is better.
/// Energy weight: joules are converted to "equivalent seconds" via the
/// burst's own best-case time/energy ratio, then discounted so makespan
/// dominates and energy breaks ties.
const ENERGY_WEIGHT: f64 = 0.25;

pub fn rollout_cost(tasks: &[Task], assignment: &[usize], state: &ShadowState) -> f64 {
    debug_assert_eq!(tasks.len(), assignment.len());
    let mut rolling = state.clone();
    let mut energy = 0.0;
    // Burst-intrinsic conversion: seconds per joule at the best-case
    // operating point, so the two terms are commensurate regardless of
    // burst composition.
    let (mut best_t, mut best_e) = (0.0, 0.0);
    for (task, &a) in tasks.iter().zip(assignment) {
        let applied = rolling.apply(task, a);
        if !applied.response_s.is_finite() {
            // Mapping any task to a failed accelerator loses it: the
            // candidate is unexecutable, so it prices at +inf (dead slots
            // leave the rollout's drain untouched, so without this guard
            // they would look *free*).
            return f64::INFINITY;
        }
        energy += applied.energy_j;
        let mut bt = f64::INFINITY;
        let mut be = f64::INFINITY;
        for i in 0..state.len() {
            // Per-slot cost rows: sized cores price their own best case.
            let c = state.cost(i, task.model);
            bt = bt.min(c.time_s);
            be = be.min(c.energy_j);
        }
        best_t += bt;
        best_e += be;
    }
    let drain = rolling
        .busy_until
        .iter()
        .fold(0.0_f64, |m, &b| m.max(b - state.now));
    let sec_per_joule = if best_e > 0.0 { best_t / best_e } else { 0.0 };
    drain + ENERGY_WEIGHT * energy * sec_per_joule
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::tests::small_queue;

    #[test]
    fn balanced_assignment_costs_less_than_piled() {
        let q = small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(11).cloned().collect();
        let piled = vec![0; 11];
        let spread: Vec<usize> = (0..11).collect();
        assert!(
            rollout_cost(&burst, &spread, &state) < rollout_cost(&burst, &piled, &state)
        );
    }

    #[test]
    fn cost_does_not_mutate_state() {
        let q = small_queue(2);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(5).cloned().collect();
        let _ = rollout_cost(&burst, &[0, 1, 2, 3, 4], &state);
        assert!(state.busy_until.iter().all(|&b| b == 0.0));
    }
}
