//! Shared fitness/cost function for the guided random-search baselines
//! (GA, SA).  As the paper notes (§7): "a fitness equation in GA and a
//! cost function in SA are needed ... thus the global performance like
//! resource utilization of HMAI can't be taken into account" — so this
//! cost deliberately covers only *time and energy* (Table 11), never
//! R_Balance or MS.
//!
//! This free function is the thin compatibility wrapper over
//! [`RolloutCtx::rollout_cost`](super::RolloutCtx::rollout_cost): it
//! builds a fresh per-burst context (cost rows + the genome-invariant
//! best-case fold) and prices one assignment.  GA and SA construct the
//! context once per burst instead, so population/neighbor loops pay
//! neither the old full `ShadowState` clone nor the redundant O(B·N)
//! best-case rescan per genome.  `reference::ref_rollout_cost` keeps the
//! pre-overhaul implementation as the executable spec; bit-identity is
//! pinned in the tests below and in `tests/perf_equiv.rs`.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::RolloutCtx;

/// Cost of mapping the burst `tasks` with `assignment`: the burst-local
/// makespan (when the last accelerator drains) plus normalized energy.
/// Lower is better.
/// Energy weight: joules are converted to "equivalent seconds" via the
/// burst's own best-case time/energy ratio, then discounted so makespan
/// dominates and energy breaks ties (see
/// [`rollout::ENERGY_WEIGHT`](super::rollout)).
pub fn rollout_cost(tasks: &[Task], assignment: &[usize], state: &ShadowState) -> f64 {
    RolloutCtx::for_burst(tasks, state).rollout_cost(tasks, assignment)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::reference::ref_rollout_cost;
    use crate::sched::tests::small_queue;
    use crate::util::rng::Rng;

    #[test]
    fn balanced_assignment_costs_less_than_piled() {
        let q = small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(11).cloned().collect();
        let piled = vec![0; 11];
        let spread: Vec<usize> = (0..11).collect();
        assert!(
            rollout_cost(&burst, &spread, &state) < rollout_cost(&burst, &piled, &state)
        );
    }

    #[test]
    fn cost_does_not_mutate_state() {
        let q = small_queue(2);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(5).cloned().collect();
        let _ = rollout_cost(&burst, &[0, 1, 2, 3, 4], &state);
        assert!(state.busy_until.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn matches_reference_bit_for_bit() {
        // The slim-view fast path against the full clone-and-apply spec:
        // random genomes on healthy, backlogged, derated and failed
        // platforms, including dead-slot (+inf) genomes and mixed cores.
        let q = small_queue(3);
        let mut rng = Rng::new(17);
        for spec in ["hmai", "so:2@2x,si:2,mm:2@0.5x", "so:2@2x,si:2,mm:2@0.5x+mesh2x2"] {
            let platform = Platform::parse(spec).unwrap();
            let mut state = ShadowState::new(&platform, NormScales::unit());
            for round in 0..4 {
                let burst: Vec<_> = q.tasks.iter().take(20).cloned().collect();
                for _ in 0..40 {
                    let genome: Vec<usize> =
                        burst.iter().map(|_| rng.below(state.len())).collect();
                    let fast = rollout_cost(&burst, &genome, &state);
                    let slow = ref_rollout_cost(&burst, &genome, &state);
                    assert_eq!(fast.to_bits(), slow.to_bits(), "{spec} round {round}");
                }
                // Mutate the platform between rounds: backlog, derate, fail.
                state.apply(&burst[0], round % state.len());
                match round {
                    1 => state.set_speed(1, 0.5),
                    2 => state.set_speed(0, 0.0),
                    _ => {}
                }
            }
        }
    }
}
