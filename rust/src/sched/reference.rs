//! Reference (pre-overhaul) burst schedulers — the executable spec of the
//! hot-path overhaul.
//!
//! These are the exact algorithms Min-Min, ATA, EDP, GA and SA ran before
//! the [`RolloutCtx`](super::RolloutCtx) / incremental-Min-Min rewrite:
//! full `ShadowState` clones, global (task × accel) rescans per
//! assignment, and a per-genome best-case fold.  They are deliberately
//! unoptimized — do **not** "fix" their complexity; their whole job is to
//! stay naive so that
//!
//! * `tests/perf_equiv.rs` can pin old-vs-new
//!   `SweepSummary::fingerprint` equality for every registered scheduler
//!   (the optimizations provably change no result bits), and
//! * `benches/bench_perf.rs` can time the "before" side of its speedup
//!   sections against the same build.
//!
//! Each reference scheduler reports the same display `name()` as its
//! optimized twin, so sweep rows and fingerprints are directly comparable.

use std::sync::Arc;

use crate::env::taskgen::Task;
use crate::sim::ShadowState;
use crate::util::rng::Rng;

use super::ga::GaParams;
use super::rollout::ENERGY_WEIGHT;
use super::sa::SaParams;
use super::{sequential, Registry, Scheduler, UpSet};

/// The pre-overhaul `fitness::rollout_cost`: clone the full state, `apply`
/// every (task, accel) pair, and re-fold the burst's best-case time/energy
/// inside the genome loop.  Kept bit-for-bit (the optimized path is tested
/// against it in `sched::fitness` and `tests/perf_equiv.rs`).
pub fn ref_rollout_cost(tasks: &[Task], assignment: &[usize], state: &ShadowState) -> f64 {
    debug_assert_eq!(tasks.len(), assignment.len());
    let mut rolling = state.clone();
    let mut energy = 0.0;
    let (mut best_t, mut best_e) = (0.0, 0.0);
    for (task, &a) in tasks.iter().zip(assignment) {
        let applied = rolling.apply(task, a);
        if !applied.response_s.is_finite() {
            return f64::INFINITY;
        }
        energy += applied.energy_j;
        let mut bt = f64::INFINITY;
        let mut be = f64::INFINITY;
        for i in 0..state.len() {
            let c = state.cost(i, task.model);
            bt = bt.min(c.time_s);
            be = be.min(c.energy_j);
        }
        best_t += bt;
        best_e += be;
    }
    let drain = rolling
        .busy_until
        .iter()
        .fold(0.0_f64, |m, &b| m.max(b - state.now));
    let sec_per_joule = if best_e > 0.0 { best_t / best_e } else { 0.0 };
    drain + ENERGY_WEIGHT * energy * sec_per_joule
}

/// Pre-overhaul Min-Min: O(B²·N) global (unassigned task × accel) rescan
/// per assignment against a full rolling clone.
#[derive(Debug, Default)]
pub struct RefMinMin;

impl RefMinMin {
    pub fn new() -> RefMinMin {
        RefMinMin
    }
}

impl Scheduler for RefMinMin {
    fn name(&self) -> String {
        "Min-Min".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        if state.is_empty() {
            return vec![0; tasks.len()];
        }
        let mut rolling = state.clone();
        let mut out = vec![usize::MAX; tasks.len()];
        let mut unassigned: Vec<usize> = (0..tasks.len()).collect();

        while !unassigned.is_empty() {
            // Global minimum completion time over (unassigned task, accel).
            let mut best: Option<(usize, usize, f64)> = None; // (pos, accel, ct)
            for (pos, &ti) in unassigned.iter().enumerate() {
                for a in 0..rolling.len() {
                    let ct = rolling.est_completion(&tasks[ti], a);
                    if best.map(|(_, _, b)| ct < b).unwrap_or(true) {
                        best = Some((pos, a, ct));
                    }
                }
            }
            let Some((pos, accel, _)) = best else {
                break; // unreachable: platform non-empty is checked above
            };
            let ti = unassigned.swap_remove(pos);
            rolling.apply(&tasks[ti], accel);
            out[ti] = accel;
        }
        out
    }
}

/// Pre-overhaul ATA: `sequential` over a full rolling clone, probing the
/// state's estimators per (task, accel).
#[derive(Debug, Default)]
pub struct RefAta;

impl RefAta {
    pub fn new() -> RefAta {
        RefAta
    }
}

impl Scheduler for RefAta {
    fn name(&self) -> String {
        "ATA".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        sequential(tasks, state, |task, s| {
            let mut best_safe: Option<(usize, f64)> = None; // (accel, energy)
            let mut best_any: Option<(usize, f64)> = None; // (accel, response)
            for a in 0..s.len() {
                let resp = s.est_response(task, a);
                let e = s.est_energy(task, a);
                if resp <= task.safety_time_s
                    && best_safe.map(|(_, be)| e < be).unwrap_or(true)
                {
                    best_safe = Some((a, e));
                }
                if best_any.map(|(_, br)| resp < br).unwrap_or(true) {
                    best_any = Some((a, resp));
                }
            }
            // lint:allow(panic-in-hot-path): every platform has at least one
            // accelerator, so best_any is always Some.
            best_safe.or(best_any).expect("non-empty platform").0
        })
    }
}

/// Pre-overhaul EDP: `sequential` over a full rolling clone.
#[derive(Debug, Default)]
pub struct RefEdp;

impl RefEdp {
    pub fn new() -> RefEdp {
        RefEdp
    }
}

impl Scheduler for RefEdp {
    fn name(&self) -> String {
        "EDP".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        sequential(tasks, state, |task, s| {
            let mut best = 0;
            let mut best_edp = f64::INFINITY;
            for a in 0..s.len() {
                let edp = s.est_energy(task, a) * s.est_response(task, a);
                if edp < best_edp {
                    best_edp = edp;
                    best = a;
                }
            }
            best
        })
    }
}

fn tournament_pick<'a>(
    rng: &mut Rng,
    rounds: usize,
    pop: &'a [(Vec<usize>, f64)],
) -> &'a (Vec<usize>, f64) {
    let mut best = &pop[rng.below(pop.len())];
    for _ in 1..rounds {
        let c = &pop[rng.below(pop.len())];
        if c.1 < best.1 {
            best = c;
        }
    }
    best
}

/// Pre-overhaul GA: one `ref_rollout_cost` (full clone + best-case
/// rescan) per genome, fresh population/offspring vectors per generation.
/// The rng stream is identical to [`super::ga::Ga`]'s.
#[derive(Debug)]
pub struct RefGa {
    pub params: GaParams,
    seed: u64,
    rng: Rng,
}

impl RefGa {
    pub fn new(seed: u64) -> RefGa {
        RefGa { params: GaParams::default(), seed, rng: Rng::new(seed) }
    }
}

impl Scheduler for RefGa {
    fn name(&self) -> String {
        "GA".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let ups = UpSet::new(state);
        let p = self.params;

        let mut pop: Vec<(Vec<usize>, f64)> = (0..p.population)
            .map(|_| {
                let genome: Vec<usize> =
                    tasks.iter().map(|_| ups.draw(&mut self.rng)).collect();
                let cost = ref_rollout_cost(tasks, &genome, state);
                (genome, cost)
            })
            .collect();

        for _gen in 0..p.generations {
            pop.sort_by(|a, b| a.1.total_cmp(&b.1));
            let mut next: Vec<(Vec<usize>, f64)> =
                pop.iter().take(p.elites).cloned().collect();
            while next.len() < p.population {
                let a = tournament_pick(&mut self.rng, p.tournament, &pop).0.clone();
                let b = tournament_pick(&mut self.rng, p.tournament, &pop).0.clone();
                let mut child = if self.rng.chance(p.crossover_p) {
                    a.iter()
                        .zip(&b)
                        .map(|(&x, &y)| if self.rng.chance(0.5) { x } else { y })
                        .collect()
                } else {
                    a
                };
                for g in child.iter_mut() {
                    if self.rng.chance(p.mutation_p) {
                        *g = ups.draw(&mut self.rng);
                    }
                }
                let cost = ref_rollout_cost(tasks, &child, state);
                next.push((child, cost));
            }
            pop = next;
        }
        pop.sort_by(|a, b| a.1.total_cmp(&b.1));
        pop.swap_remove(0).0
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

/// Pre-overhaul SA: greedy start through `sequential` (full clone), one
/// `ref_rollout_cost` per neighbor move.  The rng stream is identical to
/// [`super::sa::Sa`]'s.
#[derive(Debug)]
pub struct RefSa {
    pub params: SaParams,
    seed: u64,
    rng: Rng,
}

impl RefSa {
    pub fn new(seed: u64) -> RefSa {
        RefSa { params: SaParams::default(), seed, rng: Rng::new(seed) }
    }
}

impl Scheduler for RefSa {
    fn name(&self) -> String {
        "SA".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let n = state.len();
        if n == 0 {
            return vec![0; tasks.len()];
        }
        let ups = UpSet::new(state);
        let mut current = sequential(tasks, state, |task, s| {
            let mut best = 0;
            let mut best_ct = f64::INFINITY;
            for a in 0..s.len() {
                let ct = s.est_completion(task, a);
                if ct < best_ct {
                    best_ct = ct;
                    best = a;
                }
            }
            best
        });
        if tasks.len() <= 1 {
            return current;
        }

        let mut cur_cost = ref_rollout_cost(tasks, &current, state);
        let mut best = current.clone();
        let mut best_cost = cur_cost;
        let mut temp = (cur_cost * self.params.t0_frac).max(1e-12);

        for _ in 0..self.params.steps {
            let i = self.rng.below(tasks.len());
            let old = current[i];
            let new = ups.draw(&mut self.rng);
            if new == old {
                temp *= self.params.cooling;
                continue;
            }
            current[i] = new;
            let cost = ref_rollout_cost(tasks, &current, state);
            let accept = cost <= cur_cost
                || self.rng.chance(((cur_cost - cost) / temp).exp().min(1.0));
            if accept {
                cur_cost = cost;
                if cost < best_cost {
                    best_cost = cost;
                    best = current.clone();
                }
            } else {
                current[i] = old;
            }
            temp *= self.params.cooling;
        }
        best
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

/// Canonical names with a reference twin (the schedulers the overhaul
/// rewired).
pub const REFERENCE_NAMES: &[&str] = &["minmin", "ata", "edp", "ga", "sa"];

/// A [`Registry`] whose Min-Min / ATA / EDP / GA / SA factories build the
/// reference schedulers instead of the optimized ones (every other
/// scheduler keeps its stock factory).  `tests/perf_equiv.rs` runs whole
/// sweeps through this registry and demands fingerprint equality with the
/// stock one.
pub fn reference_registry() -> Registry {
    fn boxed<S: Scheduler + 'static>(s: S) -> anyhow::Result<Box<dyn Scheduler>> {
        Ok(Box::new(s))
    }
    let mut r = Registry::new();
    r.register("minmin", Arc::new(|_, _| boxed(RefMinMin::new())));
    r.register("ata", Arc::new(|_, _| boxed(RefAta::new())));
    r.register("edp", Arc::new(|_, _| boxed(RefEdp::new())));
    r.register("ga", Arc::new(|_, c| boxed(RefGa::new(c.seed))));
    r.register("sa", Arc::new(|_, c| boxed(RefSa::new(c.seed))));
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::tests::small_queue;

    #[test]
    fn reference_registry_overrides_keep_display_names() {
        let reg = reference_registry();
        for name in REFERENCE_NAMES {
            let s = reg.build_by_name(name, 3).unwrap();
            let stock = Registry::new().build_by_name(name, 3).unwrap();
            assert_eq!(s.name(), stock.name(), "{name}");
        }
        // Untouched factories still build.
        assert!(reg.build_by_name("rr", 0).is_ok());
    }

    #[test]
    fn reference_schedulers_assign_in_range() {
        let reg = reference_registry();
        let q = small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        for name in REFERENCE_NAMES {
            let mut s = reg.build_by_name(name, 7).unwrap();
            let a = s.schedule_batch(&burst, &state);
            assert_eq!(a.len(), burst.len(), "{name}");
            assert!(a.iter().all(|&i| i < platform.len()), "{name}");
        }
    }
}
