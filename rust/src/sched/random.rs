//! Uniform-random mapping (the "W-rand"-style weightless random baseline of
//! Table 11): each task goes to an accelerator drawn uniformly at random.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;
use crate::util::rng::Rng;

use super::{Scheduler, UpSet};

#[derive(Debug)]
pub struct RandomSched {
    seed: u64,
    rng: Rng,
}

impl RandomSched {
    pub fn new(seed: u64) -> RandomSched {
        RandomSched { seed, rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> String {
        "Random".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let n = state.len();
        let ups = UpSet::new(state);
        tasks
            .iter()
            .map(|_| {
                // One draw per task regardless of platform health, so the
                // rng stream (and every event-free result) is unchanged;
                // draws landing on a failed accelerator remap onto the up
                // set deterministically.
                let a = self.rng.below(n);
                if ups.all_up() || ups.none_up() || state.is_up(a) {
                    a
                } else {
                    ups.nth(a % ups.count())
                }
            })
            .collect()
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    #[test]
    fn covers_platform_and_resets() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(3);
        let burst: Vec<_> = q.tasks.iter().take(200).cloned().collect();
        let mut s = RandomSched::new(9);
        let a = s.schedule_batch(&burst, &state);
        // With 200 draws over 11 slots, every slot should be hit.
        for i in 0..platform.len() {
            assert!(a.contains(&i), "slot {i} never drawn");
        }
        s.reset();
        assert_eq!(s.schedule_batch(&burst, &state), a);
    }

    #[test]
    fn remaps_draws_off_failed_accels() {
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(4);
        let burst: Vec<_> = q.tasks.iter().take(100).cloned().collect();
        state.set_speed(5, 0.0);
        let mut s = RandomSched::new(3);
        let a = s.schedule_batch(&burst, &state);
        assert!(a.iter().all(|&i| i != 5), "drew a failed accel");
        assert!(a.iter().all(|&i| i < platform.len()));
        // Healthy-platform results are untouched by the remap path.
        let fresh = ShadowState::new(&platform, NormScales::unit());
        let mut s1 = RandomSched::new(3);
        let mut s2 = RandomSched::new(3);
        assert_eq!(s1.schedule_batch(&burst, &fresh), s2.schedule_batch(&burst, &fresh));
    }
}
