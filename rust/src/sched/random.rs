//! Uniform-random mapping (the "W-rand"-style weightless random baseline of
//! Table 11): each task goes to an accelerator drawn uniformly at random.

use crate::env::taskgen::Task;
use crate::sim::ShadowState;
use crate::util::rng::Rng;

use super::Scheduler;

#[derive(Debug)]
pub struct RandomSched {
    seed: u64,
    rng: Rng,
}

impl RandomSched {
    pub fn new(seed: u64) -> RandomSched {
        RandomSched { seed, rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> String {
        "Random".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        tasks.iter().map(|_| self.rng.below(state.len())).collect()
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    #[test]
    fn covers_platform_and_resets() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(3);
        let burst: Vec<_> = q.tasks.iter().take(200).cloned().collect();
        let mut s = RandomSched::new(9);
        let a = s.schedule_batch(&burst, &state);
        // With 200 draws over 11 slots, every slot should be hit.
        for i in 0..platform.len() {
            assert!(a.contains(&i), "slot {i} never drawn");
        }
        s.reset();
        assert_eq!(s.schedule_batch(&burst, &state), a);
    }
}
