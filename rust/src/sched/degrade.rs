//! Graceful degradation: a criticality-aware controller that wraps any
//! registered scheduler and sheds comfort-tier work when platform capacity
//! drops under faults, so safety-tier deadlines survive outages — the
//! priority-tier direction of the dataflow-accelerator literature
//! (PAPERS.md: arXiv 2109.07047) applied to the paper's safety claim.
//!
//! Policy (deterministic, documented in DESIGN.md):
//!
//! * **Healthy platform** (every accelerator up): pure pass-through.  The
//!   wrapper adds zero float/rng operations, so no-fault sweeps stay
//!   bit-identical to the unwrapped scheduler — fingerprint-pinned by
//!   `tests/faults.rs`.
//! * **Degraded platform** (≥1 accelerator down): a comfort-tier task
//!   ([`TaskCategory::Tracking`](crate::safety::ms::TaskCategory)) whose
//!   *best-case* response on every surviving accelerator already misses
//!   its safety time is **shed**: it is assigned to a dead slot, which the
//!   platform model books as a lost task (MS −1, no FIFO occupancy) — the
//!   pinned lost-task semantics of `ShadowState::apply`.  Shedding such a
//!   task can only help: it would have missed its deadline anyway, and
//!   dispatching it would have queued real work ahead of safety-tier
//!   tasks.  Safety-tier tasks and still-viable comfort tasks go to the
//!   inner scheduler as a reduced burst, and its assignments are merged
//!   back in the original task order.
//!
//! Derate-only capacity loss (all accelerators up but slower) keeps the
//! controller dormant: est-based shedding under derating would change
//! scheduling on runs whose capacity still covers demand, and the inner
//! schedulers already price derated slots through `est_response`.

use crate::env::taskgen::Task;
use crate::safety::ms::is_safety_critical;
use crate::sim::ShadowState;

use super::Scheduler;

/// The graceful-degradation wrapper.  Built by the engine around the
/// trial's scheduler when degradation is enabled (`Engine::degrade`) — it
/// is not a registry row of its own, so `name()` forwards the inner
/// scheduler's name and group keys stay comparable across the on/off arms
/// of a campaign.
pub struct DegradeSched {
    inner: Box<dyn Scheduler>,
}

impl DegradeSched {
    pub fn new(inner: Box<dyn Scheduler>) -> DegradeSched {
        DegradeSched { inner }
    }
}

impl Scheduler for DegradeSched {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let ups = state.up_count();
        if ups == state.len() || ups == 0 {
            // Healthy (pass-through, bit-identical) or hopeless (every
            // slot down: the inner scheduler's all-down fallback already
            // loses every task; shedding would change nothing).
            return self.inner.schedule_batch(tasks, state);
        }
        // First dead slot: the shed destination (exists: ups < len).
        let shed_to = (0..state.len()).find(|&i| !state.is_up(i)).unwrap_or(0);
        let mut shed = vec![false; tasks.len()];
        let mut kept: Vec<Task> = Vec::with_capacity(tasks.len());
        for (k, task) in tasks.iter().enumerate() {
            let hopeless = !is_safety_critical(task.category)
                && !state
                    .up_iter()
                    .any(|i| state.est_response(task, i) <= task.safety_time_s);
            if hopeless {
                shed[k] = true;
            } else {
                kept.push(task.clone());
            }
        }
        if kept.len() == tasks.len() {
            return self.inner.schedule_batch(tasks, state);
        }
        let inner_assign = self.inner.schedule_batch(&kept, state);
        let mut out = Vec::with_capacity(tasks.len());
        let mut j = 0;
        for dropped in shed {
            if dropped {
                out.push(shed_to);
            } else {
                out.push(inner_assign.get(j).copied().unwrap_or(shed_to));
                j += 1;
            }
        }
        out
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{CameraGroup, Scenario};
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::safety::ms::TaskCategory;
    use crate::sched::Registry;
    use crate::workload::ModelKind;

    fn task(id: u32, category: TaskCategory, safety_time_s: f64) -> Task {
        Task {
            id,
            group: CameraGroup::Fc,
            cam_idx: 0,
            release_s: 0.0,
            model: match category {
                TaskCategory::Detection => ModelKind::Yolo,
                TaskCategory::Tracking => ModelKind::Goturn,
            },
            category,
            scenario: Scenario::GoStraight,
            safety_time_s,
        }
    }

    fn wrapped(reg: &Registry) -> DegradeSched {
        DegradeSched::new(reg.build_by_name("minmin", 7).unwrap())
    }

    #[test]
    fn healthy_platform_is_pass_through() {
        let reg = Registry::new();
        let state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        let burst: Vec<Task> = (0..8)
            .map(|k| {
                task(
                    k,
                    if k % 2 == 0 { TaskCategory::Detection } else { TaskCategory::Tracking },
                    1.0,
                )
            })
            .collect();
        let mut plain = reg.build_by_name("minmin", 7).unwrap();
        let mut deg = wrapped(&reg);
        assert_eq!(deg.name(), plain.name(), "group keys must stay comparable");
        assert_eq!(deg.schedule_batch(&burst, &state), plain.schedule_batch(&burst, &state));
    }

    #[test]
    fn hopeless_comfort_tasks_are_shed_to_a_dead_slot() {
        let reg = Registry::new();
        let mut state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        state.set_speed(2, 0.0);
        // An impossible deadline: no up slot can meet 1 ns.
        let burst = vec![
            task(0, TaskCategory::Detection, 1e-9),
            task(1, TaskCategory::Tracking, 1e-9),
            task(2, TaskCategory::Tracking, 10.0),
        ];
        let mut deg = wrapped(&reg);
        let assign = deg.schedule_batch(&burst, &state);
        assert_eq!(assign.len(), 3);
        assert_eq!(assign[1], 2, "hopeless comfort task goes to the dead slot");
        assert_ne!(assign[0], 2, "safety tasks are never shed");
        assert_ne!(assign[2], 2, "viable comfort tasks are scheduled normally");
    }

    #[test]
    fn outage_without_hopeless_tasks_matches_inner() {
        let reg = Registry::new();
        let mut state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        state.set_speed(0, 0.0);
        let burst: Vec<Task> = (0..6).map(|k| task(k, TaskCategory::Tracking, 10.0)).collect();
        let mut plain = reg.build_by_name("minmin", 7).unwrap();
        let mut deg = wrapped(&reg);
        assert_eq!(deg.schedule_batch(&burst, &state), plain.schedule_batch(&burst, &state));
    }
}
