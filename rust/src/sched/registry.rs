//! Typed scheduler construction: the canonical name table, `SchedulerSpec`
//! and the extensible `Registry` of factory objects.
//!
//! This replaces the stringly `by_name` lookups that used to be duplicated
//! (with drifting alias sets) across `sched`, `harness` and the CLI usage
//! text.  There is exactly one table — `SCHEDULERS` — and the registry, the
//! usage string and the Fig. 12 baseline set are all derived from it.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{ata, edp, ga, minmin, random, roundrobin, sa, worst, Scheduler};

/// One row of the canonical scheduler table.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerInfo {
    /// Canonical short name (CLI `--sched` value, registry key).
    pub canonical: &'static str,
    /// Accepted aliases (historical / paper spellings).
    pub aliases: &'static [&'static str],
    /// Display name used in figures and report tables.
    pub display: &'static str,
    /// Member of the Fig. 12 baseline comparison set.
    pub baseline: bool,
    /// One-line help for the usage string.
    pub help: &'static str,
}

/// THE canonical scheduler table — single source of truth for the registry,
/// `hmai help`, and the baseline set.
pub const SCHEDULERS: &[SchedulerInfo] = &[
    SchedulerInfo {
        canonical: "flexai",
        aliases: &["dqn"],
        display: "FlexAI",
        baseline: false,
        help: "DQN scheduler (needs PJRT artifacts)",
    },
    SchedulerInfo {
        canonical: "minmin",
        aliases: &["min-min"],
        display: "Min-Min",
        baseline: true,
        help: "earliest-completion heuristic",
    },
    SchedulerInfo {
        canonical: "ata",
        aliases: &[],
        display: "ATA",
        baseline: true,
        help: "accuracy-targeted assignment",
    },
    SchedulerInfo {
        canonical: "edp",
        aliases: &["energy-delay"],
        display: "EDP",
        baseline: false,
        help: "energy-delay-product heuristic",
    },
    SchedulerInfo {
        canonical: "ga",
        aliases: &["genetic"],
        display: "GA",
        baseline: true,
        help: "genetic algorithm",
    },
    SchedulerInfo {
        canonical: "sa",
        aliases: &["annealing"],
        display: "SA",
        baseline: true,
        help: "simulated annealing",
    },
    SchedulerInfo {
        canonical: "worst",
        aliases: &["worse", "unscheduled", "worstcase"],
        display: "WorstCase",
        baseline: true,
        help: "unscheduled worst case",
    },
    SchedulerInfo {
        canonical: "rr",
        aliases: &["roundrobin", "round-robin"],
        display: "RoundRobin",
        baseline: false,
        help: "round robin",
    },
    SchedulerInfo {
        canonical: "random",
        aliases: &["rand", "w-rand"],
        display: "Random",
        baseline: false,
        help: "uniform random (W-rand)",
    },
];

/// Look up a table row by canonical name or alias (case-insensitive).
pub fn lookup(name: &str) -> Option<&'static SchedulerInfo> {
    let lc = name.to_ascii_lowercase();
    SCHEDULERS
        .iter()
        .find(|s| s.canonical == lc || s.aliases.contains(&lc.as_str()))
}

/// Canonical names of the Fig. 12 baseline comparison set, in table order.
pub fn baseline_names() -> Vec<&'static str> {
    SCHEDULERS.iter().filter(|s| s.baseline).map(|s| s.canonical).collect()
}

/// Baseline specs, in table order (the Fig. 12 comparison set).
pub fn baseline_specs() -> Vec<SchedulerSpec> {
    baseline_names()
        .into_iter()
        // lint:allow(panic-in-hot-path): parses the crate's own static name
        // table; a failure is a table bug, caught by the registry tests.
        .map(|n| SchedulerSpec::parse(n).expect("table names parse"))
        .collect()
}

/// `name | name | ...` scheduler list for usage strings, from the table.
pub fn usage_names() -> String {
    SCHEDULERS.iter().map(|s| s.canonical).collect::<Vec<_>>().join(" | ")
}

/// A typed scheduler choice — what `ExperimentPlan` sweeps over.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum SchedulerSpec {
    /// FlexAI, optionally restoring a checkpoint (None = fresh parameters,
    /// greedy inference).
    FlexAI { checkpoint: Option<String> },
    MinMin,
    Ata,
    Edp,
    Ga,
    Sa,
    Worst,
    RoundRobin,
    Random,
}

impl SchedulerSpec {
    /// Parse a canonical name or alias from the `SCHEDULERS` table.
    pub fn parse(name: &str) -> Result<SchedulerSpec> {
        let info = lookup(name).with_context(|| {
            format!("unknown scheduler '{}' (known: {})", name, usage_names())
        })?;
        Ok(match info.canonical {
            "flexai" => SchedulerSpec::FlexAI { checkpoint: None },
            "minmin" => SchedulerSpec::MinMin,
            "ata" => SchedulerSpec::Ata,
            "edp" => SchedulerSpec::Edp,
            "ga" => SchedulerSpec::Ga,
            "sa" => SchedulerSpec::Sa,
            "worst" => SchedulerSpec::Worst,
            "rr" => SchedulerSpec::RoundRobin,
            "random" => SchedulerSpec::Random,
            // lint:allow(panic-in-hot-path): the match arms mirror the static
            // table one-to-one; tests enumerate every entry.
            other => unreachable!("table entry '{other}' not mapped"),
        })
    }

    /// Canonical table name for this spec.
    pub fn canonical(&self) -> &'static str {
        match self {
            SchedulerSpec::FlexAI { .. } => "flexai",
            SchedulerSpec::MinMin => "minmin",
            SchedulerSpec::Ata => "ata",
            SchedulerSpec::Edp => "edp",
            SchedulerSpec::Ga => "ga",
            SchedulerSpec::Sa => "sa",
            SchedulerSpec::Worst => "worst",
            SchedulerSpec::RoundRobin => "rr",
            SchedulerSpec::Random => "random",
        }
    }

    /// Display name (figure legends), from the table.
    pub fn display(&self) -> &'static str {
        // lint:allow(panic-in-hot-path): canonical() returns names drawn from
        // the same static table this lookup reads.
        lookup(self.canonical()).expect("canonical names are in the table").display
    }
}

/// Construction context handed to factories: the per-trial seed.
#[derive(Debug, Clone, Copy)]
pub struct BuildCtx {
    pub seed: u64,
}

/// A scheduler factory.  `Send + Sync` so the `Engine` can call factories
/// from worker threads; the produced `Box<dyn Scheduler>` never crosses a
/// thread boundary (each worker builds, runs and drops its own instance).
pub type Factory = Arc<dyn Fn(&SchedulerSpec, &BuildCtx) -> Result<Box<dyn Scheduler>> + Send + Sync>;

/// Extensible scheduler registry: canonical name → factory.
///
/// `Registry::new()` registers every built-in baseline.  FlexAI is not
/// constructible without a PJRT runtime, so its runtime-providing factory
/// registers separately (`harness::flexai_factory`); the factory loads the
/// runtime on whichever worker thread builds the agent.
#[derive(Clone)]
pub struct Registry {
    factories: BTreeMap<&'static str, Factory>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// Registry with every built-in (non-FlexAI) scheduler registered.
    pub fn new() -> Registry {
        fn boxed<S: Scheduler + 'static>(s: S) -> Result<Box<dyn Scheduler>> {
            Ok(Box::new(s))
        }
        let mut r = Registry { factories: BTreeMap::new() };
        r.register("minmin", Arc::new(|_, _| boxed(minmin::MinMin::new())));
        r.register("ata", Arc::new(|_, _| boxed(ata::Ata::new())));
        r.register("edp", Arc::new(|_, _| boxed(edp::Edp::new())));
        r.register("ga", Arc::new(|_, c| boxed(ga::Ga::new(c.seed))));
        r.register("sa", Arc::new(|_, c| boxed(sa::Sa::new(c.seed))));
        r.register("worst", Arc::new(|_, _| boxed(worst::WorstCase::new())));
        r.register("rr", Arc::new(|_, _| boxed(roundrobin::RoundRobin::new())));
        r.register("random", Arc::new(|_, c| boxed(random::RandomSched::new(c.seed))));
        r
    }

    /// Register (or replace) the factory for a canonical table name.
    /// Panics on names absent from `SCHEDULERS` — factories for unknown
    /// schedulers would be unreachable from specs.
    pub fn register(&mut self, canonical: &'static str, factory: Factory) {
        assert!(
            SCHEDULERS.iter().any(|s| s.canonical == canonical),
            "'{canonical}' is not in the canonical SCHEDULERS table"
        );
        self.factories.insert(canonical, factory);
    }

    /// Canonical names with a registered factory, in sorted order.
    pub fn registered(&self) -> Vec<&'static str> {
        self.factories.keys().copied().collect()
    }

    /// Build a scheduler for `spec` with the per-trial `seed`.
    pub fn build(&self, spec: &SchedulerSpec, seed: u64) -> Result<Box<dyn Scheduler>> {
        let name = spec.canonical();
        let f = self.factories.get(name).with_context(|| {
            if name == "flexai" {
                "scheduler 'flexai' needs a PJRT runtime — use a registry with a \
                 FlexAI factory registered (see harness::registry)"
                    .to_string()
            } else {
                format!("no factory registered for scheduler '{name}'")
            }
        })?;
        f(spec, &BuildCtx { seed })
    }

    /// Parse + build in one step (CLI convenience).
    pub fn build_by_name(&self, name: &str, seed: u64) -> Result<Box<dyn Scheduler>> {
        self.build(&SchedulerSpec::parse(name)?, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_table_row_parses_to_its_canonical_spec() {
        for info in SCHEDULERS {
            let spec = SchedulerSpec::parse(info.canonical).unwrap();
            assert_eq!(spec.canonical(), info.canonical);
            assert_eq!(spec.display(), info.display);
            for alias in info.aliases {
                let via_alias = SchedulerSpec::parse(alias).unwrap();
                assert_eq!(via_alias.canonical(), info.canonical, "alias {alias}");
            }
            // Case-insensitive.
            let upper = SchedulerSpec::parse(&info.canonical.to_ascii_uppercase()).unwrap();
            assert_eq!(upper.canonical(), info.canonical);
        }
        assert!(SchedulerSpec::parse("nope").is_err());
    }

    #[test]
    fn aliases_never_collide() {
        let mut seen = std::collections::BTreeSet::new();
        for info in SCHEDULERS {
            assert!(seen.insert(info.canonical), "dup canonical {}", info.canonical);
            for a in info.aliases {
                assert!(seen.insert(a), "alias '{a}' collides");
            }
        }
    }

    #[test]
    fn registry_builds_every_non_flexai_scheduler() {
        let reg = Registry::new();
        for info in SCHEDULERS {
            let spec = SchedulerSpec::parse(info.canonical).unwrap();
            if info.canonical == "flexai" {
                let err = reg.build(&spec, 7).unwrap_err();
                assert!(err.to_string().contains("PJRT"), "{err:#}");
            } else {
                let s = reg.build(&spec, 7).unwrap();
                assert_eq!(s.name(), info.display, "{}", info.canonical);
            }
        }
        assert!(reg.build_by_name("bogus", 0).is_err());
    }

    #[test]
    fn seeded_schedulers_are_deterministic_per_seed() {
        use crate::metrics::NormScales;
        use crate::platform::Platform;
        use crate::sim::ShadowState;

        let reg = Registry::new();
        let q = crate::sched::tests::small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        for name in ["ga", "sa", "random"] {
            let mut a = reg.build_by_name(name, 9).unwrap();
            let mut b = reg.build_by_name(name, 9).unwrap();
            assert_eq!(
                a.schedule_batch(&burst, &state),
                b.schedule_batch(&burst, &state),
                "{name}"
            );
        }
    }

    #[test]
    fn baseline_set_is_the_fig12_comparison() {
        assert_eq!(baseline_names(), vec!["minmin", "ata", "ga", "sa", "worst"]);
        assert_eq!(baseline_specs().len(), 5);
    }

    #[test]
    fn usage_names_cover_the_table() {
        let u = usage_names();
        for info in SCHEDULERS {
            assert!(u.contains(info.canonical), "{} missing", info.canonical);
        }
    }
}
