//! Task scheduling (§3.3, §7): the `Scheduler` trait the simulation engine
//! drives, the paper's baselines (Min-Min, ATA, EDP, GA, SA, the
//! unscheduled worst case) and FlexAI, the DQN scheduler.

pub mod ata;
pub mod degrade;
pub mod edp;
pub mod fitness;
pub mod flexai;
pub mod ga;
pub mod minmin;
pub mod random;
pub mod reference;
pub mod registry;
pub mod rollout;
pub mod roundrobin;
pub mod sa;
pub mod worst;

use crate::env::taskgen::Task;
use crate::sim::ShadowState;
use crate::util::rng::Rng;

pub use registry::{
    baseline_names, baseline_specs, BuildCtx, Registry, SchedulerInfo, SchedulerSpec, SCHEDULERS,
};
pub use rollout::RolloutCtx;

/// A task-mapping policy.  The engine hands the scheduler one *burst* (all
/// tasks released at the same instant — up to one frame from each of the 30
/// cameras) plus the exact platform state, and gets back one accelerator
/// index per task.
pub trait Scheduler {
    /// Display name (used in reports and Figure legends).
    fn name(&self) -> String;

    /// Map each task of a burst to an accelerator index in `0..state.len()`.
    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize>;

    /// Reset any per-queue state (called between task queues/episodes).
    fn reset(&mut self) {}
}

/// Zero-allocation view of a state's up set, computed once per burst (the
/// up set cannot change while a scheduler holds `&ShadowState`).  This
/// replaced the per-burst `up_accels()` `Vec` on the scheduling hot path:
/// the healthy-platform fast path never touches the iterator at all.
pub(crate) struct UpSet<'a> {
    state: &'a ShadowState,
    n: usize,
    ups: usize,
}

impl<'a> UpSet<'a> {
    pub fn new(state: &'a ShadowState) -> UpSet<'a> {
        UpSet { state, n: state.len(), ups: state.up_count() }
    }

    /// Number of up accelerators.
    pub fn count(&self) -> usize {
        self.ups
    }

    pub fn all_up(&self) -> bool {
        self.ups == self.n
    }

    pub fn none_up(&self) -> bool {
        self.ups == 0
    }

    /// `k`-th up accelerator in ascending slot order (`k < count()`).
    pub fn nth(&self, k: usize) -> usize {
        // lint:allow(panic-in-hot-path): documented precondition k < count();
        // callers draw k from count() directly.
        self.state.up_iter().nth(k).expect("k < up count")
    }

    /// Draw one accelerator index for the stochastic schedulers (GA
    /// genomes, SA neighbor moves).  On a healthy platform this is the
    /// plain uniform draw — identical rng stream and results to the
    /// pre-platform-events code; when accelerators are down the draw
    /// covers the up set only, so no candidate ever maps a task to a dead
    /// slot.  An empty up set (every accelerator down) falls back to the
    /// full range.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        if self.all_up() || self.none_up() {
            rng.below(self.n)
        } else {
            self.nth(rng.below(self.ups))
        }
    }
}

/// Drive a per-task policy over a burst: the closure picks an accelerator
/// for each task against a *rolling* shadow copy, so later picks in the
/// burst see the backlog created by earlier ones — exactly what the engine
/// will execute.
pub fn sequential<F>(tasks: &[Task], state: &ShadowState, mut pick: F) -> Vec<usize>
where
    F: FnMut(&Task, &ShadowState) -> usize,
{
    let mut rolling = state.clone();
    let mut out = Vec::with_capacity(tasks.len());
    for task in tasks {
        let a = pick(task, &rolling);
        rolling.apply(task, a);
        out.push(a);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::route::{Route, RouteParams};
    use crate::env::taskgen::TaskQueue;
    use crate::env::Area;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::util::rng::Rng;

    pub(crate) fn small_queue(seed: u64) -> TaskQueue {
        let route =
            Route::generate(RouteParams::for_area(Area::Urban, 40.0), &mut Rng::new(seed));
        crate::env::taskgen::generate(&route)
    }

    /// Every constructible scheduler returns in-range assignments and is
    /// deterministic for a fixed seed.
    #[test]
    fn registry_constructs_and_assigns_in_range() {
        let reg = Registry::new();
        let q = small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        for name in ["minmin", "ata", "edp", "ga", "sa", "worst", "rr", "random"] {
            let mut s = reg.build_by_name(name, 7).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            let a = s.schedule_batch(&burst, &state);
            assert_eq!(a.len(), burst.len(), "{name}");
            assert!(a.iter().all(|&i| i < platform.len()), "{name}");
            let mut s2 = reg.build_by_name(name, 7).unwrap();
            assert_eq!(a, s2.schedule_batch(&burst, &state), "{name} not deterministic");
        }
        assert!(reg.build_by_name("nope", 0).is_err());
    }

    #[test]
    fn upset_draw_covers_the_up_set_only() {
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        // Healthy platform: draws are the plain uniform stream.
        let ups = UpSet::new(&state);
        assert!(ups.all_up() && !ups.none_up());
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        for _ in 0..50 {
            assert_eq!(ups.draw(&mut a), b.below(state.len()));
        }
        // Degraded platform: no draw lands on a dead slot, and nth walks
        // ascending slot order exactly like the old Vec did.
        state.set_speed(0, 0.0);
        state.set_speed(6, 0.0);
        let ups = UpSet::new(&state);
        assert_eq!(ups.count(), state.len() - 2);
        let old_vec: Vec<usize> = state.up_iter().collect();
        for k in 0..ups.count() {
            assert_eq!(ups.nth(k), old_vec[k]);
        }
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            let d = ups.draw(&mut rng);
            assert!(d != 0 && d != 6 && d < state.len());
        }
        // All-down platform falls back to the full range.
        for i in 0..state.len() {
            state.set_speed(i, 0.0);
        }
        let ups = UpSet::new(&state);
        assert!(ups.none_up());
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        for _ in 0..20 {
            assert_eq!(ups.draw(&mut a), b.below(state.len()));
        }
    }

    #[test]
    fn sequential_sees_rolling_backlog() {
        let platform = Platform::from_counts("p", 1, 0, 0);
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = small_queue(2);
        let burst: Vec<_> = q.tasks.iter().take(4).cloned().collect();
        let mut delays = Vec::new();
        sequential(&burst, &state, |t, s| {
            delays.push(s.queue_delay(0));
            let _ = t;
            0
        });
        // Backlog strictly grows as the burst is assigned to the only accel.
        assert!(delays.windows(2).all(|w| w[1] > w[0]));
    }
}
