//! Experience replay (§7.1: "the record (S_i, H_j, r_i, S_{i+1}) is saved
//! in memory ... the RL agent will use record_m - record_n to start
//! learning"): a fixed-capacity ring buffer with uniform sampling straight
//! into the `qnet_train` batch layout.

use crate::runtime::TrainBatch;
use crate::util::rng::Rng;

/// One (S, a, r, S', done) transition.
#[derive(Debug, Clone)]
pub struct Transition {
    pub s: Vec<f32>,
    pub a: i32,
    pub r: f32,
    pub s2: Vec<f32>,
    pub done: f32,
}

/// Ring-buffer replay memory.
#[derive(Debug)]
pub struct Replay {
    buf: Vec<Transition>,
    capacity: usize,
    next: usize,
    pushed: u64,
}

impl Replay {
    pub fn new(capacity: usize) -> Replay {
        assert!(capacity > 0);
        Replay { buf: Vec::with_capacity(capacity.min(4096)), capacity, next: 0, pushed: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total transitions ever pushed (≥ len once the ring wraps).
    pub fn total_pushed(&self) -> u64 {
        self.pushed
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
            self.next = (self.next + 1) % self.capacity;
        }
        self.pushed += 1;
    }

    /// Fill `batch` with `train_batch` uniform samples (with replacement).
    /// Panics if empty.
    pub fn sample_into(&self, batch: &mut TrainBatch, in_dim: usize, rng: &mut Rng) {
        assert!(!self.buf.is_empty(), "sampling from empty replay");
        let b = batch.a.len();
        for k in 0..b {
            let t = &self.buf[rng.below(self.buf.len())];
            debug_assert_eq!(t.s.len(), in_dim);
            batch.s[k * in_dim..(k + 1) * in_dim].copy_from_slice(&t.s);
            batch.s2[k * in_dim..(k + 1) * in_dim].copy_from_slice(&t.s2);
            batch.a[k] = t.a;
            batch.r[k] = t.r;
            batch.done[k] = t.done;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(tag: f32) -> Transition {
        Transition { s: vec![tag; 4], a: tag as i32, r: tag, s2: vec![tag + 0.5; 4], done: 0.0 }
    }

    #[test]
    fn ring_wraps_and_keeps_capacity() {
        let mut r = Replay::new(3);
        for i in 0..7 {
            r.push(tr(i as f32));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.total_pushed(), 7);
        // Oldest entries were overwritten: all remaining tags >= 4 - 3 + ...
        assert!(r.buf.iter().all(|t| t.r >= 1.0));
    }

    #[test]
    fn sample_fills_batch_layout() {
        let mut r = Replay::new(8);
        for i in 0..8 {
            r.push(tr(i as f32));
        }
        let mut batch = TrainBatch {
            s: vec![0.0; 5 * 4],
            a: vec![0; 5],
            r: vec![0.0; 5],
            s2: vec![0.0; 5 * 4],
            done: vec![9.0; 5],
        };
        let mut rng = crate::util::rng::Rng::new(1);
        r.sample_into(&mut batch, 4, &mut rng);
        for k in 0..5 {
            let tag = batch.r[k];
            assert_eq!(batch.a[k], tag as i32);
            assert!(batch.s[k * 4..(k + 1) * 4].iter().all(|&x| x == tag));
            assert!(batch.s2[k * 4..(k + 1) * 4].iter().all(|&x| x == tag + 0.5));
            assert_eq!(batch.done[k], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn sampling_empty_panics() {
        let r = Replay::new(2);
        let mut batch = TrainBatch {
            s: vec![0.0; 4],
            a: vec![0; 1],
            r: vec![0.0; 1],
            s2: vec![0.0; 4],
            done: vec![0.0; 1],
        };
        let mut rng = crate::util::rng::Rng::new(1);
        r.sample_into(&mut batch, 4, &mut rng);
    }
}
