//! FlexAI (§7): the DQN task scheduler.  EvalNet picks the accelerator with
//! the max Q value for each incoming task; the reward is
//! `ΔGvalue + ΔMS` (§7.2); TargNet is a periodic copy of EvalNet.
//!
//! The Q-network forward pass and the full SGD train step are the AOT
//! artifacts (`qnet_infer`, `qnet_train`) — the rust side owns the RL
//! *loop*: featurization, ε-greedy, the replay memory, reward computation
//! and target-network sync.  Python never runs here.

pub mod checkpoint;
pub mod epsilon;
pub mod featurize;
pub mod replay;

use std::sync::Arc;

use anyhow::Result;

use crate::env::taskgen::Task;
use crate::runtime::{Params, Runtime, TrainBatch};
use crate::sim::ShadowState;
use crate::util::rng::Rng;

use epsilon::EpsilonSchedule;
use replay::{Replay, Transition};

use super::Scheduler;

/// FlexAI hyper-parameters (beyond what meta.json pins: γ, lr, batch).
#[derive(Debug, Clone, PartialEq)]
pub struct FlexAIConfig {
    pub epsilon: EpsilonSchedule,
    /// Train once every this many decisions (after warmup).
    pub train_every: u64,
    /// Copy EvalNet -> TargNet every this many decisions (§7.1 "copied
    /// directly every fixed time").
    pub target_sync_every: u64,
    pub replay_capacity: usize,
    /// Minimum transitions before the first train step.
    pub min_replay: usize,
    /// Deadline-aware action shield: restrict the greedy argmax to slots
    /// whose predicted response still meets the task's safety time,
    /// falling back to the unrestricted argmax when no slot can.  This is
    /// how a production scheduler deploys a learned policy (the Q values
    /// rank the *safe* choices); disable for the paper-pure DQN.
    pub safety_shield: bool,
    /// Guided exploration: half of the ε-exploration actions follow the
    /// earliest-completion heuristic instead of a uniform draw, seeding
    /// the replay memory with feasible trajectories (uniform exploration
    /// at 1700 tasks/s collapses every queue and the agent only ever sees
    /// saturated states).
    pub guided_explore: bool,
    pub seed: u64,
}

impl Default for FlexAIConfig {
    fn default() -> Self {
        FlexAIConfig {
            epsilon: EpsilonSchedule::default(),
            train_every: 4,
            target_sync_every: 1000,
            replay_capacity: 50_000,
            min_replay: 256,
            safety_shield: true,
            guided_explore: true,
            seed: 0,
        }
    }
}

/// Reward clip bound (see the clamp in `decide`).
pub const REWARD_CLIP: f32 = 5.0;

/// A transition waiting for its successor state.
#[derive(Debug)]
struct Pending {
    s: Vec<f32>,
    a: i32,
    r: f32,
}

/// The FlexAI scheduling agent.
pub struct FlexAI {
    rt: Arc<Runtime>,
    /// EvalNet parameters.
    params: Params,
    /// TargNet parameters.
    targ: Params,
    pub cfg: FlexAIConfig,
    training: bool,
    replay: Replay,
    rng: Rng,
    /// Total decisions taken (drives ε decay and train/sync cadence).
    pub steps: u64,
    /// TD losses in training order (the Fig. 11 curve).
    pub losses: Vec<f32>,
    /// Train steps executed.
    pub train_steps: u64,
    /// Target syncs executed.
    pub target_syncs: u64,
    pending: Option<Pending>,
    batch_feat_buf: Vec<f32>,
    batch_buf: TrainBatch,
}

impl FlexAI {
    /// Fresh agent with seeded He-initialised parameters.
    pub fn new(rt: Arc<Runtime>, cfg: FlexAIConfig) -> Result<FlexAI> {
        let params = rt.init_params(cfg.seed as i32)?;
        let targ = params.clone();
        let batch_feat_buf = vec![0.0; rt.meta.infer_batch * rt.meta.in_dim];
        let batch_buf = TrainBatch::zeros(&rt.meta);
        Ok(FlexAI {
            params,
            targ,
            replay: Replay::new(cfg.replay_capacity),
            rng: Rng::new(cfg.seed ^ 0x9e3779b97f4a7c15),
            steps: 0,
            losses: Vec::new(),
            train_steps: 0,
            target_syncs: 0,
            pending: None,
            batch_feat_buf,
            batch_buf,
            training: false,
            cfg,
            rt,
        })
    }

    /// Enable/disable learning.  Off: pure greedy inference (ε = 0), no
    /// replay, no parameter updates.
    pub fn set_training(&mut self, on: bool) {
        self.training = on;
    }

    pub fn is_training(&self) -> bool {
        self.training
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    /// Replace parameters (checkpoint restore).
    pub fn set_params(&mut self, params: Params) {
        self.targ = params.clone();
        self.params = params;
    }

    /// Close the trailing transition of an episode with `done = 1` (§7.1:
    /// one episode = one task queue).  Call after each queue in training.
    pub fn end_episode(&mut self) {
        if let Some(p) = self.pending.take() {
            if self.training {
                let s2 = p.s.clone(); // terminal convention: s' = s, done = 1
                self.replay.push(Transition { s: p.s, a: p.a, r: p.r, s2, done: 1.0 });
            }
        }
    }

    /// ε for the *next* decision.
    pub fn current_epsilon(&self) -> f64 {
        if self.training {
            self.cfg.epsilon.at(self.steps)
        } else {
            0.0
        }
    }

    /// Greedy/ε-greedy pick over the valid slots of the Q vector.
    ///
    /// `qd_start[i]` is each slot's queue delay at the instant the chunk
    /// was featurized: the Q values are stale with respect to backlog the
    /// *current chunk* has already created, so the greedy score applies
    /// the first-order correction `-(Δqueue_delay)/t_task` — exactly the
    /// response-time cost (in the reward's own units) that the stale
    /// featurization did not see.  Without it all tasks of a burst pile
    /// onto the chunk-start argmax slot.
    fn pick(
        &mut self,
        task: &Task,
        rolling: &ShadowState,
        q: &[f32],
        n_valid: usize,
        qd_start: &[f64],
    ) -> usize {
        debug_assert!(n_valid > 0);
        // Earliest-completion argmin over the valid slots, seeded at
        // `from` (a failed slot predicts +inf completion, so it can never
        // win) — the guided-exploration heuristic and the failed-draw
        // redirect share it so the two can never drift apart.
        let earliest_completion = |from: usize| -> usize {
            let mut best = from;
            for i in 0..n_valid {
                if rolling.est_completion(task, i) < rolling.est_completion(task, best) {
                    best = i;
                }
            }
            best
        };
        let eps = self.current_epsilon();
        if eps > 0.0 && self.rng.chance(eps) {
            if self.cfg.guided_explore && self.rng.chance(0.5) {
                return earliest_completion(0);
            }
            let a = self.rng.below(n_valid);
            if rolling.is_up(a) {
                return a;
            }
            // A uniform draw landed on a failed accelerator: redirect to
            // the earliest-completion up slot — deterministic and without
            // an extra rng draw, so healthy-platform streams are unchanged.
            return earliest_completion(a);
        }
        let t_task = rolling.metrics.scales.t_task.max(1e-12);
        let score = |i: usize| -> f64 {
            let staleness = (rolling.queue_delay(i) - qd_start[i]).max(0.0);
            q[i] as f64 - staleness / t_task
        };
        // Greedy argmax, optionally restricted to deadline-safe slots.
        let argmax = |allow: &dyn Fn(usize) -> bool| -> Option<usize> {
            let mut best: Option<usize> = None;
            for i in 0..n_valid {
                if allow(i) && best.map(|b| score(i) > score(b)).unwrap_or(true) {
                    best = Some(i);
                }
            }
            best
        };
        if self.cfg.safety_shield {
            let safe =
                |i: usize| rolling.est_response(task, i) <= task.safety_time_s;
            if let Some(a) = argmax(&safe) {
                return a;
            }
        }
        // The Q vector knows nothing about platform events, so the greedy
        // argmax walks the up slots only (`up_iter`, no allocation); only
        // an all-down platform falls back to the unrestricted argmax.
        let mut best: Option<usize> = None;
        for i in rolling.up_iter().take_while(|&i| i < n_valid) {
            if best.map(|b| score(i) > score(b)).unwrap_or(true) {
                best = Some(i);
            }
        }
        if let Some(a) = best {
            return a;
        }
        // lint:allow(panic-in-hot-path): n_valid > 0 is established above —
        // an empty platform cannot reach action selection.
        argmax(&|_| true).expect("n_valid > 0")
    }

    fn maybe_train(&mut self) -> Result<()> {
        if !self.training
            || self.replay.len() < self.cfg.min_replay
            || self.steps % self.cfg.train_every != 0
        {
            return Ok(());
        }
        // Split borrows: sample into the scratch batch, then train.
        let mut batch = std::mem::replace(&mut self.batch_buf, TrainBatch::zeros(&self.rt.meta));
        self.replay.sample_into(&mut batch, self.rt.meta.in_dim, &mut self.rng);
        let (new_params, loss) = self.rt.train_step(&self.params, &self.targ, &batch)?;
        self.batch_buf = batch;
        self.params = new_params;
        self.losses.push(loss);
        self.train_steps += 1;
        Ok(())
    }

    fn maybe_sync_target(&mut self) {
        if self.training && self.steps % self.cfg.target_sync_every == 0 {
            self.targ = self.params.clone();
            self.target_syncs += 1;
        }
    }

    /// Finish one decision: reward bookkeeping + replay + train cadence.
    /// `s_i` is the featurized state the decision was made from.
    fn commit(&mut self, task: &Task, action: usize, s_i: &[f32], rolling: &mut ShadowState) -> Result<()> {
        // Close the previous transition: its successor state is S_i.
        if self.training {
            if let Some(p) = self.pending.take() {
                self.replay.push(Transition {
                    s: p.s,
                    a: p.a,
                    r: p.r,
                    s2: s_i.to_vec(),
                    done: 0.0,
                });
            }
        }

        // Reward (§7.2: ΔGvalue + ΔMS), in its *dense* per-decision form.
        // The paper's T = max_i ΣT_i makes the per-decision time delta
        // zero whenever the chosen accelerator is not the current argmax —
        // a sparse, nearly unlearnable signal at 30k tasks/queue.  The
        // dense equivalent charges each decision its own response time and
        // energy in per-task units (NormScales::{t_task, e_task}), plus
        // the balance delta, matching the Gvalue gradient in expectation.
        let scales = rolling.metrics.scales;
        let rb0 = rolling.metrics.r_balance();
        let applied = rolling.apply(task, action);
        let rb1 = rolling.metrics.r_balance();
        let gdelta = -(applied.response_s / scales.t_task)
            - (applied.energy_j / scales.e_task)
            + (rb1 - rb0);
        // Clip (standard DQN reward clipping): once a queue is deeply
        // backlogged the raw response penalty reaches O(100) per decision
        // and the TD targets diverge under plain SGD; the clip preserves
        // the action ordering while keeping the Q scale bounded.
        let r = ((applied.ms + gdelta) as f32).clamp(-REWARD_CLIP, REWARD_CLIP);

        self.steps += 1;
        if self.training {
            self.pending = Some(Pending { s: s_i.to_vec(), a: action as i32, r });
            self.maybe_train()?;
            self.maybe_sync_target();
        }
        Ok(())
    }

    /// Schedule one chunk (≤ `infer_batch` tasks released together) with a
    /// single batched Q inference.
    ///
    /// §5.2 step 4: "the well-trained RL agent will generate a scheduling
    /// strategy for *all tasks*" of a camera burst at once — all tasks of
    /// the chunk are featurized against the chunk-start state and scored
    /// in one `qnet_infer_batch` call (one PJRT dispatch instead of 30).
    /// The deadline shield and ε-exploration still see the *rolling* state
    /// per task, so within-chunk backlog is handled on the rust side.
    fn schedule_chunk(
        &mut self,
        chunk: &[Task],
        rolling: &mut ShadowState,
        out: &mut Vec<usize>,
    ) -> Result<()> {
        let (in_dim, out_dim, infer_batch) =
            (self.rt.meta.in_dim, self.rt.meta.out_dim, self.rt.meta.infer_batch);
        debug_assert!(chunk.len() <= infer_batch);

        // Featurize every task against the chunk-start state.
        let mut feats = std::mem::take(&mut self.batch_feat_buf);
        feats.resize(infer_batch * in_dim, 0.0);
        feats.fill(0.0);
        let mut n_valid = 0;
        for (k, task) in chunk.iter().enumerate() {
            n_valid = featurize::featurize(
                task,
                rolling,
                &self.rt.meta,
                &mut feats[k * in_dim..(k + 1) * in_dim],
            );
        }

        // One PJRT dispatch for the whole chunk (single infer for size 1).
        let qs: Vec<f32> = if chunk.len() == 1 {
            self.rt.infer(&self.params, &feats[..in_dim])?
        } else {
            self.rt.infer_batch(&self.params, &feats)?
        };

        // Chunk-start queue delays anchor the staleness correction in pick.
        let qd_start: Vec<f64> = (0..n_valid).map(|i| rolling.queue_delay(i)).collect();

        for (k, task) in chunk.iter().enumerate() {
            let s_i: Vec<f32> = feats[k * in_dim..(k + 1) * in_dim].to_vec();
            let q_row: Vec<f32> = qs[k * out_dim..(k + 1) * out_dim].to_vec();
            let action = self.pick(task, rolling, &q_row, n_valid, &qd_start);
            self.commit(task, action, &s_i, rolling)?;
            out.push(action);
        }
        self.batch_feat_buf = feats;
        Ok(())
    }
}

impl Scheduler for FlexAI {
    fn name(&self) -> String {
        "FlexAI".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let mut rolling = state.clone();
        let mut out = Vec::with_capacity(tasks.len());
        let chunk_size = self.rt.meta.infer_batch;
        for chunk in tasks.chunks(chunk_size) {
            self.schedule_chunk(chunk, &mut rolling, &mut out)
                // lint:allow(panic-in-hot-path): schedule_batch is infallible
                // by trait contract; a PJRT failure here is unrecoverable.
                .expect("PJRT inference failed on the scheduling hot path");
        }
        out
    }

    fn reset(&mut self) {
        self.end_episode();
    }
}

#[cfg(test)]
#[allow(clippy::print_stderr)] // self-skipping tests explain themselves
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::tests::small_queue;
    use crate::sim::{simulate, SimOptions};

    /// Skip (with a message) when PJRT artifacts are unavailable.
    fn rt() -> Option<Arc<Runtime>> {
        match Runtime::load_default() {
            Ok(rt) => Some(Arc::new(rt)),
            Err(e) => {
                eprintln!("skipping FlexAI test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn greedy_inference_is_deterministic() {
        let Some(rt) = rt() else { return };
        let q = small_queue(1);
        let platform = Platform::hmai();
        let run = |seed| {
            let mut agent =
                FlexAI::new(rt.clone(), FlexAIConfig { seed, ..Default::default() }).unwrap();
            agent.set_training(false);
            simulate(&q, &platform, &mut agent, SimOptions::default()).summary
        };
        let a = run(3);
        let b = run(3);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.tasks_met, b.tasks_met);
    }

    #[test]
    fn training_populates_replay_and_losses() {
        let Some(rt) = rt() else { return };
        let q = small_queue(2);
        let cfg = FlexAIConfig {
            min_replay: 64,
            train_every: 8,
            target_sync_every: 200,
            ..Default::default()
        };
        let mut agent = FlexAI::new(rt, cfg).unwrap();
        agent.set_training(true);
        let r = simulate(&q, &Platform::hmai(), &mut agent, SimOptions::default());
        agent.end_episode();
        assert_eq!(r.summary.tasks as usize, q.len());
        assert!(agent.replay.len() > 64, "replay {}", agent.replay.len());
        assert!(agent.train_steps > 0);
        assert_eq!(agent.losses.len() as u64, agent.train_steps);
        assert!(agent.losses.iter().all(|l| l.is_finite()));
        assert!(agent.target_syncs >= 1);
        // Terminal transition recorded.
        assert_eq!(agent.replay.total_pushed(), q.len() as u64);
    }

    #[test]
    fn inference_mode_never_trains() {
        let Some(rt) = rt() else { return };
        let q = small_queue(3);
        let mut agent = FlexAI::new(rt, FlexAIConfig::default()).unwrap();
        agent.set_training(false);
        let before = agent.params.clone();
        simulate(&q, &Platform::hmai(), &mut agent, SimOptions::default());
        assert_eq!(agent.train_steps, 0);
        assert!(agent.replay.is_empty());
        assert!(agent.params.l2_distance(&before) < 1e-12);
        assert_eq!(agent.current_epsilon(), 0.0);
    }

    #[test]
    fn epsilon_decays_during_training() {
        let Some(rt) = rt() else { return };
        let cfg = FlexAIConfig {
            epsilon: EpsilonSchedule { start: 1.0, end: 0.1, decay_steps: 100 },
            ..Default::default()
        };
        let mut agent = FlexAI::new(rt, cfg).unwrap();
        agent.set_training(true);
        assert_eq!(agent.current_epsilon(), 1.0);
        agent.steps = 50;
        assert!((agent.current_epsilon() - 0.55).abs() < 1e-12);
        agent.steps = 500;
        assert!((agent.current_epsilon() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn actions_always_valid_for_small_platform() {
        let Some(rt) = rt() else { return };
        let q = small_queue(4);
        let platform = Platform::from_counts("mini", 1, 1, 1);
        let mut agent = FlexAI::new(rt, FlexAIConfig::default()).unwrap();
        agent.set_training(true); // exploration on — still must stay in range
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(40).cloned().collect();
        let a = agent.schedule_batch(&burst, &state);
        assert!(a.iter().all(|&i| i < 3), "out-of-range action");
    }
}
