//! ε-greedy exploration schedule: linear decay from `start` to `end` over
//! `decay_steps` decisions, then constant `end`.

/// Exploration schedule parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpsilonSchedule {
    pub start: f64,
    pub end: f64,
    pub decay_steps: u64,
}

impl Default for EpsilonSchedule {
    fn default() -> Self {
        EpsilonSchedule { start: 1.0, end: 0.05, decay_steps: 20_000 }
    }
}

impl EpsilonSchedule {
    /// Greedy-only (inference) schedule.
    pub fn greedy() -> EpsilonSchedule {
        EpsilonSchedule { start: 0.0, end: 0.0, decay_steps: 1 }
    }

    pub fn at(&self, step: u64) -> f64 {
        if self.decay_steps == 0 || step >= self.decay_steps {
            return self.end;
        }
        let frac = step as f64 / self.decay_steps as f64;
        self.start + (self.end - self.start) * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_decay_then_floor() {
        let e = EpsilonSchedule { start: 1.0, end: 0.1, decay_steps: 100 };
        assert_eq!(e.at(0), 1.0);
        assert!((e.at(50) - 0.55).abs() < 1e-12);
        assert_eq!(e.at(100), 0.1);
        assert_eq!(e.at(1_000_000), 0.1);
    }

    #[test]
    fn greedy_is_always_zero() {
        let e = EpsilonSchedule::greedy();
        assert_eq!(e.at(0), 0.0);
        assert_eq!(e.at(10), 0.0);
    }

    #[test]
    fn zero_decay_steps_is_constant_end() {
        let e = EpsilonSchedule { start: 1.0, end: 0.3, decay_steps: 0 };
        assert_eq!(e.at(0), 0.3);
    }
}
