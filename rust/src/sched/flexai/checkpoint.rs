//! FlexAI checkpoints: EvalNet parameters + training provenance as JSON.
//! The paper's deployment model (§5.2: "the RL agent can be retrained by
//! GPU in cloud ... when the task category and scheduling strategy need to
//! be changed") maps to: train → save checkpoint → ship to the vehicle →
//! load in pure-inference mode.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{Params, Runtime};
use crate::util::json::Json;

use super::{FlexAI, FlexAIConfig};

/// Checkpoint format version.
pub const VERSION: usize = 1;

/// Serialize agent parameters + provenance.
pub fn save(agent: &FlexAI, path: &Path) -> Result<()> {
    let rt = agent.runtime();
    let j = Json::from_pairs(vec![
        ("version", Json::Num(VERSION as f64)),
        ("in_dim", Json::Num(rt.meta.in_dim as f64)),
        ("out_dim", Json::Num(rt.meta.out_dim as f64)),
        ("steps", Json::Num(agent.steps as f64)),
        ("train_steps", Json::Num(agent.train_steps as f64)),
        ("params", agent.params().to_json(&rt.meta.param_names)),
    ]);
    std::fs::write(path, j.to_string())
        .with_context(|| format!("writing checkpoint {}", path.display()))?;
    Ok(())
}

/// Load a checkpoint into a fresh inference-mode agent.
pub fn load(rt: Arc<Runtime>, path: &Path, cfg: FlexAIConfig) -> Result<FlexAI> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("checkpoint json: {e:?}"))?;
    anyhow::ensure!(j.as_obj().is_some(), "checkpoint: not an object");
    let in_dim = j.get_usize("in_dim").map_err(|e| anyhow::anyhow!("{e:?}"))?;
    anyhow::ensure!(
        in_dim == rt.meta.in_dim,
        "checkpoint in_dim {} != runtime {} (stale artifacts?)",
        in_dim,
        rt.meta.in_dim
    );
    let params = Params::from_json(
        j.get("params").map_err(|e| anyhow::anyhow!("checkpoint: params: {e:?}"))?,
        &rt.meta.param_names,
    )?;
    anyhow::ensure!(
        params.shapes() == rt.meta.param_shapes.as_slice(),
        "checkpoint shapes mismatch"
    );
    let mut agent = FlexAI::new(rt, cfg)?;
    agent.set_params(params);
    agent.set_training(false);
    Ok(agent)
}

#[cfg(test)]
#[allow(clippy::print_stderr)] // self-skipping tests explain themselves
mod tests {
    use super::*;

    /// Skip (with a message) when PJRT artifacts are unavailable.
    fn rt() -> Option<Arc<Runtime>> {
        match Runtime::load_default() {
            Ok(rt) => Some(Arc::new(rt)),
            Err(e) => {
                eprintln!("skipping checkpoint test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn roundtrip_preserves_params() {
        let Some(rt) = rt() else { return };
        let mut agent = FlexAI::new(rt.clone(), FlexAIConfig::default()).unwrap();
        agent.steps = 123;
        let dir = std::env::temp_dir().join("hmai_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("agent.json");
        save(&agent, &path).unwrap();
        let loaded = load(rt, &path, FlexAIConfig::default()).unwrap();
        assert!(agent.params().l2_distance(loaded.params()) < 1e-12);
        assert!(!loaded.is_training());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_checkpoint() {
        let Some(rt) = rt() else { return };
        let dir = std::env::temp_dir().join("hmai_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"version\": 1}").unwrap();
        assert!(load(rt, &path, FlexAIConfig::default()).is_err());
        std::fs::remove_file(&path).ok();
    }
}
