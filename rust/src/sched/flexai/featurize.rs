//! State featurization: (Task-Info, HW-Info) → the flat f32 vector the
//! Q-network consumes (§7.1).
//!
//! Layout must match `python/compile/model.py`:
//!   [ task one-hot (3: YOLO | SSD | GOTURN),
//!     amount_norm, layer_num_norm, safety_time_norm,            Task-Info
//!     per-slot × N_SLOTS:                                        HW-Info
//!       [ valid_capacity, kind_so, kind_si, kind_mm,
//!         queue_time_norm, energy_share, rel_competitiveness, est_time_norm,
//!         comm_time_norm  (slot_feats >= 9 metas only) ] ]
//!
//! `valid_capacity` is 0 for an absent slot and the core's relative MAC
//! scale otherwise (0.5 half / 1.0 std / 2.0 double) — the core-size
//! feature.  Std platforms write exactly the 1.0 the pre-size `valid`
//! flag wrote, so Std featurizations are bit-identical.
//!
//! `comm_time_norm` is the data-locality feature: the slot's predicted
//! interconnect time for this task over its safety budget (0 on monolithic
//! platforms).  It only exists when the artifact's meta says
//! `slot_feats >= 9`, so Q-networks compiled against the 8-feature layout
//! featurize bit-identically to before the interconnect existed.
//!
//! All other features are bounded to [0, 1] so a policy trained on one
//! route length transfers to another (raw E_i / queue times grow
//! unboundedly along a route; ratios and shares do not).

use crate::env::taskgen::Task;
use crate::runtime::Meta;
use crate::sim::ShadowState;

/// Amount scale: SSD is the largest model at 26 GMACs (Table 1).
pub const AMOUNT_SCALE: f64 = 30.0;
/// LayerNum scale: YOLO has the most layers, 101 (Table 1).
pub const LAYER_SCALE: f64 = 101.0;
/// Safety-time scale: longest RSS safety times are ~100 ms (§6.1).
pub const SAFETY_SCALE: f64 = 0.1;

/// Write the feature vector for scheduling `task` on `state` into `out`
/// (length `meta.in_dim`).  Returns the number of valid slots.
pub fn featurize(task: &Task, state: &ShadowState, meta: &Meta, out: &mut [f32]) -> usize {
    debug_assert_eq!(out.len(), meta.in_dim);
    out.fill(0.0);

    // --- Task-Info ---
    out[task.model.index()] = 1.0;
    out[3] = (task.amount_gmacs() / AMOUNT_SCALE).min(1.0) as f32;
    out[4] = (task.layer_num() as f64 / LAYER_SCALE).min(1.0) as f32;
    out[5] = (task.safety_time_s / SAFETY_SCALE).min(1.0) as f32;

    // --- HW-Info: one block per slot ---
    let n = state.len().min(meta.n_slots);
    let total_energy: f64 =
        state.metrics.per_accel.iter().map(|m| m.energy_j).sum::<f64>().max(1e-12);
    // Best predicted response across valid slots — the anchor for the
    // *relative* competitiveness feature.  Deadline-relative features
    // alone squash millisecond-scale dataflow-affinity differences to
    // ~1e-3 (invisible to the net); the relative feature keeps them O(1).
    let mut est_min = f64::INFINITY;
    for i in 0..n {
        est_min = est_min.min(state.est_response(task, i));
    }
    let est_min = est_min.max(1e-12);
    for i in 0..n {
        let base = meta.task_feats + i * meta.slot_feats;
        let est = state.est_response(task, i);
        out[base] = state.sizes[i].scale() as f32; // valid × capacity (1.0 = Std)
        out[base + 1 + state.kinds[i].index()] = 1.0; // kind one-hot
        // Queue backlog relative to this task's deadline budget.
        out[base + 4] =
            ratio01(state.queue_delay(i) / task.safety_time_s.max(1e-9));
        // Energy share of this slot — the balance signal.
        out[base + 5] = (state.accel_metrics(i).energy_j / total_energy) as f32;
        // Relative competitiveness: 0 for the best slot, →1 as the slot's
        // predicted response falls behind the best (affinity + backlog).
        out[base + 6] = ((est / est_min - 1.0).clamp(0.0, 1.0)) as f32;
        // Predicted response over safety time — the MS signal.
        out[base + 7] = ratio01(est / task.safety_time_s.max(1e-9));
        if meta.slot_feats >= 9 {
            // Data locality: predicted interconnect time (contended links +
            // weight-residency misses) over the safety budget.
            out[base + 8] = ratio01(state.est_comm_s(task, i) / task.safety_time_s.max(1e-9));
        }
    }
    n
}

/// Map a nonnegative ratio to [0, 1]: identity on [0, 1], saturating at 2×.
fn ratio01(r: f64) -> f32 {
    (r.min(2.0) / 2.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    fn meta() -> Meta {
        Meta::parse(
            r#"{
            "n_slots": 16, "task_feats": 6, "slot_feats": 8,
            "in_dim": 134, "h1": 256, "h2": 64, "out_dim": 16,
            "train_batch": 64, "infer_batch": 30,
            "gamma": 0.95, "lr": 0.01,
            "param_names": ["w1","b1","w2","b2","w3","b3"],
            "param_shapes": [[134,256],[256],[256,64],[64],[64,16],[16]]
        }"#,
        )
        .unwrap()
    }

    fn env() -> (Task, ShadowState, Meta) {
        let q = crate::sched::tests::small_queue(1);
        let state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        (q.tasks[0].clone(), state, meta())
    }

    #[test]
    fn layout_and_bounds() {
        let (task, state, meta) = env();
        let mut out = vec![0.0f32; meta.in_dim];
        let n = featurize(&task, &state, &meta, &mut out);
        assert_eq!(n, 11);
        // One-hot task kind.
        let onehot: f32 = out[..3].iter().sum();
        assert_eq!(onehot, 1.0);
        assert_eq!(out[task.model.index()], 1.0);
        // All bounded.
        assert!(out.iter().all(|&x| (0.0..=1.0).contains(&x)));
        // Slots 11..16 invalid → all-zero blocks.
        for i in 11..16 {
            let base = meta.task_feats + i * meta.slot_feats;
            assert!(out[base..base + meta.slot_feats].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn kind_onehot_matches_platform_layout() {
        let (task, state, meta) = env();
        let mut out = vec![0.0f32; meta.in_dim];
        featurize(&task, &state, &meta, &mut out);
        // HMAI: slots 0-3 SO, 4-7 SI, 8-10 MM.
        for (slot, kidx) in [(0usize, 1usize), (4, 2), (8, 3)] {
            let base = meta.task_feats + slot * meta.slot_feats;
            assert_eq!(out[base + kidx], 1.0, "slot {slot}");
        }
    }

    #[test]
    fn backlog_moves_queue_and_est_features() {
        let (task, mut state, meta) = env();
        let mut before = vec![0.0f32; meta.in_dim];
        featurize(&task, &state, &meta, &mut before);
        for _ in 0..5 {
            state.apply(&task, 0);
        }
        let mut after = vec![0.0f32; meta.in_dim];
        featurize(&task, &state, &meta, &mut after);
        let base = meta.task_feats;
        assert!(after[base + 4] > before[base + 4], "queue feature must rise");
        assert!(after[base + 7] > before[base + 7], "est feature must rise");
        // Slot 1 untouched.
        let b1 = meta.task_feats + meta.slot_feats;
        assert_eq!(after[b1 + 4], before[b1 + 4]);
    }

    #[test]
    fn capacity_feature_tracks_core_size_and_is_std_bit_compat() {
        let meta = meta();
        let q = crate::sched::tests::small_queue(2);
        let task = q.tasks[0].clone();
        // Std platform: the capacity feature is exactly the old 1.0 flag.
        let std_state = ShadowState::new(&Platform::hmai(), NormScales::unit());
        let mut out = vec![0.0f32; meta.in_dim];
        featurize(&task, &std_state, &meta, &mut out);
        for i in 0..11 {
            assert_eq!(out[meta.task_feats + i * meta.slot_feats].to_bits(), 1.0f32.to_bits());
        }
        // Mixed sizes: the feature is the per-slot MAC scale.
        let p = Platform::parse("so:1@0.5x,si:1,mm:1@2x").unwrap();
        let state = ShadowState::new(&p, NormScales::unit());
        let mut out = vec![0.0f32; meta.in_dim];
        let n = featurize(&task, &state, &meta, &mut out);
        assert_eq!(n, 3);
        assert_eq!(out[meta.task_feats], 0.5);
        assert_eq!(out[meta.task_feats + meta.slot_feats], 1.0);
        assert_eq!(out[meta.task_feats + 2 * meta.slot_feats], 2.0);
    }

    fn meta9() -> Meta {
        Meta::parse(
            r#"{
            "n_slots": 16, "task_feats": 6, "slot_feats": 9,
            "in_dim": 150, "h1": 256, "h2": 64, "out_dim": 16,
            "train_batch": 64, "infer_batch": 30,
            "gamma": 0.95, "lr": 0.01,
            "param_names": ["w1","b1","w2","b2","w3","b3"],
            "param_shapes": [[150,256],[256],[256,64],[64],[64,16],[16]]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn locality_feature_is_gated_on_meta_layout() {
        let q = crate::sched::tests::small_queue(3);
        let task = q.tasks[0].clone();
        let noc = ShadowState::new(&Platform::parse("hmai+mesh2x2").unwrap(), NormScales::unit());
        // An 8-feature meta never writes the locality slot — old artifacts
        // featurize bit-identically even on a chiplet platform state.
        let m8 = meta();
        let mut out8 = vec![0.0f32; m8.in_dim];
        featurize(&task, &noc, &m8, &mut out8);
        // A 9-feature meta sees comm: off-ingress slots nonzero, ingress 0.
        let m9 = meta9();
        let mut out9 = vec![0.0f32; m9.in_dim];
        let n = featurize(&task, &noc, &m9, &mut out9);
        assert_eq!(n, 11);
        let feat = |slot: usize| out9[m9.task_feats + slot * m9.slot_feats + 8];
        assert_eq!(feat(0), 0.0, "ingress slot moves nothing");
        assert!(feat(1) > 0.0, "off-ingress slot pays transfers");
        // The shared prefix (features 0..8 per slot) agrees bit for bit.
        for slot in 0..11 {
            for f in 0..8 {
                let a = out8[m8.task_feats + slot * m8.slot_feats + f];
                let b = out9[m9.task_feats + slot * m9.slot_feats + f];
                assert_eq!(a.to_bits(), b.to_bits(), "slot {slot} feat {f}");
            }
        }
        // Monolithic platform: the locality feature exists but is zero.
        let mono = ShadowState::new(&Platform::hmai(), NormScales::unit());
        let mut out = vec![0.0f32; m9.in_dim];
        featurize(&task, &mono, &m9, &mut out);
        for slot in 0..11 {
            assert_eq!(out[m9.task_feats + slot * m9.slot_feats + 8], 0.0);
        }
    }

    #[test]
    fn energy_share_sums_to_one_over_active_slots() {
        let (task, mut state, meta) = env();
        state.apply(&task, 0);
        state.apply(&task, 5);
        let mut out = vec![0.0f32; meta.in_dim];
        featurize(&task, &state, &meta, &mut out);
        let total: f32 = (0..11)
            .map(|i| out[meta.task_feats + i * meta.slot_feats + 5])
            .sum();
        assert!((total - 1.0).abs() < 1e-5, "shares sum {total}");
    }
}
