//! EDP [53] (Hamano et al., power-aware dynamic scheduling): per task, pick
//! the accelerator minimizing the energy-delay product of the decision —
//! `energy × predicted response time`.  Considers time and energy
//! (Table 11) but neither balance nor MS.
//!
//! Hot path: the per-task scan runs against a [`RolloutCtx`] (per-burst
//! cached cost rows + rolling drain view) instead of a full `ShadowState`
//! clone with per-task metrics updates — same picks, bit for bit
//! ([`reference::RefEdp`](super::reference::RefEdp) keeps the old path).

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::{RolloutCtx, Scheduler};

#[derive(Debug, Default)]
pub struct Edp;

impl Edp {
    pub fn new() -> Edp {
        Edp
    }
}

impl Scheduler for Edp {
    fn name(&self) -> String {
        "EDP".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let mut ctx = RolloutCtx::new(state);
        let mut out = Vec::with_capacity(tasks.len());
        for task in tasks {
            let mut best = 0;
            let mut best_edp = f64::INFINITY;
            for a in 0..ctx.len() {
                let edp = ctx.est_energy(task, a) * ctx.est_response(task, a);
                if edp < best_edp {
                    best_edp = edp;
                    best = a;
                }
            }
            ctx.push(task, best);
            out.push(best);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sim::{simulate, SimOptions};

    #[test]
    fn minimizes_edp_on_idle_platform() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let task = q.tasks[0].clone();
        let a = Edp::new().schedule_batch(std::slice::from_ref(&task), &state)[0];
        let edp_of = |i: usize| state.est_energy(&task, i) * state.est_response(&task, i);
        let min = (0..state.len()).map(edp_of).fold(f64::INFINITY, f64::min);
        assert!((edp_of(a) - min).abs() < 1e-15);
    }

    #[test]
    fn queue_pressure_diverts_tasks() {
        // Once the EDP-best accel is backlogged, the delay term pushes
        // tasks elsewhere — EDP does balance *implicitly* via delay.
        let q = crate::sched::tests::small_queue(2);
        let r = simulate(&q, &Platform::hmai(), &mut Edp::new(), SimOptions::default());
        let used = r
            .final_state
            .metrics
            .per_accel
            .iter()
            .filter(|m| m.num_tasks > 0)
            .count();
        assert!(used >= 4, "EDP used only {used} accels");
    }

    #[test]
    fn matches_reference_scan_exactly() {
        let q = crate::sched::tests::small_queue(7);
        let platform = Platform::parse("so:2@2x,si:2,mm:2@0.5x").unwrap();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        state.set_speed(1, 0.0);
        let burst: Vec<_> = q.tasks.iter().take(40).cloned().collect();
        let fast = Edp::new().schedule_batch(&burst, &state);
        let slow = crate::sched::reference::RefEdp::new().schedule_batch(&burst, &state);
        assert_eq!(fast, slow);
    }
}
