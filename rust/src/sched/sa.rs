//! Simulated annealing baseline [73, 74]: start from the greedy
//! earliest-completion assignment, then anneal single-task reassignment
//! moves under the time+energy cost of `fitness::rollout_cost`.
//!
//! SA starts from a good greedy point (unlike GA's random population), so
//! it lands close to Min-Min in Fig. 12(a) — but its cost function still
//! covers only time and energy (Table 11), so balance and MS lag FlexAI.
//!
//! Hot path: one [`RolloutCtx`] per burst serves both the greedy start
//! (rolling drain view, no `ShadowState` clone) and every neighbor-move
//! cost (no clone, no per-genome best-case rescan); the accepted-best
//! genome is kept via `clone_from` so the anneal loop allocates nothing.
//! The rng stream and every result bit are identical to
//! [`reference::RefSa`](super::reference::RefSa).

use crate::env::taskgen::Task;
use crate::sim::ShadowState;
use crate::util::rng::Rng;

use super::{RolloutCtx, Scheduler, UpSet};

/// SA hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct SaParams {
    /// Initial temperature as a fraction of the initial cost.
    pub t0_frac: f64,
    /// Geometric cooling rate per step.
    pub cooling: f64,
    /// Annealing steps per burst.
    pub steps: usize,
}

impl Default for SaParams {
    fn default() -> Self {
        SaParams { t0_frac: 0.3, cooling: 0.97, steps: 120 }
    }
}

#[derive(Debug)]
pub struct Sa {
    pub params: SaParams,
    seed: u64,
    rng: Rng,
}

impl Sa {
    pub fn new(seed: u64) -> Sa {
        Sa { params: SaParams::default(), seed, rng: Rng::new(seed) }
    }

    pub fn with_params(seed: u64, params: SaParams) -> Sa {
        Sa { params, seed, rng: Rng::new(seed) }
    }
}

impl Scheduler for Sa {
    fn name(&self) -> String {
        "SA".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let n = state.len();
        if n == 0 {
            // Degenerate zero-accelerator platform: the greedy start (and
            // every neighbor draw) would index an empty accelerator list —
            // fall back to accel 0 for every task instead of panicking.
            return vec![0; tasks.len()];
        }
        let ups = UpSet::new(state);
        let mut ctx = RolloutCtx::for_burst(tasks, state);
        // Greedy earliest-completion start against the rolling drain view
        // (a failed accelerator predicts an infinite completion time, so
        // the greedy pick routes past it).
        let mut current = Vec::with_capacity(tasks.len());
        for task in tasks {
            let mut best = 0;
            let mut best_ct = f64::INFINITY;
            for a in 0..n {
                let ct = ctx.est_completion(task, a);
                if ct < best_ct {
                    best_ct = ct;
                    best = a;
                }
            }
            ctx.push(task, best);
            current.push(best);
        }
        if tasks.len() <= 1 {
            return current;
        }

        let mut cur_cost = ctx.rollout_cost(tasks, &current);
        let mut best = current.clone();
        let mut best_cost = cur_cost;
        let mut temp = (cur_cost * self.params.t0_frac).max(1e-12);

        for _ in 0..self.params.steps {
            // Neighbor: reassign one random task to a random up accelerator.
            let i = self.rng.below(tasks.len());
            let old = current[i];
            let new = ups.draw(&mut self.rng);
            if new == old {
                temp *= self.params.cooling;
                continue;
            }
            current[i] = new;
            let cost = ctx.rollout_cost(tasks, &current);
            let accept = cost <= cur_cost
                || self.rng.chance(((cur_cost - cost) / temp).exp().min(1.0));
            if accept {
                cur_cost = cost;
                if cost < best_cost {
                    best_cost = cost;
                    best.clone_from(&current);
                }
            } else {
                current[i] = old;
            }
            temp *= self.params.cooling;
        }
        best
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::fitness::rollout_cost;
    use crate::sched::sequential;
    use crate::sched::tests::small_queue;

    #[test]
    fn never_worse_than_greedy_start() {
        let q = small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        let greedy = sequential(&burst, &state, |task, s| {
            (0..s.len())
                .min_by(|&a, &b| {
                    s.est_completion(task, a).total_cmp(&s.est_completion(task, b))
                })
                .unwrap()
        });
        let greedy_cost = rollout_cost(&burst, &greedy, &state);
        let mut sa = Sa::new(3);
        let sol = sa.schedule_batch(&burst, &state);
        assert!(rollout_cost(&burst, &sol, &state) <= greedy_cost + 1e-12);
    }

    #[test]
    fn beats_ga_on_queue_waiting_time() {
        // The paper's ordering (Fig. 12a): SA lands close to FlexAI while
        // GA lags badly.  Compare on a whole queue, where SA's greedy
        // start compounds and GA's random drift accumulates waiting time.
        use crate::sim::{simulate, SimOptions};
        let q = small_queue(2);
        let platform = Platform::hmai();
        let sa = simulate(&q, &platform, &mut Sa::new(5), SimOptions::default());
        let ga = simulate(
            &q,
            &platform,
            &mut crate::sched::ga::Ga::new(5),
            SimOptions::default(),
        );
        assert!(
            sa.summary.wait_s <= ga.summary.wait_s,
            "sa wait {} vs ga wait {}",
            sa.summary.wait_s,
            ga.summary.wait_s
        );
    }

    #[test]
    fn anneals_around_failed_accels() {
        let q = small_queue(4);
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        state.set_speed(1, 0.0);
        state.set_speed(9, 0.0);
        let burst: Vec<_> = q.tasks.iter().take(24).cloned().collect();
        let a = Sa::new(6).schedule_batch(&burst, &state);
        assert!(a.iter().all(|&i| i != 1 && i != 9), "SA mapped a dead slot: {a:?}");
    }

    #[test]
    fn zero_accelerator_platform_does_not_panic() {
        // Regression: the greedy start used to roll an empty platform.
        let platform = Platform::from_counts("empty", 0, 0, 0);
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = small_queue(1);
        let burst: Vec<_> = q.tasks.iter().take(4).cloned().collect();
        let a = Sa::new(7).schedule_batch(&burst, &state);
        assert_eq!(a, vec![0; 4]);
    }

    #[test]
    fn single_task_is_greedy() {
        let q = small_queue(3);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let task = q.tasks[0].clone();
        let a = Sa::new(1).schedule_batch(std::slice::from_ref(&task), &state)[0];
        let min_ct = (0..state.len())
            .map(|i| state.est_completion(&task, i))
            .fold(f64::INFINITY, f64::min);
        assert!((state.est_completion(&task, a) - min_ct).abs() < 1e-15);
    }

    #[test]
    fn matches_reference_sa_exactly() {
        // Same seed, same burst → identical rng stream, costs and evolved
        // assignment as the full-clone reference — healthy and degraded.
        let q = small_queue(8);
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        for seed in [2u64, 13, 42] {
            let fast = Sa::new(seed).schedule_batch(&burst, &state);
            let slow = crate::sched::reference::RefSa::new(seed).schedule_batch(&burst, &state);
            assert_eq!(fast, slow, "seed {seed}");
        }
        state.apply(&burst[1], 2);
        state.set_speed(4, 0.0);
        state.set_speed(10, 0.5);
        let fast = Sa::new(21).schedule_batch(&burst, &state);
        let slow = crate::sched::reference::RefSa::new(21).schedule_batch(&burst, &state);
        assert_eq!(fast, slow, "degraded platform");
    }
}
