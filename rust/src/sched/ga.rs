//! Genetic algorithm baseline [54, 55, 56]: evolve burst-assignment vectors
//! under the time+energy fitness of `fitness::rollout_cost`.
//!
//! Faithful to the paper's characterization: the initial population is
//! *purely random* ("GA's performance is affected by the selection of the
//! initial population", §8.3) and the per-burst budget is bounded — a
//! scheduler must decide within a frame period, so GA cannot search long
//! enough to recover from a bad draw.  This is what makes GA the weakest
//! baseline in Fig. 12(a).
//!
//! Hot path: one [`RolloutCtx`] per burst prices every genome (no
//! `ShadowState` clone, no per-genome best-case rescan), parents are
//! borrowed from the population instead of cloned, and the two population
//! buffers are swapped between generations — a generation allocates
//! nothing beyond genome storage.  The rng stream and every result bit
//! are identical to [`reference::RefGa`](super::reference::RefGa).

use crate::env::taskgen::Task;
use crate::sim::ShadowState;
use crate::util::rng::Rng;

use super::{RolloutCtx, Scheduler, UpSet};

/// GA hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub tournament: usize,
    pub crossover_p: f64,
    pub mutation_p: f64,
    pub elites: usize,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 16,
            generations: 10,
            tournament: 3,
            crossover_p: 0.9,
            mutation_p: 0.08,
            elites: 2,
        }
    }
}

#[derive(Debug)]
pub struct Ga {
    pub params: GaParams,
    seed: u64,
    rng: Rng,
}

impl Ga {
    pub fn new(seed: u64) -> Ga {
        Ga { params: GaParams::default(), seed, rng: Rng::new(seed) }
    }

    pub fn with_params(seed: u64, params: GaParams) -> Ga {
        Ga { params, seed, rng: Rng::new(seed) }
    }

    fn tournament_pick<'a>(
        &mut self,
        pop: &'a [(Vec<usize>, f64)],
    ) -> &'a (Vec<usize>, f64) {
        let mut best = &pop[self.rng.below(pop.len())];
        for _ in 1..self.params.tournament {
            let c = &pop[self.rng.below(pop.len())];
            if c.1 < best.1 {
                best = c;
            }
        }
        best
    }
}

impl Scheduler for Ga {
    fn name(&self) -> String {
        "GA".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let ups = UpSet::new(state);
        let mut ctx = RolloutCtx::for_burst(tasks, state);
        let p = self.params;

        // Random initial population (no greedy seeding — see module docs).
        let mut pop: Vec<(Vec<usize>, f64)> = (0..p.population)
            .map(|_| {
                let genome: Vec<usize> =
                    tasks.iter().map(|_| ups.draw(&mut self.rng)).collect();
                let cost = ctx.rollout_cost(tasks, &genome);
                (genome, cost)
            })
            .collect();
        // Double buffer: `next` and `pop` swap roles each generation, so
        // steady state allocates only the offspring genomes themselves.
        let mut next: Vec<(Vec<usize>, f64)> = Vec::with_capacity(p.population);

        for _gen in 0..p.generations {
            pop.sort_by(|a, b| a.1.total_cmp(&b.1));
            next.clear();
            next.extend(pop.iter().take(p.elites).cloned());
            while next.len() < p.population {
                // Parents stay borrowed from `pop` (the old path cloned
                // both); only the offspring genome is materialized.
                let pa = self.tournament_pick(&pop);
                let pb = self.tournament_pick(&pop);
                let mut child: Vec<usize> = if self.rng.chance(p.crossover_p) {
                    // Uniform crossover.
                    pa.0.iter()
                        .zip(&pb.0)
                        .map(|(&x, &y)| if self.rng.chance(0.5) { x } else { y })
                        .collect()
                } else {
                    pa.0.clone()
                };
                for g in child.iter_mut() {
                    if self.rng.chance(p.mutation_p) {
                        *g = ups.draw(&mut self.rng);
                    }
                }
                let cost = ctx.rollout_cost(tasks, &child);
                next.push((child, cost));
            }
            std::mem::swap(&mut pop, &mut next);
        }
        pop.sort_by(|a, b| a.1.total_cmp(&b.1));
        pop.swap_remove(0).0
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sched::fitness::rollout_cost;
    use crate::sched::tests::small_queue;

    #[test]
    fn improves_over_random_assignment() {
        let q = small_queue(1);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        let mut ga = Ga::new(11);
        let sol = ga.schedule_batch(&burst, &state);
        let ga_cost = rollout_cost(&burst, &sol, &state);
        // Mean cost of fresh random genomes must be worse.
        let mut rng = Rng::new(99);
        let mut rand_cost = 0.0;
        for _ in 0..20 {
            let genome: Vec<usize> =
                burst.iter().map(|_| rng.below(state.len())).collect();
            rand_cost += rollout_cost(&burst, &genome, &state);
        }
        rand_cost /= 20.0;
        assert!(ga_cost < rand_cost, "ga {ga_cost} vs random {rand_cost}");
    }

    #[test]
    fn genomes_never_touch_failed_accels() {
        let q = small_queue(3);
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        state.set_speed(2, 0.0);
        state.set_speed(7, 0.0);
        let burst: Vec<_> = q.tasks.iter().take(20).cloned().collect();
        let a = Ga::new(4).schedule_batch(&burst, &state);
        assert!(a.iter().all(|&i| i != 2 && i != 7), "GA mapped a dead slot: {a:?}");
    }

    #[test]
    fn deterministic_per_seed_and_resettable() {
        let q = small_queue(2);
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(12).cloned().collect();
        let mut a = Ga::new(5);
        let sol1 = a.schedule_batch(&burst, &state);
        a.reset();
        let sol2 = a.schedule_batch(&burst, &state);
        assert_eq!(sol1, sol2);
    }

    #[test]
    fn matches_reference_ga_exactly() {
        // Same seed, same burst → the RolloutCtx path must reproduce the
        // full-clone reference bit-for-bit (identical rng stream, costs
        // and therefore identical evolved assignments) — healthy and
        // degraded platforms both.
        let q = small_queue(6);
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<_> = q.tasks.iter().take(30).cloned().collect();
        for seed in [1u64, 9, 42] {
            let fast = Ga::new(seed).schedule_batch(&burst, &state);
            let slow = crate::sched::reference::RefGa::new(seed).schedule_batch(&burst, &state);
            assert_eq!(fast, slow, "seed {seed}");
        }
        state.apply(&burst[0], 3);
        state.set_speed(5, 0.0);
        state.set_speed(8, 0.5);
        let fast = Ga::new(7).schedule_batch(&burst, &state);
        let slow = crate::sched::reference::RefGa::new(7).schedule_batch(&burst, &state);
        assert_eq!(fast, slow, "degraded platform");
    }
}
