//! ATA — Adaptive Task-partitioning Algorithm [47] (Oh et al.): pick the
//! mapping that "consumes as little energy as possible while guaranteeing
//! the latency".  Per task: among accelerators whose predicted response
//! time meets the task's safety time, choose the energy-cheapest; if none
//! can meet it, fall back to the earliest-completion accelerator (minimize
//! the violation).
//!
//! ATA is the only baseline optimized toward MS (Table 11 / §8.3: "ATA is
//! optimized towards MS, the STMRate of each task queue is also very high
//! under ATA") — but it ignores global balance, which costs it Fig. 12(a/b).
//!
//! Hot path: the per-task scan runs against a [`RolloutCtx`] (per-burst
//! cached cost rows + rolling drain view) instead of a full `ShadowState`
//! clone with per-task metrics updates — same picks, bit for bit
//! ([`reference::RefAta`](super::reference::RefAta) keeps the old path).

use crate::env::taskgen::Task;
use crate::sim::ShadowState;

use super::{RolloutCtx, Scheduler};

#[derive(Debug, Default)]
pub struct Ata;

impl Ata {
    pub fn new() -> Ata {
        Ata
    }
}

impl Scheduler for Ata {
    fn name(&self) -> String {
        "ATA".into()
    }

    fn schedule_batch(&mut self, tasks: &[Task], state: &ShadowState) -> Vec<usize> {
        let mut ctx = RolloutCtx::new(state);
        let mut out = Vec::with_capacity(tasks.len());
        for task in tasks {
            let mut best_safe: Option<(usize, f64)> = None; // (accel, energy)
            let mut best_any: Option<(usize, f64)> = None; // (accel, response)
            for a in 0..ctx.len() {
                let resp = ctx.est_response(task, a);
                let e = ctx.est_energy(task, a);
                if resp <= task.safety_time_s
                    && best_safe.map(|(_, be)| e < be).unwrap_or(true)
                {
                    best_safe = Some((a, e));
                }
                if best_any.map(|(_, br)| resp < br).unwrap_or(true) {
                    best_any = Some((a, resp));
                }
            }
            // lint:allow(panic-in-hot-path): every platform has at least one
            // accelerator, so best_any is always Some.
            let pick = best_safe.or(best_any).expect("non-empty platform").0;
            ctx.push(task, pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;
    use crate::sim::{simulate, SimOptions};

    #[test]
    fn prefers_energy_cheapest_safe_accel() {
        let platform = Platform::hmai();
        let state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(1);
        let task = q.tasks[0].clone();
        let mut s = Ata::new();
        let a = s.schedule_batch(std::slice::from_ref(&task), &state)[0];
        // On an idle platform every accel is safe; the pick must be the
        // global energy minimum for this model.
        let min_e = (0..state.len())
            .map(|i| state.est_energy(&task, i))
            .fold(f64::INFINITY, f64::min);
        assert!((state.est_energy(&task, a) - min_e).abs() < 1e-12);
    }

    #[test]
    fn high_stm_rate_like_paper() {
        // §8.4: "the STMRate of each task queue is also very high under ATA".
        let q = crate::sched::tests::small_queue(2);
        let r = simulate(&q, &Platform::hmai(), &mut Ata::new(), SimOptions::default());
        assert!(r.summary.stm_rate() > 0.9, "stm = {}", r.summary.stm_rate());
    }

    #[test]
    fn falls_back_when_nothing_is_safe() {
        // Saturate the platform so no accelerator can meet the deadline;
        // ATA must still return a valid index (earliest completion).
        let platform = Platform::from_counts("tiny", 1, 1, 0);
        let mut state = ShadowState::new(&platform, NormScales::unit());
        let q = crate::sched::tests::small_queue(3);
        let task = q.tasks[0].clone();
        // Pile tasks until no accelerator can meet the deadline.
        while (0..2).any(|i| state.est_response(&task, i) <= task.safety_time_s) {
            state.apply(&task, 0);
            state.apply(&task, 1);
        }
        let mut s = Ata::new();
        let a = s.schedule_batch(std::slice::from_ref(&task), &state)[0];
        assert!(a < 2);
        assert!(state.est_response(&task, a) > task.safety_time_s);
        // Fallback is earliest completion.
        let other = 1 - a;
        assert!(state.est_response(&task, a) <= state.est_response(&task, other));
    }

    #[test]
    fn matches_reference_scan_exactly() {
        let q = crate::sched::tests::small_queue(5);
        let platform = Platform::hmai();
        let mut state = ShadowState::new(&platform, NormScales::unit());
        state.set_speed(2, 0.0);
        state.set_speed(6, 0.5);
        let burst: Vec<_> = q.tasks.iter().take(40).cloned().collect();
        let fast = Ata::new().schedule_batch(&burst, &state);
        let slow = crate::sched::reference::RefAta::new().schedule_batch(&burst, &state);
        assert_eq!(fast, slow);
    }
}
