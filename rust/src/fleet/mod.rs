//! Fleet sweep service: sharded, checkpoint-resumable sweeps whose merged
//! report is fingerprint-identical to a single-process run.
//!
//! The pipeline is plan → work → merge (`hmai fleet plan|work|merge`):
//!
//! 1. **plan** captures every axis of the sweep in a plan file together
//!    with a `plan_hash` — an FNV-1a digest of the *expanded* trial list
//!    (every field of every [`Trial`] that influences results, in id
//!    order).  Because `ExperimentPlan` expansion is deterministic, any
//!    process loading the same plan file derives the same trials, the same
//!    hash, and the same contiguous [`ShardSpec`] ranges.
//! 2. **work** runs one shard's trial range, folding each result into a
//!    partial [`SweepSummary`] and checkpointing it periodically with
//!    atomic write-temp-then-rename ([`crate::util::json::write_atomic`]).
//!    A killed worker restarts from its checkpoint: the load verifies the
//!    plan hash and shard range, then skips the already-folded prefix —
//!    the summary state round-trips bit-for-bit (f64 sums stored as bit
//!    hex), so a kill/resume cycle is invisible in the final report.
//! 3. **merge** folds complete shard checkpoints in trial-id order after
//!    verifying they cover the plan exactly once.  The sweep fingerprint
//!    is partition-invariant by construction (see
//!    [`crate::metrics::summary`]), so the merged fingerprint equals the
//!    monolithic `sweep_streaming` fingerprint for *any* shard count.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::engine::Engine;
use crate::env::taskgen::DeadlineMode;
use crate::env::Area;
use crate::metrics::quantile::parse_bits_hex;
use crate::metrics::summary::SweepSummary;
use crate::plan::{replicate_seeds, ExperimentPlan, Trial};
use crate::sched::{Registry, SchedulerSpec};
use crate::util::json::Json;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

fn fnv_word(h: &mut u64, w: u64) {
    *h ^= w;
    *h = h.wrapping_mul(FNV_PRIME);
}

fn fnv_str(h: &mut u64, s: &str) {
    for b in s.bytes() {
        fnv_word(h, b as u64);
    }
    // Length-delimit so concatenated fields can't alias.
    fnv_word(h, s.len() as u64);
}

/// Every axis of a fleet sweep, as captured in the plan file.  Scheduler
/// and platform stay in their *spec string* form (what the user typed) so
/// the file is self-describing; resolution re-validates on every load.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub scenarios: Vec<String>,
    pub area: Area,
    pub distances_m: Vec<f64>,
    pub deadline: DeadlineMode,
    pub platforms: Vec<String>,
    /// Scheduler name tokens (`SchedulerSpec::parse` form).
    pub schedulers: Vec<String>,
    /// FlexAI checkpoint path attached to any `flexai` token (empty =
    /// fresh init).
    pub checkpoint: String,
    pub seeds: Vec<u64>,
    pub events: bool,
    pub shards: usize,
}

impl FleetPlan {
    /// Build from an experiment config; `--sched` accepts a comma list
    /// here (a fleet sweep usually compares schedulers).
    pub fn from_config(cfg: &ExperimentConfig, shards: usize) -> Result<FleetPlan> {
        let schedulers: Vec<String> = cfg
            .scheduler
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        anyhow::ensure!(!schedulers.is_empty(), "fleet plan: no schedulers");
        for s in &schedulers {
            SchedulerSpec::parse(s)?;
        }
        Ok(FleetPlan {
            scenarios: cfg.scenarios.clone(),
            area: cfg.env.area,
            distances_m: cfg.env.distances_m.clone(),
            deadline: cfg.deadline,
            platforms: vec![cfg.platform.clone()],
            schedulers,
            checkpoint: cfg.checkpoint.clone(),
            seeds: replicate_seeds(cfg.env.seed, cfg.replicates.max(1)),
            events: cfg.events,
            shards: shards.max(1),
        })
    }

    fn scheduler_specs(&self) -> Result<Vec<SchedulerSpec>> {
        self.schedulers
            .iter()
            .map(|s| {
                Ok(match SchedulerSpec::parse(s)? {
                    SchedulerSpec::FlexAI { .. } => SchedulerSpec::FlexAI {
                        checkpoint: if self.checkpoint.is_empty() {
                            None
                        } else {
                            Some(self.checkpoint.clone())
                        },
                    },
                    other => other,
                })
            })
            .collect()
    }

    /// The `ExperimentPlan` this fleet plan expands (scenarios override
    /// the area axis, exactly like `ExperimentConfig::plan`).
    pub fn experiment_plan(&self) -> Result<ExperimentPlan> {
        let mut plan = ExperimentPlan::new()
            .area(self.area)
            .distances(self.distances_m.iter().copied())
            .deadline(self.deadline)
            .platforms(self.platforms.iter().cloned())
            .schedulers(self.scheduler_specs()?)
            .seeds(self.seeds.iter().copied());
        if !self.scenarios.is_empty() {
            plan = plan.scenarios(self.scenarios.iter().cloned());
        }
        Ok(plan)
    }

    /// Expand trials, hash them, and split into contiguous shard ranges.
    pub fn resolve(&self) -> Result<ResolvedPlan> {
        let trials = self.experiment_plan()?.trials()?;
        anyhow::ensure!(!trials.is_empty(), "fleet plan expands to zero trials");
        anyhow::ensure!(
            self.shards <= trials.len(),
            "fleet plan: {} shards for {} trials",
            self.shards,
            trials.len()
        );
        let plan_hash = plan_hash(self.events, &trials);
        let shards = shard_ranges(trials.len(), self.shards)
            .into_iter()
            .enumerate()
            .map(|(i, (lo, hi))| ShardSpec { shard: i, plan_hash, lo, hi })
            .collect();
        Ok(ResolvedPlan { trials, plan_hash, shards })
    }

    /// Plan-file form.  Seeds are hex strings (u64 doesn't survive f64
    /// JSON numbers); distances are plain numbers (our writer emits the
    /// shortest round-tripping form).
    pub fn to_json(&self, resolved: &ResolvedPlan) -> Json {
        Json::from_pairs(vec![
            ("version", Json::Num(1.0)),
            ("plan_hash", Json::Str(format!("{:016x}", resolved.plan_hash))),
            ("trials", Json::Num(resolved.trials.len() as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("events", Json::Bool(self.events)),
            ("area", Json::Str(self.area.name().to_lowercase())),
            ("deadline", Json::Str(self.deadline.name().to_string())),
            (
                "scenarios",
                Json::Arr(self.scenarios.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            ("distances_m", Json::array_f64(&self.distances_m)),
            (
                "platforms",
                Json::Arr(self.platforms.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "schedulers",
                Json::Arr(self.schedulers.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            ("checkpoint", Json::Str(self.checkpoint.clone())),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|s| Json::Str(format!("{s:016x}"))).collect()),
            ),
        ])
    }

    /// Write the plan file (atomic, like every artifact).
    pub fn save(&self, path: &Path, resolved: &ResolvedPlan) -> Result<()> {
        self.to_json(resolved)
            .write_to(path)
            .with_context(|| format!("writing fleet plan {}", path.display()))
    }

    /// Load and re-resolve a plan file, verifying that this binary expands
    /// it to the same trial list the planner hashed (a version skew or a
    /// hand-edited file fails here, not at merge time).
    pub fn load(path: &Path) -> Result<(FleetPlan, ResolvedPlan)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fleet plan {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("fleet plan {}: {e}", path.display()))?;
        let version = j.get_f64("version")? as u64;
        anyhow::ensure!(version == 1, "fleet plan version {version} unsupported");
        let strings = |key: &str| -> Result<Vec<String>> {
            Ok(j.get_arr(key)?
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect())
        };
        let mut seeds = Vec::new();
        for s in j.get_arr("seeds")? {
            seeds.push(parse_bits_hex(s.as_str().context("fleet plan: seed not a string")?)?);
        }
        let plan = FleetPlan {
            scenarios: strings("scenarios")?,
            area: Area::parse(j.get_str("area")?)
                .context("fleet plan: bad area")?,
            distances_m: j
                .get_arr("distances_m")?
                .iter()
                .filter_map(|x| x.as_f64())
                .collect(),
            deadline: DeadlineMode::parse(j.get_str("deadline")?)
                .context("fleet plan: bad deadline")?,
            platforms: strings("platforms")?,
            schedulers: strings("schedulers")?,
            checkpoint: j.get_str("checkpoint")?.to_string(),
            seeds,
            events: j.get("events")?.as_bool().context("fleet plan: events not a bool")?,
            shards: j.get_usize("shards")?,
        };
        let resolved = plan.resolve()?;
        let stored = parse_bits_hex(j.get_str("plan_hash")?)?;
        anyhow::ensure!(
            stored == resolved.plan_hash,
            "fleet plan {}: stored plan_hash {:016x} != recomputed {:016x} \
             (edited file or incompatible binary)",
            path.display(),
            stored,
            resolved.plan_hash
        );
        anyhow::ensure!(
            j.get_f64("trials")? as usize == resolved.trials.len(),
            "fleet plan {}: trial count drifted",
            path.display()
        );
        Ok((plan, resolved))
    }
}

/// A fleet plan expanded into its trial list, hash and shard ranges.
pub struct ResolvedPlan {
    pub trials: Vec<Trial>,
    pub plan_hash: u64,
    pub shards: Vec<ShardSpec>,
}

/// One shard's slice of the plan: trials `lo..hi` (trial-id order).  The
/// embedded `plan_hash` ties every checkpoint to the exact trial list it
/// was computed over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub shard: usize,
    pub plan_hash: u64,
    pub lo: usize,
    pub hi: usize,
}

impl ShardSpec {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }
}

/// Contiguous near-equal split of `n` trials into `k` ranges (first
/// `n % k` shards take one extra).
fn shard_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    let k = k.max(1);
    let (base, rem) = (n / k, n % k);
    let mut out = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let hi = lo + base + usize::from(i < rem);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Hash of the expanded trial list: every result-influencing field of
/// every trial, in id order, plus the events flag.  Two binaries agreeing
/// on this hash will run identical trial sets.
fn plan_hash(events: bool, trials: &[Trial]) -> u64 {
    let mut h = FNV_OFFSET;
    fnv_word(&mut h, events as u64);
    fnv_word(&mut h, trials.len() as u64);
    for t in trials {
        fnv_word(&mut h, t.id as u64);
        fnv_str(&mut h, &t.scenario.scenario_name());
        fnv_str(&mut h, t.scenario.area.name());
        fnv_word(&mut h, t.scenario.distance_m.to_bits());
        fnv_str(&mut h, t.scenario.deadline.name());
        fnv_word(&mut h, t.queue_index as u64);
        fnv_str(&mut h, &t.platform);
        fnv_str(&mut h, t.scheduler.canonical());
        fnv_word(&mut h, t.seed);
        fnv_word(&mut h, t.sched_seed);
    }
    h
}

/// A shard worker's durable state: how far it has folded (`next_trial`)
/// and the partial summary of `lo..next_trial`.  Saved atomically, so a
/// kill leaves the previous consistent checkpoint.
#[derive(Debug, Clone)]
pub struct ShardCheckpoint {
    pub spec: ShardSpec,
    /// First trial id NOT yet folded into `summary`.
    pub next_trial: usize,
    pub summary: SweepSummary,
}

impl ShardCheckpoint {
    fn fresh(spec: ShardSpec) -> ShardCheckpoint {
        ShardCheckpoint { spec, next_trial: spec.lo, summary: SweepSummary::new() }
    }

    pub fn complete(&self) -> bool {
        self.next_trial >= self.spec.hi
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", Json::Num(1.0)),
            ("plan_hash", Json::Str(format!("{:016x}", self.spec.plan_hash))),
            ("shard", Json::Num(self.spec.shard as f64)),
            ("lo", Json::Num(self.spec.lo as f64)),
            ("hi", Json::Num(self.spec.hi as f64)),
            ("next_trial", Json::Num(self.next_trial as f64)),
            ("fingerprint", Json::Str(format!("{:016x}", self.summary.fingerprint()))),
            ("summary", self.summary.state_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardCheckpoint> {
        let version = j.get_f64("version")? as u64;
        anyhow::ensure!(version == 1, "shard checkpoint version {version} unsupported");
        let spec = ShardSpec {
            shard: j.get_usize("shard")?,
            plan_hash: parse_bits_hex(j.get_str("plan_hash")?)?,
            lo: j.get_usize("lo")?,
            hi: j.get_usize("hi")?,
        };
        let ckpt = ShardCheckpoint {
            spec,
            next_trial: j.get_usize("next_trial")?,
            summary: SweepSummary::from_state_json(j.get("summary")?)?,
        };
        anyhow::ensure!(
            spec.lo <= ckpt.next_trial && ckpt.next_trial <= spec.hi,
            "shard checkpoint: next_trial {} outside {}..{}",
            ckpt.next_trial,
            spec.lo,
            spec.hi
        );
        let stored = parse_bits_hex(j.get_str("fingerprint")?)?;
        anyhow::ensure!(
            stored == ckpt.summary.fingerprint(),
            "shard checkpoint: summary fingerprint mismatch (corrupt or hand-edited)"
        );
        Ok(ckpt)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json()
            .write_to(path)
            .with_context(|| format!("writing shard checkpoint {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ShardCheckpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading shard checkpoint {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("shard checkpoint {}: {e}", path.display()))?;
        Self::from_json(&j).with_context(|| path.display().to_string())
    }
}

/// Worker knobs for [`run_shard`].
#[derive(Debug, Clone, Copy)]
pub struct WorkOptions {
    /// Engine worker threads (0 = all cores).
    pub jobs: usize,
    /// Checkpoint after this many folded trials.
    pub checkpoint_every: usize,
    /// Stop after folding this many trials this invocation (None = run the
    /// shard to completion).  The test and CI "kill" knob: stopping early
    /// leaves a valid mid-shard checkpoint to resume from.
    pub max_trials: Option<usize>,
}

impl Default for WorkOptions {
    fn default() -> Self {
        WorkOptions { jobs: 1, checkpoint_every: 500, max_trials: None }
    }
}

/// Move an unreadable checkpoint aside as `<path>.corrupt` (numbered
/// `.corrupt.N` when earlier quarantines exist) so the worker can restart
/// the shard fresh without destroying the evidence.
fn quarantine_checkpoint(path: &Path) -> Result<std::path::PathBuf> {
    let candidate = |n: u32| -> std::path::PathBuf {
        let mut s = path.as_os_str().to_owned();
        if n == 0 {
            s.push(".corrupt");
        } else {
            s.push(format!(".corrupt.{n}"));
        }
        std::path::PathBuf::from(s)
    };
    let mut dest = candidate(0);
    let mut n = 0u32;
    while dest.exists() && n < 1000 {
        n += 1;
        dest = candidate(n);
    }
    std::fs::rename(path, &dest).with_context(|| {
        format!("quarantining corrupt checkpoint {} -> {}", path.display(), dest.display())
    })?;
    Ok(dest)
}

/// Run (or resume) one shard: fold trials `next_trial..hi` into the
/// partial summary, checkpointing every `checkpoint_every` trials and at
/// the end.  Returns the final checkpoint state (complete unless
/// `max_trials` stopped it early).
///
/// A checkpoint that fails to *load* (truncated by a crash mid-write
/// outside the atomic path, bit rot, fingerprint mismatch) is quarantined
/// — renamed `<path>.corrupt` and logged — and the shard restarts from
/// scratch: re-running a shard is always safe (determinism), losing a
/// fleet to one bad file is not.  A checkpoint that loads but belongs to a
/// *different* shard/plan stays a hard error: that is an operator mix-up
/// (wrong path or stale directory), and silently discarding someone
/// else's valid work would be worse than stopping.
pub fn run_shard(
    registry: &Registry,
    plan: &FleetPlan,
    resolved: &ResolvedPlan,
    shard: usize,
    checkpoint_path: &Path,
    opts: WorkOptions,
) -> Result<ShardCheckpoint> {
    let spec = *resolved
        .shards
        .get(shard)
        .with_context(|| format!("shard {shard} out of range ({} shards)", resolved.shards.len()))?;
    let mut ckpt = if checkpoint_path.exists() {
        let c = match ShardCheckpoint::load(checkpoint_path) {
            Ok(c) => c,
            Err(e) => {
                let dest = quarantine_checkpoint(checkpoint_path)?;
                crate::log_warn!(
                    "fleet",
                    "shard {shard}: corrupt checkpoint quarantined to {} ({e:#}); \
                     restarting the shard from scratch",
                    dest.display()
                );
                ShardCheckpoint::fresh(spec)
            }
        };
        anyhow::ensure!(
            c.spec == spec,
            "checkpoint {} is for shard {}/plan {:016x} range {}..{}, expected \
             shard {}/plan {:016x} range {}..{}",
            checkpoint_path.display(),
            c.spec.shard,
            c.spec.plan_hash,
            c.spec.lo,
            c.spec.hi,
            spec.shard,
            spec.plan_hash,
            spec.lo,
            spec.hi
        );
        c
    } else {
        ShardCheckpoint::fresh(spec)
    };
    if ckpt.complete() {
        return Ok(ckpt);
    }
    let start = ckpt.next_trial;
    let end = match opts.max_trials {
        Some(m) => (start + m).min(spec.hi),
        None => spec.hi,
    };
    let every = opts.checkpoint_every.max(1);
    let mut summary = ckpt.summary;
    let mut next = start;
    let mut since = 0usize;
    // The sink can't return an error, so a failed periodic save is
    // deferred and surfaced after the run (the final save would fail the
    // same way anyway).
    let mut save_err: Option<anyhow::Error> = None;
    let engine = Engine::new(registry).jobs(opts.jobs).events(plan.events);
    engine.run_trials_streamed(&resolved.trials[start..end], |r| {
        let key = r.sweep_key();
        summary.push(key, r.summary);
        next += 1;
        since += 1;
        if since >= every && next < end && save_err.is_none() {
            since = 0;
            let c = ShardCheckpoint { spec, next_trial: next, summary: summary.clone() };
            if let Err(e) = c.save(checkpoint_path) {
                save_err = Some(e);
            }
        }
    })?;
    if let Some(e) = save_err {
        return Err(e);
    }
    ckpt = ShardCheckpoint { spec, next_trial: next, summary };
    ckpt.save(checkpoint_path)?;
    Ok(ckpt)
}

/// Fold complete shard checkpoints into the fleet summary, verifying they
/// belong to `resolved` and tile its trial range exactly once.  Folding in
/// trial-id order keeps merged f64 moments as close to the monolithic fold
/// as shard boundaries allow; the fingerprint is exactly equal for any
/// partition.
pub fn merge_checkpoints(
    resolved: &ResolvedPlan,
    parts: &[ShardCheckpoint],
) -> Result<SweepSummary> {
    anyhow::ensure!(!parts.is_empty(), "fleet merge: no shard checkpoints");
    let mut ordered: Vec<&ShardCheckpoint> = parts.iter().collect();
    ordered.sort_by_key(|c| c.spec.lo);
    let mut cursor = 0usize;
    for c in &ordered {
        anyhow::ensure!(
            c.spec.plan_hash == resolved.plan_hash,
            "fleet merge: shard {} belongs to plan {:016x}, not {:016x}",
            c.spec.shard,
            c.spec.plan_hash,
            resolved.plan_hash
        );
        anyhow::ensure!(
            c.complete(),
            "fleet merge: shard {} incomplete ({} of {} trials folded) — resume it first",
            c.spec.shard,
            c.next_trial - c.spec.lo,
            c.spec.len()
        );
        anyhow::ensure!(
            c.spec.lo == cursor,
            "fleet merge: trial coverage gap or overlap at {} (shard {} starts at {})",
            cursor,
            c.spec.shard,
            c.spec.lo
        );
        cursor = c.spec.hi;
    }
    anyhow::ensure!(
        cursor == resolved.trials.len(),
        "fleet merge: shards cover {} of {} trials",
        cursor,
        resolved.trials.len()
    );
    let mut merged = SweepSummary::new();
    for c in &ordered {
        merged.merge(&c.summary);
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_tile_exactly() {
        for (n, k) in [(10, 3), (7, 7), (5, 1), (100, 16), (3, 3)] {
            let r = shard_ranges(n, k);
            assert_eq!(r.len(), k);
            assert_eq!(r[0].0, 0);
            assert_eq!(r[k - 1].1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            let sizes: Vec<usize> = r.iter().map(|(lo, hi)| hi - lo).collect();
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "near-equal split: {sizes:?}");
        }
    }

    fn tiny_fleet() -> FleetPlan {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = "rr,minmin".into();
        cfg.env.distances_m = vec![40.0, 60.0];
        cfg.replicates = 2;
        FleetPlan::from_config(&cfg, 3).unwrap()
    }

    #[test]
    fn resolve_is_deterministic_and_sharded() {
        let plan = tiny_fleet();
        let a = plan.resolve().unwrap();
        let b = plan.resolve().unwrap();
        assert_eq!(a.plan_hash, b.plan_hash);
        assert_eq!(a.trials.len(), 2 * 2 * 2); // seeds × schedulers × distances
        assert_eq!(a.shards.len(), 3);
        assert_eq!(a.shards[0].lo, 0);
        assert_eq!(a.shards[2].hi, a.trials.len());
        // Any axis change changes the hash.
        let mut other = plan.clone();
        other.events = true;
        assert_ne!(other.resolve().unwrap().plan_hash, a.plan_hash);
    }

    #[test]
    fn plan_file_roundtrip_and_tamper_rejection() {
        let dir = std::env::temp_dir().join("hmai_fleet_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let plan = tiny_fleet();
        let resolved = plan.resolve().unwrap();
        plan.save(&path, &resolved).unwrap();
        let (back, re) = FleetPlan::load(&path).unwrap();
        assert_eq!(re.plan_hash, resolved.plan_hash);
        assert_eq!(back.schedulers, plan.schedulers);
        assert_eq!(back.seeds, plan.seeds);
        // Tampering with an axis without fixing the hash is rejected.
        let tampered = std::fs::read_to_string(&path).unwrap().replace("\"rr\"", "\"sa\"");
        std::fs::write(&path, tampered).unwrap();
        let err = FleetPlan::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("plan_hash"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fresh_checkpoint_and_range_validation() {
        let plan = tiny_fleet();
        let resolved = plan.resolve().unwrap();
        let c = ShardCheckpoint::fresh(resolved.shards[1]);
        assert!(!c.complete());
        assert_eq!(c.next_trial, resolved.shards[1].lo);
        // Merge refuses incomplete shards.
        let err = merge_checkpoints(&resolved, &[c]).unwrap_err();
        assert!(format!("{err:#}").contains("incomplete"), "{err:#}");
    }
}
