//! The shadow platform state: the deterministic timing/energy model of the
//! multi-accelerator platform that both the simulation engine and every
//! scheduler share.
//!
//! Schedulers (Min-Min, GA, SA, FlexAI, ...) need to predict exactly what
//! the engine will do with a candidate assignment; giving them the same
//! `ShadowState::apply` the engine itself executes guarantees the
//! prediction is exact, not an approximation.

use crate::accel::{cost, AccelKind};
use crate::env::taskgen::Task;
use crate::metrics::{AccelMetrics, NormScales, PlatformMetrics};
use crate::platform::Platform;
use crate::safety::ms::matching_score;

/// What happened when a task was applied to an accelerator.
#[derive(Debug, Clone, Copy)]
pub struct Applied {
    pub accel: usize,
    pub start_s: f64,
    pub finish_s: f64,
    /// Waiting time in the accelerator's queue.
    pub wait_s: f64,
    /// Pure execution time on the accelerator.
    pub compute_s: f64,
    /// wait + compute — what the MS responds to.
    pub response_s: f64,
    pub energy_j: f64,
    /// Matching score of this (task, response) pair (§6.1).
    pub ms: f64,
    /// Per-task balance rate `r_j` (§7.2): busy fraction at dispatch.
    pub r_j: f64,
    pub met_deadline: bool,
}

/// Deterministic platform state: per-accelerator FIFO backlog plus the §7.2
/// running metrics.  Cloning is cheap (a few `Vec<f64>` of length N), which
/// is what GA/SA rollouts and Min-Min need.
#[derive(Debug, Clone)]
pub struct ShadowState {
    pub kinds: Vec<AccelKind>,
    /// Simulation clock: release time of the task being scheduled.
    pub now: f64,
    /// Time at which each accelerator drains its queue.
    pub busy_until: Vec<f64>,
    pub metrics: PlatformMetrics,
}

impl ShadowState {
    pub fn new(platform: &Platform, scales: NormScales) -> ShadowState {
        let kinds: Vec<AccelKind> = platform.accels.iter().map(|a| a.kind).collect();
        let n = kinds.len();
        ShadowState {
            kinds,
            now: 0.0,
            busy_until: vec![0.0; n],
            metrics: PlatformMetrics::new(n, scales),
        }
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Queue delay a task dispatched now would see on accelerator `i`.
    pub fn queue_delay(&self, i: usize) -> f64 {
        (self.busy_until[i] - self.now).max(0.0)
    }

    /// Predicted response time (wait + compute) of `task` on accelerator `i`.
    pub fn est_response(&self, task: &Task, i: usize) -> f64 {
        self.queue_delay(i) + cost(self.kinds[i], task.model).time_s
    }

    /// Predicted completion-time point on the route clock.
    pub fn est_completion(&self, task: &Task, i: usize) -> f64 {
        self.now + self.est_response(task, i)
    }

    /// Energy `task` would consume on accelerator `i`.
    pub fn est_energy(&self, task: &Task, i: usize) -> f64 {
        cost(self.kinds[i], task.model).energy_j
    }

    /// Fraction of accelerators still busy at `t`.
    pub fn busy_fraction_at(&self, t: f64) -> f64 {
        if self.kinds.is_empty() {
            return 0.0;
        }
        let busy = self.busy_until.iter().filter(|&&b| b > t).count();
        busy as f64 / self.kinds.len() as f64
    }

    /// Advance the clock to a task release time (never backwards).
    pub fn advance(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    /// Execute `task` on accelerator `accel`: FIFO semantics, §7.2 metric
    /// updates, matching score.  This is the single source of truth for
    /// platform timing — the engine and all scheduler rollouts call it.
    pub fn apply(&mut self, task: &Task, accel: usize) -> Applied {
        debug_assert!(accel < self.kinds.len());
        let c = cost(self.kinds[accel], task.model);
        let start = self.busy_until[accel].max(self.now);
        let finish = start + c.time_s;
        self.busy_until[accel] = finish;

        let wait = start - self.now;
        let response = finish - self.now;
        let ms = matching_score(task.category, response, task.safety_time_s);
        // r_j: busy fraction right after dispatch — "the higher R_Balance,
        // the less idle accelerators in HMAI at every moment" (§6.2).
        let r_j = self.busy_fraction_at(self.now);
        self.metrics.per_accel[accel].update(c.energy_j, c.time_s, response, ms, r_j);

        Applied {
            accel,
            start_s: start,
            finish_s: finish,
            wait_s: wait,
            compute_s: c.time_s,
            response_s: response,
            energy_j: c.energy_j,
            ms,
            r_j,
            met_deadline: response <= task.safety_time_s,
        }
    }

    /// Gvalue + total MS — the pair whose per-task delta is the RL reward
    /// (§7.2: reward = Gvalue_new - Gvalue + MS_new - MS).
    pub fn gvalue_ms(&self) -> (f64, f64) {
        (self.metrics.gvalue(), self.metrics.ms_total())
    }

    /// Per-accelerator §7.2 snapshot, used by featurization.
    pub fn accel_metrics(&self, i: usize) -> &AccelMetrics {
        &self.metrics.per_accel[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{CameraGroup, Scenario};
    use crate::safety::ms::TaskCategory;
    use crate::workload::ModelKind;

    fn task(model: ModelKind, release: f64, safety: f64) -> Task {
        Task {
            id: 0,
            group: CameraGroup::Fc,
            cam_idx: 0,
            release_s: release,
            model,
            category: TaskCategory::Detection,
            scenario: Scenario::GoStraight,
            safety_time_s: safety,
        }
    }

    fn shadow() -> ShadowState {
        ShadowState::new(&Platform::hmai(), NormScales::unit())
    }

    #[test]
    fn fifo_backlog_accumulates() {
        let mut s = shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let a1 = s.apply(&t, 0);
        let a2 = s.apply(&t, 0);
        assert_eq!(a1.wait_s, 0.0);
        assert!((a2.wait_s - a1.compute_s).abs() < 1e-12);
        assert!((a2.finish_s - 2.0 * a1.compute_s).abs() < 1e-12);
        // A different accelerator is still free.
        assert_eq!(s.queue_delay(1), 0.0);
    }

    #[test]
    fn clock_advance_drains_queues() {
        let mut s = shadow();
        let t = task(ModelKind::Ssd, 0.0, 1.0);
        let a = s.apply(&t, 3);
        s.advance(a.finish_s + 1.0);
        assert_eq!(s.queue_delay(3), 0.0);
        assert_eq!(s.busy_fraction_at(s.now), 0.0);
    }

    #[test]
    fn est_response_matches_apply() {
        let mut s = shadow();
        let t1 = task(ModelKind::Yolo, 0.0, 1.0);
        let t2 = task(ModelKind::Goturn, 0.0, 1.0);
        s.apply(&t1, 5);
        let est = s.est_response(&t2, 5);
        let a = s.apply(&t2, 5);
        assert!((est - a.response_s).abs() < 1e-12);
    }

    #[test]
    fn deadline_and_ms_sign() {
        let mut s = shadow();
        // Generous deadline -> met, MS > 0 for detection.
        let a = s.apply(&task(ModelKind::Yolo, 0.0, 10.0), 0);
        assert!(a.met_deadline);
        assert!(a.ms > 0.0);
        // Impossible deadline -> missed, MS == -1.
        let b = s.apply(&task(ModelKind::Yolo, 0.0, 1e-9), 1);
        assert!(!b.met_deadline);
        assert_eq!(b.ms, -1.0);
    }

    #[test]
    fn r_j_tracks_busy_fraction() {
        let mut s = shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let a1 = s.apply(&t, 0);
        // After dispatching to accel 0, 1 of 11 is busy.
        assert!((a1.r_j - 1.0 / 11.0).abs() < 1e-12);
        let a2 = s.apply(&t, 1);
        assert!((a2.r_j - 2.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn rollout_clone_is_independent(){
        let mut s = shadow();
        let t = task(ModelKind::Ssd, 0.0, 1.0);
        let mut rollout = s.clone();
        rollout.apply(&t, 0);
        assert_eq!(s.queue_delay(0), 0.0);
        s.apply(&t, 1);
        assert_eq!(rollout.queue_delay(1), 0.0);
    }
}
