//! The shadow platform state: the deterministic timing/energy model of the
//! multi-accelerator platform that both the simulation engine and every
//! scheduler share.
//!
//! Schedulers (Min-Min, GA, SA, FlexAI, ...) need to predict exactly what
//! the engine will do with a candidate assignment; giving them the same
//! `ShadowState::apply` the engine itself executes guarantees the
//! prediction is exact, not an approximation.

use std::sync::Arc;

use crate::accel::{AccelKind, CoreSize, CostModel, TaskCost};
use crate::env::taskgen::Task;
use crate::interconnect::{CommPlan, CommState};
use crate::metrics::{AccelMetrics, NormScales, PlatformMetrics};
use crate::platform::Platform;
use crate::safety::ms::matching_score;
use crate::workload::ModelKind;

/// What happened when a task was applied to an accelerator.
#[derive(Debug, Clone, Copy)]
pub struct Applied {
    pub accel: usize,
    pub start_s: f64,
    pub finish_s: f64,
    /// Waiting time in the accelerator's queue.
    pub wait_s: f64,
    /// Pure execution time on the accelerator.
    pub compute_s: f64,
    /// wait + compute — what the MS responds to.
    pub response_s: f64,
    pub energy_j: f64,
    /// Matching score of this (task, response) pair (§6.1).
    pub ms: f64,
    /// Per-task balance rate `r_j` (§7.2): busy fraction at dispatch.
    pub r_j: f64,
    pub met_deadline: bool,
}

/// Deterministic platform state: per-accelerator FIFO backlog plus the §7.2
/// running metrics.  Cloning is cheap (a few `Vec<f64>` of length N), which
/// is what GA/SA rollouts and Min-Min need.
///
/// `speed` is the runtime capacity model behind
/// [`sim::events`](crate::sim::events): 1.0 is nominal, a value in (0, 1)
/// is a frequency-derated accelerator (compute time divides by it), and
/// 0.0 is a failed accelerator — `est_response`/`est_completion` go to
/// `+inf` there, so state-aware schedulers route around it, and the
/// state-blind baselines consult `is_up`/`up_iter` explicitly.
#[derive(Debug, Clone)]
pub struct ShadowState {
    pub kinds: Vec<AccelKind>,
    /// Per-slot core size (drives the per-slot cost rows and the FlexAI
    /// capacity feature).
    pub sizes: Vec<CoreSize>,
    /// Per-slot (model → cost) rows — the instance-parameterized cost
    /// model that replaced the global Std-only `accel::cost` free function
    /// on the hot paths.  Behind an `Arc` so rollout clones (GA/SA) stay
    /// as cheap as before the parameterization.
    costs: Arc<CostModel>,
    /// Simulation clock: release time of the task being scheduled.
    pub now: f64,
    /// Time at which each accelerator drains its queue.  Read-only outside
    /// this module: mutate only through [`ShadowState::apply`] /
    /// [`ShadowState::advance`], which keep the cached `busy_now` count in
    /// sync.
    pub busy_until: Vec<f64>,
    /// Per-accelerator speed factor: 1.0 nominal, (0, 1) derated, 0.0 down.
    pub speed: Vec<f64>,
    pub metrics: PlatformMetrics,
    /// Cached `|{i : busy_until[i] > now}|` — the §7.2 `r_j` numerator.
    /// Maintained incrementally (O(1) per `apply`, one O(N) recount per
    /// clock `advance`) so the per-task dispatch path stops re-scanning
    /// the whole platform; `BENCH_PERF.json` carries the scan-vs-cached
    /// micro numbers that motivated it.
    busy_now: usize,
    /// Interconnect occupancy + residency, present iff the platform spec
    /// carried a chiplet topology ([`Platform::pricing`]).  `None` is the
    /// monolithic platform — every timing expression below is then
    /// textually the pre-interconnect one, which is what pins monolithic
    /// sweeps bit-identical to the compute-only model.
    pub comm: Option<CommState>,
}

impl ShadowState {
    pub fn new(platform: &Platform, scales: NormScales) -> ShadowState {
        let kinds: Vec<AccelKind> = platform.accels.iter().map(|a| a.kind).collect();
        let sizes: Vec<CoreSize> = platform.accels.iter().map(|a| a.size).collect();
        let n = kinds.len();
        let pricing = platform.pricing();
        let comm = pricing.topology().map(|t| CommState::new(Arc::clone(t), n));
        ShadowState {
            kinds,
            sizes,
            costs: Arc::clone(pricing.compute()),
            now: 0.0,
            busy_until: vec![0.0; n],
            speed: vec![1.0; n],
            metrics: PlatformMetrics::new(n, scales),
            busy_now: 0,
            comm,
        }
    }

    /// Cost of `model` on slot `i` — one indexed load off this platform's
    /// own (kind, size) rows.
    #[inline]
    pub fn cost(&self, i: usize, model: ModelKind) -> TaskCost {
        self.costs.of(i, model)
    }

    /// Is accelerator `i` accepting work (not failed)?
    pub fn is_up(&self, i: usize) -> bool {
        self.speed[i] > 0.0
    }

    /// Indices of accelerators currently accepting work, without
    /// allocating (ascending order).  Schedulers iterate this on the
    /// per-burst path; collect it when a materialized list is needed.
    pub fn up_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.speed.iter().enumerate().filter(|(_, &s)| s > 0.0).map(|(i, _)| i)
    }

    /// Number of accelerators currently accepting work.
    pub fn up_count(&self) -> usize {
        self.speed.iter().filter(|&&s| s > 0.0).count()
    }

    /// Set accelerator `i`'s speed factor (0.0 = failed, 1.0 = nominal).
    /// Out-of-range indices are ignored so scenario events written for a
    /// large platform degrade gracefully on a smaller one.
    pub fn set_speed(&mut self, i: usize, speed: f64) {
        if let Some(s) = self.speed.get_mut(i) {
            *s = speed.clamp(0.0, 1.0);
        }
    }

    /// Set interconnect link `link`'s speed factor (0.0 = dead, 1.0 =
    /// nominal bandwidth).  A no-op on monolithic platforms (no
    /// `CommState`) and for out-of-range indices, so link events written
    /// for a chiplet platform degrade gracefully everywhere else.
    pub fn set_link_speed(&mut self, link: usize, speed: f64) {
        if let Some(comm) = &mut self.comm {
            comm.set_link_speed(link, speed);
        }
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Queue delay a task dispatched now would see on accelerator `i`.
    pub fn queue_delay(&self, i: usize) -> f64 {
        (self.busy_until[i] - self.now).max(0.0)
    }

    /// Predicted response time (wait + compute) of `task` on accelerator
    /// `i`.  A derated accelerator stretches compute time by 1/speed; a
    /// failed one predicts `+inf`, which is what steers min-seeking
    /// schedulers away from it.  (Division by a speed of exactly 1.0 is
    /// bit-exact in IEEE 754, so the nominal path is unchanged.)
    ///
    /// On a chiplet platform the prediction walks the slot's route
    /// ([`CommState::plan`]) so response = input/weight transfers + queue +
    /// compute + output return; ingress-chiplet slots have an empty route
    /// and take the monolithic expression unchanged.
    pub fn est_response(&self, task: &Task, i: usize) -> f64 {
        let compute = self.costs.of(i, task.model).time_s / self.speed[i];
        if let Some(comm) = &self.comm {
            if compute.is_finite() {
                if let Some(p) = comm.plan(i, task.model, self.now, self.busy_until[i], compute)
                {
                    return p.done_s - self.now;
                }
            }
        }
        self.queue_delay(i) + compute
    }

    /// Predicted completion-time point on the route clock.
    pub fn est_completion(&self, task: &Task, i: usize) -> f64 {
        self.now + self.est_response(task, i)
    }

    /// Energy `task` would consume on accelerator `i`.
    pub fn est_energy(&self, task: &Task, i: usize) -> f64 {
        self.costs.of(i, task.model).energy_j
    }

    /// Predicted interconnect time of `task` on slot `i` — the inbound
    /// transfer delay plus the output return leg, after link contention.
    /// 0.0 on monolithic platforms, ingress-chiplet slots and failed
    /// accelerators.  The FlexAI locality feature reads this.
    pub fn est_comm_s(&self, task: &Task, i: usize) -> f64 {
        if let Some(comm) = &self.comm {
            let compute = self.costs.of(i, task.model).time_s / self.speed[i];
            if compute.is_finite() {
                if let Some(p) = comm.plan(i, task.model, self.now, self.busy_until[i], compute)
                {
                    return p.comm_s;
                }
            }
        }
        0.0
    }

    /// Fraction of accelerators still busy at `t` — the O(N) scan form
    /// for arbitrary probe times.  The dispatch hot path (`apply`'s `r_j`)
    /// reads the incrementally maintained [`ShadowState::busy_count`]
    /// instead.
    pub fn busy_fraction_at(&self, t: f64) -> f64 {
        if self.kinds.is_empty() {
            return 0.0;
        }
        let busy = self.busy_until.iter().filter(|&&b| b > t).count();
        busy as f64 / self.kinds.len() as f64
    }

    /// Number of accelerators still busy at the current clock (cached;
    /// equals `busy_until.iter().filter(|b| **b > now).count()` at all
    /// times).
    pub fn busy_count(&self) -> usize {
        self.busy_now
    }

    /// Advance the clock to a task release time (never backwards).  This
    /// is the once-per-burst point where the cached busy count is recounted
    /// (queues drain as the clock moves); `apply` then maintains it in
    /// O(1) per dispatched task.
    pub fn advance(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
            self.busy_now = self.busy_until.iter().filter(|&&b| b > t).count();
        }
    }

    /// Execute `task` on accelerator `accel`: FIFO semantics, §7.2 metric
    /// updates, matching score.  This is the single source of truth for
    /// platform timing — the engine and all scheduler rollouts call it.
    pub fn apply(&mut self, task: &Task, accel: usize) -> Applied {
        debug_assert!(accel < self.kinds.len());
        debug_assert_eq!(
            self.busy_now,
            self.busy_until.iter().filter(|&&b| b > self.now).count(),
            "cached busy count out of sync"
        );
        let c = self.costs.of(accel, task.model);
        let speed = self.speed[accel];
        if speed <= 0.0 {
            // A failed accelerator accepts no work: the task is *lost*
            // (infinite response, missed deadline, MS = -1) but the dead
            // slot's FIFO and energy/busy/response accumulators are not
            // poisoned — service resumes cleanly when a Recover event
            // fires.  The loss still *counts*: `num_tasks` (the STMRate
            // denominator) and `ms_sum` (-1) record the missed dispatch,
            // while the zero energy/busy/response contributions keep the
            // §7.2 sums describing executed work only — semantics pinned
            // by `lost_task_accounting_is_pinned` below.  Schedulers only
            // reach this on an all-down platform (their fallback paths);
            // rollouts probing a dead slot see the infinite response and
            // price the genome accordingly.
            let ms = matching_score(task.category, f64::INFINITY, task.safety_time_s);
            let r_j = self.busy_now as f64 / self.kinds.len() as f64;
            self.metrics.per_accel[accel].update(0.0, 0.0, 0.0, ms, r_j);
            return Applied {
                accel,
                start_s: self.now,
                finish_s: f64::INFINITY,
                wait_s: 0.0,
                compute_s: f64::INFINITY,
                response_s: f64::INFINITY,
                energy_j: 0.0,
                ms,
                r_j,
                met_deadline: false,
            };
        }
        // Speed-scaled execution: 1.0 nominal (bit-exact), (0,1) derated.
        // Energy is the task's work, not its duration, so it is not scaled.
        let compute = c.time_s / speed;
        // Chiplet path: price the route's transfers and reserve its links.
        // The plan's timeline (arrive → start → finish → done) replaces the
        // local-FIFO one below; an empty route (ingress-chiplet slot, or a
        // monolithic platform) falls through to the unchanged expressions.
        let mut planned: Option<CommPlan> = None;
        if let Some(comm) = &mut self.comm {
            planned = comm.plan(accel, task.model, self.now, self.busy_until[accel], compute);
            if let Some(p) = planned {
                if !p.done_s.is_finite() {
                    // A severed route (dead link, no surviving path to the
                    // slot's chiplet): the task is lost exactly like a
                    // dispatch to a failed accelerator — and crucially the
                    // plan is *not* committed, so neither the slot's FIFO
                    // nor the link occupancy is poisoned past the link's
                    // recovery.
                    let ms =
                        matching_score(task.category, f64::INFINITY, task.safety_time_s);
                    let r_j = self.busy_now as f64 / self.kinds.len() as f64;
                    self.metrics.per_accel[accel].update(0.0, 0.0, 0.0, ms, r_j);
                    return Applied {
                        accel,
                        start_s: self.now,
                        finish_s: f64::INFINITY,
                        wait_s: 0.0,
                        compute_s: f64::INFINITY,
                        response_s: f64::INFINITY,
                        energy_j: 0.0,
                        ms,
                        r_j,
                        met_deadline: false,
                    };
                }
                comm.commit(accel, task.model, &p);
            }
        }
        if let Some(p) = planned {
            let was_busy = self.busy_until[accel] > self.now;
            self.busy_until[accel] = p.finish_s;
            if !was_busy && p.finish_s > self.now {
                self.busy_now += 1;
            }
            let wait = p.start_s - self.now;
            let response = p.done_s - self.now;
            let ms = matching_score(task.category, response, task.safety_time_s);
            let r_j = self.busy_now as f64 / self.kinds.len() as f64;
            self.metrics.per_accel[accel].update(c.energy_j, compute, response, ms, r_j);
            return Applied {
                accel,
                start_s: p.start_s,
                finish_s: p.finish_s,
                wait_s: wait,
                compute_s: compute,
                response_s: response,
                energy_j: c.energy_j,
                ms,
                r_j,
                met_deadline: response <= task.safety_time_s,
            };
        }
        let was_busy = self.busy_until[accel] > self.now;
        let start = self.busy_until[accel].max(self.now);
        let finish = start + compute;
        self.busy_until[accel] = finish;
        if !was_busy && finish > self.now {
            self.busy_now += 1;
        }

        let wait = start - self.now;
        let response = finish - self.now;
        let ms = matching_score(task.category, response, task.safety_time_s);
        // r_j: busy fraction right after dispatch — "the higher R_Balance,
        // the less idle accelerators in HMAI at every moment" (§6.2).
        let r_j = self.busy_now as f64 / self.kinds.len() as f64;
        self.metrics.per_accel[accel].update(c.energy_j, compute, response, ms, r_j);

        Applied {
            accel,
            start_s: start,
            finish_s: finish,
            wait_s: wait,
            compute_s: compute,
            response_s: response,
            energy_j: c.energy_j,
            ms,
            r_j,
            met_deadline: response <= task.safety_time_s,
        }
    }

    /// Gvalue + total MS — the pair whose per-task delta is the RL reward
    /// (§7.2: reward = Gvalue_new - Gvalue + MS_new - MS).
    pub fn gvalue_ms(&self) -> (f64, f64) {
        (self.metrics.gvalue(), self.metrics.ms_total())
    }

    /// Per-accelerator §7.2 snapshot, used by featurization.
    pub fn accel_metrics(&self, i: usize) -> &AccelMetrics {
        &self.metrics.per_accel[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{CameraGroup, Scenario};
    use crate::safety::ms::TaskCategory;
    use crate::workload::ModelKind;

    fn task(model: ModelKind, release: f64, safety: f64) -> Task {
        Task {
            id: 0,
            group: CameraGroup::Fc,
            cam_idx: 0,
            release_s: release,
            model,
            category: TaskCategory::Detection,
            scenario: Scenario::GoStraight,
            safety_time_s: safety,
        }
    }

    fn shadow() -> ShadowState {
        ShadowState::new(&Platform::hmai(), NormScales::unit())
    }

    #[test]
    fn fifo_backlog_accumulates() {
        let mut s = shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let a1 = s.apply(&t, 0);
        let a2 = s.apply(&t, 0);
        assert_eq!(a1.wait_s, 0.0);
        assert!((a2.wait_s - a1.compute_s).abs() < 1e-12);
        assert!((a2.finish_s - 2.0 * a1.compute_s).abs() < 1e-12);
        // A different accelerator is still free.
        assert_eq!(s.queue_delay(1), 0.0);
    }

    #[test]
    fn clock_advance_drains_queues() {
        let mut s = shadow();
        let t = task(ModelKind::Ssd, 0.0, 1.0);
        let a = s.apply(&t, 3);
        s.advance(a.finish_s + 1.0);
        assert_eq!(s.queue_delay(3), 0.0);
        assert_eq!(s.busy_fraction_at(s.now), 0.0);
    }

    #[test]
    fn est_response_matches_apply() {
        let mut s = shadow();
        let t1 = task(ModelKind::Yolo, 0.0, 1.0);
        let t2 = task(ModelKind::Goturn, 0.0, 1.0);
        s.apply(&t1, 5);
        let est = s.est_response(&t2, 5);
        let a = s.apply(&t2, 5);
        assert!((est - a.response_s).abs() < 1e-12);
    }

    #[test]
    fn deadline_and_ms_sign() {
        let mut s = shadow();
        // Generous deadline -> met, MS > 0 for detection.
        let a = s.apply(&task(ModelKind::Yolo, 0.0, 10.0), 0);
        assert!(a.met_deadline);
        assert!(a.ms > 0.0);
        // Impossible deadline -> missed, MS == -1.
        let b = s.apply(&task(ModelKind::Yolo, 0.0, 1e-9), 1);
        assert!(!b.met_deadline);
        assert_eq!(b.ms, -1.0);
    }

    #[test]
    fn r_j_tracks_busy_fraction() {
        let mut s = shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let a1 = s.apply(&t, 0);
        // After dispatching to accel 0, 1 of 11 is busy.
        assert!((a1.r_j - 1.0 / 11.0).abs() < 1e-12);
        let a2 = s.apply(&t, 1);
        assert!((a2.r_j - 2.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn nominal_speed_is_bit_exact() {
        // speed = 1.0 must not perturb a single bit of the timing model.
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let mut a = shadow();
        let mut b = shadow();
        b.set_speed(2, 1.0); // explicit no-op write
        let ra = a.apply(&t, 2);
        let rb = b.apply(&t, 2);
        assert_eq!(ra.finish_s.to_bits(), rb.finish_s.to_bits());
        assert_eq!(ra.compute_s.to_bits(), rb.compute_s.to_bits());
        assert_eq!(a.est_response(&t, 2).to_bits(), b.est_response(&t, 2).to_bits());
    }

    #[test]
    fn derated_accel_stretches_compute() {
        let t = task(ModelKind::Yolo, 0.0, 10.0);
        let mut s = shadow();
        let nominal = s.clone().apply(&t, 0).compute_s;
        s.set_speed(0, 0.5);
        let a = s.apply(&t, 0);
        assert!((a.compute_s - 2.0 * nominal).abs() < 1e-12);
        assert!(s.is_up(0), "derated is still up");
    }

    #[test]
    fn failed_accel_predicts_infinite_response() {
        let t = task(ModelKind::Ssd, 0.0, 1.0);
        let mut s = shadow();
        s.set_speed(3, 0.0);
        assert!(!s.is_up(3));
        assert!(s.est_response(&t, 3).is_infinite());
        assert!(s.est_completion(&t, 3).is_infinite());
        let ups: Vec<usize> = s.up_iter().collect();
        assert_eq!(ups.len(), s.len() - 1);
        assert!(!ups.contains(&3));
        // Applying anyway (a fallback on an all-down platform, or a
        // rollout probing the dead slot) loses the task: missed deadline,
        // MS = -1, no energy — and the dead FIFO stays untouched, so the
        // outage cannot poison the accelerator past its recovery.
        let a = s.apply(&t, 3);
        assert!(!a.met_deadline);
        assert_eq!(a.ms, -1.0);
        assert!(a.response_s.is_infinite());
        assert_eq!(a.energy_j, 0.0);
        assert_eq!(s.busy_until[3], 0.0, "dead FIFO must stay clean");
        // Recovery restores service: new work completes finitely.
        s.set_speed(3, 1.0);
        assert!(s.is_up(3));
        assert!(s.est_response(&t, 3).is_finite());
        let b = s.apply(&t, 3);
        assert!(b.response_s.is_finite());
        assert!(s.metrics.per_accel[3].busy_s.is_finite());
        // Out-of-range event indices are ignored.
        s.set_speed(999, 0.0);
        assert_eq!(s.up_count(), s.len());
    }

    #[test]
    fn lost_task_accounting_is_pinned() {
        // A dispatch to a failed accelerator is a LOST task.  Intended
        // per-accel semantics (verified, not skewed): it counts in
        // `num_tasks` (the STMRate denominator) and `ms_sum` (-1), folds
        // its dispatch-time r_j into the balance recurrence, and adds
        // exactly zero to the energy/busy/response sums — so E_i, T_i and
        // the busy-time makespan describe executed work only, and an
        // outage can neither deflate nor inflate them.
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let mut s = shadow();
        s.apply(&t, 1); // one live task elsewhere → busy fraction 1/11
        s.set_speed(0, 0.0);
        let before = s.metrics.per_accel[0];
        let a = s.apply(&t, 0);
        let m = s.metrics.per_accel[0];
        assert_eq!(m.num_tasks, before.num_tasks + 1, "lost task must count");
        assert_eq!(m.ms_sum, before.ms_sum - 1.0, "lost task scores MS = -1");
        assert_eq!(m.energy_j.to_bits(), before.energy_j.to_bits());
        assert_eq!(m.busy_s.to_bits(), before.busy_s.to_bits());
        assert_eq!(m.resp_s.to_bits(), before.resp_s.to_bits());
        assert!((a.r_j - 1.0 / 11.0).abs() < 1e-12, "r_j observed at dispatch");
        assert!((m.r_balance - a.r_j).abs() < 1e-12, "first fold is r_j itself");
        // The platform aggregates stay finite and executed-work-only.
        assert!(s.metrics.energy_j().is_finite());
        assert!(s.metrics.resp_makespan_s().is_finite());
        assert_eq!(s.metrics.total_tasks(), 2, "lost task in the STM denominator");
    }

    #[test]
    fn busy_count_cache_matches_scan() {
        let q = {
            let route = crate::env::route::Route::generate(
                crate::env::route::RouteParams::for_area(crate::env::Area::Urban, 40.0),
                &mut crate::util::rng::Rng::new(5),
            );
            crate::env::taskgen::generate(&route)
        };
        let mut s = shadow();
        let scan = |s: &ShadowState| s.busy_until.iter().filter(|&&b| b > s.now).count();
        assert_eq!(s.busy_count(), 0);
        for (k, t) in q.tasks.iter().take(200).enumerate() {
            s.advance(t.release_s);
            assert_eq!(s.busy_count(), scan(&s), "after advance to {}", t.release_s);
            if k % 17 == 0 {
                s.set_speed(k % s.len(), if k % 34 == 0 { 0.0 } else { 1.0 });
            }
            let a = s.apply(t, k % s.len());
            assert_eq!(s.busy_count(), scan(&s), "after apply #{k}");
            if a.response_s.is_finite() {
                // r_j is the post-dispatch busy fraction, from the cache.
                assert_eq!(
                    a.r_j.to_bits(),
                    (scan(&s) as f64 / s.len() as f64).to_bits()
                );
            }
        }
    }

    #[test]
    fn per_slot_costs_follow_core_sizes() {
        // A Double core must predict (and apply) exactly the sized cost;
        // Std slots stay bit-identical to the global Std matrix.
        use crate::accel::{cost_sized, CoreSize};
        let p = Platform::parse("so:1@0.5x,si:1,mm:1@2x").unwrap();
        let mut s = ShadowState::new(&p, NormScales::unit());
        assert_eq!(s.sizes, vec![CoreSize::Half, CoreSize::Std, CoreSize::Double]);
        let t = task(ModelKind::Yolo, 0.0, 10.0);
        let std_cost = crate::accel::cost(AccelKind::SconvIC, ModelKind::Yolo);
        assert_eq!(s.cost(1, ModelKind::Yolo).time_s.to_bits(), std_cost.time_s.to_bits());
        assert_eq!(s.est_response(&t, 1).to_bits(), std_cost.time_s.to_bits());
        let half = cost_sized(AccelKind::SconvOD, ModelKind::Yolo, CoreSize::Half);
        let a = s.apply(&t, 0);
        assert_eq!(a.compute_s.to_bits(), half.time_s.to_bits());
        assert_eq!(a.energy_j.to_bits(), half.energy_j.to_bits());
        // The half core is slower than its Std sibling would be.
        assert!(half.time_s > crate::accel::cost(AccelKind::SconvOD, ModelKind::Yolo).time_s);
    }

    #[test]
    fn up_iter_walks_up_slots_in_ascending_order() {
        let mut s = shadow();
        assert_eq!(s.up_iter().collect::<Vec<_>>(), (0..s.len()).collect::<Vec<_>>());
        assert_eq!(s.up_count(), s.len());
        s.set_speed(2, 0.0);
        s.set_speed(7, 0.0);
        let ups: Vec<usize> = s.up_iter().collect();
        let want: Vec<usize> = (0..s.len()).filter(|&i| i != 2 && i != 7).collect();
        assert_eq!(ups, want);
        assert_eq!(s.up_count(), s.len() - 2);
    }

    fn noc_shadow() -> ShadowState {
        ShadowState::new(&Platform::parse("hmai+mesh2x2").unwrap(), NormScales::unit())
    }

    #[test]
    fn comm_est_matches_apply_bit_for_bit() {
        // The comm-aware prediction must be as exact as the monolithic one:
        // est_response and apply walk the identical plan.
        let mut s = noc_shadow();
        let models = [ModelKind::Yolo, ModelKind::Ssd, ModelKind::Goturn];
        for k in 0..24 {
            let t = task(models[k % 3], k as f64 * 0.002, 1.0);
            s.advance(t.release_s);
            let i = (k * 5) % s.len();
            let est = s.est_response(&t, i);
            let a = s.apply(&t, i);
            assert_eq!(est.to_bits(), a.response_s.to_bits(), "task {k} slot {i}");
        }
        let comm = s.comm.as_ref().unwrap();
        assert!(comm.delay_s > 0.0 && comm.bytes > 0.0);
    }

    #[test]
    fn ingress_slots_stay_compute_only() {
        // hmai+mesh2x2, round-robin placement: slots 0/4/8 live on the
        // ingress chiplet — empty route, so their timing is bit-identical
        // to the monolithic platform; off-chiplet slots pay transfers.
        let mut mono = shadow();
        let mut noc = noc_shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        assert_eq!(mono.est_response(&t, 0).to_bits(), noc.est_response(&t, 0).to_bits());
        let (a, b) = (mono.apply(&t, 0), noc.apply(&t, 0));
        assert_eq!(a.response_s.to_bits(), b.response_s.to_bits());
        assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
        assert!(noc.est_response(&t, 1) > mono.est_response(&t, 1));
        assert!(noc.est_comm_s(&t, 1) > 0.0);
        assert_eq!(noc.est_comm_s(&t, 4), 0.0, "ingress slot moves nothing");
        assert_eq!(mono.est_comm_s(&t, 1), 0.0, "monolithic moves nothing");
    }

    #[test]
    fn weight_residency_drops_repeat_cost() {
        let mut s = noc_shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let first = s.est_response(&t, 1);
        let a = s.apply(&t, 1);
        s.advance(a.finish_s + 1.0);
        // Same model, warm slot: weights stay resident, only activations move.
        let second = s.est_response(&t, 1);
        assert!(second < first, "{second} !< {first}");
        // A different model evicts the weights; the repeat pays in full again.
        let g = task(ModelKind::Goturn, s.now, 1.0);
        let b = s.apply(&g, 1);
        s.advance(b.finish_s + 1.0);
        let third = s.est_response(&t, 1);
        assert!(third > second, "{third} !> {second}");
    }

    #[test]
    fn severed_route_loses_tasks_without_poisoning() {
        // Two slots over a ring2: slot 1 lives across the package's only
        // link.  Severing it makes slot 1 unreachable — dispatches there
        // are lost tasks, and neither its FIFO nor the link occupancy is
        // poisoned past the link's recovery.
        let p = Platform::parse("so:1,si:1+ring2").unwrap();
        let mut s = ShadowState::new(&p, NormScales::unit());
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        s.set_link_speed(0, 0.0);
        assert!(s.est_response(&t, 1).is_infinite());
        let a = s.apply(&t, 1);
        assert!(!a.met_deadline);
        assert_eq!(a.ms, -1.0);
        assert!(a.response_s.is_infinite());
        assert_eq!(a.energy_j, 0.0);
        assert_eq!(s.busy_until[1], 0.0, "severed slot's FIFO must stay clean");
        let comm = s.comm.as_ref().unwrap();
        assert_eq!(comm.delay_s, 0.0, "no commit on a severed route");
        assert!(comm.link_busy.iter().all(|&b| b == 0.0));
        // Recovery restores service: new work completes finitely.
        s.set_link_speed(0, 1.0);
        assert!(s.est_response(&t, 1).is_finite());
        let b = s.apply(&t, 1);
        assert!(b.response_s.is_finite());
    }

    #[test]
    fn link_failure_reroutes_on_mesh() {
        let mut s = noc_shadow();
        let t = task(ModelKind::Yolo, 0.0, 1.0);
        let nominal = s.est_response(&t, 1);
        let li = s.comm.as_ref().unwrap().topology().route(1)[0];
        s.set_link_speed(li, 0.0);
        // The 2x2 mesh survives one dead link: slot 1 takes the 3-hop
        // detour — finite, slower, and est still matches apply bit-exact.
        let detour = s.est_response(&t, 1);
        assert!(detour.is_finite());
        assert!(detour > nominal);
        let a = s.apply(&t, 1);
        assert_eq!(a.response_s.to_bits(), detour.to_bits());
        // Monolithic platforms ignore link events entirely.
        let mut mono = shadow();
        mono.set_link_speed(0, 0.0);
        assert!(mono.comm.is_none());
        assert!(mono.est_response(&t, 1).is_finite());
    }

    #[test]
    fn comm_clone_is_independent() {
        let s = noc_shadow();
        let t = task(ModelKind::Ssd, 0.0, 1.0);
        let mut r = s.clone();
        r.apply(&t, 3);
        assert!(r.comm.as_ref().unwrap().bytes > 0.0);
        let orig = s.comm.as_ref().unwrap();
        assert_eq!(orig.bytes, 0.0);
        assert!(orig.link_busy.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn rollout_clone_is_independent(){
        let mut s = shadow();
        let t = task(ModelKind::Ssd, 0.0, 1.0);
        let mut rollout = s.clone();
        rollout.apply(&t, 0);
        assert_eq!(s.queue_delay(0), 0.0);
        s.apply(&t, 1);
        assert_eq!(rollout.queue_delay(1), 0.0);
    }
}
