//! Discrete-event simulation of a task queue on a multi-accelerator
//! platform: tasks arrive on their camera frame clocks, the scheduler maps
//! each burst to accelerators, and per-accelerator FIFO queues determine
//! waiting, response times and the §6/§7.2 metrics.

pub mod shadow;

use std::time::Instant;

use crate::env::taskgen::TaskQueue;
use crate::metrics::summary::RunSummary;
use crate::metrics::NormScales;
use crate::platform::Platform;
use crate::sched::Scheduler;
use crate::workload::ModelKind;

pub use shadow::{Applied, ShadowState};

/// Release times within this window belong to the same burst (all cameras
/// that fire "simultaneously", §7: "when 30 cameras in a vehicle work once,
/// 30 frames will be generated simultaneously").
pub const BURST_EPS_S: f64 = 1e-9;

/// Per-task outcome record (kept only when `SimOptions::record_tasks`).
#[derive(Debug, Clone, Copy)]
pub struct TaskRecord {
    pub task_id: u32,
    pub model: ModelKind,
    pub accel: usize,
    pub release_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    pub wait_s: f64,
    pub compute_s: f64,
    pub response_s: f64,
    pub energy_j: f64,
    pub ms: f64,
    pub safety_time_s: f64,
    pub met_deadline: bool,
}

/// Simulation options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Keep a per-task record vector (needed for Fig. 14's braking probe).
    pub record_tasks: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { record_tasks: false }
    }
}

/// Full simulation result.
#[derive(Debug)]
pub struct SimResult {
    pub summary: RunSummary,
    /// Final platform state (metrics + backlog) at queue end.
    pub final_state: ShadowState,
    /// Per-task records if requested.
    pub records: Vec<TaskRecord>,
    /// Wall-clock seconds spent inside the scheduler.
    pub sched_wall_s: f64,
    /// Number of scheduling invocations (bursts).
    pub bursts: u64,
}

impl SimResult {
    /// Mean scheduler wall time per task (the Fig. 14 `T_schedule`).
    pub fn sched_per_task_s(&self) -> f64 {
        if self.summary.tasks == 0 {
            0.0
        } else {
            self.sched_wall_s / self.summary.tasks as f64
        }
    }
}

/// First detection (non-tracker) task released at or after `t_probe` —
/// the Fig. 14 braking-probe selection, shared by the CLI, the braking
/// bench and the drive_route example.
///
/// `records` is sorted by release time (the simulator emits records in
/// release order), so the probe binary-searches the release boundary
/// (`partition_point`) and takes the first detection record after it —
/// O(log n + gap) per probe instead of the old full `filter().min_by()`
/// pass.  Behavior matches the old scan exactly, including ties: releases
/// are sorted, so the first non-tracker at or past the boundary has the
/// minimal release, and `Iterator::min_by` returns the *first* of equal
/// minima — also the first in iteration order.
pub fn first_detection_after(records: &[TaskRecord], t_probe: f64) -> Option<&TaskRecord> {
    let start = records.partition_point(|r| r.release_s < t_probe);
    records[start..].iter().find(|r| !r.model.is_tracker())
}

/// Run `queue` on `platform` under `scheduler`.
///
/// Tasks are processed in release order, grouped into bursts of identical
/// release time; the scheduler sees the exact `ShadowState` the engine
/// executes on, so scheduler-side predictions are exact.
pub fn simulate(
    queue: &TaskQueue,
    platform: &Platform,
    scheduler: &mut dyn Scheduler,
    opts: SimOptions,
) -> SimResult {
    let scales = NormScales::for_queue(queue, platform);
    simulate_with_scales(queue, platform, scheduler, opts, scales)
}

/// `simulate` with externally-fixed normalization scales (so a trained
/// agent can be evaluated with the scales it was trained under).
pub fn simulate_with_scales(
    queue: &TaskQueue,
    platform: &Platform,
    scheduler: &mut dyn Scheduler,
    opts: SimOptions,
    scales: NormScales,
) -> SimResult {
    let mut state = ShadowState::new(platform, scales);
    let mut records = Vec::new();
    if opts.record_tasks {
        records.reserve(queue.len());
    }

    let mut wait_s = 0.0;
    let mut met: u64 = 0;
    let mut response_sum = 0.0;
    let mut response_max = 0.0_f64;
    let mut sched_wall = 0.0;
    let mut bursts: u64 = 0;

    let tasks = &queue.tasks;
    let mut i = 0;
    while i < tasks.len() {
        // Collect the burst [i, j): all tasks released together.
        let t0 = tasks[i].release_s;
        let mut j = i + 1;
        while j < tasks.len() && tasks[j].release_s - t0 <= BURST_EPS_S {
            j += 1;
        }
        let burst = &tasks[i..j];
        state.advance(t0);

        let clk = Instant::now();
        let assignment = scheduler.schedule_batch(burst, &state);
        sched_wall += clk.elapsed().as_secs_f64();
        bursts += 1;
        debug_assert_eq!(assignment.len(), burst.len());

        for (task, &accel) in burst.iter().zip(&assignment) {
            let a = state.apply(task, accel);
            wait_s += a.wait_s;
            if a.met_deadline {
                met += 1;
            }
            response_sum += a.response_s;
            response_max = response_max.max(a.response_s);
            if opts.record_tasks {
                records.push(TaskRecord {
                    task_id: task.id,
                    model: task.model,
                    accel,
                    release_s: task.release_s,
                    start_s: a.start_s,
                    finish_s: a.finish_s,
                    wait_s: a.wait_s,
                    compute_s: a.compute_s,
                    response_s: a.response_s,
                    energy_j: a.energy_j,
                    ms: a.ms,
                    safety_time_s: task.safety_time_s,
                    met_deadline: a.met_deadline,
                });
            }
        }
        i = j;
    }

    let n = queue.len() as f64;
    let summary = RunSummary::from_metrics(
        &scheduler.name(),
        &platform.name,
        &state.metrics,
        met,
        wait_s,
        sched_wall,
        if n > 0.0 { response_sum / n } else { 0.0 },
        response_max,
    );
    SimResult { summary, final_state: state, records, sched_wall_s: sched_wall, bursts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::route::{Route, RouteParams};
    use crate::env::Area;
    use crate::sched::roundrobin::RoundRobin;
    use crate::util::rng::Rng;

    fn queue(dist: f64, seed: u64) -> TaskQueue {
        let route =
            Route::generate(RouteParams::for_area(Area::Urban, dist), &mut Rng::new(seed));
        crate::env::taskgen::generate(&route)
    }

    #[test]
    fn processes_every_task() {
        let q = queue(60.0, 1);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        assert_eq!(r.summary.tasks as usize, q.len());
        assert_eq!(r.records.len(), q.len());
        assert!(r.bursts > 0 && r.bursts <= r.summary.tasks);
    }

    #[test]
    fn records_are_causally_consistent() {
        let q = queue(40.0, 2);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        for rec in &r.records {
            assert!(rec.start_s >= rec.release_s - 1e-12);
            assert!((rec.finish_s - rec.start_s - rec.compute_s).abs() < 1e-9);
            assert!((rec.response_s - (rec.wait_s + rec.compute_s)).abs() < 1e-9);
            assert_eq!(rec.met_deadline, rec.response_s <= rec.safety_time_s);
        }
    }

    #[test]
    fn per_accel_fifo_no_overlap() {
        let q = queue(40.0, 3);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        // Tasks on the same accelerator never overlap in time.
        let n = Platform::hmai().len();
        for accel in 0..n {
            let mut last_finish = 0.0;
            for rec in r.records.iter().filter(|r| r.accel == accel) {
                assert!(rec.start_s >= last_finish - 1e-9);
                last_finish = rec.finish_s;
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let q = queue(50.0, 4);
        let run = |q: &TaskQueue| {
            let mut s = RoundRobin::new();
            simulate(q, &Platform::hmai(), &mut s, SimOptions::default())
        };
        let a = run(&q);
        let b = run(&q);
        assert_eq!(a.summary.energy_j, b.summary.energy_j);
        assert_eq!(a.summary.makespan_s, b.summary.makespan_s);
        assert_eq!(a.summary.tasks_met, b.summary.tasks_met);
    }

    /// The old O(n) probe selection, kept as the reference implementation.
    fn linear_scan_probe(records: &[TaskRecord], t_probe: f64) -> Option<&TaskRecord> {
        records
            .iter()
            .filter(|r| r.release_s >= t_probe && !r.model.is_tracker())
            .min_by(|a, b| a.release_s.total_cmp(&b.release_s))
    }

    #[test]
    fn probe_matches_old_linear_scan() {
        let q = queue(80.0, 6);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        let end = q.route_duration_s;
        for k in 0..50 {
            let t_probe = end * k as f64 / 40.0; // includes probes past the end
            let fast = first_detection_after(&r.records, t_probe).map(|x| x.task_id);
            let slow = linear_scan_probe(&r.records, t_probe).map(|x| x.task_id);
            assert_eq!(fast, slow, "probe at t={t_probe}");
        }
    }

    #[test]
    fn probe_tie_behavior_matches_min_by() {
        // Synthetic release-tie run: detection / tracker records sharing a
        // release time.  min_by keeps the FIRST equal minimum, so the
        // probe must return the first detection of the tie run.
        let mk = |id: u32, rel: f64, model: ModelKind| TaskRecord {
            task_id: id,
            model,
            accel: 0,
            release_s: rel,
            start_s: rel,
            finish_s: rel + 0.01,
            wait_s: 0.0,
            compute_s: 0.01,
            response_s: 0.01,
            energy_j: 0.1,
            ms: 0.5,
            safety_time_s: 0.1,
            met_deadline: true,
        };
        let recs = vec![
            mk(0, 1.0, ModelKind::Yolo),
            mk(1, 2.0, ModelKind::Goturn),
            mk(2, 2.0, ModelKind::Yolo),
            mk(3, 2.0, ModelKind::Ssd),
            mk(4, 3.0, ModelKind::Yolo),
        ];
        for t_probe in [0.0, 1.5, 2.0, 2.5, 3.0, 9.0] {
            let fast = first_detection_after(&recs, t_probe).map(|x| x.task_id);
            let slow = linear_scan_probe(&recs, t_probe).map(|x| x.task_id);
            assert_eq!(fast, slow, "t_probe={t_probe}");
        }
        assert_eq!(first_detection_after(&recs, 2.0).unwrap().task_id, 2);
        assert!(first_detection_after(&recs, 9.0).is_none());
    }

    #[test]
    fn summary_matches_metrics() {
        let q = queue(50.0, 5);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions::default());
        assert!((r.summary.energy_j - r.final_state.metrics.energy_j()).abs() < 1e-9);
        assert!((r.summary.gvalue - r.final_state.metrics.gvalue()).abs() < 1e-12);
        assert!(r.summary.stm_rate() >= 0.0 && r.summary.stm_rate() <= 1.0);
    }
}
