//! Discrete-event simulation of a task queue on a multi-accelerator
//! platform: tasks arrive on their camera frame clocks, the scheduler maps
//! each burst to accelerators, and per-accelerator FIFO queues determine
//! waiting, response times and the §6/§7.2 metrics.
//!
//! The core is the streaming [`Sim`] stepper: one [`Sim::step`] call
//! schedules and applies one release burst, draining any pending
//! [`events::PlatformEvent`]s (accelerator failure / recovery / derating)
//! into the [`ShadowState`] first, so schedulers see capacity change
//! mid-route.  [`observer::SimObserver`]s consume the route as it unfolds;
//! the one-shot [`simulate`] is a thin, bit-identical convenience wrapper
//! over the stepper.

pub mod events;
pub mod observer;
pub mod shadow;

use std::time::Instant;

use crate::env::taskgen::{Task, TaskQueue};
use crate::metrics::summary::RunSummary;
use crate::metrics::NormScales;
use crate::platform::Platform;
use crate::safety::ms::is_safety_critical;
use crate::sched::Scheduler;
use crate::workload::ModelKind;

pub use events::{EventAction, EventTimeline, PlatformEvent};
pub use observer::{
    BrakingProbe, DeadlineAbort, Progress, RecordCollector, SimFlow, SimObserver,
};
pub use shadow::{Applied, ShadowState};

/// Release times within this window belong to the same burst (all cameras
/// that fire "simultaneously", §7: "when 30 cameras in a vehicle work once,
/// 30 frames will be generated simultaneously").
pub const BURST_EPS_S: f64 = 1e-9;

/// Per-task outcome record (kept only when `SimOptions::record_tasks`).
#[derive(Debug, Clone, Copy)]
pub struct TaskRecord {
    pub task_id: u32,
    pub model: ModelKind,
    pub accel: usize,
    pub release_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    pub wait_s: f64,
    pub compute_s: f64,
    pub response_s: f64,
    pub energy_j: f64,
    pub ms: f64,
    pub safety_time_s: f64,
    pub met_deadline: bool,
}

impl TaskRecord {
    /// The one record constructor every observer shares, so a record of a
    /// (task, applied) pair can never disagree between consumers.
    pub fn of(task: &Task, a: &Applied) -> TaskRecord {
        TaskRecord {
            task_id: task.id,
            model: task.model,
            accel: a.accel,
            release_s: task.release_s,
            start_s: a.start_s,
            finish_s: a.finish_s,
            wait_s: a.wait_s,
            compute_s: a.compute_s,
            response_s: a.response_s,
            energy_j: a.energy_j,
            ms: a.ms,
            safety_time_s: task.safety_time_s,
            met_deadline: a.met_deadline,
        }
    }
}

/// Simulation options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Keep a per-task record vector (needed for Fig. 14's braking probe).
    pub record_tasks: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { record_tasks: false }
    }
}

/// Full simulation result.
#[derive(Debug)]
pub struct SimResult {
    pub summary: RunSummary,
    /// Final platform state (metrics + backlog) at queue end.
    pub final_state: ShadowState,
    /// Per-task records if requested.
    pub records: Vec<TaskRecord>,
    /// Wall-clock seconds spent inside the scheduler.
    pub sched_wall_s: f64,
    /// Number of scheduling invocations (bursts).
    pub bursts: u64,
}

impl SimResult {
    /// Mean scheduler wall time per task (the Fig. 14 `T_schedule`).
    pub fn sched_per_task_s(&self) -> f64 {
        if self.summary.tasks == 0 {
            0.0
        } else {
            self.sched_wall_s / self.summary.tasks as f64
        }
    }
}

/// First detection (non-tracker) task released at or after `t_probe` —
/// the Fig. 14 braking-probe selection, shared by the CLI, the braking
/// bench and the drive_route example.
///
/// `records` is sorted by release time (the simulator emits records in
/// release order), so the probe binary-searches the release boundary
/// (`partition_point`) and takes the first detection record after it —
/// O(log n + gap) per probe instead of the old full `filter().min_by()`
/// pass.  Behavior matches the old scan exactly, including ties: releases
/// are sorted, so the first non-tracker at or past the boundary has the
/// minimal release, and `Iterator::min_by` returns the *first* of equal
/// minima — also the first in iteration order.
pub fn first_detection_after(records: &[TaskRecord], t_probe: f64) -> Option<&TaskRecord> {
    let start = records.partition_point(|r| r.release_s < t_probe);
    records[start..].iter().find(|r| !r.model.is_tracker())
}

/// One scheduled-and-applied release burst, as handed to observers (and
/// returned by [`Sim::step`]).  Borrows the stepper's scratch buffers —
/// consume it before the next `step`.
#[derive(Debug)]
pub struct BurstOutcome<'a> {
    /// 0-based burst index.
    pub index: u64,
    /// Release time of the burst (the route clock at scheduling).
    pub release_s: f64,
    /// The tasks of the burst, in queue order.
    pub tasks: &'a [Task],
    /// The scheduler's accelerator choice per task.
    pub assignment: &'a [usize],
    /// What executing each choice did to the platform.
    pub applied: &'a [Applied],
    /// Wall-clock seconds inside the scheduler for this burst.
    pub sched_elapsed_s: f64,
    /// Platform events that fired before this burst was scheduled.
    pub events_applied: usize,
    /// Platform state *after* the burst executed.
    pub state: &'a ShadowState,
}

/// Incremental simulation stepper.  Each [`Sim::step`]: drain due platform
/// events into the state, collect the next release burst, let `scheduler`
/// map it, execute the mapping, and return the [`BurstOutcome`].
///
/// `state` is public on purpose: between steps a caller may inject its own
/// capacity changes (the [`EventTimeline`] is exactly that, pre-scheduled).
pub struct Sim<'q> {
    tasks: &'q [Task],
    platform_name: String,
    /// The live platform state schedulers see (mutable between steps).
    pub state: ShadowState,
    events: EventTimeline,
    i: usize,
    bursts: u64,
    processed: u64,
    /// Tasks that actually completed (finite response) — the mean-response
    /// denominator; equals `processed` unless platform events lost tasks.
    completed: u64,
    met: u64,
    /// Safety-critical (Detection-tier) tasks seen / met — the survival
    /// numerators of fault campaigns (report-only, never fingerprinted).
    safety_tasks: u64,
    safety_met: u64,
    wait_s: f64,
    response_sum: f64,
    response_max: f64,
    sched_wall_s: f64,
    // Per-burst scratch, reused across steps and lent out via BurstOutcome.
    assignment: Vec<usize>,
    applied: Vec<Applied>,
}

impl<'q> Sim<'q> {
    pub fn new(queue: &'q TaskQueue, platform: &Platform, scales: NormScales) -> Sim<'q> {
        Sim {
            tasks: &queue.tasks,
            platform_name: platform.name.clone(),
            state: ShadowState::new(platform, scales),
            events: EventTimeline::default(),
            i: 0,
            bursts: 0,
            processed: 0,
            completed: 0,
            met: 0,
            safety_tasks: 0,
            safety_met: 0,
            wait_s: 0.0,
            response_sum: 0.0,
            response_max: 0.0,
            sched_wall_s: 0.0,
            assignment: Vec::new(),
            applied: Vec::new(),
        }
    }

    /// Attach timed platform events (applied between bursts).
    pub fn with_events(mut self, events: Vec<PlatformEvent>) -> Sim<'q> {
        self.events = EventTimeline::new(events);
        self
    }

    /// All tasks processed?
    pub fn is_done(&self) -> bool {
        self.i >= self.tasks.len()
    }

    /// Bursts scheduled so far.
    pub fn bursts(&self) -> u64 {
        self.bursts
    }

    /// Tasks applied so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule and execute the next burst; `None` once the queue is done.
    pub fn step(&mut self, scheduler: &mut dyn Scheduler) -> Option<BurstOutcome<'_>> {
        if self.i >= self.tasks.len() {
            return None;
        }
        // Collect the burst [i, j): all tasks released together.
        let tasks = self.tasks;
        let i = self.i;
        let t0 = tasks[i].release_s;
        let mut j = i + 1;
        while j < tasks.len() && tasks[j].release_s - t0 <= BURST_EPS_S {
            j += 1;
        }
        let burst = &tasks[i..j];
        self.state.advance(t0);
        let now = self.state.now;
        let events_applied = self.events.apply_until(now, &mut self.state);

        // lint:allow(wallclock-in-results): sched_wall_s is diagnostic-only —
        // it feeds the Sched µs/task column, never a fingerprint.
        let clk = Instant::now();
        self.assignment = scheduler.schedule_batch(burst, &self.state);
        let sched_elapsed_s = clk.elapsed().as_secs_f64();
        self.sched_wall_s += sched_elapsed_s;
        self.bursts += 1;
        debug_assert_eq!(self.assignment.len(), burst.len());

        self.applied.clear();
        for (task, &accel) in burst.iter().zip(&self.assignment) {
            let a = self.state.apply(task, accel);
            self.wait_s += a.wait_s;
            if a.met_deadline {
                self.met += 1;
            }
            if is_safety_critical(task.category) {
                self.safety_tasks += 1;
                if a.met_deadline {
                    self.safety_met += 1;
                }
            }
            // Tasks lost to a failed accelerator respond "never" (+inf);
            // they count as missed deadlines (and MS = -1) but stay out of
            // the response accumulators *and* the mean's denominator, so
            // mean/max response describe the completed work only.
            // Event-free runs take this branch always.
            if a.response_s.is_finite() {
                self.response_sum += a.response_s;
                self.response_max = self.response_max.max(a.response_s);
                self.completed += 1;
            }
            self.applied.push(a);
        }
        self.processed += burst.len() as u64;
        self.i = j;

        Some(BurstOutcome {
            index: self.bursts - 1,
            release_s: t0,
            tasks: burst,
            assignment: &self.assignment,
            applied: &self.applied,
            sched_elapsed_s,
            events_applied,
            state: &self.state,
        })
    }

    /// Finish the run: fold the accumulators into a [`SimResult`] (with an
    /// empty record vector — attach a [`RecordCollector`] for records).
    pub fn into_result(self, scheduler_name: &str) -> SimResult {
        // Mean response over *completed* tasks (== all processed tasks on
        // an event-free run, so `simulate()` stays bit-identical).
        let n = self.completed as f64;
        let mut summary = RunSummary::from_metrics(
            scheduler_name,
            &self.platform_name,
            &self.state.metrics,
            self.met,
            self.wait_s,
            self.sched_wall_s,
            if n > 0.0 { self.response_sum / n } else { 0.0 },
            self.response_max,
        );
        // Interconnect totals (0.0 on monolithic platforms — the fields
        // exist either way so fingerprints cover them uniformly).
        if let Some(comm) = &self.state.comm {
            summary.comm_delay_s = comm.delay_s;
            summary.comm_gb = comm.bytes / 1e9;
        }
        // Survival counters (report-only; see RunSummary docs).  Lost =
        // processed minus finite-response completions.
        summary.safety_tasks = self.safety_tasks;
        summary.safety_met = self.safety_met;
        summary.lost_tasks = self.processed - self.completed;
        SimResult {
            summary,
            final_state: self.state,
            records: Vec::new(),
            sched_wall_s: self.sched_wall_s,
            bursts: self.bursts,
        }
    }

    /// Drive the stepper to completion (or an observer stop), notifying
    /// `observers` per burst and per task, then `on_end` exactly once.
    pub fn run(
        mut self,
        scheduler: &mut dyn Scheduler,
        observers: &mut [&mut dyn SimObserver],
    ) -> SimResult {
        let mut stop = false;
        while !stop {
            let Some(b) = self.step(scheduler) else { break };
            for obs in observers.iter_mut() {
                if obs.on_burst(&b) == SimFlow::Stop {
                    stop = true;
                }
            }
            for (task, a) in b.tasks.iter().zip(b.applied.iter()) {
                for obs in observers.iter_mut() {
                    obs.on_task(task, a);
                }
            }
        }
        let result = self.into_result(&scheduler.name());
        for obs in observers.iter_mut() {
            obs.on_end(&result.summary, &result.final_state);
        }
        result
    }
}

/// Run `queue` on `platform` under `scheduler`.
///
/// Tasks are processed in release order, grouped into bursts of identical
/// release time; the scheduler sees the exact `ShadowState` the engine
/// executes on, so scheduler-side predictions are exact.
///
/// This is a thin wrapper over the [`Sim`] stepper (no events, a
/// [`RecordCollector`] when `opts.record_tasks`) and is bit-identical to
/// the pre-stepper one-shot loop — `tests/stream.rs` pins the equivalence
/// and `tests/scenario.rs` the per-archetype fingerprints.
pub fn simulate(
    queue: &TaskQueue,
    platform: &Platform,
    scheduler: &mut dyn Scheduler,
    opts: SimOptions,
) -> SimResult {
    let scales = NormScales::for_queue(queue, platform);
    simulate_with_scales(queue, platform, scheduler, opts, scales)
}

/// `simulate` with externally-fixed normalization scales (so a trained
/// agent can be evaluated with the scales it was trained under).
pub fn simulate_with_scales(
    queue: &TaskQueue,
    platform: &Platform,
    scheduler: &mut dyn Scheduler,
    opts: SimOptions,
    scales: NormScales,
) -> SimResult {
    simulate_observed_with_scales(queue, platform, scheduler, opts, scales, Vec::new(), &mut [])
}

/// Full-control entry point: externally-fixed scales, a platform-event
/// timeline, and caller observers.  Everything else (`simulate`, the
/// engine, the braking probes) layers on this.
pub fn simulate_observed_with_scales(
    queue: &TaskQueue,
    platform: &Platform,
    scheduler: &mut dyn Scheduler,
    opts: SimOptions,
    scales: NormScales,
    events: Vec<PlatformEvent>,
    observers: &mut [&mut dyn SimObserver],
) -> SimResult {
    let sim = Sim::new(queue, platform, scales).with_events(events);
    if !opts.record_tasks {
        return sim.run(scheduler, observers);
    }
    let mut collector = RecordCollector::with_capacity(queue.len());
    let mut all: Vec<&mut dyn SimObserver> = Vec::with_capacity(observers.len() + 1);
    all.push(&mut collector);
    for obs in observers.iter_mut() {
        all.push(&mut **obs);
    }
    let mut result = sim.run(scheduler, &mut all);
    result.records = collector.into_records();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::route::{Route, RouteParams};
    use crate::env::Area;
    use crate::sched::roundrobin::RoundRobin;
    use crate::util::rng::Rng;

    fn queue(dist: f64, seed: u64) -> TaskQueue {
        let route =
            Route::generate(RouteParams::for_area(Area::Urban, dist), &mut Rng::new(seed));
        crate::env::taskgen::generate(&route)
    }

    #[test]
    fn processes_every_task() {
        let q = queue(60.0, 1);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        assert_eq!(r.summary.tasks as usize, q.len());
        assert_eq!(r.records.len(), q.len());
        assert!(r.bursts > 0 && r.bursts <= r.summary.tasks);
    }

    #[test]
    fn records_are_causally_consistent() {
        let q = queue(40.0, 2);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        for rec in &r.records {
            assert!(rec.start_s >= rec.release_s - 1e-12);
            assert!((rec.finish_s - rec.start_s - rec.compute_s).abs() < 1e-9);
            assert!((rec.response_s - (rec.wait_s + rec.compute_s)).abs() < 1e-9);
            assert_eq!(rec.met_deadline, rec.response_s <= rec.safety_time_s);
        }
    }

    #[test]
    fn per_accel_fifo_no_overlap() {
        let q = queue(40.0, 3);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        // Tasks on the same accelerator never overlap in time.
        let n = Platform::hmai().len();
        for accel in 0..n {
            let mut last_finish = 0.0;
            for rec in r.records.iter().filter(|r| r.accel == accel) {
                assert!(rec.start_s >= last_finish - 1e-9);
                last_finish = rec.finish_s;
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let q = queue(50.0, 4);
        let run = |q: &TaskQueue| {
            let mut s = RoundRobin::new();
            simulate(q, &Platform::hmai(), &mut s, SimOptions::default())
        };
        let a = run(&q);
        let b = run(&q);
        assert_eq!(a.summary.energy_j, b.summary.energy_j);
        assert_eq!(a.summary.makespan_s, b.summary.makespan_s);
        assert_eq!(a.summary.tasks_met, b.summary.tasks_met);
    }

    /// The old O(n) probe selection, kept as the reference implementation.
    fn linear_scan_probe(records: &[TaskRecord], t_probe: f64) -> Option<&TaskRecord> {
        records
            .iter()
            .filter(|r| r.release_s >= t_probe && !r.model.is_tracker())
            .min_by(|a, b| a.release_s.total_cmp(&b.release_s))
    }

    #[test]
    fn probe_matches_old_linear_scan() {
        let q = queue(80.0, 6);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions { record_tasks: true });
        let end = q.route_duration_s;
        for k in 0..50 {
            let t_probe = end * k as f64 / 40.0; // includes probes past the end
            let fast = first_detection_after(&r.records, t_probe).map(|x| x.task_id);
            let slow = linear_scan_probe(&r.records, t_probe).map(|x| x.task_id);
            assert_eq!(fast, slow, "probe at t={t_probe}");
        }
    }

    #[test]
    fn probe_tie_behavior_matches_min_by() {
        // Synthetic release-tie run: detection / tracker records sharing a
        // release time.  min_by keeps the FIRST equal minimum, so the
        // probe must return the first detection of the tie run.
        let mk = |id: u32, rel: f64, model: ModelKind| TaskRecord {
            task_id: id,
            model,
            accel: 0,
            release_s: rel,
            start_s: rel,
            finish_s: rel + 0.01,
            wait_s: 0.0,
            compute_s: 0.01,
            response_s: 0.01,
            energy_j: 0.1,
            ms: 0.5,
            safety_time_s: 0.1,
            met_deadline: true,
        };
        let recs = vec![
            mk(0, 1.0, ModelKind::Yolo),
            mk(1, 2.0, ModelKind::Goturn),
            mk(2, 2.0, ModelKind::Yolo),
            mk(3, 2.0, ModelKind::Ssd),
            mk(4, 3.0, ModelKind::Yolo),
        ];
        for t_probe in [0.0, 1.5, 2.0, 2.5, 3.0, 9.0] {
            let fast = first_detection_after(&recs, t_probe).map(|x| x.task_id);
            let slow = linear_scan_probe(&recs, t_probe).map(|x| x.task_id);
            assert_eq!(fast, slow, "t_probe={t_probe}");
        }
        assert_eq!(first_detection_after(&recs, 2.0).unwrap().task_id, 2);
        assert!(first_detection_after(&recs, 9.0).is_none());
    }

    #[test]
    fn stepper_is_bit_identical_to_simulate() {
        let q = queue(60.0, 7);
        let platform = Platform::hmai();
        let mut s1 = RoundRobin::new();
        let oneshot = simulate(&q, &platform, &mut s1, SimOptions { record_tasks: true });

        let mut s2 = RoundRobin::new();
        let scales = NormScales::for_queue(&q, &platform);
        let mut sim = Sim::new(&q, &platform, scales);
        let mut bursts = 0u64;
        let mut tasks = 0usize;
        while let Some(b) = sim.step(&mut s2) {
            assert_eq!(b.index, bursts);
            assert_eq!(b.tasks.len(), b.assignment.len());
            assert_eq!(b.tasks.len(), b.applied.len());
            assert_eq!(b.events_applied, 0);
            bursts += 1;
            tasks += b.tasks.len();
        }
        assert!(sim.is_done());
        assert_eq!(sim.processed(), tasks as u64);
        let stepped = sim.into_result(&s2.name());

        assert_eq!(oneshot.bursts, bursts);
        assert_eq!(oneshot.summary.tasks, stepped.summary.tasks);
        assert_eq!(oneshot.summary.tasks_met, stepped.summary.tasks_met);
        for (a, b) in [
            (oneshot.summary.energy_j, stepped.summary.energy_j),
            (oneshot.summary.makespan_s, stepped.summary.makespan_s),
            (oneshot.summary.wait_s, stepped.summary.wait_s),
            (oneshot.summary.compute_s, stepped.summary.compute_s),
            (oneshot.summary.r_balance, stepped.summary.r_balance),
            (oneshot.summary.ms_total, stepped.summary.ms_total),
            (oneshot.summary.gvalue, stepped.summary.gvalue),
            (oneshot.summary.mean_response_s, stepped.summary.mean_response_s),
            (oneshot.summary.max_response_s, stepped.summary.max_response_s),
        ] {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn record_collector_reproduces_inline_records() {
        let q = queue(50.0, 8);
        let platform = Platform::hmai();
        let mut s1 = RoundRobin::new();
        let r = simulate(&q, &platform, &mut s1, SimOptions { record_tasks: true });

        let mut s2 = RoundRobin::new();
        let scales = NormScales::for_queue(&q, &platform);
        let mut collector = RecordCollector::new();
        Sim::new(&q, &platform, scales).run(&mut s2, &mut [&mut collector]);
        let recs = collector.into_records();
        assert_eq!(recs.len(), r.records.len());
        for (a, b) in recs.iter().zip(&r.records) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.accel, b.accel);
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.wait_s.to_bits(), b.wait_s.to_bits());
        }
    }

    #[test]
    fn deadline_abort_stops_the_run_early() {
        // One slow accelerator drowns instantly under an urban queue, so
        // the aborting run processes a strict prefix of the full one.
        let q = queue(60.0, 9);
        let platform = Platform::from_counts("tiny", 1, 0, 0);
        let mut s1 = RoundRobin::new();
        let full = simulate(&q, &platform, &mut s1, SimOptions::default());
        assert!(full.summary.tasks_met < full.summary.tasks, "setup must miss deadlines");

        let mut s2 = RoundRobin::new();
        let scales = NormScales::for_queue(&q, &platform);
        let mut abort = DeadlineAbort::after(1);
        let r = Sim::new(&q, &platform, scales).run(&mut s2, &mut [&mut abort]);
        assert!(abort.triggered());
        assert!(abort.misses() >= 1);
        assert!(
            r.summary.tasks < full.summary.tasks,
            "abort at {} of {}",
            r.summary.tasks,
            full.summary.tasks
        );
        assert!(r.bursts < full.bursts);
    }

    #[test]
    fn braking_probe_matches_record_scan() {
        let q = queue(80.0, 10);
        let platform = Platform::hmai();
        let mut s1 = RoundRobin::new();
        let r = simulate(&q, &platform, &mut s1, SimOptions { record_tasks: true });
        let end = q.route_duration_s;
        for k in [0usize, 7, 20, 39] {
            let t_probe = end * k as f64 / 40.0;
            let mut s2 = RoundRobin::new();
            let scales = NormScales::for_queue(&q, &platform);
            let mut probe = BrakingProbe::new(t_probe);
            Sim::new(&q, &platform, scales).run(&mut s2, &mut [&mut probe]);
            let want = first_detection_after(&r.records, t_probe).map(|x| x.task_id);
            assert_eq!(probe.captured().map(|x| x.task_id), want, "t={t_probe}");
        }
    }

    #[test]
    fn events_fire_between_bursts_and_reroute_work() {
        let q = queue(60.0, 11);
        let platform = Platform::hmai();
        let dur = q.route_duration_s;
        let (t_fail, t_rec) = (0.25 * dur, 0.75 * dur);
        let events = vec![
            PlatformEvent { at_s: t_fail, action: EventAction::Fail { accel: 0 } },
            PlatformEvent { at_s: t_rec, action: EventAction::Recover { accel: 0 } },
        ];
        let mut s = RoundRobin::new();
        let scales = NormScales::for_queue(&q, &platform);
        let r = simulate_observed_with_scales(
            &q,
            &platform,
            &mut s,
            SimOptions { record_tasks: true },
            scales,
            events,
            &mut [],
        );
        let margin = 1e-6;
        let in_window: Vec<_> = r
            .records
            .iter()
            .filter(|x| x.release_s >= t_fail + margin && x.release_s < t_rec - margin)
            .collect();
        assert!(!in_window.is_empty(), "window must contain tasks");
        assert!(
            in_window.iter().all(|x| x.accel != 0),
            "no assignments to the failed accelerator while it is down"
        );
        // The accelerator serves traffic on both sides of the outage.
        assert!(r.records.iter().any(|x| x.release_s < t_fail && x.accel == 0));
        assert!(r.records.iter().any(|x| x.release_s >= t_rec + margin && x.accel == 0));
    }

    #[test]
    fn progress_observer_ticks_every_n_bursts() {
        let q = queue(40.0, 12);
        let platform = Platform::hmai();
        let mut s = RoundRobin::new();
        let scales = NormScales::for_queue(&q, &platform);
        let mut ticks = Vec::new();
        let mut progress = Progress::every(10, |bursts, _t, tasks| ticks.push((bursts, tasks)));
        let r = Sim::new(&q, &platform, scales).run(&mut s, &mut [&mut progress]);
        assert_eq!(ticks.len() as u64, r.bursts / 10);
        assert!(ticks.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 < w[1].1));
    }

    #[test]
    fn survival_counters_track_safety_tier_and_losses() {
        // Event-free run: nothing lost; the safety tier matches a record
        // scan (Detection-category tasks are exactly the non-trackers).
        let q = queue(50.0, 13);
        let platform = Platform::hmai();
        let mut s = RoundRobin::new();
        let r = simulate(&q, &platform, &mut s, SimOptions { record_tasks: true });
        assert_eq!(r.summary.lost_tasks, 0);
        let det: Vec<_> = r.records.iter().filter(|x| !x.model.is_tracker()).collect();
        assert_eq!(r.summary.safety_tasks, det.len() as u64);
        assert_eq!(
            r.summary.safety_met,
            det.iter().filter(|x| x.met_deadline).count() as u64
        );
        assert!(r.summary.safety_tasks > 0 && r.summary.safety_tasks < r.summary.tasks);

        // A one-accel platform whose accelerator dies and never recovers:
        // every later task is lost, and the counter sees each one.
        let tiny = Platform::from_counts("tiny", 1, 0, 0);
        let events = vec![PlatformEvent {
            at_s: 0.5 * q.route_duration_s,
            action: EventAction::Fail { accel: 0 },
        }];
        let mut s2 = RoundRobin::new();
        let scales = NormScales::for_queue(&q, &tiny);
        let lossy = simulate_observed_with_scales(
            &q,
            &tiny,
            &mut s2,
            SimOptions { record_tasks: true },
            scales,
            events,
            &mut [],
        );
        let lost = lossy.records.iter().filter(|x| !x.response_s.is_finite()).count() as u64;
        assert!(lost > 0, "outage must lose tasks");
        assert_eq!(lossy.summary.lost_tasks, lost);
    }

    #[test]
    fn summary_matches_metrics() {
        let q = queue(50.0, 5);
        let mut s = RoundRobin::new();
        let r = simulate(&q, &Platform::hmai(), &mut s, SimOptions::default());
        assert!((r.summary.energy_j - r.final_state.metrics.energy_j()).abs() < 1e-9);
        assert!((r.summary.gvalue - r.final_state.metrics.gvalue()).abs() < 1e-12);
        assert!(r.summary.stm_rate() >= 0.0 && r.summary.stm_rate() <= 1.0);
    }
}
