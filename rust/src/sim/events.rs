//! Timed platform events — the runtime-variability half of the paper's
//! premise: available compute changes *mid-route* (an accelerator fails
//! and recovers, thermal pressure derates a clock) while the workload
//! keeps streaming.
//!
//! Events carry an absolute route-clock time and a [`ShadowState`] edit.
//! The [`Sim`](crate::sim::Sim) stepper drains an [`EventTimeline`]
//! *between bursts*: every event with `at_s <= now` is applied before the
//! scheduler sees the state, so schedulers transparently observe capacity
//! changes through the same `ShadowState` they always read — no scheduler
//! API change.  Scenario archetypes declare events as route-duration
//! fractions (`env::scenario::EventSpec`) and compile them to absolute
//! times per queue.

use super::shadow::ShadowState;

/// What an event does to the platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventAction {
    /// Accelerator drops out of service: new work routes elsewhere.
    Fail { accel: usize },
    /// Accelerator returns to nominal speed.
    Recover { accel: usize },
    /// Accelerator derates to `speed` × nominal (0 < speed < 1: compute
    /// time divides by `speed`; energy is unchanged — the work is the
    /// same, only slower).
    Derate { accel: usize, speed: f64 },
    /// Interconnect link drops dead: its hops price at `+inf` and ingress
    /// routes fall back to surviving BFS paths.  A no-op on monolithic
    /// platforms (no `CommState`).
    LinkFail { link: usize },
    /// Interconnect link returns to nominal bandwidth.
    LinkRecover { link: usize },
    /// Interconnect link derates to `speed` × nominal bandwidth
    /// (0 < speed < 1); per-hop latency is a PHY property and unchanged.
    LinkDerate { link: usize, speed: f64 },
}

impl EventAction {
    /// Apply this action to a platform state.
    pub fn apply(&self, state: &mut ShadowState) {
        match *self {
            EventAction::Fail { accel } => state.set_speed(accel, 0.0),
            EventAction::Recover { accel } => state.set_speed(accel, 1.0),
            EventAction::Derate { accel, speed } => state.set_speed(accel, speed),
            EventAction::LinkFail { link } => state.set_link_speed(link, 0.0),
            EventAction::LinkRecover { link } => state.set_link_speed(link, 1.0),
            EventAction::LinkDerate { link, speed } => state.set_link_speed(link, speed),
        }
    }

    /// Short human label (`env list`, progress lines).
    pub fn describe(&self) -> String {
        match *self {
            EventAction::Fail { accel } => format!("fail a{accel}"),
            EventAction::Recover { accel } => format!("recover a{accel}"),
            EventAction::Derate { accel, speed } => format!("derate a{accel}x{speed}"),
            EventAction::LinkFail { link } => format!("linkfail l{link}"),
            EventAction::LinkRecover { link } => format!("linkrecover l{link}"),
            EventAction::LinkDerate { link, speed } => format!("linkderate l{link}x{speed}"),
        }
    }
}

/// One timed platform event on the route clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlatformEvent {
    pub at_s: f64,
    pub action: EventAction,
}

/// A time-sorted queue of platform events with a drain cursor.  An empty
/// timeline is free on the simulation hot path (one index compare per
/// burst).
#[derive(Debug, Clone, Default)]
pub struct EventTimeline {
    events: Vec<PlatformEvent>,
    next: usize,
}

impl EventTimeline {
    /// Build a timeline; events are stably sorted by time so same-instant
    /// events apply in declaration order.
    pub fn new(mut events: Vec<PlatformEvent>) -> EventTimeline {
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        EventTimeline { events, next: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Events not yet applied.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.next
    }

    /// Apply every event with `at_s <= t` to `state`; returns how many
    /// fired.  Idempotent per event: the cursor only moves forward.
    pub fn apply_until(&mut self, t: f64, state: &mut ShadowState) -> usize {
        let start = self.next;
        while let Some(e) = self.events.get(self.next) {
            if e.at_s > t {
                break;
            }
            e.action.apply(state);
            self.next += 1;
        }
        self.next - start
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NormScales;
    use crate::platform::Platform;

    fn state() -> ShadowState {
        ShadowState::new(&Platform::hmai(), NormScales::unit())
    }

    #[test]
    fn timeline_sorts_and_drains_in_order() {
        let mut tl = EventTimeline::new(vec![
            PlatformEvent { at_s: 5.0, action: EventAction::Recover { accel: 0 } },
            PlatformEvent { at_s: 1.0, action: EventAction::Fail { accel: 0 } },
        ]);
        assert_eq!(tl.len(), 2);
        let mut s = state();
        assert_eq!(tl.apply_until(0.5, &mut s), 0);
        assert!(s.is_up(0));
        assert_eq!(tl.apply_until(1.0, &mut s), 1);
        assert!(!s.is_up(0), "fail fired at its timestamp");
        assert_eq!(tl.apply_until(2.0, &mut s), 0, "cursor does not re-fire");
        assert_eq!(tl.remaining(), 1);
        assert_eq!(tl.apply_until(100.0, &mut s), 1);
        assert!(s.is_up(0), "recovery fired");
        assert_eq!(tl.remaining(), 0);
    }

    #[test]
    fn same_instant_events_apply_in_declaration_order() {
        // Stable sort: a fail+derate pair at the same time lands with the
        // later declaration winning.
        let mut tl = EventTimeline::new(vec![
            PlatformEvent { at_s: 2.0, action: EventAction::Fail { accel: 1 } },
            PlatformEvent { at_s: 2.0, action: EventAction::Derate { accel: 1, speed: 0.5 } },
        ]);
        let mut s = state();
        assert_eq!(tl.apply_until(2.0, &mut s), 2);
        assert!((s.speed[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn link_events_touch_comm_and_noop_on_mono() {
        // Monolithic platform: no CommState, link events are no-ops.
        let mut mono = state();
        EventAction::LinkFail { link: 0 }.apply(&mut mono);
        assert!(mono.comm.is_none());
        // Chiplet platform: the link's speed factor follows the events.
        let mut noc = ShadowState::new(
            &Platform::parse("hmai+mesh2x2").unwrap(),
            NormScales::unit(),
        );
        EventAction::LinkDerate { link: 1, speed: 0.5 }.apply(&mut noc);
        assert!((noc.comm.as_ref().unwrap().link_speed(1) - 0.5).abs() < 1e-12);
        EventAction::LinkFail { link: 1 }.apply(&mut noc);
        assert_eq!(noc.comm.as_ref().unwrap().link_speed(1), 0.0);
        EventAction::LinkRecover { link: 1 }.apply(&mut noc);
        assert_eq!(noc.comm.as_ref().unwrap().link_speed(1), 1.0);
        assert!(EventAction::LinkFail { link: 1 }.describe().contains("l1"));
        assert!(EventAction::LinkDerate { link: 1, speed: 0.5 }
            .describe()
            .contains("linkderate l1x0.5"));
    }

    #[test]
    fn derate_action_sets_fractional_speed() {
        let mut s = state();
        EventAction::Derate { accel: 2, speed: 0.25 }.apply(&mut s);
        assert!((s.speed[2] - 0.25).abs() < 1e-12);
        assert!(s.is_up(2));
        assert!(EventAction::Fail { accel: 2 }.describe().contains("a2"));
    }
}
