//! Streaming simulation observers: consume a route *as it unfolds*
//! instead of post-processing a retained `Vec<TaskRecord>`.
//!
//! The [`Sim`](crate::sim::Sim) stepper notifies observers once per burst
//! ([`SimObserver::on_burst`], which can stop the run early) and once per
//! applied task ([`SimObserver::on_task`]); [`SimObserver::on_end`] fires
//! exactly once with the finished summary.  Stock observers cover the
//! call sites that previously needed `SimOptions { record_tasks: true }`:
//! [`RecordCollector`] reproduces the full record vector bit-for-bit,
//! [`BrakingProbe`] captures the Fig. 14 probe task without retaining
//! anything else, [`DeadlineAbort`] ends a hopeless run early, and
//! [`Progress`] streams periodic progress for long sweeps.

use crate::env::taskgen::Task;
use crate::metrics::summary::RunSummary;

use super::shadow::{Applied, ShadowState};
use super::{BurstOutcome, TaskRecord};

/// Observer verdict after a burst: keep stepping or stop the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimFlow {
    Continue,
    Stop,
}

/// Callbacks driven by the [`Sim`](crate::sim::Sim) stepper.  All methods
/// default to no-ops so observers implement only what they need.
pub trait SimObserver {
    /// One scheduled-and-applied burst; return [`SimFlow::Stop`] to end
    /// the run after this burst (remaining tasks are never scheduled).
    fn on_burst(&mut self, _burst: &BurstOutcome<'_>) -> SimFlow {
        SimFlow::Continue
    }

    /// One applied task (fires after `on_burst`, in burst order).
    fn on_task(&mut self, _task: &Task, _applied: &Applied) {}

    /// The run is over (end of queue or an observer stop).
    fn on_end(&mut self, _summary: &RunSummary, _final_state: &ShadowState) {}
}

/// Collects the classic per-task record vector — the observer behind
/// `SimOptions { record_tasks: true }`.
#[derive(Debug, Default)]
pub struct RecordCollector {
    records: Vec<TaskRecord>,
}

impl RecordCollector {
    pub fn new() -> RecordCollector {
        RecordCollector { records: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> RecordCollector {
        RecordCollector { records: Vec::with_capacity(n) }
    }

    pub fn records(&self) -> &[TaskRecord] {
        &self.records
    }

    pub fn into_records(self) -> Vec<TaskRecord> {
        self.records
    }
}

impl SimObserver for RecordCollector {
    fn on_task(&mut self, task: &Task, a: &Applied) {
        self.records.push(TaskRecord::of(task, a));
    }
}

/// Streaming Fig. 14 braking probe: captures the first *detection*
/// (non-tracker) task released at or after `t_probe` — the exact
/// [`first_detection_after`](crate::sim::first_detection_after) selection,
/// taken on the fly so the run retains one record instead of all of them.
#[derive(Debug)]
pub struct BrakingProbe {
    t_probe: f64,
    captured: Option<TaskRecord>,
}

impl BrakingProbe {
    pub fn new(t_probe: f64) -> BrakingProbe {
        BrakingProbe { t_probe, captured: None }
    }

    /// The probe task, if the route reached `t_probe`.
    pub fn captured(&self) -> Option<&TaskRecord> {
        self.captured.as_ref()
    }
}

impl SimObserver for BrakingProbe {
    fn on_task(&mut self, task: &Task, a: &Applied) {
        // Tasks stream in release order, so the first match is the probe.
        if self.captured.is_none()
            && task.release_s >= self.t_probe
            && !task.model.is_tracker()
        {
            self.captured = Some(TaskRecord::of(task, a));
        }
    }
}

/// Early exit once `allowed` deadlines have been missed — a sweep over a
/// hopeless (scheduler, platform) cell stops paying for the rest of the
/// route.  The resulting summary covers only the processed prefix.
#[derive(Debug)]
pub struct DeadlineAbort {
    allowed: u64,
    misses: u64,
}

impl DeadlineAbort {
    /// Stop after `allowed` missed deadlines (1 = stop on the first miss).
    pub fn after(allowed: u64) -> DeadlineAbort {
        DeadlineAbort { allowed: allowed.max(1), misses: 0 }
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn triggered(&self) -> bool {
        self.misses >= self.allowed
    }
}

impl SimObserver for DeadlineAbort {
    fn on_burst(&mut self, b: &BurstOutcome<'_>) -> SimFlow {
        self.misses += b.applied.iter().filter(|a| !a.met_deadline).count() as u64;
        if self.triggered() {
            SimFlow::Stop
        } else {
            SimFlow::Continue
        }
    }
}

/// Periodic progress reporting: invokes the callback every `every` bursts
/// with (bursts so far, route clock, tasks so far) — what the engine and
/// long-running examples surface instead of polling retained results.
pub struct Progress<F: FnMut(u64, f64, u64)> {
    every: u64,
    tasks: u64,
    callback: F,
}

impl<F: FnMut(u64, f64, u64)> Progress<F> {
    pub fn every(every: u64, callback: F) -> Progress<F> {
        Progress { every: every.max(1), tasks: 0, callback }
    }
}

impl<F: FnMut(u64, f64, u64)> SimObserver for Progress<F> {
    fn on_burst(&mut self, b: &BurstOutcome<'_>) -> SimFlow {
        self.tasks += b.tasks.len() as u64;
        if (b.index + 1) % self.every == 0 {
            (self.callback)(b.index + 1, b.release_s, self.tasks);
        }
        SimFlow::Continue
    }
}
