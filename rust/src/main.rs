//! `hmai` — the leader binary: drive the HMAI platform model, the driving
//! environment and the schedulers from the command line.
//!
//! Subcommands:
//!   report <name|all>   regenerate a paper table (table1-9, table11, fig1)
//!   env                 generate a route + task queue, print statistics
//!   platform            homogeneous-vs-heterogeneous exploration (Fig. 2)
//!   schedule            sweep a scheduler over task queues (Fig. 12/13)
//!   train               train the FlexAI DQN, save a checkpoint (Fig. 11)
//!   braking             braking-distance probe (Fig. 14)
//!   faults              MTBF/MTTR fault campaign, degradation off vs on
//!
//! `schedule`, `platform` and `braking` run through the typed
//! `ExperimentPlan`/`Engine` API; `--jobs N` executes trials on N worker
//! threads with bit-identical summaries to `--jobs 1`.

// The CLI's error/notice channel is stderr by design; the package-wide
// `clippy::print_stderr` deny (Cargo.toml `[lints]`) carves out this one
// binary root plus reports/ and util/logging.
#![allow(clippy::print_stderr)]

use anyhow::{Context, Result};

use hmai::config::ExperimentConfig;
use hmai::engine::Engine;
use hmai::env::route::{Route, RouteParams};
use hmai::env::{scenario, taskgen, ALL_SCENARIOS};
use hmai::faults::FaultModel;
use hmai::fleet::{self, FleetPlan, ShardCheckpoint, WorkOptions};
use hmai::harness;
use hmai::metrics::summary::SweepSummary;
use hmai::platform::alloc;
use hmai::safety::braking::{braking_distance_m, BrakingBreakdown};
use hmai::sched::registry;
use hmai::sim::BrakingProbe;
use hmai::util::cli::Args;
use hmai::util::json::Json;
use hmai::util::rng::Rng;
use hmai::util::table::{f1, f2, pct, Table};

fn main() {
    let args = Args::from_env();
    if let Some(lvl) = args.get("log") {
        hmai::util::logging::set_level_from_str(lvl);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("hmai: error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("report") => cmd_report(args),
        Some("env") => cmd_env(args),
        Some("platform") => cmd_platform(args),
        Some("schedule") => cmd_schedule(args),
        Some("train") => cmd_train(args),
        Some("braking") => cmd_braking(args),
        Some("dse") => cmd_dse(args),
        Some("faults") => cmd_faults(args),
        Some("fleet") => cmd_fleet(args),
        Some("lint") => cmd_lint(args),
        Some("help") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand '{other}' (try `hmai help`)"),
    }
}

fn usage() -> String {
    let mut s = String::from(
        "hmai — HMAI platform model + FlexAI scheduler (paper reproduction)\n\n\
         USAGE:\n    hmai <SUBCOMMAND> [OPTIONS]\n\nSUBCOMMANDS:\n\
         \x20   report <name|all>   regenerate a paper table\n\
         \x20   env [list]          route + task-queue statistics (list: the scenario library)\n\
         \x20   platform            Fig. 2 homogeneous-vs-HMAI exploration\n\
         \x20   schedule            sweep a scheduler over task queues\n\
         \x20   train               train FlexAI, save a checkpoint\n\
         \x20   braking             Fig. 14 braking-distance probe\n\
         \x20   dse                 design-space exploration over core mixes (Pareto frontier)\n\
         \x20   faults              MTBF/MTTR fault campaign: graceful degradation off vs on\n\
         \x20   fleet plan|work|merge  sharded, checkpoint-resumable fleet sweeps\n\
         \x20   lint                determinism & panic-safety lint over the crate source\n\nOPTIONS:\n",
    );
    // The scheduler list comes from the one canonical table, so the usage
    // string can never drift from what the registry accepts.
    let sched_help = registry::usage_names();
    for o in [
        ("--config <file>", "JSON config (defaults < file < flags)".to_string()),
        ("--sched <name>", sched_help),
        ("--ckpt <file>", "FlexAI checkpoint to load".to_string()),
        (
            "--platform <spec>",
            "hmai | 13so | 13si | 12mm | \"so,si,mm\" | \"so:4@2x,si:4,mm:3@0.5x\"".to_string(),
        ),
        ("--area <a>", "ub | uhw | hw".to_string()),
        (
            "--scenario <n|all>",
            format!("scenario library: {}", scenario::names().join(" | ")),
        ),
        (
            "--events",
            "apply scenario platform events (accel failure/derating; see `env list`)"
                .to_string(),
        ),
        (
            "--json <path>",
            "write the full sweep summary as JSON (schedule/platform/braking/faults)".to_string(),
        ),
        ("--mtbf <s>", "faults: accelerator mean time between failures".to_string()),
        ("--mttr <s>", "faults: accelerator mean repair time".to_string()),
        (
            "--link-mtbf <s>",
            "faults: link mean time between failures (chiplet platforms)".to_string(),
        ),
        ("--link-mttr <s>", "faults: link mean repair time".to_string()),
        ("--dist <m,...>", "route distances in meters (alias: --distance)".to_string()),
        ("--deadline <mode>", "rss | frame (deadline regime)".to_string()),
        ("--budget <area>", "dse: area budget in Std-core equivalents".to_string()),
        ("--power-cap <W>", "dse: optional peak-power cap".to_string()),
        (
            "--topology <t,...>",
            "package topology: mono | mesh<R>x<C> | ring<N> | package<N> [@0.5x|2x] \
             (dse: comma list adds a topology search axis)"
                .to_string(),
        ),
        ("--search <mode>", "dse: auto | full | greedy".to_string()),
        ("--beam <n>", "dse: greedy beam width".to_string()),
        ("--max-evals <n>", "dse: cap on simulated candidate mixes".to_string()),
        (
            "--fidelity <mode>",
            "dse: multi (bound pruning + screening, default) | exact".to_string(),
        ),
        ("--rungs <n>", "dse: successive-halving screening rungs (1..=6)".to_string()),
        (
            "--keep-frac <f>",
            "dse: fraction promoted per rung, (0,1] (the screening frontier always \
             promotes)"
                .to_string(),
        ),
        ("--jobs <n>", "engine worker threads (0 = all cores)".to_string()),
        ("--replicates <n>", "seed replicates per sweep cell (expands the seed axis)".to_string()),
        ("--shards <n>", "fleet plan: number of worker shards".to_string()),
        ("--plan <file>", "fleet work/merge: plan file (default fleet_plan.json)".to_string()),
        ("--shard <k>", "fleet work: shard index to run/resume".to_string()),
        (
            "--checkpoint-every <n>",
            "fleet work: trials between checkpoint saves (default 500)".to_string(),
        ),
        (
            "--max-trials <n>",
            "fleet work: stop after n trials this invocation (kill/resume drills)".to_string(),
        ),
        ("--root <dir>", "lint: source root to scan (default src/ or rust/src/)".to_string()),
        ("--rules", "lint: print the rule table and exit".to_string()),
        ("--seed <u64>", "top-level seed".to_string()),
        ("--episodes <n>", "training episodes".to_string()),
        ("--episode-dist <m>", "training route length".to_string()),
        ("--out <file>", "checkpoint output path (train)".to_string()),
        ("--log <level>", "error|warn|info|debug|trace".to_string()),
    ] {
        s.push_str(&format!("    {:<22} {}\n", o.0, o.1));
    }
    s
}

fn config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    cfg.apply_args(args)?;
    Ok(cfg)
}

/// `schedule`/`braking` default to FlexAI (the paper's headline agent);
/// when no `--sched` was given and the PJRT runtime is unavailable, fall
/// back to Min-Min so the CLI — including `schedule --scenario all` —
/// works out of the box instead of erroring on missing artifacts.
fn default_sched_fallback(cfg: &mut ExperimentConfig, args: &Args) {
    if args.get("sched").is_none()
        && registry::lookup(&cfg.scheduler).map(|i| i.canonical) == Some("flexai")
        && harness::load_runtime().is_err()
    {
        eprintln!("note: FlexAI runtime unavailable — using minmin (pass --sched to override)");
        cfg.scheduler = "minmin".into();
    }
}

fn cmd_report(args: &Args) -> Result<()> {
    let name = args.rest().first().map(String::as_str).unwrap_or("all");
    if name == "all" {
        for n in hmai::reports::ALL_REPORTS {
            println!("── {n} " );
            println!("{}", hmai::reports::render(n)?);
        }
        return Ok(());
    }
    print!("{}", hmai::reports::render(name)?);
    Ok(())
}

/// Write a `--json` report through the shared `util::json` writer.
fn write_json_report(args: &Args, report: Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        report
            .write_to(std::path::Path::new(path))
            .with_context(|| format!("writing --json {path}"))?;
        println!("json -> {path}");
    }
    Ok(())
}

/// Sweep-report JSON shared by `schedule`/`platform`/`braking`: the full
/// `SweepSummary` (every `SweepKey` row with its per-scenario breakdown
/// and runs) plus the config and the jobs-invariant fingerprint.
fn sweep_json(command: &str, cfg: &ExperimentConfig, sweep: &SweepSummary) -> Json {
    Json::from_pairs(vec![
        ("command", Json::Str(command.to_string())),
        ("fingerprint", Json::Str(format!("{:016x}", sweep.fingerprint()))),
        ("config", cfg.to_json()),
        ("sweep", sweep.to_json()),
    ])
}

/// Whether `--events` can actually fire for this config: some selected
/// scenario archetype must declare platform events.  Warns (once) when
/// events were requested but nothing can apply them, so the printed
/// "events = on/off" status is always truthful.
fn events_effective(cfg: &ExperimentConfig) -> bool {
    if !cfg.events {
        return false;
    }
    let any = cfg
        .scenarios
        .iter()
        .filter_map(|n| scenario::find(n).ok())
        .any(|a| !a.events.is_empty());
    if !any {
        eprintln!(
            "note: --events has no effect — no selected scenario declares platform events \
             (see `hmai env list`)"
        );
    }
    any
}

/// `hmai env list`: the scenario library, one row per archetype — names,
/// composition, and the platform events behind `--events` — so nobody has
/// to read `env/scenario.rs` to discover what `--scenario` accepts.
fn cmd_env_list() -> Result<()> {
    let mut t = Table::new([
        "Scenario", "Legs", "Cameras", "Hz x", "Dropouts", "Events", "Description",
    ]);
    for arch in scenario::library() {
        let events = if arch.events.is_empty() {
            "-".to_string()
        } else {
            arch.events
                .iter()
                .map(|e| format!("{}@{:.0}%", e.action.describe(), e.at_frac * 100.0))
                .collect::<Vec<_>>()
                .join(", ")
        };
        t.row([
            arch.name.clone(),
            arch.legs.len().to_string(),
            arch.rig.total().to_string(),
            f2(arch.hz_scale),
            arch.dropouts.len().to_string(),
            events,
            arch.help.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nusage: --scenario <name[,name...]|all>; --events applies the Events column \
         to the platform mid-route"
    );
    Ok(())
}

fn cmd_env(args: &Args) -> Result<()> {
    if args.rest().first().map(String::as_str) == Some("list") {
        return cmd_env_list();
    }
    let cfg = config(args)?;
    if !cfg.scenarios.is_empty() {
        return cmd_env_scenarios(&cfg);
    }
    let mut rng = Rng::new(cfg.env.seed);
    let mut t = Table::new([
        "Queue", "Distance (m)", "Duration (s)", "Tasks", "Tasks/s", "YOLO", "SSD", "GOTURN",
        "Turns", "Reverses",
    ]);
    for (i, &d) in cfg.env.distances_m.iter().enumerate() {
        let mut stream = rng.fork(i as u64);
        let route = Route::generate(RouteParams::for_area(cfg.env.area, d), &mut stream);
        let q = taskgen::generate_with_deadline(&route, cfg.deadline);
        let count = |m: hmai::workload::ModelKind| {
            q.tasks.iter().filter(|t| t.model == m).count().to_string()
        };
        let turns = route
            .segments
            .iter()
            .filter(|s| s.scenario == hmai::env::Scenario::Turn)
            .count();
        let revs = route
            .segments
            .iter()
            .filter(|s| s.scenario == hmai::env::Scenario::Reverse)
            .count();
        t.row([
            (i + 1).to_string(),
            f1(d),
            f1(route.duration_s),
            q.len().to_string(),
            f1(q.len() as f64 / route.duration_s),
            count(hmai::workload::ModelKind::Yolo),
            count(hmai::workload::ModelKind::Ssd),
            count(hmai::workload::ModelKind::Goturn),
            turns.to_string(),
            revs.to_string(),
        ]);
    }
    println!("area = {}  deadline = {}", cfg.env.area.name(), cfg.deadline.name());
    t.print();
    Ok(())
}

/// `hmai env --scenario <names|all>`: per-archetype queue statistics of
/// the scenario library (compiled routes, rigs, task rates).
fn cmd_env_scenarios(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = Table::new([
        "Scenario", "Distance (m)", "Duration (s)", "Legs", "Cameras", "Hz x", "Events",
        "Tasks", "Tasks/s",
    ]);
    for name in &cfg.scenarios {
        let arch = scenario::find(name)?;
        for (i, &d) in cfg.env.distances_m.iter().enumerate() {
            let q = arch.queue_for(d, i, cfg.deadline, cfg.env.seed);
            t.row([
                arch.name.clone(),
                f1(d),
                f1(q.route_duration_s),
                arch.legs.len().to_string(),
                arch.rig.total().to_string(),
                f2(arch.hz_scale),
                arch.events.len().to_string(),
                q.len().to_string(),
                f1(q.len() as f64 / q.route_duration_s),
            ]);
        }
    }
    println!("deadline = {}  seed = {}", cfg.deadline.name(), cfg.env.seed);
    t.print();
    Ok(())
}

/// Fig. 2: energy + utilization of homogeneous platforms vs HMAI across the
/// three UB scenarios (allocation search), followed by an `Engine` sweep of
/// one scheduler over the same four platforms on real task queues.
fn cmd_platform(args: &Args) -> Result<()> {
    let mut cfg = config(args)?;
    let area = cfg.env.area;
    let mut t = Table::new(["Platform", "Scenario", "Feasible", "Power (W)", "Utilization"]);
    let platforms = ["13so", "13si", "12mm", "hmai"];
    let counts_of = [(13, 0, 0), (0, 13, 0), (0, 0, 12), (4, 4, 3)];
    let names = ["13xSconvOD", "13xSconvIC", "12xMconvMC", "HMAI(4,4,3)"];
    for (name, counts) in names.iter().zip(counts_of) {
        for s in ALL_SCENARIOS {
            if s == hmai::env::Scenario::Reverse && !area.allows_reverse() {
                continue;
            }
            let reqs = alloc::requirements(area, s);
            match alloc::best_allocation(counts, &reqs) {
                Some((a, u)) => t.row([
                    name.to_string(),
                    s.name().to_string(),
                    "yes".into(),
                    f2(alloc::power_w_provisioned(&a, &reqs, counts)),
                    pct(u),
                ]),
                None => t.row([
                    name.to_string(),
                    s.name().to_string(),
                    "NO".into(),
                    "-".into(),
                    "-".into(),
                ]),
            };
        }
    }
    println!("area = {}", area.name());
    t.print();

    // Scheduling sweep over the platform set (holds the scheduler fixed so
    // the comparison isolates the hardware — Fig. 10's framing).  Short
    // default route unless the user chose distances explicitly.
    if args.get("dist").is_none() {
        cfg.env.distances_m = vec![300.0];
    }
    if args.get("sched").is_none() {
        cfg.scheduler = "sa".into();
    }
    let reg = harness::registry(&cfg);
    let plan = cfg
        .plan()?
        .platforms(platforms.iter().map(|p| p.to_string()));
    // Aggregate-only sweep: stream trials straight into the summary.
    let events_on = events_effective(&cfg);
    let sweep = Engine::new(&reg).jobs(cfg.jobs).events(events_on).sweep_streaming(&plan)?;
    println!(
        "\nscheduling sweep: {} on {:.0} m ({}), {} trials",
        cfg.scheduler,
        cfg.env.distances_m.iter().sum::<f64>(),
        area.name(),
        sweep.total_runs()
    );
    hmai::reports::sweep_table(&sweep).print();
    write_json_report(args, sweep_json("platform", &cfg, &sweep))?;
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let mut cfg = config(args)?;
    default_sched_fallback(&mut cfg, args);
    let reg = harness::registry(&cfg);
    let plan = cfg.plan()?;
    let events_on = events_effective(&cfg);
    let engine = Engine::new(&reg).jobs(cfg.jobs).events(events_on);
    let (results, sweep) = engine.sweep(&plan)?;

    let mut t = Table::new([
        "Scenario", "Queue", "Tasks", "STMRate", "Time (s)", "Wait (s)", "Makespan (s)",
        "Energy (J)", "R_Balance", "MS/task", "Gvalue", "Sched µs/task",
    ]);
    for r in &results {
        let s = &r.summary;
        t.row([
            r.trial.scenario.scenario_name(),
            (r.trial.queue_index + 1).to_string(),
            s.tasks.to_string(),
            pct(s.stm_rate()),
            f2(s.total_time_s),
            f2(s.wait_s),
            f2(s.makespan_s),
            f1(s.energy_j),
            f2(s.r_balance),
            f2(s.ms_per_task()),
            f2(s.gvalue),
            f2(r.sched_per_task_s() * 1e6),
        ]);
    }
    let place = if cfg.scenarios.is_empty() {
        format!("area = {}", cfg.env.area.name())
    } else {
        format!("scenarios = {}", cfg.scenarios.join(","))
    };
    println!(
        "scheduler = {}  platform = {}  {}  deadline = {}  jobs = {}  events = {}",
        cfg.scheduler,
        cfg.platform_spec(),
        place,
        cfg.deadline.name(),
        cfg.jobs,
        if events_on { "on" } else { "off" }
    );
    t.print();
    println!("\nsweep summary (per-scenario breakdown):");
    hmai::reports::sweep_table(&sweep).print();
    write_json_report(args, sweep_json("schedule", &cfg, &sweep))?;
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config(args)?;
    let out = harness::train_flexai(&cfg)?;
    println!(
        "trained {} episodes, {} decisions, {} train steps, {} target syncs",
        cfg.train.episodes,
        out.agent.steps,
        out.agent.train_steps,
        out.agent.target_syncs
    );
    if !out.losses.is_empty() {
        let k = out.losses.len();
        let head = &out.losses[..k.min(5)];
        let tail = &out.losses[k.saturating_sub(5)..];
        println!("loss: first {head:?} ... last {tail:?}");
    }
    let mut t = Table::new(["Episode", "Tasks", "STMRate", "Wait (s)", "MS/task", "R_Balance"]);
    for (i, s) in out.episode_summaries.iter().enumerate() {
        t.row([
            (i + 1).to_string(),
            s.tasks.to_string(),
            pct(s.stm_rate()),
            f2(s.wait_s),
            f2(s.ms_per_task()),
            f2(s.r_balance),
        ]);
    }
    t.print();
    let path = std::path::Path::new(&cfg.train.checkpoint);
    hmai::sched::flexai::checkpoint::save(&out.agent, path)
        .with_context(|| format!("saving checkpoint {}", path.display()))?;
    println!("checkpoint -> {}", path.display());
    Ok(())
}

/// Brake point on a trial's own cruise clock: a library archetype walks
/// its legs at their own speeds, so the point lands in the correct leg of
/// a composite route.  Returns (probe time, area at the brake point).
fn probe_point(trial: &hmai::plan::Trial, brake_at_m: f64) -> (f64, hmai::env::Area) {
    match &trial.scenario.archetype {
        Some(arch) => arch.at_distance(trial.scenario.distance_m, brake_at_m),
        None => {
            let area = trial.scenario.area;
            (brake_at_m / area.max_velocity_ms(), area)
        }
    }
}

/// Fig. 14: a brake event at `--brake-at` meters (default: half the
/// route, so the probe always exists); the braking distance follows from
/// the probe task's wait/compute plus the measured scheduler runtime, CAN
/// latency and mechanical lag.  With `--scenario <names|all>` the probe
/// runs once per archetype and prints a per-scenario breakdown.
///
/// Each trial runs with a streaming [`BrakingProbe`] observer on the
/// engine's worker pool (`--jobs`), capturing the probe task on the fly —
/// no per-task record vector is ever retained (the old path held every
/// record of every trial until the end).
fn cmd_braking(args: &Args) -> Result<()> {
    let mut cfg = config(args)?;
    default_sched_fallback(&mut cfg, args);
    if cfg.env.distances_m.len() > 1 {
        cfg.env.distances_m.truncate(1);
    }
    let brake_at_m = args.get_f64("brake-at", cfg.env.distances_m[0] * 0.5)?;

    let reg = harness::registry(&cfg);
    let trials = cfg.plan()?.trials()?;
    anyhow::ensure!(!trials.is_empty(), "plan expanded to no trials");
    let events_on = events_effective(&cfg);
    let engine = Engine::new(&reg).jobs(cfg.jobs).events(events_on);

    println!(
        "scheduler = {}  brake point = {brake_at_m} m of {} m  events = {}",
        cfg.scheduler,
        cfg.env.distances_m[0],
        if events_on { "on" } else { "off" }
    );
    let mut t = Table::new([
        "Scenario", "Area", "v (m/s)", "T_wait (ms)", "T_sched (ms)", "T_compute (ms)",
        "T_data (ms)", "T_mech (ms)", "Total (ms)", "Braking distance (m)",
    ]);
    let mut sweep = SweepSummary::new();
    let want_json = args.get("json").is_some();
    let mut braking_rows = Vec::new();
    // One streaming probe per trial, trials on the engine's worker pool.
    let results = engine
        .run_trials_observed(&trials, |trial| BrakingProbe::new(probe_point(trial, brake_at_m).0))?;
    for (r, probe) in results {
        let trial = &r.trial;
        let (_, area) = probe_point(trial, brake_at_m);
        let v = area.max_velocity_ms();
        let rec = probe.captured().with_context(|| {
            format!(
                "trial {}: route too short for the brake point (increase --dist)",
                trial.label()
            )
        })?;
        let bd = BrakingBreakdown::new(rec.wait_s, r.sched_per_task_s(), rec.compute_s);
        let distance_m = braking_distance_m(v, &bd);
        t.row([
            trial.scenario.scenario_name(),
            area.name().to_string(),
            f1(v),
            f2(bd.t_wait * 1e3),
            f2(bd.t_schedule * 1e3),
            f2(bd.t_compute * 1e3),
            f2(bd.t_data * 1e3),
            f2(bd.t_mech * 1e3),
            f2(bd.total() * 1e3),
            f2(distance_m),
        ]);
        if want_json {
            braking_rows.push(Json::from_pairs(vec![
                ("scenario", Json::Str(trial.scenario.scenario_name())),
                ("area", Json::Str(area.name().to_string())),
                ("v_ms", Json::Num(v)),
                ("t_wait_s", Json::Num(bd.t_wait)),
                ("t_schedule_s", Json::Num(bd.t_schedule)),
                ("t_compute_s", Json::Num(bd.t_compute)),
                ("t_data_s", Json::Num(bd.t_data)),
                ("t_mech_s", Json::Num(bd.t_mech)),
                ("total_s", Json::Num(bd.total())),
                ("braking_distance_m", Json::Num(distance_m)),
            ]));
        }
        sweep.push(r.sweep_key(), r.summary);
    }
    t.print();
    if want_json {
        let mut report = sweep_json("braking", &cfg, &sweep);
        if let Json::Obj(o) = &mut report {
            o.insert("braking", Json::Arr(braking_rows));
        }
        write_json_report(args, report)?;
    }
    Ok(())
}

/// `hmai dse`: design-space exploration over heterogeneous
/// (kind × core-size × count) platform mixes under an area/power budget —
/// enumerated or beam-searched, each candidate evaluated on the engine
/// over a scenario slice, reported as the Pareto frontier of
/// deadline-met % vs energy vs area (★ rows).
///
///     hmai dse --budget 12 --scenario urban-rush --json BENCH_DSE.json
///
/// Defaults: budget 12 area units, urban-rush, one 150 m queue, Min-Min
/// (deterministic and runtime-free; pass --sched to override).
fn cmd_dse(args: &Args) -> Result<()> {
    let cfg = config(args)?;
    let scheduler = match args.get("sched") {
        Some(_) => cfg.scheduler_spec()?,
        None => hmai::sched::SchedulerSpec::MinMin,
    };
    let defaults = hmai::dse::DseConfig::default();
    let dse_cfg = hmai::dse::DseConfig {
        budget_area: args.get_f64("budget", defaults.budget_area)?,
        power_cap_w: match args.get("power-cap") {
            Some(_) => Some(args.get_f64("power-cap", 0.0)?),
            None => None,
        },
        scenarios: if cfg.scenarios.is_empty() {
            defaults.scenarios.clone()
        } else {
            cfg.scenarios.clone()
        },
        // Honor any user-chosen distances — `--dist`/`--distance` flags or a
        // `--config` file's `distances_m` — and fall back to the short DSE
        // default route only when the config still has the paper's eval
        // distances (a DSE over five 1-2 km routes per candidate would be
        // needlessly heavy to merely rank mixes).
        distances_m: if cfg.env.distances_m != hmai::config::EnvConfig::default().distances_m {
            cfg.env.distances_m.clone()
        } else {
            defaults.distances_m.clone()
        },
        deadline: cfg.deadline,
        scheduler,
        seed: cfg.env.seed,
        jobs: cfg.jobs,
        max_evals: args.get_usize("max-evals", defaults.max_evals)?,
        beam: args.get_usize("beam", defaults.beam)?.max(1),
        search: hmai::dse::SearchMode::parse(args.get_or("search", "auto"))?,
        // `--topology mesh2x2,ring4`: chiplet topologies searched alongside
        // the implicit monolithic candidate (activates the reticle cap).
        topologies: args
            .get("topology")
            .map(|t| {
                t.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
            })
            .unwrap_or_default(),
        // `--fidelity multi` (default): bound pruning + successive-halving
        // screening; `--fidelity exact` reproduces the pre-fidelity
        // evaluator bit-for-bit.
        fidelity: hmai::dse::FidelityMode::parse(args.get_or("fidelity", "multi"))?,
        rungs: args.get_usize("rungs", defaults.rungs)?,
        keep_frac: args.get_f64("keep-frac", defaults.keep_frac)?,
        replicates: args.get_usize("replicates", defaults.replicates)?.max(1),
    };
    let reg = harness::registry(&cfg);
    let report = hmai::dse::run(&dse_cfg, &reg)?;
    println!(
        "dse: budget = {} area units{}  search = {}  fidelity = {}  scheduler = {}  \
         scenarios = {}  topologies = {}  evaluated = {} candidates ({} not searched)  \
         frontier = {} (★)",
        dse_cfg.budget_area,
        dse_cfg.power_cap_w.map(|c| format!(" (power cap {c} W)")).unwrap_or_default(),
        report.search,
        report.fidelity,
        dse_cfg.scheduler.display(),
        dse_cfg.scenarios.join(","),
        report.topologies.join(","),
        report.evaluated,
        report.truncated,
        report.frontier,
    );
    if report.fidelity == "multi" {
        println!(
            "dse pipeline: pool = {}  pruned = {} (analytic bounds)  screened out = {}  \
             promoted = {}  low-fidelity evals = {}",
            report.pool,
            report.pruned(),
            report.screened_out,
            report.promoted,
            report.low_fidelity_evals,
        );
        hmai::reports::dse_pipeline_table(&report).print();
        println!();
    }
    hmai::reports::dse_table(&report).print();
    let hmai_spec = hmai::dse::Mix::hmai_std().spec();
    if let Some(r) = report.find(&hmai_spec) {
        println!(
            "\nHMAI(4,4,3)@Std: {} the frontier (STMRate {:.1}%, {:.1} J, area {:.2})",
            if r.on_frontier { "ON" } else { "behind" },
            r.stm_rate * 100.0,
            r.energy_j,
            r.area
        );
    }
    let json = Json::from_pairs(vec![
        ("command", Json::Str("dse".to_string())),
        ("scheduler", Json::Str(dse_cfg.scheduler.canonical().to_string())),
        (
            "scenarios",
            Json::Arr(dse_cfg.scenarios.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        ("distances_m", Json::array_f64(&dse_cfg.distances_m)),
        ("seed", Json::Num(dse_cfg.seed as f64)),
        ("dse", report.to_json()),
    ]);
    write_json_report(args, json)?;
    Ok(())
}

/// Aggregate survival counters of a sweep, across every group: overall
/// STM, safety-tier STM (1.0 when the plan produced no safety-critical
/// tasks — nothing was at risk), lost tasks, and panicked trials.
struct Survival {
    stm: f64,
    safety_stm: f64,
    lost: u64,
    failed: u64,
}

fn survival(sweep: &SweepSummary) -> Survival {
    let (mut tasks, mut met, mut st, mut sm, mut lost, mut failed) = (0u64, 0, 0, 0, 0, 0);
    for g in &sweep.groups {
        tasks += g.stats.sum_tasks;
        met += g.stats.sum_tasks_met;
        st += g.stats.sum_safety_tasks;
        sm += g.stats.sum_safety_met;
        lost += g.stats.sum_lost_tasks;
        failed += g.stats.failed_trials;
    }
    Survival {
        stm: if tasks == 0 { 0.0 } else { met as f64 / tasks as f64 },
        safety_stm: if st == 0 { 1.0 } else { sm as f64 / st as f64 },
        lost,
        failed,
    }
}

/// `hmai faults`: a seeded MTBF/MTTR fault-injection campaign run twice —
/// graceful degradation off, then on — over *identical* fault timelines
/// (both arms draw every outage from `trial.seed`, so the comparison
/// isolates the degradation policy), reporting overall and safety-tier
/// STM, lost tasks, and panicked trials per arm.
///
///     hmai faults --platform hmai+mesh2x2 --json BENCH_FAULTS.json
///
/// Defaults: Min-Min (deterministic, runtime-free; pass --sched to
/// override), one 300 m urban route, 6 seed replicates.  `--mtbf/--mttr`
/// shape accelerator faults, `--link-mtbf/--link-mttr` link faults
/// (chiplet platforms only — monolithic platforms have no links).
fn cmd_faults(args: &Args) -> Result<()> {
    let mut cfg = config(args)?;
    if args.get("sched").is_none() {
        cfg.scheduler = "minmin".into();
    }
    if args.get("dist").is_none() && args.get("distance").is_none() {
        cfg.env.distances_m = vec![300.0];
    }
    if args.get("replicates").is_none() {
        cfg.replicates = 6;
    }
    let d = FaultModel::default();
    let model = FaultModel {
        accel_mtbf_s: args.get_f64("mtbf", d.accel_mtbf_s)?,
        accel_mttr_s: args.get_f64("mttr", d.accel_mttr_s)?,
        link_mtbf_s: args.get_f64("link-mtbf", d.link_mtbf_s)?,
        link_mttr_s: args.get_f64("link-mttr", d.link_mttr_s)?,
    };
    let reg = harness::registry(&cfg);
    let plan = cfg.plan()?;
    let events_on = events_effective(&cfg);
    let arm = |degrade: bool| -> Result<SweepSummary> {
        Engine::new(&reg)
            .jobs(cfg.jobs)
            .events(events_on)
            .faults(Some(model))
            .degrade(degrade)
            .sweep_streaming(&plan)
    };
    let off = arm(false)?;
    let on = arm(true)?;

    println!(
        "fault campaign: scheduler = {}  platform = {}  {} trial(s)/arm  \
         accel MTBF/MTTR = {}/{} s  link MTBF/MTTR = {}/{} s",
        cfg.scheduler,
        cfg.platform_spec(),
        off.total_runs(),
        model.accel_mtbf_s,
        model.accel_mttr_s,
        model.link_mtbf_s,
        model.link_mttr_s,
    );
    let mut t = Table::new(["Arm", "STMRate", "Safety STM", "Lost", "Panicked"]);
    for (name, sweep) in [("degrade off", &off), ("degrade on", &on)] {
        let s = survival(sweep);
        t.row([
            name.to_string(),
            pct(s.stm),
            pct(s.safety_stm),
            s.lost.to_string(),
            s.failed.to_string(),
        ]);
    }
    t.print();
    println!("\nper-group breakdown (degrade on):");
    hmai::reports::sweep_table(&on).print();

    let arm_json = |sweep: &SweepSummary| {
        let s = survival(sweep);
        Json::from_pairs(vec![
            ("stm_rate", Json::Num(s.stm)),
            ("safety_stm_rate", Json::Num(s.safety_stm)),
            ("lost_tasks", Json::Num(s.lost as f64)),
            ("failed_trials", Json::Num(s.failed as f64)),
            ("fingerprint", Json::Str(format!("{:016x}", sweep.fingerprint()))),
            ("sweep", sweep.to_json()),
        ])
    };
    write_json_report(
        args,
        Json::from_pairs(vec![
            ("command", Json::Str("faults".to_string())),
            (
                "model",
                Json::from_pairs(vec![
                    ("accel_mtbf_s", Json::Num(model.accel_mtbf_s)),
                    ("accel_mttr_s", Json::Num(model.accel_mttr_s)),
                    ("link_mtbf_s", Json::Num(model.link_mtbf_s)),
                    ("link_mttr_s", Json::Num(model.link_mttr_s)),
                ]),
            ),
            ("config", cfg.to_json()),
            ("degrade_off", arm_json(&off)),
            ("degrade_on", arm_json(&on)),
        ]),
    )?;
    Ok(())
}

/// `hmai fleet <plan|work|merge>`: sharded, checkpoint-resumable sweeps.
///
///     hmai fleet plan --sched rr,minmin --replicates 100 --shards 3 --out plan.json
///     hmai fleet work --plan plan.json --shard 0        # once per shard, resumable
///     hmai fleet merge --plan plan.json --json merged.json
///
/// The merged report is fingerprint-identical to a single-process
/// `sweep_streaming` over the same plan — for any shard count, including
/// after killing and resuming workers (see DESIGN.md "Fleet sweeps").
fn cmd_fleet(args: &Args) -> Result<()> {
    match args.rest().first().map(String::as_str) {
        Some("plan") => cmd_fleet_plan(args),
        Some("work") => cmd_fleet_work(args),
        Some("merge") => cmd_fleet_merge(args),
        _ => anyhow::bail!("usage: hmai fleet <plan|work|merge> (see `hmai help`)"),
    }
}

/// Default shard-checkpoint path: a sibling of the plan file.
fn shard_path(plan_path: &std::path::Path, shard: usize) -> std::path::PathBuf {
    let name = format!("fleet_shard_{shard}.json");
    match plan_path.parent().filter(|d| !d.as_os_str().is_empty()) {
        Some(d) => d.join(name),
        None => std::path::PathBuf::from(name),
    }
}

fn cmd_fleet_plan(args: &Args) -> Result<()> {
    let mut cfg = config(args)?;
    default_sched_fallback(&mut cfg, args);
    let shards = args.get_usize("shards", 1)?;
    let plan = FleetPlan::from_config(&cfg, shards)?;
    let resolved = plan.resolve()?;
    let out = std::path::PathBuf::from(args.get_or("out", "fleet_plan.json"));
    plan.save(&out, &resolved)?;
    println!(
        "fleet plan: {} trials, plan_hash {:016x}, {} shard(s) -> {}",
        resolved.trials.len(),
        resolved.plan_hash,
        resolved.shards.len(),
        out.display()
    );
    let mut t = Table::new(["Shard", "Trials", "Range", "Checkpoint"]);
    for s in &resolved.shards {
        t.row([
            s.shard.to_string(),
            s.len().to_string(),
            format!("{}..{}", s.lo, s.hi),
            shard_path(&out, s.shard).display().to_string(),
        ]);
    }
    t.print();
    println!("\nnext: `hmai fleet work --plan {} --shard <k>` for each shard", out.display());
    Ok(())
}

fn cmd_fleet_work(args: &Args) -> Result<()> {
    let cfg = config(args)?;
    let plan_path = std::path::PathBuf::from(args.get_or("plan", "fleet_plan.json"));
    let (plan, resolved) = FleetPlan::load(&plan_path)?;
    let shard = args.get_usize("shard", 0)?;
    let out = args
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| shard_path(&plan_path, shard));
    let opts = WorkOptions {
        jobs: cfg.jobs,
        checkpoint_every: args.get_usize("checkpoint-every", 500)?,
        max_trials: match args.get("max-trials") {
            Some(_) => Some(args.get_usize("max-trials", 0)?),
            None => None,
        },
    };
    let reg = harness::registry(&cfg);
    let ckpt = fleet::run_shard(&reg, &plan, &resolved, shard, &out, opts)?;
    println!(
        "fleet work: shard {} folded {}/{} trials ({}), fingerprint {:016x} -> {}",
        shard,
        ckpt.next_trial - ckpt.spec.lo,
        ckpt.spec.len(),
        if ckpt.complete() { "complete" } else { "paused — rerun to resume" },
        ckpt.summary.fingerprint(),
        out.display()
    );
    Ok(())
}

fn cmd_fleet_merge(args: &Args) -> Result<()> {
    let plan_path = std::path::PathBuf::from(args.get_or("plan", "fleet_plan.json"));
    let (plan, resolved) = FleetPlan::load(&plan_path)?;
    // Shard files: positionals after `merge`, or the default sibling paths.
    let files: Vec<std::path::PathBuf> = if args.rest().len() > 1 {
        args.rest()[1..].iter().map(std::path::PathBuf::from).collect()
    } else {
        (0..plan.shards).map(|k| shard_path(&plan_path, k)).collect()
    };
    let parts = files
        .iter()
        .map(|p| ShardCheckpoint::load(p))
        .collect::<Result<Vec<_>>>()?;
    let merged = fleet::merge_checkpoints(&resolved, &parts)?;
    println!(
        "fleet merge: {} shard(s), {} trials, fingerprint {:016x}",
        parts.len(),
        merged.total_runs(),
        merged.fingerprint()
    );
    hmai::reports::sweep_table(&merged).print();
    write_json_report(
        args,
        Json::from_pairs(vec![
            ("command", Json::Str("fleet merge".to_string())),
            ("fingerprint", Json::Str(format!("{:016x}", merged.fingerprint()))),
            ("plan_hash", Json::Str(format!("{:016x}", resolved.plan_hash))),
            ("trials", Json::Num(merged.total_runs() as f64)),
            ("sweep", merged.to_json()),
        ]),
    )?;
    Ok(())
}

/// `hmai lint [--json <path>] [--root <dir>] [--rules]`: determinism &
/// panic-safety static analysis over the crate's own source (see
/// DESIGN.md "Determinism invariants & static analysis").  Exits
/// non-zero on any violation; `--json` writes the full report first, so
/// CI always gets the artifact even on a failing run.
fn cmd_lint(args: &Args) -> Result<()> {
    if args.flag("rules") {
        let mut t = Table::new(["Rule", "Scope", "Hazard"]);
        for r in hmai::lint::rules::RULES {
            t.row([r.name.to_string(), r.scope.describe(), r.hazard.to_string()]);
        }
        t.print();
        return Ok(());
    }
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => ["src", "rust/src"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_dir())
            .ok_or_else(|| {
                anyhow::anyhow!("no src/ or rust/src/ under the current directory — pass --root <dir>")
            })?,
    };
    let report = hmai::lint::lint_dir(&root)?;
    print!("{}", report.render());
    if let Some(path) = args.get("json") {
        report
            .to_json()
            .write_to(std::path::Path::new(path))
            .with_context(|| format!("writing --json {path}"))?;
        println!("json -> {path}");
    }
    if !report.violations.is_empty() {
        anyhow::bail!(
            "{} lint violation(s) — fix, or justify with `// lint:allow(<rule>): <reason>`",
            report.violations.len()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use hmai::sched::SchedulerSpec;
    use hmai::sim::TaskRecord;

    #[test]
    fn usage_mentions_every_subcommand() {
        let u = usage();
        for cmd in [
            "report", "env", "platform", "schedule", "train", "braking", "dse", "faults",
            "fleet", "lint",
        ] {
            assert!(u.contains(cmd), "{cmd} missing from usage");
        }
        assert!(u.contains("fleet plan|work|merge"), "fleet actions missing from usage");
        for opt in [
            "--budget", "--power-cap", "--topology", "--search", "--beam", "--max-evals",
            "--fidelity", "--rungs", "--keep-frac",
        ] {
            assert!(u.contains(opt), "{opt} missing from usage");
        }
        for opt in ["--replicates", "--shards", "--plan", "--shard", "--checkpoint-every", "--max-trials"]
        {
            assert!(u.contains(opt), "{opt} missing from usage");
        }
        for opt in ["--root", "--rules"] {
            assert!(u.contains(opt), "{opt} missing from usage");
        }
        for opt in ["--mtbf", "--mttr", "--link-mtbf", "--link-mttr"] {
            assert!(u.contains(opt), "{opt} missing from usage");
        }
    }

    #[test]
    fn lint_rules_table_prints_every_rule() {
        // `hmai lint --rules` is the discoverability contract for the
        // rule set (the scan itself is exercised by tests/lint.rs).
        cmd_lint(&Args::parse(["lint", "--rules"].iter().map(|s| s.to_string()))).unwrap();
        for r in hmai::lint::rules::RULES {
            assert!(hmai::lint::rules::by_name(r.name).is_some());
        }
    }

    #[test]
    fn usage_lists_every_canonical_scheduler() {
        let u = usage();
        for info in hmai::sched::SCHEDULERS {
            assert!(u.contains(info.canonical), "{} missing from usage", info.canonical);
        }
        assert!(u.contains("--jobs"), "--jobs missing from usage");
    }

    #[test]
    fn usage_lists_every_scenario_archetype() {
        let u = usage();
        assert!(u.contains("--scenario"), "--scenario missing from usage");
        for name in hmai::env::scenario::names() {
            assert!(u.contains(&name), "{name} missing from usage");
        }
    }

    #[test]
    fn scenario_schedule_runs_through_engine() {
        // A miniature `hmai schedule --scenario all --distance 50`.
        let args = Args::parse(
            ["schedule", "--sched", "rr", "--scenario", "all", "--distance", "50"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = config(&args).unwrap();
        let reg = harness::registry(&cfg);
        let (results, sweep) = Engine::new(&reg)
            .jobs(2)
            .sweep(&cfg.plan().unwrap())
            .unwrap();
        let n = hmai::env::scenario::names().len();
        assert_eq!(results.len(), n);
        assert_eq!(sweep.groups.len(), n, "one sweep row per archetype");
    }

    #[test]
    fn config_from_flags() {
        let args = Args::parse(
            ["schedule", "--sched", "minmin", "--area", "hw"].iter().map(|s| s.to_string()),
        );
        let cfg = config(&args).unwrap();
        assert_eq!(cfg.scheduler, "minmin");
        assert_eq!(cfg.env.area, hmai::env::Area::Highway);
        assert_eq!(cfg.scheduler_spec().unwrap(), SchedulerSpec::MinMin);
    }

    #[test]
    fn schedule_plan_runs_through_engine() {
        // A miniature `hmai schedule` end-to-end (baseline scheduler).
        let args = Args::parse(
            ["schedule", "--sched", "rr", "--dist", "40", "--seed", "3"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = config(&args).unwrap();
        let reg = harness::registry(&cfg);
        let (results, sweep) = Engine::new(&reg)
            .jobs(cfg.jobs)
            .sweep(&cfg.plan().unwrap())
            .unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(sweep.total_runs(), 1);
        assert_eq!(sweep.groups[0].key.scheduler, "RoundRobin");
    }

    #[test]
    fn probe_finds_first_detection_after_t() {
        let mk = |id: u32, rel: f64, model: hmai::workload::ModelKind| TaskRecord {
            task_id: id,
            model,
            accel: 0,
            release_s: rel,
            start_s: rel,
            finish_s: rel + 0.01,
            wait_s: 0.0,
            compute_s: 0.01,
            response_s: 0.01,
            energy_j: 0.1,
            ms: 0.5,
            safety_time_s: 0.1,
            met_deadline: true,
        };
        use hmai::workload::ModelKind::*;
        let recs = vec![mk(0, 1.0, Yolo), mk(1, 2.0, Goturn), mk(2, 2.5, Ssd), mk(3, 3.0, Yolo)];
        assert_eq!(hmai::sim::first_detection_after(&recs, 2.0).unwrap().task_id, 2);
        assert!(hmai::sim::first_detection_after(&recs, 10.0).is_none());
    }

    #[test]
    fn usage_lists_events_and_json_flags() {
        let u = usage();
        assert!(u.contains("--events"), "--events missing from usage");
        assert!(u.contains("--json"), "--json missing from usage");
        assert!(u.contains("env [list]"), "env list missing from usage");
    }

    #[test]
    fn env_list_renders_every_archetype_with_its_events() {
        // The discoverability contract: `env list` must enumerate every
        // registered archetype, and fault archetypes must show their
        // events inline.
        let mut t = Table::new(["Scenario", "Events"]);
        for arch in scenario::library() {
            let events = if arch.events.is_empty() {
                "-".to_string()
            } else {
                arch.events
                    .iter()
                    .map(|e| e.action.describe())
                    .collect::<Vec<_>>()
                    .join(", ")
            };
            t.row([arch.name.clone(), events]);
        }
        let rendered = t.render();
        for name in scenario::names() {
            assert!(rendered.contains(&name), "{name} missing");
        }
        assert!(rendered.contains("fail a0"), "accel-failure events missing");
        assert!(rendered.contains("derate a4"), "thermal-throttle events missing");
        // The real command runs end to end.
        cmd_env_list().unwrap();
    }

    #[test]
    fn events_effective_is_truthful() {
        let mut c = ExperimentConfig::default();
        assert!(!events_effective(&c), "off by default");
        c.events = true;
        assert!(!events_effective(&c), "no scenarios -> nothing can fire");
        c.scenarios = vec!["night-rain".into()];
        assert!(!events_effective(&c), "night-rain declares no platform events");
        c.scenarios = vec!["night-rain".into(), "accel-failure".into()];
        assert!(events_effective(&c), "accel-failure declares events");
    }

    #[test]
    fn dse_cli_runs_a_tiny_exploration() {
        // A miniature `hmai dse --budget 1.8 --dist 40 --search greedy`.
        let args = Args::parse(
            [
                "dse", "--budget", "1.8", "--dist", "40", "--search", "greedy", "--beam", "1",
                "--max-evals", "12", "--scenario", "urban-rush",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        cmd_dse(&args).unwrap();
        // And the bad-spec path explains itself through the engine.
        let cfg = {
            let a = Args::parse(["schedule", "--platform", "4,x,3"].iter().map(|s| s.to_string()));
            config(&a)
        }
        .unwrap();
        let err = cfg.platform().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("component 2"), "{msg}");
    }

    #[test]
    fn faults_cli_runs_both_arms_and_reports_survival() {
        // A miniature `hmai faults --sched rr --dist 40 --replicates 2`,
        // with the JSON report parsed back: both arms present, the model
        // echoed, and every survival field a finite number.
        let dir = std::env::temp_dir().join(format!("hmai_faults_cli_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("faults.json");
        let args = Args::parse(
            [
                "faults", "--sched", "rr", "--dist", "40", "--replicates", "2", "--seed", "7",
                "--json", out.to_str().unwrap(),
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        cmd_faults(&args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get_str("command").unwrap(), "faults");
        let model = j.get("model").unwrap();
        assert_eq!(model.get_f64("accel_mtbf_s").unwrap(), 30.0);
        for arm in ["degrade_off", "degrade_on"] {
            let a = j.get(arm).unwrap();
            for k in ["stm_rate", "safety_stm_rate", "lost_tasks", "failed_trials"] {
                let v = a.get_f64(k).unwrap();
                assert!(v.is_finite() && v >= 0.0, "{arm}.{k} = {v}");
            }
            assert!(a.get_f64("safety_stm_rate").unwrap() <= 1.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fleet_cli_plan_work_merge_roundtrip() {
        // A miniature `hmai fleet plan` → `work` ×2 → `merge`, verifying
        // the merged fingerprint equals a monolithic sweep_streaming run.
        let dir = std::env::temp_dir().join(format!("hmai_fleet_cli_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let plan_file = dir.join("plan.json");
        let argv = |toks: &[&str]| Args::parse(toks.iter().map(|s| s.to_string()));
        cmd_fleet(&argv(&[
            "fleet", "plan", "--sched", "rr,minmin", "--dist", "40,60", "--replicates", "2",
            "--shards", "2", "--seed", "5", "--out", plan_file.to_str().unwrap(),
        ]))
        .unwrap();
        for k in ["0", "1"] {
            cmd_fleet(&argv(&[
                "fleet", "work", "--plan", plan_file.to_str().unwrap(), "--shard", k,
                "--checkpoint-every", "2",
            ]))
            .unwrap();
        }
        let merged_file = dir.join("merged.json");
        cmd_fleet(&argv(&[
            "fleet", "merge", "--plan", plan_file.to_str().unwrap(), "--json",
            merged_file.to_str().unwrap(),
        ]))
        .unwrap();
        let merged = Json::parse(&std::fs::read_to_string(&merged_file).unwrap()).unwrap();
        // Monolithic reference over the same plan.
        let (plan, _) = FleetPlan::load(&plan_file).unwrap();
        let reg = harness::registry(&ExperimentConfig::default());
        let mono = Engine::new(&reg)
            .events(plan.events)
            .sweep_streaming(&plan.experiment_plan().unwrap())
            .unwrap();
        assert_eq!(
            merged.get_str("fingerprint").unwrap(),
            format!("{:016x}", mono.fingerprint()),
            "fleet merge drifted from the monolithic sweep"
        );
        assert_eq!(merged.get_f64("trials").unwrap() as usize, mono.total_runs());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn braking_probe_path_matches_record_scan() {
        // The streaming braking probe must select the same task the old
        // record-retaining path did.
        let args = Args::parse(
            ["braking", "--sched", "rr", "--dist", "60", "--seed", "3"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = config(&args).unwrap();
        let reg = harness::registry(&cfg);
        let trials = cfg.plan().unwrap().trials().unwrap();
        let trial = &trials[0];
        let t_probe = 30.0 / trial.scenario.area.max_velocity_ms();
        let mut probe = BrakingProbe::new(t_probe);
        let r = Engine::new(&reg).run_trial_observed(trial, &mut [&mut probe]).unwrap();
        assert!(r.records.is_empty());
        let rec = probe.captured().expect("probe found");
        let full = Engine::new(&reg)
            .sim_options(hmai::sim::SimOptions { record_tasks: true })
            .run_trial(trial)
            .unwrap();
        let want = hmai::sim::first_detection_after(&full.records, t_probe).unwrap();
        assert_eq!(rec.task_id, want.task_id);
        assert_eq!(rec.compute_s.to_bits(), want.compute_s.to_bits());
    }
}
