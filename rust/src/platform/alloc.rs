//! Static task allocation onto a platform (§3.1, Table 9, Fig. 2): split
//! each sub-accelerator pool among the three CNN models so every model's
//! FPS requirement is met, and score allocations by resource utilization
//! and energy.  The exhaustive search over partitions is what "the best
//! method on each heterogeneous platform" means in Fig. 2.

use crate::accel::{cost, AccelKind, ALL_ACCELS};
use crate::env::camera_hz::model_fps_requirement;
use crate::env::{Area, Scenario};
use crate::workload::{ModelKind, ALL_MODELS};

/// `alloc[kind][model]` = number of accelerators of `kind` serving `model`.
/// Unallocated units idle.
pub type Allocation = [[usize; 3]; 3];

/// FPS requirement per model for one (area, scenario).
pub fn requirements(area: Area, scenario: Scenario) -> [f64; 3] {
    let mut r = [0.0; 3];
    for m in ALL_MODELS {
        r[m.index()] = model_fps_requirement(area, scenario, m);
    }
    r
}

/// Aggregate FPS capacity an allocation provides for `model`.
pub fn capacity(alloc: &Allocation, model: ModelKind) -> f64 {
    ALL_ACCELS
        .iter()
        .map(|k| alloc[k.index()][model.index()] as f64 * cost(*k, model).fps())
        .sum()
}

/// Does the allocation meet every model's requirement?
pub fn feasible(alloc: &Allocation, reqs: &[f64; 3]) -> bool {
    ALL_MODELS.iter().all(|m| capacity(alloc, *m) >= reqs[m.index()] - 1e-9)
}

/// Number of accelerators the allocation uses.
pub fn units_used(alloc: &Allocation) -> usize {
    alloc.iter().map(|row| row.iter().sum::<usize>()).sum()
}

/// Resource utilization rate (Fig. 2b): mean busy fraction over *all* units
/// of the platform — units serving model `m` are busy `req_m / capacity_m`
/// of the time, unallocated units are idle.
pub fn utilization(alloc: &Allocation, reqs: &[f64; 3], total_units: usize) -> f64 {
    if total_units == 0 {
        return 0.0;
    }
    let mut busy_units = 0.0;
    for m in ALL_MODELS {
        let cap = capacity(alloc, m);
        if cap <= 0.0 {
            continue;
        }
        let busy = (reqs[m.index()] / cap).min(1.0);
        let units: usize = ALL_ACCELS.iter().map(|k| alloc[k.index()][m.index()]).sum();
        busy_units += busy * units as f64;
    }
    busy_units / total_units as f64
}

/// Average power (W) of running the scenario's steady-state load on the
/// allocation (Fig. 2a's energy axis): each model's task flow is split
/// across its units proportionally to their FPS share; provisioned units
/// burn `idle_power_w` for their idle fraction (unallocated units idle
/// 100% of the time).  Pass the full platform `counts` so unallocated
/// units are charged.
pub fn power_w_provisioned(
    alloc: &Allocation,
    reqs: &[f64; 3],
    counts: (usize, usize, usize),
) -> f64 {
    let mut w = 0.0;
    let mut allocated = [0usize; 3]; // per kind
    for m in ALL_MODELS {
        let cap = capacity(alloc, m);
        if cap <= 0.0 {
            continue;
        }
        let busy = (reqs[m.index()] / cap).min(1.0);
        for k in ALL_ACCELS {
            let n = alloc[k.index()][m.index()];
            if n == 0 {
                continue;
            }
            allocated[k.index()] += n;
            let c = cost(k, m);
            let share = n as f64 * c.fps() / cap;
            // Dynamic: tasks/second routed here × energy per task.
            w += reqs[m.index()] * share * c.energy_j;
            // Idle fraction of the allocated units.
            w += n as f64 * (1.0 - busy) * crate::accel::energy::idle_power_w(k);
        }
    }
    // Fully-idle provisioned units.
    let totals = [counts.0, counts.1, counts.2];
    for k in ALL_ACCELS {
        let spare = totals[k.index()].saturating_sub(allocated[k.index()]);
        w += spare as f64 * crate::accel::energy::idle_power_w(k);
    }
    w
}

/// Dynamic-only power of an allocation (no provisioning/idle charge).
pub fn power_w(alloc: &Allocation, reqs: &[f64; 3]) -> f64 {
    let mut w = 0.0;
    for m in ALL_MODELS {
        let cap = capacity(alloc, m);
        if cap <= 0.0 {
            continue;
        }
        for k in ALL_ACCELS {
            let c = cost(k, m);
            let share = alloc[k.index()][m.index()] as f64 * c.fps() / cap;
            // Tasks/second routed here × energy per task.
            w += reqs[m.index()] * share * c.energy_j;
        }
    }
    w
}

/// Enumerate all splits of `n` units among (YOLO, SSD, GOTURN, idle).
fn partitions(n: usize) -> Vec<[usize; 3]> {
    let mut out = Vec::new();
    for y in 0..=n {
        for s in 0..=(n - y) {
            for g in 0..=(n - y - s) {
                out.push([y, s, g]);
            }
        }
    }
    out
}

/// Best feasible allocation of a `(so, si, mm)` platform for one scenario:
/// maximize utilization, tie-break on lower power.  Returns `None` when the
/// platform cannot meet the requirements at all.
pub fn best_allocation(
    counts: (usize, usize, usize),
    reqs: &[f64; 3],
) -> Option<(Allocation, f64)> {
    let total = counts.0 + counts.1 + counts.2;
    let (ps_so, ps_si, ps_mm) =
        (partitions(counts.0), partitions(counts.1), partitions(counts.2));
    let mut best: Option<(Allocation, f64, f64)> = None;
    for so in &ps_so {
        for si in &ps_si {
            for mm in &ps_mm {
                let alloc: Allocation = [*so, *si, *mm];
                if !feasible(&alloc, reqs) {
                    continue;
                }
                let u = utilization(&alloc, reqs, total);
                let p = power_w_provisioned(&alloc, reqs, counts);
                let better = match &best {
                    None => true,
                    Some((_, bu, bp)) => u > *bu + 1e-12 || (u > *bu - 1e-12 && p < *bp),
                };
                if better {
                    best = Some((alloc, u, p));
                }
            }
        }
    }
    best.map(|(a, u, _)| (a, u))
}

/// The paper's Table 9 allocations on (4 SO, 4 SI, 3 MM) for urban areas.
pub fn table9(scenario: Scenario) -> Allocation {
    // rows: [SconvOD, SconvIC, MconvMC]; cols: [YOLO, SSD, GOTURN]
    match scenario {
        Scenario::GoStraight => [[1, 3, 0], [2, 1, 1], [0, 2, 1]],
        Scenario::Turn => [[2, 2, 0], [0, 4, 0], [1, 0, 2]],
        Scenario::Reverse => [[0, 2, 2], [3, 0, 1], [0, 3, 0]],
    }
}

/// Accelerators of one kind needed per model for a homogeneous platform
/// (§3.1's "3 SconvOD, 6 SconvOD, and 3 SconvOD" analysis).
pub fn homogeneous_counts(kind: AccelKind, area: Area, scenario: Scenario) -> [usize; 3] {
    let reqs = requirements(area, scenario);
    let mut out = [0; 3];
    for m in ALL_MODELS {
        out[m.index()] = (reqs[m.index()] / cost(kind, m).fps()).ceil() as usize;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const UB: Area = Area::Urban;

    #[test]
    fn requirements_match_table5() {
        let r = requirements(UB, Scenario::GoStraight);
        assert!((r[ModelKind::Yolo.index()] - 435.0).abs() < 1.0);
        assert!((r[ModelKind::Ssd.index()] - 435.0).abs() < 1.0);
        assert!((r[ModelKind::Goturn.index()] - 840.0).abs() < 1.0);
        let rv = requirements(UB, Scenario::Reverse);
        assert!((rv[ModelKind::Yolo.index()] - 370.0).abs() < 1.0);
        assert!((rv[ModelKind::Goturn.index()] - 740.0).abs() < 1.0);
    }

    #[test]
    fn paper_3_1_homogeneous_sconvod() {
        // §3.1: going straight in UB: 3 SO for YOLO, 6 for SSD, 3 for
        // GOTURN -> 12 total.
        let c = homogeneous_counts(AccelKind::SconvOD, UB, Scenario::GoStraight);
        assert_eq!(c, [3, 6, 3]);
    }

    #[test]
    fn table9_allocations_are_feasible_and_tight() {
        for s in crate::env::ALL_SCENARIOS {
            let alloc = table9(s);
            let reqs = requirements(UB, s);
            assert!(feasible(&alloc, &reqs), "{s:?} infeasible");
            let u = utilization(&alloc, &reqs, 11);
            // Fig. 2b: 96.86 / 95.81 / 85.40 % — our model lands nearby.
            assert!(u > 0.80, "{s:?} util {u}");
        }
    }

    #[test]
    fn search_beats_or_matches_table9_utilization() {
        for s in crate::env::ALL_SCENARIOS {
            let reqs = requirements(UB, s);
            let (_, u) = best_allocation((4, 4, 3), &reqs).expect("feasible");
            let u9 = utilization(&table9(s), &reqs, 11);
            assert!(u >= u9 - 1e-9, "{s:?}: search {u} < table9 {u9}");
        }
    }

    #[test]
    fn infeasible_platform_returns_none() {
        let reqs = requirements(UB, Scenario::GoStraight);
        assert!(best_allocation((1, 0, 0), &reqs).is_none());
    }

    #[test]
    fn fig2_hmai_beats_homogeneous_on_power_and_utilization() {
        // Fig. 2: HMAI's provisioned power is below every homogeneous
        // platform and its utilization above, in every UB scenario.
        let homo = [(13, 0, 0), (0, 13, 0), (0, 0, 12)];
        for s in crate::env::ALL_SCENARIOS {
            let reqs = requirements(UB, s);
            let (ha, hu) = best_allocation((4, 4, 3), &reqs).unwrap();
            let hp = power_w_provisioned(&ha, &reqs, (4, 4, 3));
            for counts in homo {
                let (a, u) = best_allocation(counts, &reqs)
                    .unwrap_or_else(|| panic!("{counts:?} infeasible in {s:?}"));
                let p = power_w_provisioned(&a, &reqs, counts);
                assert!(hp < p, "{s:?} {counts:?}: HMAI {hp} W !< homo {p} W");
                assert!(hu > u, "{s:?} {counts:?}: HMAI {hu} !> homo {u}");
            }
        }
    }

    #[test]
    fn provisioned_power_exceeds_dynamic() {
        let reqs = requirements(UB, Scenario::GoStraight);
        let (a, _) = best_allocation((4, 4, 3), &reqs).unwrap();
        assert!(power_w_provisioned(&a, &reqs, (4, 4, 3)) > power_w(&a, &reqs));
    }

    #[test]
    fn partitions_count() {
        // C(n+3, 3) compositions of n into 4 labelled bins.
        assert_eq!(partitions(4).len(), 35);
        assert_eq!(partitions(3).len(), 20);
        assert_eq!(partitions(0).len(), 1);
    }

    #[test]
    fn utilization_bounds() {
        let reqs = requirements(UB, Scenario::GoStraight);
        let (alloc, u) = best_allocation((4, 4, 3), &reqs).unwrap();
        assert!(u > 0.0 && u <= 1.0);
        assert!(units_used(&alloc) <= 11);
        assert!(power_w(&alloc, &reqs) > 0.0);
    }
}
