//! Hardware platforms: HMAI — the paper's (4 SconvOD, 4 SconvIC,
//! 3 MconvMC) heterogeneous configuration (§8.2) — plus the homogeneous
//! baselines (13 SO / 13 SI / 12 MM, §3.1) and arbitrary custom mixes.

pub mod alloc;

use crate::accel::AccelKind;

/// One physical sub-accelerator instance.
#[derive(Debug, Clone, Copy)]
pub struct AccelInstance {
    pub id: usize,
    pub kind: AccelKind,
}

/// A multi-accelerator platform.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub accels: Vec<AccelInstance>,
}

impl Platform {
    /// Build from per-kind counts (SO, SI, MM).
    pub fn from_counts(name: &str, so: usize, si: usize, mm: usize) -> Platform {
        let mut accels = Vec::with_capacity(so + si + mm);
        let mut id = 0;
        for (kind, n) in [
            (AccelKind::SconvOD, so),
            (AccelKind::SconvIC, si),
            (AccelKind::MconvMC, mm),
        ] {
            for _ in 0..n {
                accels.push(AccelInstance { id, kind });
                id += 1;
            }
        }
        Platform { name: name.to_string(), accels }
    }

    /// The paper's HMAI: (4 SconvOD, 4 SconvIC, 3 MconvMC) — §8.2.
    pub fn hmai() -> Platform {
        Platform::from_counts("HMAI(4SO,4SI,3MM)", 4, 4, 3)
    }

    /// Homogeneous baselines (§3.1/§8.2): sized to meet the max-scenario
    /// requirement — 13 SconvOD, 13 SconvIC or 12 MconvMC.
    pub fn homogeneous(kind: AccelKind) -> Platform {
        match kind {
            AccelKind::SconvOD => Platform::from_counts("13xSconvOD", 13, 0, 0),
            AccelKind::SconvIC => Platform::from_counts("13xSconvIC", 0, 13, 0),
            AccelKind::MconvMC => Platform::from_counts("12xMconvMC", 0, 0, 12),
        }
    }

    pub fn len(&self) -> usize {
        self.accels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accels.is_empty()
    }

    pub fn count_of(&self, kind: AccelKind) -> usize {
        self.accels.iter().filter(|a| a.kind == kind).count()
    }

    /// Peak compute of the whole platform, TOPS.
    pub fn peak_tops(&self) -> f64 {
        self.len() as f64 * crate::accel::peak_tops()
    }

    /// Parse "4,4,3"-style counts or a named platform.
    pub fn parse(s: &str) -> Option<Platform> {
        match s.to_ascii_lowercase().as_str() {
            "hmai" => return Some(Platform::hmai()),
            "13so" => return Some(Platform::homogeneous(AccelKind::SconvOD)),
            "13si" => return Some(Platform::homogeneous(AccelKind::SconvIC)),
            "12mm" => return Some(Platform::homogeneous(AccelKind::MconvMC)),
            _ => {}
        }
        let parts: Vec<usize> = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
        // A platform needs at least one accelerator: "0,0,0" would make
        // every scheduler's assignment unsatisfiable and panic the sim.
        if parts.len() == 3 && parts.iter().sum::<usize>() > 0 {
            Some(Platform::from_counts(
                &format!("custom({},{},{})", parts[0], parts[1], parts[2]),
                parts[0],
                parts[1],
                parts[2],
            ))
        } else {
            None
        }
    }
}

/// Number of accelerators of `kind` needed to sustain `fps_req` on `model`.
pub fn accels_needed(kind: AccelKind, model: crate::workload::ModelKind, fps_req: f64) -> usize {
    (fps_req / crate::accel::cost(kind, model).fps()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::camera_hz::model_fps_requirement;
    use crate::env::{Area, Scenario};
    use crate::workload::ModelKind;

    #[test]
    fn hmai_composition() {
        let p = Platform::hmai();
        assert_eq!(p.len(), 11);
        assert_eq!(p.count_of(AccelKind::SconvOD), 4);
        assert_eq!(p.count_of(AccelKind::SconvIC), 4);
        assert_eq!(p.count_of(AccelKind::MconvMC), 3);
        // Stable ids 0..11.
        assert!(p.accels.iter().enumerate().all(|(i, a)| a.id == i));
    }

    #[test]
    fn homogeneous_sizes_match_paper() {
        assert_eq!(Platform::homogeneous(AccelKind::SconvOD).len(), 13);
        assert_eq!(Platform::homogeneous(AccelKind::SconvIC).len(), 13);
        assert_eq!(Platform::homogeneous(AccelKind::MconvMC).len(), 12);
    }

    #[test]
    fn paper_3_1_sconvod_counts() {
        // §3.1: going straight in UB needs 3 SconvOD for YOLO, 6 for SSD,
        // 3 for GOTURN -> 12 total.  Our Table 8-pinned FPS reproduces it.
        let a = Area::Urban;
        let s = Scenario::GoStraight;
        let k = AccelKind::SconvOD;
        assert_eq!(accels_needed(k, ModelKind::Yolo, model_fps_requirement(a, s, ModelKind::Yolo)), 3);
        assert_eq!(accels_needed(k, ModelKind::Ssd, model_fps_requirement(a, s, ModelKind::Ssd)), 6);
        assert_eq!(
            accels_needed(k, ModelKind::Goturn, model_fps_requirement(a, s, ModelKind::Goturn)),
            3
        );
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Platform::parse("hmai").unwrap().len(), 11);
        assert_eq!(Platform::parse("2,1,1").unwrap().len(), 4);
        assert!(Platform::parse("nonsense").is_none());
        // Zero-accelerator platforms are rejected at the parse boundary
        // (schedulers additionally fall back gracefully when handed one).
        assert!(Platform::parse("0,0,0").is_none());
    }
}
