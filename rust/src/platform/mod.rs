//! Hardware platforms: HMAI — the paper's (4 SconvOD, 4 SconvIC,
//! 3 MconvMC) heterogeneous configuration (§8.2) — plus the homogeneous
//! baselines (13 SO / 13 SI / 12 MM, §3.1) and arbitrary custom mixes of
//! per-core *kind × size* ([`CoreSize`]): the two axes `hmai dse`
//! explores.
//!
//! Spec grammar (`Platform::try_parse`):
//!   * named: `hmai` | `13so` | `13si` | `12mm`
//!   * legacy counts: `"4,4,3"` (SO,SI,MM — all standard-size cores)
//!   * sized mix: `"so:4@2x,si:4,mm:3@0.5x"` — comma-separated
//!     `kind:count[@size]` components, size ∈ `0.5x | 1x | 2x`
//!     (default `1x`); repeated kinds append.
//!   * chiplet topology suffix: `"<spec>+<topology>"` — e.g.
//!     `hmai+mesh2x2`, `so:4@2x,si:4,mm:3+ring4@2x` — attaches an
//!     [`interconnect::Topology`](crate::interconnect::Topology) so the
//!     simulator prices inter-chiplet transfers.  A monolithic suffix
//!     (`+mono`, `+mesh1x1`, ...) normalizes away entirely: same name,
//!     no topology, bit-identical behavior.

pub mod alloc;

use std::sync::Arc;

use crate::accel::{self, AccelKind, CoreSize, CostModel};
use crate::interconnect::{CommCostModel, ComputeOnly, PlatformCostModel, Topology};

/// One physical sub-accelerator instance.
#[derive(Debug, Clone, Copy)]
pub struct AccelInstance {
    pub id: usize,
    pub kind: AccelKind,
    /// MAC provisioning of this core (Std = the paper's 8192 MACs).
    pub size: CoreSize,
}

/// A multi-accelerator platform.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub accels: Vec<AccelInstance>,
    /// Chiplet interconnect, when the spec carried a `+<topology>` suffix.
    /// `None` (monolithic) prices compute only — the pre-interconnect
    /// behavior, bit for bit.
    pub topology: Option<Arc<Topology>>,
}

impl Platform {
    /// Build from per-kind counts (SO, SI, MM) of standard-size cores.
    pub fn from_counts(name: &str, so: usize, si: usize, mm: usize) -> Platform {
        Platform::from_mix(
            name,
            &[
                (AccelKind::SconvOD, CoreSize::Std, so),
                (AccelKind::SconvIC, CoreSize::Std, si),
                (AccelKind::MconvMC, CoreSize::Std, mm),
            ],
        )
    }

    /// Build from (kind, size, count) components, in order.
    pub fn from_mix(name: &str, mix: &[(AccelKind, CoreSize, usize)]) -> Platform {
        let mut accels = Vec::with_capacity(mix.iter().map(|(_, _, n)| n).sum());
        let mut id = 0;
        for &(kind, size, n) in mix {
            for _ in 0..n {
                accels.push(AccelInstance { id, kind, size });
                id += 1;
            }
        }
        Platform { name: name.to_string(), accels, topology: None }
    }

    /// The paper's HMAI: (4 SconvOD, 4 SconvIC, 3 MconvMC) — §8.2.
    pub fn hmai() -> Platform {
        Platform::from_counts("HMAI(4SO,4SI,3MM)", 4, 4, 3)
    }

    /// Homogeneous baselines (§3.1/§8.2): sized to meet the max-scenario
    /// requirement — 13 SconvOD, 13 SconvIC or 12 MconvMC.
    pub fn homogeneous(kind: AccelKind) -> Platform {
        match kind {
            AccelKind::SconvOD => Platform::from_counts("13xSconvOD", 13, 0, 0),
            AccelKind::SconvIC => Platform::from_counts("13xSconvIC", 0, 13, 0),
            AccelKind::MconvMC => Platform::from_counts("12xMconvMC", 0, 0, 12),
        }
    }

    pub fn len(&self) -> usize {
        self.accels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accels.is_empty()
    }

    pub fn count_of(&self, kind: AccelKind) -> usize {
        self.accels.iter().filter(|a| a.kind == kind).count()
    }

    pub fn count_of_sized(&self, kind: AccelKind, size: CoreSize) -> usize {
        self.accels.iter().filter(|a| a.kind == kind && a.size == size).count()
    }

    /// Peak compute of the whole platform, TOPS — summed per core, so
    /// mixed-size platforms are accounted correctly (the pre-size
    /// implementation multiplied the core count by the uniform Std peak,
    /// which over/under-counted any non-Std core).
    pub fn peak_tops(&self) -> f64 {
        self.accels.iter().map(|a| accel::peak_tops_sized(a.size)).sum()
    }

    /// Die-area estimate in standard-core equivalents
    /// ([`CoreSize::area_units`]) — the `hmai dse --budget` unit.
    pub fn area_units(&self) -> f64 {
        self.accels.iter().map(|a| a.size.area_units()).sum()
    }

    /// Peak sustained power estimate (W): each core at its most
    /// power-hungry workload ([`accel::peak_power_w`]).
    pub fn peak_power_w(&self) -> f64 {
        self.accels.iter().map(|a| accel::peak_power_w(a.kind, a.size)).sum()
    }

    /// The instance-parameterized cost model of this platform (per-slot
    /// (kind, size) rows) — what `ShadowState` consults per decision.
    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.accels.iter().map(|a| (a.kind, a.size)))
    }

    /// How this platform prices work: compute-only on a monolithic die,
    /// compute + interconnect transfers when a chiplet topology is
    /// attached.  The [`PlatformCostModel`] seam `ShadowState::new`
    /// consults — both pricings share the same [`CostModel`] rows.
    pub fn pricing(&self) -> Box<dyn PlatformCostModel> {
        let compute = Arc::new(self.cost_model());
        match &self.topology {
            Some(t) => Box::new(CommCostModel { compute, topology: Arc::clone(t) }),
            None => Box::new(ComputeOnly { compute }),
        }
    }

    /// Parse a platform spec; `None` on any error (see [`Platform::try_parse`]
    /// for the error-reporting form the CLI uses).
    pub fn parse(s: &str) -> Option<Platform> {
        Platform::try_parse(s).ok()
    }

    /// Parse a platform spec with a descriptive error: a named platform,
    /// legacy `"so,si,mm"` counts, or `kind:count[@size]` components, each
    /// optionally followed by `+<topology>` (see the module docs for the
    /// grammar).
    pub fn try_parse(s: &str) -> Result<Platform, String> {
        let lc = s.trim().to_ascii_lowercase();
        let Some((base, topo_s)) = lc.split_once('+') else {
            return Self::parse_base(s, &lc);
        };
        let mut platform = Self::parse_base(s, base.trim())?;
        let topo = Topology::try_parse(topo_s.trim())?;
        // A single-chiplet package IS the monolithic die: normalize it away
        // so `hmai+mono` is `hmai` — same name, same fingerprints.
        if !topo.is_mono() {
            topo.bind(platform.accels.len()).map_err(|e| format!("'{lc}': {e}"))?;
            platform.name = format!("{}+{}", platform.name, topo.name);
            platform.topology = Some(Arc::new(topo));
        }
        Ok(platform)
    }

    /// The topology-free part of the spec grammar.
    fn parse_base(s: &str, lc: &str) -> Result<Platform, String> {
        match lc {
            "hmai" => return Ok(Platform::hmai()),
            "13so" => return Ok(Platform::homogeneous(AccelKind::SconvOD)),
            "13si" => return Ok(Platform::homogeneous(AccelKind::SconvIC)),
            "12mm" => return Ok(Platform::homogeneous(AccelKind::MconvMC)),
            "" => return Err("empty platform spec".to_string()),
            _ => {}
        }
        let parts: Vec<&str> = lc.split(',').map(str::trim).collect();
        if parts.iter().any(|p| p.contains(':')) {
            return Self::parse_mix(lc, &parts);
        }
        // Legacy count-triple form "so,si,mm".
        if parts.len() != 3 {
            return Err(format!(
                "'{s}': expected 3 comma-separated counts \"so,si,mm\" (got {}), \
                 a named platform (hmai | 13so | 13si | 12mm), or \
                 \"kind:count[@size]\" components like \"so:4@2x,si:4,mm:3\"",
                parts.len()
            ));
        }
        let mut counts = [0usize; 3];
        for (i, p) in parts.iter().enumerate() {
            counts[i] = p.parse().map_err(|_| {
                format!(
                    "'{s}' component {} ('{p}'): not a count — expected e.g. \
                     \"4,4,3\" or \"so:4@2x,si:4,mm:3\"",
                    i + 1
                )
            })?;
        }
        if counts.iter().sum::<usize>() == 0 {
            // A platform needs at least one accelerator: "0,0,0" would make
            // every scheduler's assignment unsatisfiable and panic the sim.
            return Err(format!("'{s}': a platform needs at least one accelerator"));
        }
        Ok(Platform::from_counts(
            &format!("custom({},{},{})", counts[0], counts[1], counts[2]),
            counts[0],
            counts[1],
            counts[2],
        ))
    }

    /// The `kind:count[@size]` component form.
    fn parse_mix(lc: &str, parts: &[&str]) -> Result<Platform, String> {
        let expected = "expected \"kind:count[@size]\" with kind so|si|mm and \
                        size 0.5x|1x|2x — e.g. \"so:4@2x,si:4,mm:3\"";
        let mut mix: Vec<(AccelKind, CoreSize, usize)> = Vec::with_capacity(parts.len());
        for (i, comp) in parts.iter().enumerate() {
            let err = |what: &str| {
                format!("'{lc}' component {} ('{comp}'): {what} — {expected}", i + 1)
            };
            let (kind_s, rest) = comp.split_once(':').ok_or_else(|| err("missing ':'"))?;
            let kind = AccelKind::parse(kind_s.trim())
                .ok_or_else(|| err(&format!("unknown kind '{}'", kind_s.trim())))?;
            let (count_s, size) = match rest.split_once('@') {
                Some((c, sz)) => {
                    let size = CoreSize::parse(sz.trim())
                        .ok_or_else(|| err(&format!("unknown size '{}'", sz.trim())))?;
                    (c.trim(), size)
                }
                None => (rest.trim(), CoreSize::Std),
            };
            let count: usize =
                count_s.parse().map_err(|_| err(&format!("bad count '{count_s}'")))?;
            mix.push((kind, size, count));
        }
        if mix.iter().map(|(_, _, n)| n).sum::<usize>() == 0 {
            return Err(format!("'{lc}': a platform needs at least one accelerator"));
        }
        let canon: Vec<String> = mix
            .iter()
            .filter(|(_, _, n)| *n > 0)
            .map(|(k, s, n)| format!("{}:{}{}", k.short().to_ascii_lowercase(), n, s.suffix()))
            .collect();
        Ok(Platform::from_mix(&format!("custom({})", canon.join(",")), &mix))
    }
}

/// Number of accelerators of `kind` needed to sustain `fps_req` on `model`.
pub fn accels_needed(kind: AccelKind, model: crate::workload::ModelKind, fps_req: f64) -> usize {
    (fps_req / crate::accel::cost(kind, model).fps()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::camera_hz::model_fps_requirement;
    use crate::env::{Area, Scenario};
    use crate::workload::ModelKind;

    #[test]
    fn hmai_composition() {
        let p = Platform::hmai();
        assert_eq!(p.len(), 11);
        assert_eq!(p.count_of(AccelKind::SconvOD), 4);
        assert_eq!(p.count_of(AccelKind::SconvIC), 4);
        assert_eq!(p.count_of(AccelKind::MconvMC), 3);
        // Stable ids 0..11, all standard cores.
        assert!(p.accels.iter().enumerate().all(|(i, a)| a.id == i));
        assert!(p.accels.iter().all(|a| a.size == CoreSize::Std));
        assert!((p.area_units() - 11.0).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_sizes_match_paper() {
        assert_eq!(Platform::homogeneous(AccelKind::SconvOD).len(), 13);
        assert_eq!(Platform::homogeneous(AccelKind::SconvIC).len(), 13);
        assert_eq!(Platform::homogeneous(AccelKind::MconvMC).len(), 12);
    }

    #[test]
    fn paper_3_1_sconvod_counts() {
        // §3.1: going straight in UB needs 3 SconvOD for YOLO, 6 for SSD,
        // 3 for GOTURN -> 12 total.  Our Table 8-pinned FPS reproduces it.
        let a = Area::Urban;
        let s = Scenario::GoStraight;
        let k = AccelKind::SconvOD;
        assert_eq!(accels_needed(k, ModelKind::Yolo, model_fps_requirement(a, s, ModelKind::Yolo)), 3);
        assert_eq!(accels_needed(k, ModelKind::Ssd, model_fps_requirement(a, s, ModelKind::Ssd)), 6);
        assert_eq!(
            accels_needed(k, ModelKind::Goturn, model_fps_requirement(a, s, ModelKind::Goturn)),
            3
        );
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Platform::parse("hmai").unwrap().len(), 11);
        assert_eq!(Platform::parse("2,1,1").unwrap().len(), 4);
        assert!(Platform::parse("nonsense").is_none());
        // Zero-accelerator platforms are rejected at the parse boundary
        // (schedulers additionally fall back gracefully when handed one).
        assert!(Platform::parse("0,0,0").is_none());
    }

    #[test]
    fn parse_sized_mix_round_trips() {
        let p = Platform::parse("so:4@2x,si:4,mm:3@0.5x").unwrap();
        assert_eq!(p.len(), 11);
        assert_eq!(p.count_of_sized(AccelKind::SconvOD, CoreSize::Double), 4);
        assert_eq!(p.count_of_sized(AccelKind::SconvIC, CoreSize::Std), 4);
        assert_eq!(p.count_of_sized(AccelKind::MconvMC, CoreSize::Half), 3);
        assert_eq!(p.name, "custom(so:4@2x,si:4,mm:3@0.5x)");
        // The canonical name parses back to the same composition.
        let p2 = Platform::parse(&p.name["custom(".len()..p.name.len() - 1]).unwrap();
        assert_eq!(p2.name, p.name);
        // Slots are laid out component-major, like from_counts.
        assert_eq!(p.accels[0].kind, AccelKind::SconvOD);
        assert_eq!(p.accels[0].size, CoreSize::Double);
        assert_eq!(p.accels[10].kind, AccelKind::MconvMC);
        // Repeated kinds append.
        let rep = Platform::parse("so:1,so:2@2x").unwrap();
        assert_eq!(rep.len(), 3);
        assert_eq!(rep.count_of_sized(AccelKind::SconvOD, CoreSize::Double), 2);
    }

    #[test]
    fn mix_spec_equals_legacy_counts_platform() {
        // "so:4,si:4,mm:3" is the same machine as "4,4,3" (name aside).
        let a = Platform::parse("4,4,3").unwrap();
        let b = Platform::parse("so:4,si:4,mm:3").unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.accels.iter().zip(&b.accels) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.size, y.size);
        }
        assert_eq!(a.peak_tops().to_bits(), b.peak_tops().to_bits());
    }

    #[test]
    fn try_parse_errors_explain_themselves() {
        // The PR-2-era parser silently collapsed "4,x,3" into a generic
        // None; the CLI now surfaces what exactly was wrong.
        let e = Platform::try_parse("4,x,3").unwrap_err();
        assert!(e.contains("component 2") && e.contains("'x'"), "{e}");
        let e = Platform::try_parse("4,4").unwrap_err();
        assert!(e.contains("expected 3"), "{e}");
        let e = Platform::try_parse("so:1@9x").unwrap_err();
        assert!(e.contains("unknown size '9x'"), "{e}");
        let e = Platform::try_parse("zz:1").unwrap_err();
        assert!(e.contains("unknown kind 'zz'"), "{e}");
        let e = Platform::try_parse("so:0,si:0").unwrap_err();
        assert!(e.contains("at least one accelerator"), "{e}");
        let e = Platform::try_parse("so:x").unwrap_err();
        assert!(e.contains("bad count 'x'"), "{e}");
        assert!(Platform::try_parse("").is_err());
    }

    #[test]
    fn topology_suffix_attaches_interconnect() {
        let p = Platform::parse("hmai+mesh2x2").unwrap();
        assert_eq!(p.name, "HMAI(4SO,4SI,3MM)+mesh2x2");
        assert_eq!(p.len(), 11);
        let topo = p.topology.as_ref().expect("mesh2x2 attaches a topology");
        assert_eq!(topo.chiplets, 4);
        // Compute side is untouched: same cost-model rows as plain hmai.
        let mono = Platform::hmai();
        let (a, b) = (p.cost_model(), mono.cost_model());
        assert_eq!(
            a.of(0, ModelKind::Yolo).time_s.to_bits(),
            b.of(0, ModelKind::Yolo).time_s.to_bits()
        );
        // Mix specs compose with the suffix too.
        let m = Platform::parse("so:2@2x,si:2,mm:2+ring3@2x").unwrap();
        assert_eq!(m.name, "custom(so:2@2x,si:2,mm:2)+ring3@2x");
        assert!(m.topology.is_some());
    }

    #[test]
    fn mono_topology_suffix_normalizes_away() {
        // `+mono` (or any 1-chiplet preset) IS the monolithic platform:
        // same name, no topology — which is what keeps its sweep
        // fingerprints bit-identical to the suffix-free spec.
        for spec in ["hmai+mono", "hmai+mesh1x1", "hmai+ring1"] {
            let p = Platform::parse(spec).unwrap();
            assert_eq!(p.name, Platform::hmai().name, "{spec}");
            assert!(p.topology.is_none(), "{spec}");
        }
    }

    #[test]
    fn topology_suffix_errors_are_pointed() {
        let e = Platform::try_parse("hmai+torus3").unwrap_err();
        assert!(e.contains("torus3"), "{e}");
        // Placement arity mismatch names the platform and the counts.
        let e = Platform::try_parse("hmai+mesh2x2/0.1").unwrap_err();
        assert!(e.contains("2 entries") && e.contains("11 accelerator slots"), "{e}");
        // Errors on either side of '+' still surface.
        assert!(Platform::try_parse("+mesh2x2").is_err());
        assert!(Platform::try_parse("hmai+").is_err());
    }

    #[test]
    fn pricing_follows_topology() {
        let mono = Platform::hmai().pricing();
        assert!(mono.topology().is_none());
        let noc = Platform::parse("hmai+mesh2x2").unwrap().pricing();
        assert_eq!(noc.topology().expect("comm pricing").chiplets, 4);
        assert_eq!(
            mono.compute().of(3, ModelKind::Ssd).time_s.to_bits(),
            noc.compute().of(3, ModelKind::Ssd).time_s.to_bits()
        );
    }

    #[test]
    fn peak_tops_accounts_for_core_sizes() {
        // The pre-size peak_tops() assumed uniform cores; a mixed platform
        // must sum per-core peaks.
        let p = Platform::parse("so:1@2x,si:1,mm:1@0.5x").unwrap();
        let std1 = crate::accel::peak_tops();
        assert!((p.peak_tops() - 3.5 * std1).abs() < 1e-9, "{}", p.peak_tops());
        assert!((p.area_units() - (1.75 + 1.0 + 0.625)).abs() < 1e-12);
        assert!(p.peak_power_w() > 0.0);
        // Std-only platforms keep the old value (count × Std peak).
        let h = Platform::hmai();
        assert!((h.peak_tops() - 11.0 * std1).abs() < 1e-9);
    }

    #[test]
    fn cost_model_rows_follow_slot_layout() {
        let p = Platform::parse("so:1@0.5x,mm:2@2x").unwrap();
        let cm = p.cost_model();
        assert_eq!(cm.len(), 3);
        let want0 =
            crate::accel::cost_sized(AccelKind::SconvOD, ModelKind::Yolo, CoreSize::Half);
        assert_eq!(cm.of(0, ModelKind::Yolo).time_s.to_bits(), want0.time_s.to_bits());
        let want2 =
            crate::accel::cost_sized(AccelKind::MconvMC, ModelKind::Goturn, CoreSize::Double);
        assert_eq!(cm.of(2, ModelKind::Goturn).time_s.to_bits(), want2.time_s.to_bits());
    }
}
