fn main() {
    use hmai::accel::{task_cost, ALL_ACCELS};
    use hmai::workload::{ALL_MODELS, model};
    for m in ALL_MODELS {
        print!("{:8}", m.name());
        for a in ALL_ACCELS {
            let c = task_cost(a, m);
            print!("  {}={:7.2} fps (util {:4.2}, {:6.1} mJ)", a.short(), c.fps(), c.utilization, c.energy_j*1e3);
        }
        println!("  [{:.1} GMACs]", model(m).gmacs());
    }
}
