//! `hmai lint` — dependency-free determinism & panic-safety static
//! analysis over the crate's own source.
//!
//! Every result this reproduction ships rests on determinism invariants
//! (jobs-invariant fingerprints, shard-merge equality, kill/resume
//! exactness) that runtime tests can only spot-check on the inputs they
//! happen to run.  The linter checks them at the source level, on every
//! line, three ways: the `hmai lint` CLI subcommand, the `tests/lint.rs`
//! meta-test (so `cargo test` is the gate), and a CI step emitting a JSON
//! report.
//!
//! Pipeline: [`scan`] sanitizes source (comments out, literal contents
//! blanked, test regions marked) → [`rules`] match tokens per line or per
//! statement → [`pragma`]s suppress individual findings, but only with a
//! justification.  Suppressions are counted in the report, never silent;
//! a malformed, reasonless or unknown-rule pragma is itself a violation
//! (pseudo-rules `pragma-malformed`, `pragma-missing-reason`,
//! `pragma-unknown-rule`), and those can never be suppressed.

pub mod pragma;
pub mod rules;
pub mod scan;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::table::Table;

/// One finding.
#[derive(Debug, Clone)]
pub struct Violation {
    /// `/`-separated path relative to the scanned root.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule name (or a `pragma-*` pseudo-rule).
    pub rule: String,
    /// The offending original source line, trimmed.
    pub snippet: String,
    /// What matched, or what is wrong with the pragma.
    pub note: String,
}

/// Aggregate result of linting a tree.
#[derive(Debug)]
pub struct LintReport {
    pub root: String,
    pub files: usize,
    pub lines: usize,
    /// Findings suppressed by justified pragmas (counted, not silent).
    pub suppressed: usize,
    pub violations: Vec<Violation>,
}

impl LintReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "lint: {} files, {} lines scanned under {} — {} violation(s), {} suppressed by pragma\n",
            self.files,
            self.lines,
            self.root,
            self.violations.len(),
            self.suppressed
        );
        if !self.violations.is_empty() {
            let mut t = Table::new(["file", "line", "rule", "note", "snippet"]);
            for v in &self.violations {
                t.row([
                    v.file.clone(),
                    v.line.to_string(),
                    v.rule.clone(),
                    v.note.clone(),
                    v.snippet.clone(),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("root", Json::Str(self.root.clone())),
            ("files", Json::Num(self.files as f64)),
            ("lines", Json::Num(self.lines as f64)),
            ("suppressed", Json::Num(self.suppressed as f64)),
            (
                "violations",
                Json::Arr(
                    self.violations
                        .iter()
                        .map(|v| {
                            Json::from_pairs(vec![
                                ("file", Json::Str(v.file.clone())),
                                ("line", Json::Num(v.line as f64)),
                                ("rule", Json::Str(v.rule.clone())),
                                ("note", Json::Str(v.note.clone())),
                                ("snippet", Json::Str(v.snippet.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Longest statement (in sanitized lines) the co-occurrence matcher will
/// group; a backstop against pathological unterminated runs.
const MAX_STMT_LINES: usize = 16;

/// Lint one file's source.  `rel` is the `/`-separated path relative to
/// the scanned root (used for rule scoping).  Returns the findings plus
/// the number of findings suppressed by justified pragmas.
pub fn lint_source(rel: &str, text: &str) -> (Vec<Violation>, usize) {
    let scanned = scan::scan(text);
    let orig: Vec<&str> = text.lines().collect();
    let snippet_of = |line: usize| -> String {
        orig.get(line.saturating_sub(1)).map_or(String::new(), |s| {
            s.trim().chars().take(96).collect()
        })
    };
    let mut violations: Vec<Violation> = Vec::new();
    let mut push = |line: usize, rule: &str, note: String, snippet: String| {
        violations.push(Violation { file: rel.to_string(), line, rule: rule.to_string(), snippet, note });
    };

    // Parse pragmas out of the comment stream.  A pragma covers its own
    // line when that line carries code (trailing comment), otherwise the
    // next non-blank code line.
    let mut cover: BTreeMap<usize, Vec<pragma::Pragma>> = BTreeMap::new();
    for c in &scanned.comments {
        match pragma::parse(c.line, &c.text) {
            None => {}
            Some(Err(pragma::PragmaError::Malformed { line, detail })) => {
                push(line, "pragma-malformed", detail.to_string(), snippet_of(line));
            }
            Some(Err(pragma::PragmaError::MissingReason { line })) => {
                push(
                    line,
                    "pragma-missing-reason",
                    "a lint:allow pragma must justify the exception".to_string(),
                    snippet_of(line),
                );
            }
            Some(Ok(p)) => {
                for r in &p.rules {
                    if rules::by_name(r).is_none() {
                        push(
                            p.line,
                            "pragma-unknown-rule",
                            format!("no rule named '{r}'"),
                            snippet_of(p.line),
                        );
                    }
                }
                let target = match scanned.line(p.line) {
                    Some(l) if !l.code.trim().is_empty() => p.line,
                    _ => scanned.next_code_line(p.line + 1).unwrap_or(p.line),
                };
                cover.entry(target).or_default().push(p);
            }
        }
    }

    // Candidate findings: (line, rule name, note).
    let mut candidates: Vec<(usize, &'static str, String)> = Vec::new();
    for rule in rules::RULES {
        if !rule.scope.applies(rel) {
            continue;
        }
        match rule.matcher {
            rules::Matcher::Tokens(needles) => {
                for l in &scanned.lines {
                    if l.in_test {
                        continue;
                    }
                    if let Some(n) = needles.iter().find(|n| rules::find_token(&l.code, n)) {
                        candidates.push((l.num, rule.name, format!("token `{n}`")));
                    }
                }
            }
            rules::Matcher::Reduction { reduce, source } => {
                // Group sanitized lines into statements (terminated by
                // `;` or `}`); a reduce token fires when a source token
                // shares its statement.
                let mut stmt: Vec<(usize, &str)> = Vec::new();
                let mut close = |stmt: &mut Vec<(usize, &str)>| {
                    let has_source = stmt
                        .iter()
                        .any(|(_, c)| source.iter().any(|s| rules::find_token(c, s)));
                    if has_source {
                        let hit = stmt.iter().find_map(|(num, c)| {
                            reduce.iter().find(|r| rules::find_token(c, r)).map(|r| (*num, *r))
                        });
                        if let Some((num, r)) = hit {
                            candidates.push((num, rule.name, format!("token `{r}` over an unordered source")));
                        }
                    }
                    stmt.clear();
                };
                for l in &scanned.lines {
                    if l.in_test {
                        close(&mut stmt);
                        continue;
                    }
                    stmt.push((l.num, &l.code));
                    if l.code.contains(';') || l.code.contains('}') || stmt.len() >= MAX_STMT_LINES
                    {
                        close(&mut stmt);
                    }
                }
                close(&mut stmt);
            }
        }
    }

    // Apply suppression, then order findings for stable reports.
    let mut suppressed = 0usize;
    for (line, rule, note) in candidates {
        let covered = cover
            .get(&line)
            .is_some_and(|ps| ps.iter().any(|p| p.rules.iter().any(|r| r == rule)));
        if covered {
            suppressed += 1;
        } else {
            push(line, rule, note, snippet_of(line));
        }
    }
    violations.sort_by(|a, b| a.line.cmp(&b.line).then_with(|| a.rule.cmp(&b.rule)));
    (violations, suppressed)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("listing {}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (recursively, in sorted order, so
/// the report itself is deterministic).
pub fn lint_dir(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut report = LintReport {
        root: root.display().to_string(),
        files: files.len(),
        lines: 0,
        suppressed: 0,
        violations: Vec::new(),
    };
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        report.lines += text.lines().count();
        let (mut v, sup) = lint_source(&rel, &text);
        report.suppressed += sup;
        report.violations.append(&mut v);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trailing_pragma_covers_its_own_line() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() } // lint:allow(panic-in-hot-path): invariant documented\n";
        let (v, sup) = lint_source("sched/core.rs", src);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(sup, 1);
    }

    #[test]
    fn standalone_pragma_covers_next_code_line_across_blanks() {
        let src = "// lint:allow(panic-in-hot-path): invariant documented\n\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let (v, sup) = lint_source("sched/core.rs", src);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(sup, 1);
    }

    #[test]
    fn pragma_does_not_leak_past_its_target_line() {
        let src = "// lint:allow(panic-in-hot-path): only the first\nfn a(x: Option<u32>) -> u32 { x.unwrap() }\nfn b(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let (v, sup) = lint_source("sched/core.rs", src);
        assert_eq!(sup, 1);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn unknown_rule_pragma_is_a_violation_and_suppresses_nothing_it_names() {
        let src = "// lint:allow(no-such-rule): misguided\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let (v, sup) = lint_source("sched/core.rs", src);
        assert!(v.iter().any(|x| x.rule == "pragma-unknown-rule"), "{v:?}");
        assert!(v.iter().any(|x| x.rule == "panic-in-hot-path"), "{v:?}");
        assert_eq!(sup, 0);
    }

    #[test]
    fn multi_rule_pragma_suppresses_each_named_rule() {
        let src = "// lint:allow(unordered-iteration, float-fold-order): audited ordering\nfn t(m: &HashMap<u32, f64>) -> f64 { m.values().sum::<f64>() }\n";
        let (v, sup) = lint_source("metrics/agg.rs", src);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(sup, 2);
    }

    #[test]
    fn pseudo_rules_cannot_be_suppressed() {
        // A reasonless pragma next to a pragma that tries to allow the
        // pseudo-rule: the pseudo-violation must survive.
        let src = "// lint:allow(pragma-missing-reason): nice try\n// lint:allow(panic-in-hot-path)\nfn f() {}\n";
        let (v, _) = lint_source("sched/core.rs", src);
        assert!(v.iter().any(|x| x.rule == "pragma-missing-reason"), "{v:?}");
        // And allowing a pseudo-rule by name is itself unknown-rule noise.
        assert!(v.iter().any(|x| x.rule == "pragma-unknown-rule"), "{v:?}");
    }

    #[test]
    fn report_renders_and_serializes() {
        let (v, _) = lint_source("sim/hot.rs", "fn f() -> Instant { Instant::now() }\n");
        let report = LintReport {
            root: "src".to_string(),
            files: 1,
            lines: 1,
            suppressed: 0,
            violations: v,
        };
        let text = report.render();
        assert!(text.contains("wallclock-in-results"), "{text}");
        assert!(text.contains("sim/hot.rs"), "{text}");
        let j = report.to_json();
        assert_eq!(j.get_usize("files").unwrap(), 1);
        let vs = j.get_arr("violations").unwrap();
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].get_str("rule").unwrap(), "wallclock-in-results");
        // Round-trips through the writer.
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back.get_usize("files").unwrap(), 1);
    }

    #[test]
    fn lint_dir_walks_recursively_with_relative_paths() {
        let dir = std::env::temp_dir().join(format!("hmai_lint_dir_test_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("sched")).unwrap();
        std::fs::write(
            dir.join("sched/core.rs"),
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        )
        .unwrap();
        std::fs::write(dir.join("clean.rs"), "fn ok() {}\n").unwrap();
        let report = lint_dir(&dir).unwrap();
        assert_eq!(report.files, 2);
        assert_eq!(report.violations.len(), 1, "{:?}", report.violations);
        assert_eq!(report.violations[0].file, "sched/core.rs");
        assert_eq!(report.violations[0].rule, "panic-in-hot-path");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn violations_are_sorted_by_line_then_rule() {
        let src = "fn b(x: Option<u32>) -> u32 { x.unwrap() }\nfn a() -> Instant { Instant::now() }\n";
        let (v, _) = lint_source("sim/hot.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert_eq!(v[0].line, 1);
        assert_eq!(v[1].line, 2);
    }
}
