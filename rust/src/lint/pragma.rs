//! Allow-pragma parsing.
//!
//! A violation is suppressed — never silenced — by an inline pragma that
//! names the rule *and* justifies the exception:
//!
//! ```text
//! // lint:allow(wallclock-in-results): diagnostic column only, never
//! // feeds a fingerprint.
//! let clk = Instant::now();
//! ```
//!
//! The pragma covers the rest of its own line (trailing comment) or, when
//! it stands alone, the next non-blank code line.  Multiple rules may be
//! listed: `lint:allow(unordered-iteration, float-fold-order): shared
//! justification`.  A pragma without a
//! justification — or one naming an unknown rule — is itself a violation,
//! so allowances can never rot into unexplained noise.

/// A parsed, well-formed pragma.
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    /// Line of the comment carrying the pragma.
    pub line: usize,
    /// Rule names this pragma suppresses.
    pub rules: Vec<String>,
    /// The mandatory justification.
    pub reason: String,
}

/// Why a pragma failed to parse (each is reported as a violation).
#[derive(Debug, Clone, PartialEq)]
pub enum PragmaError {
    /// No `(rules)` list, or an empty one.
    Malformed { line: usize, detail: &'static str },
    /// No `: reason` after the rule list, or an empty reason.
    MissingReason { line: usize },
}

/// Parse a comment's text.  `None` when the comment is not a pragma at all.
pub fn parse(line: usize, comment: &str) -> Option<Result<Pragma, PragmaError>> {
    let idx = comment.find("lint:allow")?;
    let rest = comment[idx + "lint:allow".len()..].trim_start();
    let Some(body) = rest.strip_prefix('(') else {
        return Some(Err(PragmaError::Malformed { line, detail: "expected '(' after lint:allow" }));
    };
    let Some(close) = body.find(')') else {
        return Some(Err(PragmaError::Malformed { line, detail: "unclosed rule list" }));
    };
    let rules: Vec<String> = body[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(Err(PragmaError::Malformed { line, detail: "empty rule list" }));
    }
    let after = body[close + 1..].trim_start();
    let Some(reason) = after.strip_prefix(':') else {
        return Some(Err(PragmaError::MissingReason { line }));
    };
    if reason.trim().is_empty() {
        return Some(Err(PragmaError::MissingReason { line }));
    }
    Some(Ok(Pragma { line, rules, reason: reason.trim().to_string() }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_comment_is_not_a_pragma() {
        assert!(parse(1, " just words about lint policies").is_none());
    }

    #[test]
    fn well_formed_pragma_parses() {
        let p = parse(7, " lint:allow(wallclock-in-results): diagnostic only")
            .unwrap()
            .unwrap();
        assert_eq!(p.line, 7);
        assert_eq!(p.rules, vec!["wallclock-in-results".to_string()]);
        assert_eq!(p.reason, "diagnostic only");
    }

    #[test]
    fn multi_rule_pragma_parses() {
        let p = parse(3, " lint:allow(rule-a, rule-b): shared justification")
            .unwrap()
            .unwrap();
        assert_eq!(p.rules, vec!["rule-a".to_string(), "rule-b".to_string()]);
    }

    #[test]
    fn missing_reason_is_an_error() {
        let e = parse(2, " lint:allow(panic-in-hot-path)").unwrap().unwrap_err();
        assert_eq!(e, PragmaError::MissingReason { line: 2 });
        let e = parse(2, " lint:allow(panic-in-hot-path):   ").unwrap().unwrap_err();
        assert_eq!(e, PragmaError::MissingReason { line: 2 });
    }

    #[test]
    fn malformed_pragmas_are_errors() {
        assert!(matches!(
            parse(4, " lint:allow panic").unwrap().unwrap_err(),
            PragmaError::Malformed { .. }
        ));
        assert!(matches!(
            parse(4, " lint:allow(): because").unwrap().unwrap_err(),
            PragmaError::Malformed { .. }
        ));
        assert!(matches!(
            parse(4, " lint:allow(rule-a").unwrap().unwrap_err(),
            PragmaError::Malformed { .. }
        ));
    }
}
