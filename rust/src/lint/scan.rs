//! Comment/string-aware source scanner for the determinism linter.
//!
//! `scan` turns Rust source text into *sanitized* lines: comments are
//! stripped (and collected separately, so allow-pragmas survive),
//! string/char-literal contents are blanked, and every line is flagged as
//! test or non-test code.  Rules then match tokens against the sanitized
//! text, so a hazard name inside a string literal, a doc comment or a
//! `#[cfg(test)]` module can never produce a false positive.
//!
//! The scanner is deliberately token-level, not a parser: the crate's
//! dependency budget is `anyhow`-only (no `syn`), and the rules it feeds
//! need token presence plus brace-depth structure, nothing more.  Handled
//! precisely: line comments, nested block comments, string escapes,
//! multi-line strings, raw strings (`r"…"`, `r#"…"#`, any hash depth),
//! byte strings, char literals vs. lifetimes, and `#[cfg(test)]` /
//! `#[test]` region tracking via brace depth.

/// One scanned source line.
#[derive(Debug)]
pub struct ScanLine {
    /// 1-based line number in the original file.
    pub num: usize,
    /// Sanitized code: comments removed, literal contents blanked.
    pub code: String,
    /// Inside a `#[cfg(test)]` module or `#[test]` function.
    pub in_test: bool,
}

/// One comment (line or block), attributed to its starting line.
#[derive(Debug)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Scan result: sanitized lines plus every comment.
#[derive(Debug, Default)]
pub struct Scanned {
    pub lines: Vec<ScanLine>,
    pub comments: Vec<Comment>,
}

impl Scanned {
    /// The sanitized line with number `num`, if any.
    pub fn line(&self, num: usize) -> Option<&ScanLine> {
        self.lines.iter().find(|l| l.num == num)
    }

    /// First line at or after `num` whose sanitized code is non-blank.
    /// Used to attach a standalone pragma comment to the statement below it.
    pub fn next_code_line(&self, num: usize) -> Option<usize> {
        self.lines
            .iter()
            .find(|l| l.num >= num && !l.code.trim().is_empty())
            .map(|l| l.num)
    }
}

fn flush(lines: &mut Vec<ScanLine>, code: &mut String, num: usize) {
    lines.push(ScanLine { num, code: std::mem::take(code), in_test: false });
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `text` into sanitized lines and comments.
pub fn scan(text: &str) -> Scanned {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = Scanned::default();
    let mut code = String::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        // Line comment: collect to end of line ('\n' handled next round).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment { line, text: chars[start.min(i)..i].iter().collect() });
            continue;
        }
        // Block comment, possibly nested, possibly multi-line.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let first = line;
            let mut text = String::new();
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        flush(&mut out.lines, &mut code, line);
                        line += 1;
                        text.push('\n');
                    } else {
                        text.push(chars[i]);
                    }
                    i += 1;
                }
            }
            out.comments.push(Comment { line: first, text });
            continue;
        }
        // Raw / byte strings: r"…", r#"…"#, br"…", b"…".  Only when the
        // leading r/b is not the tail of an identifier (e.g. `for`, `rbr`).
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident_char(chars[i - 1])) {
            let mut j = i;
            if chars[j] == 'b' {
                j += 1;
            }
            let is_raw = j < n && chars[j] == 'r';
            if is_raw {
                j += 1;
            }
            let mut hashes = 0usize;
            if is_raw {
                while j < n && chars[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
            }
            if j < n && chars[j] == '"' && (is_raw || c == 'b') {
                code.push_str("\"\"");
                i = j + 1;
                if is_raw {
                    // No escapes in raw strings: scan for `"` + `hashes` #s.
                    while i < n {
                        if chars[i] == '"'
                            && (i + 1..=i + hashes).all(|k| k < n && chars[k] == '#')
                        {
                            i += 1 + hashes;
                            break;
                        }
                        if chars[i] == '\n' {
                            flush(&mut out.lines, &mut code, line);
                            line += 1;
                        }
                        i += 1;
                    }
                } else {
                    // Byte string: normal escape handling.
                    while i < n {
                        match chars[i] {
                            '\\' => i += 2,
                            '"' => {
                                i += 1;
                                break;
                            }
                            '\n' => {
                                flush(&mut out.lines, &mut code, line);
                                line += 1;
                                i += 1;
                            }
                            _ => i += 1,
                        }
                    }
                }
                continue;
            }
            // Not a string prefix — plain identifier character.
            code.push(c);
            i += 1;
            continue;
        }
        match c {
            '\n' => {
                flush(&mut out.lines, &mut code, line);
                line += 1;
                i += 1;
            }
            '"' => {
                code.push_str("\"\"");
                i += 1;
                while i < n {
                    match chars[i] {
                        '\\' => i += 2,
                        '"' => {
                            i += 1;
                            break;
                        }
                        '\n' => {
                            flush(&mut out.lines, &mut code, line);
                            line += 1;
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
            }
            '\'' => {
                if i + 1 < n && chars[i + 1] == '\\' {
                    // Escaped char literal: skip to the closing quote.
                    i += 2;
                    while i < n && chars[i] != '\'' {
                        i += 1;
                    }
                    i += 1;
                    code.push_str("' '");
                } else if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                    // Plain char literal 'x'.
                    code.push_str("' '");
                    i += 3;
                } else {
                    // Lifetime ('a, 'static): keep the tick, scan on.
                    code.push('\'');
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    if !code.is_empty() {
        flush(&mut out.lines, &mut code, line);
    }
    mark_tests(&mut out.lines);
    out
}

/// Mark lines inside `#[cfg(test)]` / `#[test]` items via brace depth on the
/// sanitized text (string-literal braces are already blanked, so depth is
/// reliable).  An attribute arms `pending`; the next `{` opens a test region
/// that closes when depth returns; a `;` before any `{` disarms (covers
/// `#[cfg(test)] use …;`).
fn mark_tests(lines: &mut [ScanLine]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut test_depth: Option<i64> = None;
    for l in lines.iter_mut() {
        let compact: String = l.code.chars().filter(|c| !c.is_whitespace()).collect();
        if test_depth.is_none()
            && (compact.contains("#[cfg(test)]")
                || compact.contains("#[test]")
                || compact.contains("#[cfg(all(test"))
        {
            pending = true;
        }
        l.in_test = test_depth.is_some() || pending;
        for c in l.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending {
                        test_depth = Some(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if test_depth == Some(depth) {
                        test_depth = None;
                    }
                    depth -= 1;
                }
                ';' => pending = false,
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(s: &Scanned, num: usize) -> &str {
        &s.line(num).unwrap().code
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let s = scan("let a = \"Instant::now()\"; // Instant::now\nlet b = 2;\n");
        assert_eq!(code_of(&s, 1), "let a = \"\"; ");
        assert_eq!(code_of(&s, 2), "let b = 2;");
        assert_eq!(s.comments.len(), 1);
        assert!(s.comments[0].text.contains("Instant::now"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let s = scan("let a = r#\"panic! \"quoted\" HashMap\"#;\nlet b = r\"SystemTime\";\n");
        assert_eq!(code_of(&s, 1), "let a = \"\";");
        assert_eq!(code_of(&s, 2), "let b = \"\";");
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let s = scan("let a = b\"panic!\"; let c = b'{'; let d = br\"todo!\";\n");
        let code = code_of(&s, 1);
        assert!(!code.contains("panic"), "{code}");
        assert!(!code.contains("todo"), "{code}");
        assert!(!code.contains('{'), "byte-char brace must be blanked: {code}");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let s = scan("fn f<'a>(x: &'a str) -> char { let c = '{'; let e = '\\n'; c }\n");
        let code = code_of(&s, 1);
        assert!(code.contains("<'a>"), "{code}");
        assert!(code.contains("&'a str"), "{code}");
        // The literal '{' must not unbalance brace depth: exactly one
        // unmatched-free pair from the fn body.
        let opens = code.matches('{').count();
        let closes = code.matches('}').count();
        assert_eq!(opens, 1, "{code}");
        assert_eq!(closes, 1, "{code}");
    }

    #[test]
    fn nested_block_comments() {
        let s = scan("let a = 1; /* outer /* inner panic! */ still out */ let b = 2;\n");
        let code = code_of(&s, 1);
        assert!(!code.contains("panic"), "{code}");
        assert!(code.contains("let b = 2;"), "{code}");
        assert!(s.comments[0].text.contains("inner panic!"));
    }

    #[test]
    fn multiline_strings_keep_line_numbers() {
        let s = scan("let a = \"first\nsecond\nthird\";\nlet b = 1;\n");
        assert_eq!(code_of(&s, 4), "let b = 1;");
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "\
fn live() { x.unwrap(); }

#[cfg(test)]
mod tests {
    #[test]
    fn t() { y.unwrap(); }
}

fn live2() {}
";
        let s = scan(src);
        assert!(!s.line(1).unwrap().in_test);
        assert!(s.line(3).unwrap().in_test, "attribute line");
        assert!(s.line(4).unwrap().in_test, "mod header");
        assert!(s.line(6).unwrap().in_test, "test body");
        assert!(s.line(7).unwrap().in_test, "closing brace");
        assert!(!s.line(9).unwrap().in_test, "code after the test mod");
    }

    #[test]
    fn cfg_test_on_a_use_does_not_poison_the_rest() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() { x.unwrap(); }\n";
        let s = scan(src);
        assert!(s.line(2).unwrap().in_test);
        assert!(!s.line(3).unwrap().in_test);
    }

    #[test]
    fn test_fn_without_mod_is_marked() {
        let src = "#[test]\nfn t() {\n    a.unwrap();\n}\nfn live() {}\n";
        let s = scan(src);
        assert!(s.line(3).unwrap().in_test);
        assert!(!s.line(5).unwrap().in_test);
    }

    #[test]
    fn next_code_line_skips_blanks_and_comment_only_lines() {
        let s = scan("// pragma here\n\nlet a = 1;\n");
        assert_eq!(s.next_code_line(1), Some(3));
    }
}
