//! The rule table: each determinism / panic-safety hazard this tree has
//! actually hit, expressed as a scoped token (or co-occurrence) matcher.
//!
//! Rules match against *sanitized* lines from [`super::scan`] — comments
//! stripped, literal contents blanked — so a hazard name in a string or a
//! doc comment never fires.  Lines inside `#[cfg(test)]` / `#[test]`
//! regions are exempt: the invariants protect shipped results, not test
//! scaffolding.  Scoping is by path prefix on the `/`-separated path
//! relative to `src/`, so a rule can target the result-producing modules
//! and leave `util/` alone (or vice versa).

/// Path scope for a rule.
#[derive(Debug, Clone, Copy)]
pub enum Scope {
    /// Applies everywhere except under these path prefixes.
    AllExcept(&'static [&'static str]),
    /// Applies only under these path prefixes.
    Only(&'static [&'static str]),
}

impl Scope {
    /// Does the rule apply to this `/`-separated relative path?
    pub fn applies(&self, rel: &str) -> bool {
        match self {
            Scope::AllExcept(list) => !list.iter().any(|p| rel.starts_with(p)),
            Scope::Only(list) => list.iter().any(|p| rel.starts_with(p)),
        }
    }

    /// Human-readable scope for `hmai lint --rules`.
    pub fn describe(&self) -> String {
        match self {
            Scope::AllExcept(list) => format!("all except {}", list.join(", ")),
            Scope::Only(list) => format!("only {}", list.join(", ")),
        }
    }
}

/// How a rule matches a sanitized line.
#[derive(Debug, Clone, Copy)]
pub enum Matcher {
    /// Any of these tokens on a line fires (at most once per line).
    Tokens(&'static [&'static str]),
    /// A `reduce` token fires only when a `source` token appears in the
    /// same statement — catches order-sensitive folds over unordered
    /// collections without banning reductions outright.
    Reduction { reduce: &'static [&'static str], source: &'static [&'static str] },
}

/// One lint rule.
#[derive(Debug, Clone, Copy)]
pub struct RuleDef {
    pub name: &'static str,
    /// Why the pattern is hazardous in this codebase (shown in `--rules`).
    pub hazard: &'static str,
    pub scope: Scope,
    pub matcher: Matcher,
}

/// Modules whose output feeds fingerprints, reports or checkpoints — the
/// determinism contract (jobs-invariance, shard-merge equality, resume
/// exactness) lives or dies here.
pub const RESULT_MODULES: &[&str] =
    &["metrics/", "sched/", "sim/", "dse/", "fleet/", "reports/", "engine.rs"];

/// The shipped rule set.
pub const RULES: &[RuleDef] = &[
    RuleDef {
        name: "wallclock-in-results",
        hazard: "wall time read outside bench/logging can leak into a \
                 fingerprint, breaking run-to-run bit-identity",
        scope: Scope::AllExcept(&["util/bench.rs", "util/logging.rs"]),
        matcher: Matcher::Tokens(&["Instant::now", "SystemTime"]),
    },
    RuleDef {
        name: "unordered-iteration",
        hazard: "HashMap/HashSet iteration order is randomized per process; \
                 in result-producing modules it leaks into output ordering",
        scope: Scope::Only(RESULT_MODULES),
        matcher: Matcher::Tokens(&["HashMap", "HashSet"]),
    },
    RuleDef {
        name: "unseeded-rng",
        hazard: "entropy-seeded randomness breaks replay; all randomness \
                 must flow through the seeded util::rng generators",
        scope: Scope::AllExcept(&["util/rng.rs"]),
        matcher: Matcher::Tokens(&[
            "thread_rng",
            "rand::",
            "from_entropy",
            "StdRng",
            "SmallRng",
            "OsRng",
        ]),
    },
    RuleDef {
        name: "panic-in-hot-path",
        hazard: "a panic in the scheduling/simulation hot path kills a \
                 worker mid-sweep and poisons shared queues; hot-path code \
                 returns errors or justifies its invariant",
        scope: Scope::Only(&["sched/", "sim/", "metrics/", "fleet/", "interconnect/", "faults/"]),
        matcher: Matcher::Tokens(&[
            ".unwrap()",
            ".expect(",
            "panic!",
            "unreachable!",
            "unimplemented!",
            "todo!",
        ]),
    },
    RuleDef {
        name: "float-fold-order",
        hazard: "float addition is not associative; folding over an \
                 unordered collection makes the sum depend on iteration \
                 order",
        scope: Scope::Only(RESULT_MODULES),
        matcher: Matcher::Reduction {
            reduce: &[".sum::<f64>", ".sum::<f32>", ".product::<f64>", ".product::<f32>", ".fold("],
            source: &["HashMap", "HashSet", "par_iter"],
        },
    },
    RuleDef {
        name: "env-read-in-sim",
        hazard: "environment reads in simulation/runtime code make results \
                 depend on ambient machine state; config flows through \
                 config/ and the CLI",
        scope: Scope::AllExcept(&["config/", "main.rs", "util/"]),
        matcher: Matcher::Tokens(&["std::env"]),
    },
];

/// Look up a rule by name (used to validate pragma rule lists).
pub fn by_name(name: &str) -> Option<&'static RuleDef> {
    RULES.iter().find(|r| r.name == name)
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Token search with identifier-boundary checks: when the needle starts
/// (ends) with an identifier character, the preceding (following) source
/// byte must not be one.  Keeps `.unwrap()` from matching inside
/// `unwrap_or`-like names and `rand::` from matching `operand::`.
pub fn find_token(code: &str, needle: &str) -> bool {
    let cb = code.as_bytes();
    let nb = needle.as_bytes();
    if nb.is_empty() || cb.len() < nb.len() {
        return false;
    }
    let bound_left = is_ident_byte(nb[0]);
    let bound_right = is_ident_byte(nb[nb.len() - 1]);
    for i in 0..=cb.len() - nb.len() {
        if &cb[i..i + nb.len()] != nb {
            continue;
        }
        let left_ok = !bound_left || i == 0 || !is_ident_byte(cb[i - 1]);
        let right_ok =
            !bound_right || i + nb.len() == cb.len() || !is_ident_byte(cb[i + nb.len()]);
        if left_ok && right_ok {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lint_source;

    #[test]
    fn token_boundaries() {
        assert!(find_token("let x = y.unwrap();", ".unwrap()"));
        assert!(!find_token("let x = y.unwrap_or(0);", ".unwrap()"));
        assert!(find_token("let mut r = rand::thread_rng();", "rand::"));
        assert!(!find_token("let w = operand::width();", "rand::"));
        assert!(find_token("let m = HashMap::new();", "HashMap"));
        assert!(!find_token("let m = MyHashMapLike::new();", "HashMap"));
        assert!(!find_token("let m = HashMapper::new();", "HashMap"));
        assert!(find_token("t.expect(\"\")", ".expect("));
        assert!(!find_token("", ".unwrap()"));
    }

    #[test]
    fn scope_prefix_matching() {
        let s = Scope::Only(RESULT_MODULES);
        assert!(s.applies("sched/minmin.rs"));
        assert!(s.applies("engine.rs"));
        assert!(!s.applies("util/json.rs"));
        assert!(!s.applies("lint/rules.rs"));
        let s = Scope::AllExcept(&["util/", "main.rs"]);
        assert!(!s.applies("util/bench.rs"));
        assert!(!s.applies("main.rs"));
        assert!(s.applies("sim/mod.rs"));
    }

    #[test]
    fn every_rule_name_resolves() {
        for r in RULES {
            assert!(by_name(r.name).is_some());
        }
        assert!(by_name("no-such-rule").is_none());
    }

    /// (rule, path-in-scope, firing snippet, clean snippet).
    const FIXTURES: &[(&str, &str, &str, &str)] = &[
        (
            "wallclock-in-results",
            "sim/hot.rs",
            "fn stamp() -> u128 { let t = Instant::now(); t.elapsed().as_nanos() }",
            "fn stamp(clock: &SimClock) -> u64 { clock.now_ns() }",
        ),
        (
            "unordered-iteration",
            "metrics/agg.rs",
            "fn count() -> usize { let m = std::collections::HashMap::<u32, f64>::new(); m.len() }",
            "fn count() -> usize { let m = std::collections::BTreeMap::<u32, f64>::new(); m.len() }",
        ),
        (
            "unseeded-rng",
            "sched/pick.rs",
            "fn draw() -> u64 { let mut r = rand::thread_rng(); r.next_raw() }",
            "fn draw() -> u64 { let mut r = crate::util::rng::Rng::seeded(7); r.next_raw() }",
        ),
        (
            "panic-in-hot-path",
            "sched/core.rs",
            "fn pick(x: Option<u32>) -> u32 { x.unwrap() }",
            "fn pick(x: Option<u32>) -> u32 { x.unwrap_or(0) }",
        ),
        (
            "float-fold-order",
            "metrics/sumup.rs",
            "fn total(v: &V) -> f64 { v.par_iter().map(score).sum::<f64>() }",
            "fn total(v: &[f64]) -> f64 { v.iter().copied().sum::<f64>() }",
        ),
        (
            "env-read-in-sim",
            "sim/cfg.rs",
            "fn trace() -> bool { std::env::var_os(\"HMAI_TRACE\").is_some() }",
            "fn trace(cfg: &Config) -> bool { cfg.trace }",
        ),
    ];

    #[test]
    fn fixtures_fire_pass_suppress_and_require_reasons() {
        for (rule, path, firing, clean) in FIXTURES {
            // Positive snippet fires.
            let (v, _) = lint_source(path, &format!("{firing}\n"));
            assert!(
                v.iter().any(|x| x.rule == *rule),
                "{rule} should fire on {path}: {v:?}"
            );
            // Negative snippet passes.
            let (v, _) = lint_source(path, &format!("{clean}\n"));
            assert!(
                !v.iter().any(|x| x.rule == *rule),
                "{rule} should not fire on clean snippet: {v:?}"
            );
            // A justified pragma suppresses (counted, not silenced).
            let src = format!("// lint:allow({rule}): fixture-justified exception\n{firing}\n");
            let (v, sup) = lint_source(path, &src);
            assert!(
                !v.iter().any(|x| x.rule == *rule),
                "{rule} should be suppressed by a justified pragma: {v:?}"
            );
            assert!(sup >= 1, "{rule}: suppression must be counted");
            // A pragma without a reason suppresses nothing and is itself
            // a violation.
            let src = format!("// lint:allow({rule})\n{firing}\n");
            let (v, sup) = lint_source(path, &src);
            assert!(
                v.iter().any(|x| x.rule == *rule),
                "{rule}: reasonless pragma must not suppress: {v:?}"
            );
            assert!(v.iter().any(|x| x.rule == "pragma-missing-reason"), "{v:?}");
            assert_eq!(sup, 0);
        }
    }

    #[test]
    fn out_of_scope_paths_pass() {
        // Wall clock is legitimate in bench/logging code.
        let wall = "fn now() -> Instant { Instant::now() }\n";
        let (v, _) = lint_source("util/bench.rs", wall);
        assert!(v.is_empty(), "{v:?}");
        // Panics are fine outside the hot modules.
        let p = "fn must(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let (v, _) = lint_source("util/json.rs", p);
        assert!(v.is_empty(), "{v:?}");
        // Env reads are the CLI/config layer's job.
        let e = "fn home() -> Option<std::ffi::OsString> { std::env::var_os(\"HOME\") }\n";
        let (v, _) = lint_source("config/mod.rs", e);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        let (v, _) = lint_source("sched/core.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_fire() {
        let src = "fn msg() -> &'static str { \"call Instant::now here\" } // Instant::now\n";
        let (v, _) = lint_source("sim/hot.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn reduction_matches_across_statement_lines() {
        let src = "fn total(m: &M) -> f64 {\n    m.par_iter()\n        .map(score)\n        .sum::<f64>()\n}\n";
        let (v, _) = lint_source("metrics/x.rs", src);
        assert!(v.iter().any(|x| x.rule == "float-fold-order"), "{v:?}");
    }

    #[test]
    fn reduction_needs_both_halves() {
        // A fold over an ordered source is fine...
        let src = "fn total(v: &[f64]) -> f64 { v.iter().fold(0.0, |a, b| a + b) }\n";
        let (v, _) = lint_source("metrics/x.rs", src);
        assert!(!v.iter().any(|x| x.rule == "float-fold-order"), "{v:?}");
        // ...and an unordered collection without a fold is the other
        // rule's business, not this one's.
        let src = "fn peek(m: &std::collections::HashMap<u32, f64>) -> usize { m.len() }\n";
        let (v, _) = lint_source("metrics/x.rs", src);
        assert!(!v.iter().any(|x| x.rule == "float-fold-order"), "{v:?}");
        assert!(v.iter().any(|x| x.rule == "unordered-iteration"), "{v:?}");
    }
}
