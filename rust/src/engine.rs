//! Trial execution engine: runs an [`ExperimentPlan`](crate::plan::ExperimentPlan)
//! on a `std::thread` worker pool (no external deps) and aggregates the
//! streamed results into a [`SweepSummary`](crate::metrics::summary::SweepSummary).
//!
//! Determinism contract: every trial is independent (own queue, own
//! scheduler instance, fork-derived seeds), results are re-ordered by trial
//! id before aggregation, and no aggregate depends on wall-clock fields —
//! so `jobs = N` is bit-identical to `jobs = 1` for any N.  The tests in
//! `tests/sweep.rs` pin this down.
//!
//! Sweeps stream: [`Engine::run_streamed`] delivers owned results in trial
//! id order and [`Engine::sweep_streaming`] folds each into the summary
//! and drops it immediately, so a sweep's peak memory tracks the
//! out-of-order completion window instead of every `SimResult` of the
//! plan.  With
//! [`Engine::events`] enabled, trials whose scenario archetype declares
//! [`PlatformEvent`](crate::sim::events::PlatformEvent)s run them against
//! the simulation (accelerator failure / recovery / derating mid-route).

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::env::taskgen::{DeadlineMode, Task, TaskQueue};
use crate::env::Area;
use crate::faults::FaultModel;
use crate::metrics::quantile::QuantileHistogram;
use crate::metrics::summary::{RunSummary, SweepKey, SweepSummary};
use crate::metrics::NormScales;
use crate::plan::{ExperimentPlan, Trial};
use crate::safety::braking::{braking_distance_m, BrakingBreakdown};
use crate::sched::degrade::DegradeSched;
use crate::sched::Registry;
use crate::sim::{simulate_observed_with_scales, Applied, SimObserver, SimOptions, TaskRecord};

/// Render a `catch_unwind` payload for logs: panics raised via `panic!`
/// carry a `&str` or `String`; anything else is opaque.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Cache key for generated task queues: everything queue generation
/// depends on.  Trials differing only in scheduler/platform share the
/// queue instead of regenerating it (route synthesis at full paper scale
/// is ~200k tasks per queue).
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord)]
struct QueueKey {
    /// Library archetype name, when the trial is a scenario-library cell.
    scenario: Option<String>,
    area: Area,
    distance_bits: u64,
    index: usize,
    deadline: DeadlineMode,
    seed: u64,
    /// Fidelity route fraction (`Fidelity::frac_bits`): truncated queues
    /// cache separately from full ones.
    route_frac_bits: u64,
}

impl QueueKey {
    fn of(trial: &Trial) -> QueueKey {
        QueueKey {
            scenario: trial.scenario.archetype.as_ref().map(|a| a.name.clone()),
            area: trial.scenario.area,
            distance_bits: trial.scenario.distance_m.to_bits(),
            index: trial.queue_index,
            deadline: trial.scenario.deadline,
            seed: trial.seed,
            route_frac_bits: trial.fidelity.frac_bits(),
        }
    }
}

/// Thread-safe memo of generated queues, shared across engine workers —
/// and, via [`Engine::queue_cache`], across engine *runs*: the DSE hands
/// one cache to every candidate batch so routes are synthesized once per
/// (scenario, distance, seed, fidelity) for the whole exploration instead
/// of once per batch.
#[derive(Default)]
pub struct QueueCache {
    queues: Mutex<BTreeMap<QueueKey, Arc<TaskQueue>>>,
}

impl QueueCache {
    /// Get or generate the queue for `trial`.  Generation happens outside
    /// the lock, so two workers may race to build the same queue once —
    /// both get identical (deterministic) results and one copy is kept.
    ///
    /// A poisoned lock is recovered via `PoisonError::into_inner` rather
    /// than panicking: the cache holds immutable `Arc<TaskQueue>` entries
    /// that are only ever inserted (never mutated in place), so a worker
    /// that panicked mid-`get` cannot have left a torn value behind — and
    /// a worker panic must not cascade into every later cache user.
    pub fn get(&self, trial: &Trial) -> Arc<TaskQueue> {
        let key = QueueKey::of(trial);
        if let Some(q) =
            self.queues.lock().unwrap_or_else(|e| e.into_inner()).get(&key)
        {
            return q.clone();
        }
        let q = Arc::new(trial.queue());
        self.queues
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(key)
            .or_insert(q)
            .clone()
    }
}

/// Engine-internal per-trial observer feeding the tail histograms of
/// [`RunSummary`]: every applied task's response time, and the braking
/// distance its *deterministic* latency components imply at the
/// scenario's max velocity (scheduler wall clock contributes 0 so the
/// histograms — and hence sweep fingerprints — stay `--jobs`-invariant).
/// Lost tasks arrive with `response_s = +inf` and land in the nonfinite
/// bucket, so tail quantiles degrade to `+inf` rather than hiding loss.
struct TailsProbe {
    v_ms: f64,
    response: QuantileHistogram,
    braking: QuantileHistogram,
}

impl TailsProbe {
    fn new(v_ms: f64) -> TailsProbe {
        TailsProbe {
            v_ms,
            response: QuantileHistogram::response(),
            braking: QuantileHistogram::braking(),
        }
    }
}

impl SimObserver for TailsProbe {
    fn on_task(&mut self, _task: &Task, a: &Applied) {
        self.response.record(a.response_s);
        let b = BrakingBreakdown::new(a.wait_s, 0.0, a.compute_s);
        self.braking.record(braking_distance_m(self.v_ms, &b));
    }
}

/// Outcome of one executed trial.
#[derive(Debug)]
pub struct TrialResult {
    pub trial: Trial,
    pub summary: RunSummary,
    /// Wall-clock seconds inside the scheduler (measurement, not
    /// deterministic — excluded from sweep fingerprints).
    pub sched_wall_s: f64,
    /// Scheduling invocations (bursts).
    pub bursts: u64,
    /// Per-task records when the engine runs with `record_tasks`.
    pub records: Vec<TaskRecord>,
}

impl TrialResult {
    /// Mean scheduler wall time per task (the Fig. 14 `T_schedule`).
    pub fn sched_per_task_s(&self) -> f64 {
        if self.summary.tasks == 0 {
            0.0
        } else {
            self.sched_wall_s / self.summary.tasks as f64
        }
    }

    /// Aggregation key: scheduler display name × platform × scenario ×
    /// area × deadline (scenario is "-" for plain area/distance cells).
    pub fn sweep_key(&self) -> SweepKey {
        SweepKey {
            scheduler: self.summary.scheduler.clone(),
            platform: self.summary.platform.clone(),
            scenario: self.trial.scenario.scenario_name(),
            area: self.trial.scenario.area.name().to_string(),
            deadline: self.trial.scenario.deadline.name().to_string(),
        }
    }
}

/// Executes plans.  Cheap to build; borrow one registry for many runs.
pub struct Engine<'r> {
    registry: &'r Registry,
    jobs: usize,
    options: SimOptions,
    events: bool,
    faults: Option<FaultModel>,
    degrade: bool,
    cache: Option<Arc<QueueCache>>,
}

impl<'r> Engine<'r> {
    pub fn new(registry: &'r Registry) -> Engine<'r> {
        Engine {
            registry,
            jobs: 1,
            options: SimOptions::default(),
            events: false,
            faults: None,
            degrade: false,
            cache: None,
        }
    }

    /// Worker threads (1 = run on the calling thread).  0 means "all
    /// cores" (`std::thread::available_parallelism`).
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = if jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            jobs
        };
        self
    }

    pub fn sim_options(mut self, options: SimOptions) -> Self {
        self.options = options;
        self
    }

    /// Run scenario-declared platform events (accelerator failure /
    /// recovery / derating) against each trial's simulation.  Off by
    /// default: every pre-events result is reproduced bit-for-bit unless
    /// the caller opts in (CLI: `--events`).
    pub fn events(mut self, on: bool) -> Self {
        self.events = on;
        self
    }

    /// Inject stochastic platform faults: each trial draws its own
    /// accelerator/link failure–repair timeline from `model`, seeded by the
    /// trial's environment seed (see [`FaultModel::events_for_platform`]).
    /// Fault events run *in addition to* any scenario-declared events and
    /// independently of [`Engine::events`] — a campaign opts in explicitly.
    /// `None` (the default) reproduces every pre-faults result bit-for-bit.
    pub fn faults(mut self, model: Option<FaultModel>) -> Self {
        self.faults = model;
        self
    }

    /// Wrap every trial's scheduler in the graceful-degradation controller
    /// ([`DegradeSched`]): under an accelerator outage, comfort-tier tasks
    /// that cannot meet their safety time on any surviving accelerator are
    /// shed instead of queued.  Off by default; on a healthy platform the
    /// wrapper is bit-identical pass-through.
    pub fn degrade(mut self, on: bool) -> Self {
        self.degrade = on;
        self
    }

    /// Share a queue cache across engine runs.  Queue generation is
    /// deterministic, so results are bit-identical with or without a
    /// shared cache — only the route-synthesis work is saved.  Without
    /// this, each `execute` builds a private cache.
    pub fn queue_cache(mut self, cache: Arc<QueueCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Execute one trial (queue regeneration + scheduler build + sim).
    pub fn run_trial(&self, trial: &Trial) -> Result<TrialResult> {
        self.run_trial_on(trial, &trial.queue(), &mut [])
    }

    /// Execute one trial with streaming observers attached to its
    /// simulation (e.g. a [`BrakingProbe`](crate::sim::BrakingProbe) —
    /// the braking CLI captures its probe task this way instead of
    /// retaining every record of every trial).
    pub fn run_trial_observed(
        &self,
        trial: &Trial,
        observers: &mut [&mut dyn SimObserver],
    ) -> Result<TrialResult> {
        self.run_trial_on(trial, &trial.queue(), observers)
    }

    /// Run `trials` on the worker pool (`jobs` as usual), each simulation
    /// watched by its own observer built by `make` on the worker thread;
    /// `(result, observer)` pairs return in trial order.  This is the
    /// parallel form of [`Engine::run_trial_observed`] — the braking CLI
    /// probes every trial concurrently without retaining any records.
    /// (No queue cache: observed trials rarely share queues, and each
    /// observer owns its trial end to end.)
    pub fn run_trials_observed<O, F>(
        &self,
        trials: &[Trial],
        make: F,
    ) -> Result<Vec<(TrialResult, O)>>
    where
        O: SimObserver + Send,
        F: Fn(&Trial) -> O + Sync,
    {
        let mut slots: Vec<Option<(TrialResult, O)>> = Vec::with_capacity(trials.len());
        slots.resize_with(trials.len(), || None);
        self.execute_tasks(
            trials.len(),
            |i| {
                let t = &trials[i];
                let mut obs = make(t);
                let r = self.run_trial_on(t, &t.queue(), &mut [&mut obs])?;
                Ok((r, obs))
            },
            // Observed runs pair each result with a caller-built observer;
            // there is no meaningful (result, observer) to fabricate for a
            // panicked trial, so panics stay hard errors here.
            |i, msg| {
                let t = &trials[i];
                Err(anyhow!("trial {} ({}) panicked: {msg}", t.id, t.label()))
            },
            |i, pair| slots[i] = Some(pair),
        )?;
        Ok(slots.into_iter().map(|s| s.expect("every trial ran")).collect())
    }

    /// Execute one trial against an already-generated queue.
    fn run_trial_on(
        &self,
        trial: &Trial,
        queue: &TaskQueue,
        observers: &mut [&mut dyn SimObserver],
    ) -> Result<TrialResult> {
        let platform = trial.platform()?;
        let mut sched = self
            .registry
            .build(&trial.scheduler, trial.sched_seed)
            .with_context(|| format!("trial {} ({})", trial.id, trial.label()))?;
        if self.degrade {
            sched = Box::new(DegradeSched::new(sched));
        }
        let mut events = match (&trial.scenario.archetype, self.events) {
            (Some(arch), true) => arch.platform_events(queue.route_duration_s),
            _ => Vec::new(),
        };
        if let Some(fm) = &self.faults {
            // Seeded by trial.seed (not trial.id): replicates differ,
            // while the on/off degrade arms and every scheduler see the
            // *same* fault timeline for the same replicate — paired
            // comparisons, not re-rolled ones.
            events.extend(fm.events_for_platform(trial.seed, queue.route_duration_s, &platform));
        }
        let scales = NormScales::for_queue(queue, &platform);
        let mut tails = TailsProbe::new(trial.scenario.area.max_velocity_ms());
        let mut r = {
            let mut obs: Vec<&mut dyn SimObserver> = Vec::with_capacity(observers.len() + 1);
            obs.push(&mut tails);
            for o in observers.iter_mut() {
                obs.push(&mut **o);
            }
            simulate_observed_with_scales(
                queue,
                &platform,
                sched.as_mut(),
                self.options,
                scales,
                events,
                &mut obs,
            )
        };
        r.summary.response_hist = tails.response;
        r.summary.braking_hist = tails.braking;
        Ok(TrialResult {
            trial: trial.clone(),
            summary: r.summary,
            sched_wall_s: r.sched_wall_s,
            bursts: r.bursts,
            records: r.records,
        })
    }

    /// Run every trial of `plan`; results ordered by trial id.
    pub fn run(&self, plan: &ExperimentPlan) -> Result<Vec<TrialResult>> {
        self.run_with(plan, |_| {})
    }

    /// The one worker-pool core every parallel path shares: run `work(i)`
    /// for `i in 0..n` on `jobs` workers, delivering each payload to
    /// `deliver` on the calling thread in *completion* order.
    ///
    /// A trial that *panics* (e.g. a buggy scheduler indexing out of
    /// bounds) is caught per task — on both the serial and the threaded
    /// path — and handed to `recover`, which either fabricates a
    /// counted-failure payload (the sweep path) or converts the panic into
    /// a hard error (paths that cannot fabricate one).  A trial that
    /// returns `Err` stays a hard error either way: those are *setup*
    /// failures (unknown scheduler, missing runtime) the caller must see.
    fn execute_tasks<T, W, R, F>(&self, n: usize, work: W, recover: R, mut deliver: F) -> Result<()>
    where
        T: Send,
        W: Fn(usize) -> Result<T> + Sync,
        R: Fn(usize, String) -> Result<T> + Sync,
        F: FnMut(usize, T),
    {
        let run_one = |i: usize| -> Result<T> {
            match catch_unwind(AssertUnwindSafe(|| work(i))) {
                Ok(r) => r,
                Err(p) => recover(i, panic_message(p.as_ref())),
            }
        };
        let jobs = self.jobs.max(1).min(n.max(1));
        if jobs <= 1 {
            for i in 0..n {
                deliver(i, run_one(i)?);
            }
            return Ok(());
        }
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<(usize, Result<T>)>();
        let next_ref = &next;
        let abort_ref = &abort;
        let run_ref = &run_one;
        let mut first_err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if abort_ref.load(Ordering::SeqCst) {
                        break;
                    }
                    let i = next_ref.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    if tx.send((i, run_ref(i))).is_err() {
                        break; // receiver gone (error path)
                    }
                });
            }
            drop(tx);
            // The loop consumes `rx`; breaking on the first error drops
            // it immediately, so pending worker sends fail and every
            // worker exits before the scope joins.  At most one
            // in-flight task per worker still finishes.
            for (i, res) in rx {
                match res {
                    Ok(t) => deliver(i, t),
                    Err(e) => {
                        abort_ref.store(true, Ordering::SeqCst);
                        first_err = Some(e);
                        break;
                    }
                }
            }
        });
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Execute `trials` (with the shared queue cache), delivering each
    /// `TrialResult` in completion order.
    fn execute<F>(&self, trials: &[Trial], deliver: F) -> Result<()>
    where
        F: FnMut(usize, TrialResult),
    {
        let cache = match &self.cache {
            Some(shared) => Arc::clone(shared),
            None => Arc::new(QueueCache::default()),
        };
        self.execute_tasks(
            trials.len(),
            |i| {
                let t = &trials[i];
                self.run_trial_on(t, &cache.get(t), &mut [])
            },
            // A panicked trial becomes a *counted* failure: an empty
            // summary with the `failed` flag set, grouped under the same
            // sweep key as its healthy siblings (`GroupStats` counts it in
            // `failed_trials`, outside every fingerprint) — one bad trial
            // must never kill a fault campaign or a fleet shard.
            |i, msg| {
                let t = &trials[i];
                crate::log_warn!(
                    "engine",
                    "trial {} ({}) panicked and was counted as failed: {msg}",
                    t.id,
                    t.label()
                );
                Ok(TrialResult {
                    trial: t.clone(),
                    summary: RunSummary::failed(
                        t.scheduler.display().to_string(),
                        t.platform.clone(),
                    ),
                    sched_wall_s: 0.0,
                    bursts: 0,
                    records: Vec::new(),
                })
            },
            deliver,
        )
    }

    /// `run`, streaming each result to `on_result` as it completes
    /// (completion order, not id order — the returned vec is id-ordered).
    pub fn run_with<F>(&self, plan: &ExperimentPlan, mut on_result: F) -> Result<Vec<TrialResult>>
    where
        F: FnMut(&TrialResult),
    {
        let trials = plan.trials()?;
        let n = trials.len();
        let mut slots: Vec<Option<TrialResult>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        self.execute(&trials, |i, r| {
            on_result(&r);
            slots[i] = Some(r);
        })?;
        Ok(slots.into_iter().map(|s| s.expect("every trial ran")).collect())
    }

    /// Stream owned results to `sink` in *trial-id* order, retaining
    /// nothing after delivery.  Out-of-order completions wait in a
    /// re-sequencing buffer — typically a handful of results (the
    /// in-flight window), though a pathologically slow early trial can let
    /// later ones pile up behind it (the pool applies no backpressure).
    /// Even then this never retains *more* than [`Engine::run`], which
    /// always holds every result.
    pub fn run_streamed<F>(&self, plan: &ExperimentPlan, sink: F) -> Result<usize>
    where
        F: FnMut(TrialResult),
    {
        self.run_trials_streamed(&plan.trials()?, sink)
    }

    /// [`Engine::run_streamed`] over an already-expanded trial slice —
    /// the fleet worker path, where a shard runs a sub-range of a plan's
    /// trials.  Delivery order is slice order (= trial-id order when the
    /// slice is a contiguous plan range).
    pub fn run_trials_streamed<F>(&self, trials: &[Trial], mut sink: F) -> Result<usize>
    where
        F: FnMut(TrialResult),
    {
        let n = trials.len();
        let mut pending: BTreeMap<usize, TrialResult> = BTreeMap::new();
        let mut next_emit = 0usize;
        self.execute(trials, |i, r| {
            pending.insert(i, r);
            while let Some(r) = pending.remove(&next_emit) {
                sink(r);
                next_emit += 1;
            }
        })?;
        debug_assert!(pending.is_empty(), "re-sequencing buffer drained");
        Ok(n)
    }

    /// Run the plan and aggregate into a `SweepSummary` (rows keyed by
    /// scheduler × platform × area × deadline, in trial-id order).
    ///
    /// Retains every `TrialResult` for callers that render per-trial rows;
    /// use [`Engine::sweep_streaming`] when only the aggregate is needed.
    pub fn sweep(&self, plan: &ExperimentPlan) -> Result<(Vec<TrialResult>, SweepSummary)> {
        let results = self.run(plan)?;
        let summary = SweepSummary::from_trial_results(&results);
        Ok((results, summary))
    }

    /// Aggregate-only sweep: every trial outcome is folded into the
    /// summary and dropped immediately (the fix for sweeps that used to
    /// hold all records/state until aggregation).  Bit-identical rows and
    /// fingerprint to [`Engine::sweep`].
    pub fn sweep_streaming(&self, plan: &ExperimentPlan) -> Result<SweepSummary> {
        let mut summary = SweepSummary::new();
        self.run_streamed(plan, |r| {
            let key = r.sweep_key();
            summary.push(key, r.summary);
        })?;
        Ok(summary)
    }
}

impl SweepSummary {
    /// Aggregate engine results (trial-id order) into sweep rows.
    pub fn from_trial_results(results: &[TrialResult]) -> SweepSummary {
        let mut s = SweepSummary::new();
        for r in results {
            s.push(r.sweep_key(), r.summary.clone());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::Area;
    use crate::sched::SchedulerSpec;

    fn tiny_plan() -> ExperimentPlan {
        ExperimentPlan::new()
            .area(Area::Urban)
            .distances([40.0, 60.0])
            .schedulers([SchedulerSpec::MinMin, SchedulerSpec::RoundRobin])
            .seed(3)
    }

    #[test]
    fn engine_runs_every_trial_in_order() {
        let reg = Registry::new();
        let results = Engine::new(&reg).run(&tiny_plan()).unwrap();
        assert_eq!(results.len(), 4);
        assert!(results.iter().enumerate().all(|(i, r)| r.trial.id == i));
        assert!(results.iter().all(|r| r.summary.tasks > 0));
    }

    #[test]
    fn streaming_sees_every_result() {
        let reg = Registry::new();
        let mut seen = 0;
        Engine::new(&reg)
            .jobs(2)
            .run_with(&tiny_plan(), |_| seen += 1)
            .unwrap();
        assert_eq!(seen, 4);
    }

    #[test]
    fn record_tasks_flows_through() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .distances([40.0])
            .scheduler(SchedulerSpec::RoundRobin)
            .seed(1);
        let r = Engine::new(&reg)
            .sim_options(SimOptions { record_tasks: true })
            .run(&plan)
            .unwrap()
            .remove(0);
        assert_eq!(r.records.len() as u64, r.summary.tasks);
        assert!(r.sched_per_task_s() >= 0.0);
    }

    #[test]
    fn scenario_plans_run_and_group_per_scenario() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .scenarios(["urban-rush", "night-rain"])
            .distances([50.0])
            .scheduler(SchedulerSpec::RoundRobin)
            .seed(2);
        let (results, sweep) = Engine::new(&reg).jobs(2).sweep(&plan).unwrap();
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.summary.tasks > 0));
        // One sweep row per archetype (the per-scenario breakdown).
        assert_eq!(sweep.groups.len(), 2);
        let scenarios: Vec<&str> =
            sweep.groups.iter().map(|g| g.key.scenario.as_str()).collect();
        assert_eq!(scenarios, ["urban-rush", "night-rain"]);
    }

    #[test]
    fn run_streamed_delivers_in_trial_id_order() {
        let reg = Registry::new();
        let plan = tiny_plan();
        let mut ids = Vec::new();
        let n = Engine::new(&reg)
            .jobs(3)
            .run_streamed(&plan, |r| ids.push(r.trial.id))
            .unwrap();
        assert_eq!(n, 4);
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn sweep_streaming_is_bit_identical_to_sweep() {
        let reg = Registry::new();
        let plan = tiny_plan();
        for jobs in [1, 3] {
            let (_, retained) = Engine::new(&reg).jobs(jobs).sweep(&plan).unwrap();
            let streamed = Engine::new(&reg).jobs(jobs).sweep_streaming(&plan).unwrap();
            assert_eq!(retained.fingerprint(), streamed.fingerprint(), "jobs={jobs}");
            assert_eq!(retained.groups.len(), streamed.groups.len());
        }
    }

    #[test]
    fn events_reroute_scenario_faults_and_stay_deterministic() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .scenarios(["accel-failure"])
            .distances([60.0])
            .scheduler(SchedulerSpec::MinMin)
            .seed(5);
        // Events off: bit-identical to the plain urban run (default).
        let off_a = Engine::new(&reg).sweep_streaming(&plan).unwrap();
        let off_b = Engine::new(&reg).events(false).sweep_streaming(&plan).unwrap();
        assert_eq!(off_a.fingerprint(), off_b.fingerprint());
        // Events on: the outage changes the outcome, deterministically
        // and --jobs-invariantly.
        let on = Engine::new(&reg).events(true).sweep_streaming(&plan).unwrap();
        assert_ne!(on.fingerprint(), off_a.fingerprint(), "failure must be visible");
        let on_par = Engine::new(&reg).events(true).jobs(2).sweep_streaming(&plan).unwrap();
        assert_eq!(on.fingerprint(), on_par.fingerprint());
        // And the failed accelerator gets no work while it is down.
        let trials = plan.trials().unwrap();
        let trial = &trials[0];
        let r = Engine::new(&reg)
            .events(true)
            .sim_options(SimOptions { record_tasks: true })
            .run_trial(trial)
            .unwrap();
        let dur = trial.queue().route_duration_s;
        let (t_fail, t_rec) = (0.35 * dur + 1e-6, 0.70 * dur - 1e-6);
        let window: Vec<_> = r
            .records
            .iter()
            .filter(|x| x.release_s >= t_fail && x.release_s < t_rec)
            .collect();
        assert!(!window.is_empty());
        assert!(window.iter().all(|x| x.accel != 0), "work on a failed accel");
    }

    #[test]
    fn run_trial_observed_streams_without_record_retention() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .distances([50.0])
            .scheduler(SchedulerSpec::RoundRobin)
            .seed(6);
        let trials = plan.trials().unwrap();
        let trial = &trials[0];
        let mut probe = crate::sim::BrakingProbe::new(1.0);
        let r = Engine::new(&reg).run_trial_observed(trial, &mut [&mut probe]).unwrap();
        assert!(r.records.is_empty(), "no records retained");
        let rec = probe.captured().expect("probe task found");
        // The probe matches the record-based selection.
        let full = Engine::new(&reg)
            .sim_options(SimOptions { record_tasks: true })
            .run_trial(trial)
            .unwrap();
        let want = crate::sim::first_detection_after(&full.records, 1.0).unwrap();
        assert_eq!(rec.task_id, want.task_id);
        assert_eq!(rec.wait_s.to_bits(), want.wait_s.to_bits());
    }

    #[test]
    fn run_trials_observed_is_parallel_order_stable() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .distances([40.0, 50.0, 60.0])
            .schedulers([SchedulerSpec::RoundRobin, SchedulerSpec::MinMin])
            .seed(2);
        let trials = plan.trials().unwrap();
        let run = |jobs: usize| {
            Engine::new(&reg)
                .jobs(jobs)
                .run_trials_observed(&trials, |_| crate::sim::BrakingProbe::new(0.5))
                .unwrap()
        };
        let (seq, par) = (run(1), run(3));
        assert_eq!(seq.len(), trials.len());
        for ((a, pa), (b, pb)) in seq.iter().zip(&par) {
            assert_eq!(a.trial.id, b.trial.id, "trial order");
            assert_eq!(a.summary.energy_j.to_bits(), b.summary.energy_j.to_bits());
            assert_eq!(
                pa.captured().map(|x| x.task_id),
                pb.captured().map(|x| x.task_id),
                "probe drifted across jobs"
            );
            assert!(a.records.is_empty() && b.records.is_empty());
        }
    }

    #[test]
    fn flexai_without_runtime_is_a_clean_error() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .distances([40.0])
            .scheduler(SchedulerSpec::FlexAI { checkpoint: None })
            .seed(1);
        let err = Engine::new(&reg).run(&plan).unwrap_err();
        assert!(format!("{err:#}").contains("PJRT"), "{err:#}");
    }
}
