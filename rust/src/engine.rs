//! Trial execution engine: runs an [`ExperimentPlan`](crate::plan::ExperimentPlan)
//! on a `std::thread` worker pool (no external deps) and aggregates the
//! streamed results into a [`SweepSummary`](crate::metrics::summary::SweepSummary).
//!
//! Determinism contract: every trial is independent (own queue, own
//! scheduler instance, fork-derived seeds), results are re-ordered by trial
//! id before aggregation, and no aggregate depends on wall-clock fields —
//! so `jobs = N` is bit-identical to `jobs = 1` for any N.  The tests in
//! `tests/sweep.rs` pin this down.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::env::taskgen::{DeadlineMode, TaskQueue};
use crate::env::Area;
use crate::metrics::summary::{RunSummary, SweepKey, SweepSummary};
use crate::plan::{ExperimentPlan, Trial};
use crate::sched::Registry;
use crate::sim::{simulate, SimOptions, TaskRecord};

/// Cache key for generated task queues: everything queue generation
/// depends on.  Trials differing only in scheduler/platform share the
/// queue instead of regenerating it (route synthesis at full paper scale
/// is ~200k tasks per queue).
#[derive(PartialEq, Eq, Hash, Clone)]
struct QueueKey {
    /// Library archetype name, when the trial is a scenario-library cell.
    scenario: Option<String>,
    area: Area,
    distance_bits: u64,
    index: usize,
    deadline: DeadlineMode,
    seed: u64,
}

impl QueueKey {
    fn of(trial: &Trial) -> QueueKey {
        QueueKey {
            scenario: trial.scenario.archetype.as_ref().map(|a| a.name.clone()),
            area: trial.scenario.area,
            distance_bits: trial.scenario.distance_m.to_bits(),
            index: trial.queue_index,
            deadline: trial.scenario.deadline,
            seed: trial.seed,
        }
    }
}

/// Thread-safe memo of generated queues, shared across engine workers.
#[derive(Default)]
struct QueueCache {
    queues: Mutex<HashMap<QueueKey, Arc<TaskQueue>>>,
}

impl QueueCache {
    /// Get or generate the queue for `trial`.  Generation happens outside
    /// the lock, so two workers may race to build the same queue once —
    /// both get identical (deterministic) results and one copy is kept.
    fn get(&self, trial: &Trial) -> Arc<TaskQueue> {
        let key = QueueKey::of(trial);
        if let Some(q) = self.queues.lock().expect("queue cache poisoned").get(&key) {
            return q.clone();
        }
        let q = Arc::new(trial.queue());
        self.queues
            .lock()
            .expect("queue cache poisoned")
            .entry(key)
            .or_insert(q)
            .clone()
    }
}

/// Outcome of one executed trial.
#[derive(Debug)]
pub struct TrialResult {
    pub trial: Trial,
    pub summary: RunSummary,
    /// Wall-clock seconds inside the scheduler (measurement, not
    /// deterministic — excluded from sweep fingerprints).
    pub sched_wall_s: f64,
    /// Scheduling invocations (bursts).
    pub bursts: u64,
    /// Per-task records when the engine runs with `record_tasks`.
    pub records: Vec<TaskRecord>,
}

impl TrialResult {
    /// Mean scheduler wall time per task (the Fig. 14 `T_schedule`).
    pub fn sched_per_task_s(&self) -> f64 {
        if self.summary.tasks == 0 {
            0.0
        } else {
            self.sched_wall_s / self.summary.tasks as f64
        }
    }

    /// Aggregation key: scheduler display name × platform × scenario ×
    /// area × deadline (scenario is "-" for plain area/distance cells).
    pub fn sweep_key(&self) -> SweepKey {
        SweepKey {
            scheduler: self.summary.scheduler.clone(),
            platform: self.summary.platform.clone(),
            scenario: self.trial.scenario.scenario_name(),
            area: self.trial.scenario.area.name().to_string(),
            deadline: self.trial.scenario.deadline.name().to_string(),
        }
    }
}

/// Executes plans.  Cheap to build; borrow one registry for many runs.
pub struct Engine<'r> {
    registry: &'r Registry,
    jobs: usize,
    options: SimOptions,
}

impl<'r> Engine<'r> {
    pub fn new(registry: &'r Registry) -> Engine<'r> {
        Engine { registry, jobs: 1, options: SimOptions::default() }
    }

    /// Worker threads (1 = run on the calling thread).  0 means "all
    /// cores" (`std::thread::available_parallelism`).
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = if jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            jobs
        };
        self
    }

    pub fn sim_options(mut self, options: SimOptions) -> Self {
        self.options = options;
        self
    }

    /// Execute one trial (queue regeneration + scheduler build + sim).
    pub fn run_trial(&self, trial: &Trial) -> Result<TrialResult> {
        self.run_trial_on(trial, &trial.queue())
    }

    /// Execute one trial against an already-generated queue.
    fn run_trial_on(&self, trial: &Trial, queue: &TaskQueue) -> Result<TrialResult> {
        let platform = trial.platform()?;
        let mut sched = self
            .registry
            .build(&trial.scheduler, trial.sched_seed)
            .with_context(|| format!("trial {} ({})", trial.id, trial.label()))?;
        let r = simulate(queue, &platform, sched.as_mut(), self.options);
        Ok(TrialResult {
            trial: trial.clone(),
            summary: r.summary,
            sched_wall_s: r.sched_wall_s,
            bursts: r.bursts,
            records: r.records,
        })
    }

    /// Run every trial of `plan`; results ordered by trial id.
    pub fn run(&self, plan: &ExperimentPlan) -> Result<Vec<TrialResult>> {
        self.run_with(plan, |_| {})
    }

    /// `run`, streaming each result to `on_result` as it completes
    /// (completion order, not id order — the returned vec is id-ordered).
    pub fn run_with<F>(&self, plan: &ExperimentPlan, mut on_result: F) -> Result<Vec<TrialResult>>
    where
        F: FnMut(&TrialResult),
    {
        let trials = plan.trials()?;
        let n = trials.len();
        let mut slots: Vec<Option<TrialResult>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let cache = QueueCache::default();

        let jobs = self.jobs.max(1).min(n.max(1));
        if jobs <= 1 {
            for (i, t) in trials.iter().enumerate() {
                let r = self.run_trial_on(t, &cache.get(t))?;
                on_result(&r);
                slots[i] = Some(r);
            }
        } else {
            let next = AtomicUsize::new(0);
            let abort = AtomicBool::new(false);
            let (tx, rx) = mpsc::channel::<(usize, Result<TrialResult>)>();
            let trials_ref = &trials;
            let next_ref = &next;
            let abort_ref = &abort;
            let cache_ref = &cache;
            let mut first_err: Option<anyhow::Error> = None;
            std::thread::scope(|scope| {
                for _ in 0..jobs {
                    let tx = tx.clone();
                    scope.spawn(move || loop {
                        if abort_ref.load(Ordering::SeqCst) {
                            break;
                        }
                        let i = next_ref.fetch_add(1, Ordering::SeqCst);
                        if i >= trials_ref.len() {
                            break;
                        }
                        let t = &trials_ref[i];
                        let res = self.run_trial_on(t, &cache_ref.get(t));
                        if tx.send((i, res)).is_err() {
                            break; // receiver gone (error path)
                        }
                    });
                }
                drop(tx);
                // The loop consumes `rx`; breaking on the first error drops
                // it immediately, so pending worker sends fail and every
                // worker exits before the scope joins.  At most one
                // in-flight trial per worker still finishes.
                for (i, res) in rx {
                    match res {
                        Ok(r) => {
                            on_result(&r);
                            slots[i] = Some(r);
                        }
                        Err(e) => {
                            abort_ref.store(true, Ordering::SeqCst);
                            first_err = Some(e);
                            break;
                        }
                    }
                }
            });
            if let Some(e) = first_err {
                return Err(e);
            }
        }
        Ok(slots.into_iter().map(|s| s.expect("every trial ran")).collect())
    }

    /// Run the plan and aggregate into a `SweepSummary` (rows keyed by
    /// scheduler × platform × area × deadline, in trial-id order).
    pub fn sweep(&self, plan: &ExperimentPlan) -> Result<(Vec<TrialResult>, SweepSummary)> {
        let results = self.run(plan)?;
        let summary = SweepSummary::from_trial_results(&results);
        Ok((results, summary))
    }
}

impl SweepSummary {
    /// Aggregate engine results (trial-id order) into sweep rows.
    pub fn from_trial_results(results: &[TrialResult]) -> SweepSummary {
        let mut s = SweepSummary::new();
        for r in results {
            s.push(r.sweep_key(), r.summary.clone());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::Area;
    use crate::sched::SchedulerSpec;

    fn tiny_plan() -> ExperimentPlan {
        ExperimentPlan::new()
            .area(Area::Urban)
            .distances([40.0, 60.0])
            .schedulers([SchedulerSpec::MinMin, SchedulerSpec::RoundRobin])
            .seed(3)
    }

    #[test]
    fn engine_runs_every_trial_in_order() {
        let reg = Registry::new();
        let results = Engine::new(&reg).run(&tiny_plan()).unwrap();
        assert_eq!(results.len(), 4);
        assert!(results.iter().enumerate().all(|(i, r)| r.trial.id == i));
        assert!(results.iter().all(|r| r.summary.tasks > 0));
    }

    #[test]
    fn streaming_sees_every_result() {
        let reg = Registry::new();
        let mut seen = 0;
        Engine::new(&reg)
            .jobs(2)
            .run_with(&tiny_plan(), |_| seen += 1)
            .unwrap();
        assert_eq!(seen, 4);
    }

    #[test]
    fn record_tasks_flows_through() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .distances([40.0])
            .scheduler(SchedulerSpec::RoundRobin)
            .seed(1);
        let r = Engine::new(&reg)
            .sim_options(SimOptions { record_tasks: true })
            .run(&plan)
            .unwrap()
            .remove(0);
        assert_eq!(r.records.len() as u64, r.summary.tasks);
        assert!(r.sched_per_task_s() >= 0.0);
    }

    #[test]
    fn scenario_plans_run_and_group_per_scenario() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .scenarios(["urban-rush", "night-rain"])
            .distances([50.0])
            .scheduler(SchedulerSpec::RoundRobin)
            .seed(2);
        let (results, sweep) = Engine::new(&reg).jobs(2).sweep(&plan).unwrap();
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.summary.tasks > 0));
        // One sweep row per archetype (the per-scenario breakdown).
        assert_eq!(sweep.groups.len(), 2);
        let scenarios: Vec<&str> =
            sweep.groups.iter().map(|g| g.key.scenario.as_str()).collect();
        assert_eq!(scenarios, ["urban-rush", "night-rain"]);
    }

    #[test]
    fn flexai_without_runtime_is_a_clean_error() {
        let reg = Registry::new();
        let plan = ExperimentPlan::new()
            .distances([40.0])
            .scheduler(SchedulerSpec::FlexAI { checkpoint: None })
            .seed(1);
        let err = Engine::new(&reg).run(&plan).unwrap_err();
        assert!(format!("{err:#}").contains("PJRT"), "{err:#}");
    }
}
