//! HMAI + FlexAI — reproduction of "Tackling Variabilities in Autonomous
//! Driving" (CS.AR 2021).
//!
//! A heterogeneous multi-core AI accelerator platform (HMAI) model, the
//! dynamic driving environment, the RSS-derived safety criteria (Matching
//! Score, Gvalue) and the FlexAI DQN task scheduler — with the Q-network
//! AOT-compiled from JAX/Pallas to HLO and executed via the PJRT C API.
//!
//! Experiments run through the typed sweep API: an
//! [`plan::ExperimentPlan`] expands scenarios × platforms × schedulers ×
//! seeds into trials, and an [`engine::Engine`] executes them on a worker
//! pool with deterministic, `--jobs`-invariant results.  See rust/DESIGN.md
//! for the full architecture, the experiment index and the migration table
//! from the old `harness` helpers.

#![forbid(unsafe_code)]

pub mod util;
pub mod accel;
pub mod env;
pub mod safety;
pub mod workload;
pub mod interconnect;
pub mod platform;
pub mod metrics;
pub mod sim;
pub mod sched;
pub mod runtime;
pub mod config;
pub mod plan;
pub mod engine;
pub mod faults;
pub mod fleet;
pub mod dse;
pub mod harness;
pub mod lint;
pub mod reports;
